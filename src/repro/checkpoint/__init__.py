from repro.checkpoint.checkpoint import Snapshot, load_checkpoint, save_checkpoint

__all__ = ["Snapshot", "load_checkpoint", "save_checkpoint"]
