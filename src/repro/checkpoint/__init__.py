from repro.checkpoint.checkpoint import (
    CheckpointManager,
    CorruptCheckpointError,
    Snapshot,
    load_checkpoint,
    save_checkpoint,
)

__all__ = [
    "CheckpointManager",
    "CorruptCheckpointError",
    "Snapshot",
    "load_checkpoint",
    "save_checkpoint",
]
