"""Checkpointing: durable (npz on disk) and in-memory snapshots.

Elastic rescale in BFTrainer does NOT round-trip through durable storage
(paper: "without requiring a restart or resuming from checkpoints saved to
durable storage") — ``Snapshot`` keeps host copies of params/opt state that
the new mesh re-shards from.  Durable checkpoints cover Trainer preemption
to zero nodes and job restarts.

Durable checkpoints are integrity-checked (DESIGN.md §12): ``save``
stamps the payload's SHA-256 into the sidecar meta, ``load`` verifies it
and raises ``CorruptCheckpointError`` on mismatch, and
``CheckpointManager`` keeps the last ``keep`` checkpoints so a corrupt
latest restore falls back to the newest *good* one — the on-disk
realization of the checkpoint-lattice rollback the control loop models
(``TrainerJob.last_checkpoint`` / ``ChaosBackend.on_fail``).
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.obs.telemetry import NULL_TELEMETRY

Pytree = Any


class CorruptCheckpointError(RuntimeError):
    """The checkpoint payload does not match its recorded checksum (or is
    unreadable) — the restore must fall back to an older checkpoint."""


def _flatten(tree: Pytree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[jax.tree_util.keystr(path)] = np.asarray(leaf)
    return flat


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save_checkpoint(path: str, tree: Pytree, meta: Optional[Dict] = None) -> None:
    """Write ``tree`` as ``<path>.npz``.  When ``meta`` is given, a
    ``<path>.meta.json`` sidecar is written alongside, with the npz
    payload's SHA-256 added under ``"sha256"`` so ``load_checkpoint``
    can verify integrity."""
    base = path[:-4] if path.endswith(".npz") else path
    os.makedirs(os.path.dirname(base) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(base + ".npz", **flat)
    if meta is not None:
        meta = dict(meta, sha256=_sha256(base + ".npz"))
        with open(base + ".meta.json", "w") as f:
            json.dump(meta, f)


def load_checkpoint(path: str, like: Pytree, *,
                    verify: bool = True) -> Tuple[Pytree, Optional[Dict]]:
    """Restore into the structure of ``like`` (a pytree or abstract tree).

    When the sidecar meta records a ``sha256`` and ``verify`` is on, the
    payload is checksummed first; a mismatch (bit rot, torn write) raises
    ``CorruptCheckpointError`` *before* any array is deserialized.  The
    digest is a transport detail and is stripped from the returned meta —
    callers get back exactly what they passed to ``save_checkpoint``."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    meta = None
    meta_path = path[: -len(".npz")] + ".meta.json"
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
    if meta is not None and "sha256" in meta:
        recorded = meta.pop("sha256")
        if verify:
            digest = _sha256(path)
            if digest != recorded:
                raise CorruptCheckpointError(
                    f"checkpoint {path} fails integrity check: "
                    f"sha256 {digest} != recorded {recorded}")
    try:
        data = np.load(path)
        leaves_paths = jax.tree_util.tree_flatten_with_path(like)[0]
        treedef = jax.tree_util.tree_structure(like)
        leaves = [data[jax.tree_util.keystr(p)] for p, _ in leaves_paths]
    except CorruptCheckpointError:
        raise
    except Exception as exc:
        raise CorruptCheckpointError(
            f"checkpoint {path} is unreadable: {exc}") from exc
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


class CheckpointManager:
    """Rolling directory of integrity-checked checkpoints.

    ``save`` writes ``ckpt_<step>.npz`` (+ checksummed meta) and prunes
    to the newest ``keep``; ``load_latest_good`` walks checkpoints
    newest-first and returns the first that passes verification —
    exactly the last-good fallback a kill with ``corrupt_prob > 0``
    exercises in the chaos layer.
    """

    def __init__(self, directory: str, *, keep: int = 2, telemetry=None):
        if keep < 1:
            raise ValueError("keep must be >= 1")
        self.directory = directory
        self.keep = keep
        self.telemetry = telemetry or NULL_TELEMETRY
        os.makedirs(directory, exist_ok=True)

    def _base(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:012d}")

    def steps(self) -> List[int]:
        """Available checkpoint steps, oldest first."""
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("ckpt_") and name.endswith(".npz"):
                try:
                    out.append(int(name[len("ckpt_"):-len(".npz")]))
                except ValueError:
                    continue
        return sorted(out)

    def save(self, tree: Pytree, step: int,
             meta: Optional[Dict] = None) -> str:
        t0 = time.perf_counter()
        base = self._base(step)
        save_checkpoint(base, tree, meta=dict(meta or {}, step=step))
        for old in self.steps()[:-self.keep]:
            for suffix in (".npz", ".meta.json"):
                try:
                    os.remove(self._base(old) + suffix)
                except OSError:
                    pass
        tel = self.telemetry
        if tel:
            wall = time.perf_counter() - t0
            tel.observe("checkpoint.save_ms", wall * 1e3)
            tel.instant("checkpoint", "save", float(step), wall_s=wall,
                        bytes=os.path.getsize(base + ".npz"))
        return base + ".npz"

    def load_latest_good(self, like: Pytree) -> Tuple[Pytree, Dict, int]:
        """(tree, meta, step) of the newest checkpoint that verifies.

        Corrupt or unreadable checkpoints are skipped (newest-first);
        ``CorruptCheckpointError`` is raised only if *no* checkpoint
        survives."""
        steps = self.steps()
        last_exc: Optional[Exception] = None
        tel = self.telemetry
        for step in reversed(steps):
            try:
                t0 = time.perf_counter()
                tree, meta = load_checkpoint(self._base(step), like)
                if tel:
                    wall = time.perf_counter() - t0
                    tel.observe("checkpoint.load_ms", wall * 1e3)
                    tel.instant("checkpoint", "load", float(step),
                                wall_s=wall)
                return tree, (meta or {}), step
            except CorruptCheckpointError as exc:
                last_exc = exc
                if tel:
                    tel.count("checkpoint.corrupt_fallbacks")
                    tel.instant("checkpoint", "corrupt-fallback",
                                float(step))
        raise CorruptCheckpointError(
            f"no loadable checkpoint in {self.directory} "
            f"(tried steps {list(reversed(steps))})") from last_exc


@dataclass
class Snapshot:
    """In-memory host snapshot used across elastic rescales."""

    tree: Pytree
    step: int = 0

    @classmethod
    def take(cls, tree: Pytree, step: int = 0) -> "Snapshot":
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        return cls(tree=host, step=step)

    def restore(self, shardings: Optional[Pytree] = None) -> Pytree:
        if shardings is None:
            return jax.tree.map(lambda x: jax.numpy.asarray(x), self.tree)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), self.tree, shardings)
