"""Checkpointing: durable (npz on disk) and in-memory snapshots.

Elastic rescale in BFTrainer does NOT round-trip through durable storage
(paper: "without requiring a restart or resuming from checkpoints saved to
durable storage") — ``Snapshot`` keeps host copies of params/opt state that
the new mesh re-shards from.  Durable checkpoints cover Trainer preemption
to zero nodes and job restarts.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Pytree = Any


def _flatten(tree: Pytree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[jax.tree_util.keystr(path)] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree: Pytree, meta: Optional[Dict] = None) -> None:
    base = path[:-4] if path.endswith(".npz") else path
    os.makedirs(os.path.dirname(base) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(base + ".npz", **flat)
    if meta is not None:
        with open(base + ".meta.json", "w") as f:
            json.dump(meta, f)


def load_checkpoint(path: str, like: Pytree) -> Tuple[Pytree, Optional[Dict]]:
    """Restore into the structure of ``like`` (a pytree or abstract tree)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    leaves = [data[jax.tree_util.keystr(p)] for p, _ in leaves_paths]
    meta = None
    meta_path = path[: -len(".npz")] + ".meta.json"
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


@dataclass
class Snapshot:
    """In-memory host snapshot used across elastic rescales."""

    tree: Pytree
    step: int = 0

    @classmethod
    def take(cls, tree: Pytree, step: int = 0) -> "Snapshot":
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        return cls(tree=host, step=step)

    def restore(self, shardings: Optional[Pytree] = None) -> Pytree:
        if shardings is None:
            return jax.tree.map(lambda x: jax.numpy.asarray(x), self.tree)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), self.tree, shardings)
