"""AdamW optimizer + LR schedules, pure JAX (no optax dependency).

Includes the linear-scaling rule the paper relies on for weak-scaling
elastic training: per-node batch is fixed, so the global batch is
proportional to the node count and the LR is scaled accordingly
(Goyal et al. [13] in the paper; Adasum-style adjustment hook).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Pytree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Pytree
    nu: Pytree


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params: Pytree) -> AdamWState:
        zeros = lambda p: jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), p)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=zeros(params), nu=zeros(params))

    def update(self, grads: Pytree, state: AdamWState, params: Pytree,
               lr_scale: jax.Array | float = 1.0
               ) -> tuple[Pytree, AdamWState]:
        step = state.step + 1
        if self.grad_clip:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        mu = jax.tree.map(
            lambda m, g: self.b1 * m + (1 - self.b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: self.b2 * v +
            (1 - self.b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        mu_hat_c = 1.0 - self.b1 ** step.astype(jnp.float32)
        nu_hat_c = 1.0 - self.b2 ** step.astype(jnp.float32)
        lr = self.lr * lr_scale

        def upd(p, m, v):
            u = (m / mu_hat_c) / (jnp.sqrt(v / nu_hat_c) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------


def warmup_cosine(step: jax.Array, *, base_lr: float = 1.0,
                  warmup_steps: int = 100, total_steps: int = 10_000,
                  min_frac: float = 0.1) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / max(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                    0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(step < warmup_steps, warm, cos)


def linear_scaling(n_nodes: int, base_nodes: int = 1,
                   max_scale: float = 32.0) -> float:
    """Linear LR scaling rule for weak-scaling elastic rescale."""
    return float(min(n_nodes / base_nodes, max_scale))
