from repro.optim.adamw import AdamW, AdamWState, linear_scaling, warmup_cosine

__all__ = ["AdamW", "AdamWState", "linear_scaling", "warmup_cosine"]
