"""DeepSeek-V2 Multi-head Latent Attention (MLA). [arXiv:2405.04434]

Keys/values are compressed into a per-token latent ``c_kv`` of rank
``kv_lora_rank`` plus a single shared RoPE key.  The decode path uses the
*absorbed* formulation: query projections are folded through ``w_uk`` /
``w_uv`` so the KV cache stores only ``(rank + rope_dim)`` floats per token
— this is the mechanism that makes MLA serve long contexts cheaply, and is
what ``decode_32k`` lowers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.attention import NEG_INF, attend_blockwise, attend_direct, \
    BLOCKWISE_THRESHOLD
from repro.models.layers import ParamDef, apply_rope, dense_def, rms_norm


def mla_defs(cfg: ArchConfig, model_shards: int = 1, dtype=jnp.float32) -> dict:
    mla = cfg.mla
    assert mla is not None
    d, h = cfg.d_model, cfg.n_heads
    qk = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    h_spec = P(None, "model") if h % model_shards == 0 else P(None, None)
    return {
        "wq": dense_def(d, h * qk, h_spec, dtype=dtype),
        "w_dkv": dense_def(d, mla.kv_lora_rank, P(None, None), dtype=dtype),
        "kv_norm": ParamDef((mla.kv_lora_rank,), spec=P(), init="zeros",
                            dtype=jnp.float32),
        "w_kr": dense_def(d, mla.qk_rope_head_dim, P(None, None), dtype=dtype),
        "w_uk": dense_def(mla.kv_lora_rank, h * mla.qk_nope_head_dim, h_spec,
                          dtype=dtype),
        "w_uv": dense_def(mla.kv_lora_rank, h * mla.v_head_dim, h_spec,
                          dtype=dtype),
        "wo": dense_def(h * mla.v_head_dim, d,
                        P("model", None) if h % model_shards == 0 else P(None, None),
                        dtype=dtype),
    }


def mla_apply(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Full-sequence (train / prefill) MLA. x: (B,S,d)."""
    mla = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    qk = nope + rope_d

    q = (x @ p["wq"]).reshape(b, s, h, qk)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    pos = jnp.arange(s)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    c_kv = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope((x @ p["w_kr"])[:, :, None, :], pos, cfg.rope_theta)
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, nope)
    v = (c_kv @ p["w_uv"]).reshape(b, s, h, vd)

    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, rope_d))],
                        axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)

    # KV == H heads, group size 1; pad V up to qk dim not needed — attend_*
    # contracts q·k on last dim and p·v on v's own dim, but our helpers
    # assume same head_dim.  Pad v to qk (zeros) and slice after.
    qh = q_full.reshape(b, s, h, 1, qk)
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk - vd)))
    kwargs = dict(q_pos=pos, k_pos=pos, causal=True, window=0,
                  logit_cap=0.0, scale=qk ** -0.5)
    if s > BLOCKWISE_THRESHOLD:
        out = attend_blockwise(qh, k, v_pad, **kwargs)
    else:
        out = attend_direct(qh, k, v_pad, **kwargs)
    out = out.reshape(b, s, h, qk)[..., :vd]
    return out.reshape(b, s, h * vd) @ p["wo"]


# ---------------------------------------------------------------------------
# Decode with latent cache (absorbed formulation)
# ---------------------------------------------------------------------------


def init_mla_cache(cfg: ArchConfig, batch: int, cache_len: int,
                   dtype=jnp.bfloat16) -> dict:
    mla = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, cache_len, mla.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, cache_len, mla.qk_rope_head_dim), dtype),
    }


def mla_cache_specs(batch_axes, seq_axes) -> dict:
    return {"c_kv": P(batch_axes, seq_axes, None),
            "k_rope": P(batch_axes, seq_axes, None)}


def mla_decode(p: dict, x: jax.Array, cache: dict, pos: jax.Array,
               cfg: ArchConfig) -> tuple[jax.Array, dict]:
    """One-token MLA decode. x: (B,1,d)."""
    mla = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    nope, rope_d, vd = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    qk = nope + rope_d
    rank = mla.kv_lora_rank

    x1 = x[:, 0, :]
    q = (x1 @ p["wq"]).reshape(b, h, qk)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    posv = jnp.full((1,), 1, jnp.int32) * pos
    q_rope = apply_rope(q_rope[:, None], posv, cfg.rope_theta)[:, 0]

    c_new = rms_norm(x1 @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)
    kr_new = apply_rope((x1 @ p["w_kr"])[:, None, None, :], posv,
                        cfg.rope_theta)[:, 0, 0]

    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new[:, None, :].astype(cache["c_kv"].dtype), (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new[:, None, :].astype(cache["k_rope"].dtype),
        (0, pos, 0))

    # absorbed: q_lat[b,h,r] = sum_n q_nope[b,h,n] * w_uk[r, h, n]
    w_uk = p["w_uk"].reshape(rank, h, nope)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope, w_uk)

    s_lat = jnp.einsum("bhr,btr->bht", q_lat, c_kv.astype(q_lat.dtype))
    s_rope = jnp.einsum("bhd,btd->bht", q_rope, k_rope.astype(q_rope.dtype))
    scores = (s_lat + s_rope).astype(jnp.float32) * qk ** -0.5
    valid = jnp.arange(c_kv.shape[1]) <= pos
    scores = jnp.where(valid[None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)

    out_lat = jnp.einsum("bht,btr->bhr", probs.astype(c_kv.dtype), c_kv)
    w_uv = p["w_uv"].reshape(rank, h, vd)
    out = jnp.einsum("bhr,rhv->bhv", out_lat, w_uv)
    out = (out.reshape(b, h * vd) @ p["wo"])[:, None, :]
    return out, {"c_kv": c_kv, "k_rope": k_rope}
