"""Model assembly: decoder LM (dense / MoE / SSM / hybrid patterns),
encoder-decoder, scan-over-blocks, losses, prefill and decode.

The layer stack is organized as ``n_blocks`` repetitions of
``cfg.layer_pattern``; parameters for each pattern position are stacked
with a leading ``n_blocks`` axis and the whole stack runs under one
``lax.scan`` (keeps HLO size O(pattern) instead of O(n_layers) — critical
for compiling 46–80-layer configs on 512 devices).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape, LayerSpec
from repro.models import attention as attn
from repro.models import mamba2, mla, moe as moe_mod
from repro.models.layers import (
    ParamDef,
    dense_def,
    mlp_apply,
    mlp_defs,
    rms_norm,
    softcap,
)

Pytree = Any


def _norm(d_model: int) -> ParamDef:
    return ParamDef((d_model,), spec=P(), init="zeros", dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Per-layer defs
# ---------------------------------------------------------------------------


def block_defs(cfg: ArchConfig, spec: LayerSpec, model_shards: int,
               dtype) -> dict:
    d: dict = {}
    if spec.mixer in ("attn", "swa"):
        d["mixer_norm"] = _norm(cfg.d_model)
        if cfg.mla is not None:
            d["mixer"] = mla.mla_defs(cfg, model_shards, dtype)
        else:
            d["mixer"] = attn.attn_defs(cfg, model_shards, dtype=dtype)
    elif spec.mixer == "mamba":
        d["mixer_norm"] = _norm(cfg.d_model)
        d["mixer"] = mamba2.mamba_defs(cfg, model_shards, dtype)
    else:
        raise ValueError(spec.mixer)
    if cfg.post_norms:
        d["mixer_post_norm"] = _norm(cfg.d_model)

    if cfg.is_encdec:
        d["cross_norm"] = _norm(cfg.d_model)
        d["cross"] = attn.attn_defs(cfg, model_shards, dtype=dtype)

    if spec.mlp == "dense":
        d["mlp_norm"] = _norm(cfg.d_model)
        d["mlp"] = mlp_defs(cfg.d_model, cfg.dense_d_ff or cfg.d_ff,
                            dtype=dtype)
    elif spec.mlp == "moe":
        d["mlp_norm"] = _norm(cfg.d_model)
        d["mlp"] = moe_mod.moe_defs(cfg, model_shards, dtype)
    elif spec.mlp != "none":
        raise ValueError(spec.mlp)
    if cfg.post_norms and spec.mlp != "none":
        d["mlp_post_norm"] = _norm(cfg.d_model)
    return d


# ---------------------------------------------------------------------------
# Per-layer apply (full sequence)
# ---------------------------------------------------------------------------


def apply_block(cfg: ArchConfig, spec: LayerSpec, p: dict, x: jax.Array, *,
                memory: Optional[jax.Array] = None,
                moe_strategy: str = "dense",
                long_serving: bool = False) -> tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["mixer_norm"], cfg.norm_eps)
    if spec.mixer == "mamba":
        out = mamba2.mamba_apply(p["mixer"], h, cfg)
    else:
        window = cfg.sliding_window if spec.mixer == "swa" else 0
        if long_serving and cfg.sliding_window:
            window = cfg.sliding_window  # bounded-KV long-context mode
        if cfg.mla is not None:
            out = mla.mla_apply(p["mixer"], h, cfg)
        else:
            out = attn.attn_apply(p["mixer"], h, cfg=cfg, causal=True,
                                  window=window)
    if cfg.post_norms:
        out = rms_norm(out, p["mixer_post_norm"], cfg.norm_eps)
    x = x + out

    if cfg.is_encdec and memory is not None:
        h = rms_norm(x, p["cross_norm"], cfg.norm_eps)
        out = attn.attn_apply(p["cross"], h, cfg=cfg, causal=False, window=0,
                              memory=memory, use_rope=False)
        x = x + out

    if spec.mlp != "none":
        h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        if spec.mlp == "moe":
            out, a = moe_mod.moe_apply(p["mlp"], h, cfg,
                                       strategy=moe_strategy)
            aux = aux + a
        else:
            out = mlp_apply(p["mlp"], h, cfg.mlp_activation)
        if cfg.post_norms:
            out = rms_norm(out, p["mlp_post_norm"], cfg.norm_eps)
        x = x + out
    return x, aux


# ---------------------------------------------------------------------------
# Per-layer decode apply
# ---------------------------------------------------------------------------


def _uses_ring(cfg: ArchConfig, spec: LayerSpec, long_serving: bool) -> bool:
    """Bounded (ring-buffer) KV: SWA layers always; all attention layers in
    long-context serving mode (jamba / gemma2 — see DESIGN.md)."""
    return bool(cfg.sliding_window) and (spec.mixer == "swa" or long_serving)


def init_block_cache(cfg: ArchConfig, spec: LayerSpec, batch: int,
                     cache_len: int, n_frames: int = 0,
                     long_serving: bool = False,
                     dtype=jnp.bfloat16) -> dict:
    c: dict = {}
    if spec.mixer == "mamba":
        c["mamba"] = mamba2.init_mamba_cache(cfg, batch, dtype)
    elif cfg.mla is not None:
        c["mla"] = mla.init_mla_cache(cfg, batch, cache_len, dtype)
    else:
        w = cfg.sliding_window if _uses_ring(cfg, spec, long_serving) \
            else cache_len
        c["kv"] = attn.init_kv_cache(batch, min(w, cache_len),
                                     cfg.n_kv_heads, cfg.head_dim, dtype)
    if cfg.is_encdec:
        c["cross"] = attn.init_kv_cache(batch, n_frames, cfg.n_kv_heads,
                                        cfg.head_dim, dtype)
    return c


def block_cache_specs(cfg: ArchConfig, spec: LayerSpec, batch_axes,
                      seq_axes) -> dict:
    c: dict = {}
    if spec.mixer == "mamba":
        c["mamba"] = mamba2.mamba_cache_specs(batch_axes)
    elif cfg.mla is not None:
        c["mla"] = mla.mla_cache_specs(batch_axes, seq_axes)
    else:
        c["kv"] = attn.kv_cache_specs(batch_axes, seq_axes)
    if cfg.is_encdec:
        c["cross"] = attn.kv_cache_specs(batch_axes, None)
    return c


def apply_block_decode(cfg: ArchConfig, spec: LayerSpec, p: dict,
                       x: jax.Array, cache: dict, pos: jax.Array,
                       *, long_serving: bool = False) -> tuple[jax.Array, dict]:
    new_cache = dict(cache)
    h = rms_norm(x, p["mixer_norm"], cfg.norm_eps)
    if spec.mixer == "mamba":
        out, new_cache["mamba"] = mamba2.mamba_decode(p["mixer"], h, cache["mamba"], cfg)
    elif cfg.mla is not None:
        out, new_cache["mla"] = mla.mla_decode(p["mixer"], h, cache["mla"], pos, cfg)
    else:
        ring = _uses_ring(cfg, spec, long_serving)
        out, new_cache["kv"] = attn.attn_decode(
            p["mixer"], h, cache["kv"], pos, cfg=cfg,
            window=cfg.sliding_window if ring else 0)
    if cfg.post_norms:
        out = rms_norm(out, p["mixer_post_norm"], cfg.norm_eps)
    x = x + out

    if cfg.is_encdec:
        h = rms_norm(x, p["cross_norm"], cfg.norm_eps)
        out = attn.cross_attn_decode(p["cross"], h, cache["cross"], cfg=cfg)
        x = x + out

    if spec.mlp != "none":
        h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        if spec.mlp == "moe":
            out, _ = moe_mod.moe_apply(p["mlp"], h, cfg, strategy="dense")
        else:
            out = mlp_apply(p["mlp"], h, cfg.mlp_activation)
        if cfg.post_norms:
            out = rms_norm(out, p["mlp_post_norm"], cfg.norm_eps)
        x = x + out
    return x, new_cache
