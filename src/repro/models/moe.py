"""Mixture-of-Experts MLP with top-k routing, shared experts and a
load-balance auxiliary loss.

Two execution strategies (selectable; see EXPERIMENTS.md §Perf):

* ``dense``    — every expert processes every token; outputs are combined
  with the (sparse) gate weights.  Simple, numerically exact, and maps onto
  expert sharding with a single all-reduce — but costs ``E/k`` times the
  active-expert FLOPs.  This is the paper-faithful baseline path (the paper
  treats Trainers as black boxes; MoE efficiency is our extension).
* ``capacity`` — classic dispatch/combine einsum formulation with a token
  capacity per expert (drops overflow tokens).  HLO FLOPs drop to the
  active-expert count; used by the optimized configuration.

Expert weights are sharded over the ``model`` axis on the expert dimension
when ``n_experts % model_shards == 0`` (expert parallelism), otherwise on
the per-expert hidden dimension (tensor parallelism inside each expert —
e.g. granite's 40 experts on a 16-way axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.layers import ACTIVATIONS, ParamDef, mlp_apply, mlp_defs


def moe_defs(cfg: ArchConfig, model_shards: int = 1, dtype=jnp.float32) -> dict:
    moe = cfg.moe
    assert moe is not None
    d, e, de = cfg.d_model, moe.n_experts, moe.d_expert
    if e % model_shards == 0:
        w_in_spec = P("model", None, None)       # expert-parallel
        w_out_spec = P("model", None, None)
    else:
        w_in_spec = P(None, None, "model")       # TP inside experts
        w_out_spec = P(None, "model", None)
    defs = {
        "router": ParamDef((d, e), spec=P(None, None), scale=d ** -0.5,
                           dtype=jnp.float32),   # router kept in fp32
        "w_gate": ParamDef((e, d, de), spec=w_in_spec, scale=d ** -0.5,
                           dtype=dtype),
        "w_up": ParamDef((e, d, de), spec=w_in_spec, scale=d ** -0.5,
                         dtype=dtype),
        "w_down": ParamDef((e, de, d), spec=w_out_spec, scale=de ** -0.5,
                           dtype=dtype),
    }
    if moe.n_shared:
        defs["shared"] = mlp_defs(d, de * moe.n_shared, dtype=dtype)
    return defs


def _route(p: dict, x2d: jax.Array, moe: MoEConfig):
    """Returns (gates (T,E) sparse, aux_loss scalar)."""
    logits = (x2d.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                 # (T, E)
    top_vals, top_idx = jax.lax.top_k(probs, moe.top_k)     # (T, k)
    top_vals = top_vals / jnp.maximum(
        top_vals.sum(-1, keepdims=True), 1e-9
    )
    gates = jnp.zeros_like(probs).at[
        jnp.arange(x2d.shape[0])[:, None], top_idx
    ].set(top_vals)

    # Switch-style load-balance loss: E * sum_e f_e * P_e
    e = moe.n_experts
    frac_tokens = (gates > 0).astype(jnp.float32).mean(0) * (e / moe.top_k)
    frac_probs = probs.mean(0)
    aux = moe.router_aux_coef * e * jnp.sum(frac_tokens * frac_probs)
    return gates, aux


def _experts_dense(p: dict, x2d: jax.Array, gates: jax.Array,
                   activation: str) -> jax.Array:
    act = ACTIVATIONS[activation]
    # (T,d) x (E,d,de) -> (E,T,de); combine with gates -> (T,d)
    h = act(jnp.einsum("td,edf->etf", x2d, p["w_gate"]),
            jnp.einsum("td,edf->etf", x2d, p["w_up"]))
    y = jnp.einsum("etf,efd->etd", h, p["w_down"])
    return jnp.einsum("etd,te->td", y, gates.astype(y.dtype))


def _experts_capacity(p: dict, x2d: jax.Array, gates: jax.Array,
                      moe: MoEConfig, activation: str,
                      group_size: int = 512) -> jax.Array:
    """Dispatch/combine einsum with per-expert capacity (overflow dropped).

    Tokens are processed in groups of ``group_size`` with a per-group
    capacity ``C = g·k/E·cf`` (the t5x/MaxText formulation): the dispatch
    tensor is (G, g, E, C), i.e. O(T·g·k·cf) elements instead of the
    O(T²·k·cf) a global-capacity formulation would need.  Groups inherit
    the token (data) sharding, experts the expert sharding.
    """
    act = ACTIVATIONS[activation]
    t, d = x2d.shape
    e = moe.n_experts
    g = min(group_size, t)
    pad = (-t) % g
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
        gates = jnp.pad(gates, ((0, pad), (0, 0)))
    n_groups = x2d.shape[0] // g
    xg = x2d.reshape(n_groups, g, d)
    gg = gates.reshape(n_groups, g, e)
    cap = int(max(1, round(g * moe.top_k / e * moe.capacity_factor)))

    sel = gg > 0                                             # (G,g,E)
    pos = jnp.cumsum(sel.astype(jnp.int32), axis=1) - 1      # slot in expert
    keep = sel & (pos < cap)
    disp = (keep[..., None]
            & (pos[..., None] == jnp.arange(cap)[None, None, None, :]))
    disp_f = disp.astype(x2d.dtype)                          # (G,g,E,C)
    xe = jnp.einsum("gsec,gsd->gecd", disp_f, xg)            # (G,E,C,d)
    h = act(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"]),
            jnp.einsum("gecd,edf->gecf", xe, p["w_up"]))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])        # (G,E,C,d)
    comb = disp_f * gg.astype(x2d.dtype)[..., None]          # (G,g,E,C)
    y = jnp.einsum("gsec,gecd->gsd", comb, ye)
    return y.reshape(n_groups * g, d)[:t]


def moe_apply(p: dict, x: jax.Array, cfg: ArchConfig, *,
              strategy: str = "dense") -> tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (y, aux_loss)."""
    moe = cfg.moe
    assert moe is not None
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    gates, aux = _route(p, x2d, moe)
    if strategy == "capacity":
        y = _experts_capacity(p, x2d, gates, moe, cfg.mlp_activation)
    else:
        y = _experts_dense(p, x2d, gates, cfg.mlp_activation)
    if moe.n_shared:
        y = y + mlp_apply(p["shared"], x2d, cfg.mlp_activation)
    return y.reshape(b, s, d), aux
