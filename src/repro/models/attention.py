"""Grouped-query attention (GQA/MQA) with RoPE, sliding windows, logit
soft-capping, cross-attention and decode-with-KV-cache.

Two execution paths:

* ``attend_direct`` — materializes the score matrix; used for short
  sequences (training smoke, train_4k).
* ``attend_blockwise`` — online-softmax over KV chunks (flash-attention
  algorithm expressed in XLA via ``lax.scan``); used for long sequences so
  the dry-run's compiled memory stays bounded.  The Pallas TPU kernel in
  ``repro.kernels.flash_attention`` implements the same contraction with
  explicit VMEM tiling; models default to the XLA path so that the dry-run
  lowers on any backend.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import ParamDef, apply_rope, dense_def, softcap

NEG_INF = -2.0 ** 30  # large-but-finite; keeps bf16/fp32 softmax NaN-free

BLOCKWISE_THRESHOLD = 8192   # switch to online-softmax path above this
# Force the flash-style blockwise path at any length (perf variant knob).
FORCE_BLOCKWISE = False
# Use the Pallas TPU flash-attention kernel for self-attention (first-class
# deployment path on TPU; interpret-mode on CPU). Set via
# repro.models.attention.USE_PALLAS_KERNEL = True (see tests/test_kernels.py
# for the model-level equivalence check).
USE_PALLAS_KERNEL = False
Q_BLOCK = 1024
KV_BLOCK = 1024


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


def attn_defs(cfg: ArchConfig, model_shards: int = 1, cross: bool = False,
              d_model: int = 0, n_heads: int = 0, n_kv: int = 0,
              head_dim: int = 0, dtype=jnp.float32) -> dict:
    """QKV/O projections.  Heads shard over the ``model`` mesh axis when they
    divide it; otherwise the projection is replicated (TP idle for that
    tensor — see DESIGN.md / roofline notes)."""
    d = d_model or cfg.d_model
    h = n_heads or cfg.n_heads
    kv = n_kv or cfg.n_kv_heads
    hd = head_dim or cfg.head_dim
    q_spec = P(None, "model") if h % model_shards == 0 else P(None, None)
    kv_spec = P(None, "model") if kv % model_shards == 0 else P(None, None)
    o_spec = P("model", None) if h % model_shards == 0 else P(None, None)
    return {
        "wq": dense_def(d, h * hd, q_spec, dtype=dtype),
        "wk": dense_def(d, kv * hd, kv_spec, dtype=dtype),
        "wv": dense_def(d, kv * hd, kv_spec, dtype=dtype),
        "wo": dense_def(h * hd, d, o_spec, scale=(h * hd) ** -0.5, dtype=dtype),
    }


# ---------------------------------------------------------------------------
# Score-level helpers
# ---------------------------------------------------------------------------


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: int) -> jax.Array:
    """(Sq, Sk) additive bias from causal + sliding-window constraints."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, NEG_INF)


def attend_direct(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  q_pos: jax.Array, k_pos: jax.Array, causal: bool,
                  window: int, logit_cap: float, scale: float) -> jax.Array:
    """q: (B,Sq,KV,G,D)  k,v: (B,Sk,KV,D)  ->  (B,Sq,KV,G,D)."""
    scores = jnp.einsum("bqkgd,btkd->bkgqt", q, k).astype(jnp.float32) * scale
    scores = softcap(scores, logit_cap)
    scores = scores + _mask_bias(q_pos, k_pos, causal, window)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqt,btkd->bqkgd", probs, v)


def attend_blockwise(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     q_pos: jax.Array, k_pos: jax.Array, causal: bool,
                     window: int, logit_cap: float, scale: float,
                     q_block: int = Q_BLOCK,
                     kv_block: int = KV_BLOCK) -> jax.Array:
    """Flash-attention contraction in XLA: scan over KV blocks with an
    online softmax, scanned over query blocks to bound live memory."""
    B, Sq, KV, G, D = q.shape
    Sk = k.shape[1]
    nq = -(-Sq // q_block)
    nk = -(-Sk // kv_block)
    q_pad, kv_pad = nq * q_block - Sq, nk * kv_block - Sk

    qb = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0), (0, 0)))
    qb = qb.reshape(B, nq, q_block, KV, G, D)
    qpos = jnp.pad(q_pos, (0, q_pad), constant_values=-1)
    qpos = qpos.reshape(nq, q_block)
    kb = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    kb = kb.reshape(B, nk, kv_block, KV, D)
    vb = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    vb = vb.reshape(B, nk, kv_block, KV, D)
    kpos = jnp.pad(k_pos, (0, kv_pad), constant_values=2**30)
    kpos = kpos.reshape(nk, kv_block)

    def q_step(_, qi):
        q_i, qpos_i = qi                       # (B,qb,KV,G,D), (qb,)

        def kv_step(carry, ki):
            m, l, acc = carry
            k_i, v_i, kpos_i = ki
            s = jnp.einsum("bqkgd,btkd->bkgqt", q_i, k_i).astype(jnp.float32)
            s = softcap(s * scale, logit_cap)
            s = s + _mask_bias(qpos_i, kpos_i, causal, window)
            # exclude padded KV positions (kpos sentinel) in all mask modes
            s = jnp.where(kpos_i[None, None, None, None, :] < Sk, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(v_i.dtype), v_i
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_block, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), kpos),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)       # (B,KV,G,qb,D)

    _, outs = jax.lax.scan(q_step, None, (qb.swapaxes(0, 1), qpos))
    # outs: (nq, B, KV, G, qb, D) -> (B, nq, qb, KV, G, D) -> (B, Sq, KV, G, D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * q_block, KV, G, D)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# Full-sequence attention layer (train / prefill)
# ---------------------------------------------------------------------------


def _split_heads(x, n, d):
    return x.reshape(*x.shape[:-1], n, d)


def attn_apply(p: dict, x: jax.Array, *, cfg: ArchConfig, causal: bool,
               window: int, positions: Optional[jax.Array] = None,
               n_heads: int = 0, n_kv: int = 0, head_dim: int = 0,
               memory: Optional[jax.Array] = None,
               use_rope: bool = True) -> jax.Array:
    """Self- (or cross-, when ``memory`` is given) attention over a full
    sequence. x: (B, S, d_model)."""
    B, S, _ = x.shape
    H = n_heads or cfg.n_heads
    KV = n_kv or cfg.n_kv_heads
    D = head_dim or cfg.head_dim
    G = H // KV
    kv_src = memory if memory is not None else x
    Sk = kv_src.shape[1]

    q = _split_heads(x @ p["wq"], H, D)
    k = _split_heads(kv_src @ p["wk"], KV, D)
    v = _split_heads(kv_src @ p["wv"], KV, D)

    q_pos = positions if positions is not None else jnp.arange(S)
    k_pos = jnp.arange(Sk)
    if use_rope and memory is None:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, k_pos, cfg.rope_theta)

    scale = cfg.query_scale or D ** -0.5
    if USE_PALLAS_KERNEL and memory is None and S == Sk:
        from repro.kernels.ops import flash_attention as _fa_kernel
        out = _fa_kernel(q.transpose(0, 2, 1, 3),      # (B,H,S,D)
                         k.transpose(0, 2, 1, 3),      # (B,KV,S,D)
                         v.transpose(0, 2, 1, 3),
                         causal=causal, window=window,
                         logit_cap=cfg.attn_logit_softcap, scale=scale)
        return out.transpose(0, 2, 1, 3).reshape(B, S, H * D) @ p["wo"]

    q = q.reshape(B, S, KV, G, D)
    kwargs = dict(q_pos=q_pos, k_pos=k_pos, causal=causal, window=window,
                  logit_cap=cfg.attn_logit_softcap, scale=scale)
    if FORCE_BLOCKWISE or max(S, Sk) > BLOCKWISE_THRESHOLD:
        out = attend_blockwise(q, k, v, **kwargs)
    else:
        out = attend_direct(q, k, v, **kwargs)
    return out.reshape(B, S, H * D) @ p["wo"]


# ---------------------------------------------------------------------------
# Decode step with KV cache
# ---------------------------------------------------------------------------


def init_kv_cache(batch: int, cache_len: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
    }


def kv_cache_specs(batch_axes, seq_axes) -> dict:
    return {"k": P(batch_axes, seq_axes, None, None),
            "v": P(batch_axes, seq_axes, None, None)}


def attn_decode(p: dict, x: jax.Array, cache: dict, pos: jax.Array, *,
                cfg: ArchConfig, window: int, n_heads: int = 0,
                n_kv: int = 0, head_dim: int = 0,
                use_rope: bool = True) -> tuple[jax.Array, dict]:
    """One-token decode. x: (B, 1, d_model); cache k/v: (B, W, KV, D).

    The cache is a ring buffer when ``window`` is non-zero (slot =
    pos % W); otherwise slot = pos.  Keys are stored rotated at their
    absolute position, so no re-rotation is needed at read time.
    """
    B, _, _ = x.shape
    H = n_heads or cfg.n_heads
    KV = n_kv or cfg.n_kv_heads
    D = head_dim or cfg.head_dim
    G = H // KV
    W = cache["k"].shape[1]

    q = _split_heads(x @ p["wq"], H, D)
    k_new = _split_heads(x @ p["wk"], KV, D)
    v_new = _split_heads(x @ p["wv"], KV, D)
    if use_rope:
        posv = jnp.full((1,), 1, jnp.int32) * pos
        q = apply_rope(q, posv, cfg.rope_theta)
        k_new = apply_rope(k_new, posv, cfg.rope_theta)

    slot = jnp.where(window, pos % jnp.maximum(W, 1), pos)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))

    # Valid slots: ring buffer is fully valid once pos+1 >= W; before that
    # only slots <= pos hold data.  (All cached absolute positions <= pos,
    # and > pos - W by ring construction, so causality/window are implied.)
    valid = (jnp.arange(W) <= pos) | (pos >= W)

    qh = q.reshape(B, 1, KV, G, D)
    scale = cfg.query_scale or D ** -0.5
    s = jnp.einsum("bqkgd,btkd->bkgqt", qh, k).astype(jnp.float32) * scale
    s = softcap(s, cfg.attn_logit_softcap)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqt,btkd->bqkgd", probs, v)
    out = out.reshape(B, 1, H * D) @ p["wo"]
    return out, {"k": k, "v": v}


def cross_attn_decode(p: dict, x: jax.Array, cross_kv: dict, *,
                      cfg: ArchConfig, n_heads: int = 0, n_kv: int = 0,
                      head_dim: int = 0) -> jax.Array:
    """Cross-attention against a precomputed encoder-memory KV cache."""
    B = x.shape[0]
    H = n_heads or cfg.n_heads
    KV = n_kv or cfg.n_kv_heads
    D = head_dim or cfg.head_dim
    G = H // KV
    q = _split_heads(x @ p["wq"], H, D).reshape(B, 1, KV, G, D)
    k, v = cross_kv["k"], cross_kv["v"]
    scale = cfg.query_scale or D ** -0.5
    s = jnp.einsum("bqkgd,btkd->bkgqt", q, k).astype(jnp.float32) * scale
    probs = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqt,btkd->bqkgd", probs, v)
    return out.reshape(B, 1, H * D) @ p["wo"]
