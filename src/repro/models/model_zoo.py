"""Top-level Model wrapper: parameter defs, init, loss, prefill, decode and
``input_specs`` for every assigned architecture.

One class covers all 10 architectures; behaviour is driven entirely by the
``ArchConfig`` (layer pattern, MoE/MLA/SSM sub-configs, enc-dec, frontend
stubs).
"""
from __future__ import annotations

import math
from functools import cached_property, partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import mamba2, transformer as T
from repro.models import moe as moe_mod
from repro.models.layers import ParamDef, rms_norm, softcap

Pytree = Any


# Perf knob (EXPERIMENTS.md §Perf): when False, the (B,S,V) logits are
# never materialized in fp32 — max/exp stay in the logits dtype and only
# the vocab reduction accumulates in fp32.  Halves the byte traffic of the
# loss head at a small numerics cost.
CE_UPCAST = True


def _cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    if CE_UPCAST:
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return (logz - gold).mean()
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m                                  # logits dtype
    sumexp = jnp.sum(jnp.exp(shifted), axis=-1, dtype=jnp.float32)
    logz = jnp.log(sumexp) + m[..., 0].astype(jnp.float32)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (logz - gold.astype(jnp.float32)).mean()


class Model:
    def __init__(self, cfg: ArchConfig, *, model_shards: int = 1,
                 dtype=jnp.float32, moe_strategy: str = "dense",
                 remat: bool = True, long_serving: bool = False,
                 scan_unroll=1):
        self.cfg = cfg
        self.model_shards = model_shards
        self.dtype = dtype
        self.moe_strategy = moe_strategy
        self.remat = remat
        self.long_serving = long_serving
        # scan_unroll=True fully unrolls the layer stack; the dry-run uses
        # this so XLA cost_analysis counts every block (a while-loop body is
        # costed once regardless of trip count)
        self.scan_unroll = scan_unroll

    # ------------------------------------------------------------------
    # Parameter definitions
    # ------------------------------------------------------------------

    @cached_property
    def defs(self) -> Pytree:
        cfg, dtype, shards = self.cfg, self.dtype, self.model_shards
        d: dict = {
            "embed": ParamDef((cfg.vocab_size, cfg.d_model),
                              spec=P("model", None),
                              scale=cfg.d_model ** -0.5, dtype=dtype),
            "final_norm": T._norm(cfg.d_model),
            "blocks": tuple(
                L.stack_defs(T.block_defs(cfg, spec, shards, dtype),
                             cfg.n_blocks)
                for spec in cfg.layer_pattern
            ),
        }
        if not cfg.tie_embeddings:
            d["unembed"] = ParamDef((cfg.vocab_size, cfg.d_model),
                                    spec=P("model", None),
                                    scale=cfg.d_model ** -0.5, dtype=dtype)
        if cfg.is_encdec:
            enc = cfg.encoder
            enc_layer = {
                "attn_norm": T._norm(enc.d_model),
                "attn": attn.attn_defs(cfg, shards, d_model=enc.d_model,
                                       n_heads=enc.n_heads,
                                       n_kv=enc.n_kv_heads,
                                       head_dim=enc.head_dim, dtype=dtype),
                "mlp_norm": T._norm(enc.d_model),
                "mlp": L.mlp_defs(enc.d_model, enc.d_ff, dtype=dtype),
            }
            d["encoder"] = {
                "layers": L.stack_defs(enc_layer, enc.n_layers),
                "final_norm": T._norm(enc.d_model),
            }
        return d

    def init(self, rng: jax.Array) -> Pytree:
        return L.materialize(self.defs, rng)

    def abstract_params(self) -> Pytree:
        return L.abstract(self.defs)

    def pspecs(self) -> Pytree:
        return L.pspecs(self.defs)

    def n_params(self) -> int:
        return sum(math.prod(d.shape) for d in jax.tree.leaves(
            self.defs, is_leaf=lambda x: isinstance(x, ParamDef)))

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE counts top_k + shared experts)."""
        cfg = self.cfg
        if cfg.moe is None:
            return self.n_params()
        total = 0
        for d in jax.tree.leaves(self.defs,
                                 is_leaf=lambda x: isinstance(x, ParamDef)):
            total += math.prod(d.shape)
        # subtract inactive routed experts
        moe = cfg.moe
        n_moe_layers = sum(s.mlp == "moe" for s in self.cfg.layer_specs())
        per_expert = 3 * cfg.d_model * moe.d_expert
        inactive = n_moe_layers * (moe.n_experts - moe.top_k) * per_expert
        return total - inactive

    # ------------------------------------------------------------------
    # Embedding / head
    # ------------------------------------------------------------------

    def _embed(self, params, batch: dict) -> jax.Array:
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]
        if cfg.scale_embeddings:
            x = (x.astype(jnp.float32) * cfg.d_model ** 0.5).astype(x.dtype)
        if cfg.frontend == "vision" and "frontend_embeds" in batch:
            x = jnp.concatenate(
                [batch["frontend_embeds"].astype(x.dtype), x], axis=1)
        return x

    def _logits(self, params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        logits = jnp.einsum("bsd,vd->bsv", x, table)
        if not CE_UPCAST and not cfg.final_logit_softcap:
            return logits            # keep bf16; CE accumulates in fp32
        return softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)

    # ------------------------------------------------------------------
    # Encoder (enc-dec models)
    # ------------------------------------------------------------------

    def _encode(self, params, frames: jax.Array) -> jax.Array:
        cfg, enc = self.cfg, self.cfg.encoder

        def body(x, p):
            h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
            x = x + attn.attn_apply(p["attn"], h, cfg=cfg, causal=False,
                                    window=0, n_heads=enc.n_heads,
                                    n_kv=enc.n_kv_heads,
                                    head_dim=enc.head_dim)
            h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
            x = x + L.mlp_apply(p["mlp"], h, cfg.mlp_activation)
            return x, None

        if self.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, frames.astype(self.dtype),
                            params["encoder"]["layers"],
                            unroll=self.scan_unroll)
        return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)

    # ------------------------------------------------------------------
    # Forward (train / prefill)
    # ------------------------------------------------------------------

    def forward(self, params, batch: dict) -> tuple[jax.Array, jax.Array]:
        """Returns (logits, aux_loss)."""
        cfg = self.cfg
        memory = None
        if cfg.is_encdec:
            memory = self._encode(params, batch["frames"])
        x = self._embed(params, batch)

        def one_layer(spec):
            def f(p, x):
                return T.apply_block(cfg, spec, p, x, memory=memory,
                                     moe_strategy=self.moe_strategy,
                                     long_serving=self.long_serving)
            # long patterns (deepseek: 27 layers in one scan block) must be
            # checkpointed per layer, or backward keeps the whole block's
            # activations live at once
            if self.remat and len(cfg.layer_pattern) > 4:
                f = jax.checkpoint(f)
            return f

        layer_fns = [one_layer(spec) for spec in cfg.layer_pattern]

        def body(carry, p_blocks):
            x, aux = carry
            for i in range(len(cfg.layer_pattern)):
                x, a = layer_fns[i](p_blocks[i], x)
                aux = aux + a
            return (x, aux), None

        if self.remat and len(cfg.layer_pattern) <= 4:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"], unroll=self.scan_unroll)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return self._logits(params, x), aux

    def loss(self, params, batch: dict) -> jax.Array:
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        if self.cfg.frontend == "vision":
            # frontend positions carry no next-token loss
            logits = logits[:, -labels.shape[1]:]
        return _cross_entropy(logits[:, :-1], labels[:, 1:]) + aux

    # ------------------------------------------------------------------
    # Serving: cache init + one-token decode
    # ------------------------------------------------------------------

    def init_cache(self, batch: int, cache_len: int, n_frames: int = 0,
                   dtype=jnp.bfloat16) -> Pytree:
        cfg = self.cfg

        def one(spec):
            c = T.init_block_cache(cfg, spec, batch, cache_len,
                                   n_frames=n_frames,
                                   long_serving=self.long_serving,
                                   dtype=dtype)
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_blocks, *a.shape)), c)

        return tuple(one(spec) for spec in cfg.layer_pattern)

    def cache_specs(self, batch_axes, seq_axes) -> Pytree:
        cfg = self.cfg

        def one(spec):
            c = T.block_cache_specs(cfg, spec, batch_axes, seq_axes)
            return jax.tree.map(lambda s: P(None, *s), c,
                                is_leaf=lambda s: isinstance(s, P))

        return tuple(one(spec) for spec in cfg.layer_pattern)

    def decode_step(self, params, cache: Pytree, tokens: jax.Array,
                    pos: jax.Array) -> tuple[jax.Array, Pytree]:
        """tokens: (B,1) int32; pos: scalar int32 (absolute position)."""
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.scale_embeddings:
            x = (x.astype(jnp.float32) * cfg.d_model ** 0.5).astype(x.dtype)

        def body(x, xs):
            p_blocks, c_blocks = xs
            new_c = []
            for i, spec in enumerate(cfg.layer_pattern):
                x, nc = T.apply_block_decode(cfg, spec, p_blocks[i], x,
                                             c_blocks[i], pos,
                                             long_serving=self.long_serving)
                new_c.append(nc)
            return x, tuple(new_c)

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache),
                                    unroll=self.scan_unroll)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return self._logits(params, x), new_cache

    # ------------------------------------------------------------------
    # Prefill: full forward that also fills the decode cache
    # ------------------------------------------------------------------

    def prefill(self, params, batch: dict,
                cache_len: Optional[int] = None) -> tuple[jax.Array, Pytree]:
        """Returns (last-position logits, cache filled through S-1)."""
        cfg = self.cfg
        memory = None
        if cfg.is_encdec:
            memory = self._encode(params, batch["frames"])
        x = self._embed(params, batch)
        s = x.shape[1]
        cache_len = cache_len or s

        def body(x, p_blocks):
            caches = []
            for i, spec in enumerate(cfg.layer_pattern):
                h = rms_norm(x, p_blocks[i]["mixer_norm"], cfg.norm_eps)
                c: dict = {}
                if spec.mixer == "mamba":
                    out, c["mamba"] = _mamba_prefill(cfg, p_blocks[i]["mixer"], h)
                elif cfg.mla is not None:
                    out, c["mla"] = _mla_prefill(cfg, p_blocks[i]["mixer"], h,
                                                 cache_len)
                else:
                    ring = T._uses_ring(cfg, spec, self.long_serving)
                    window = cfg.sliding_window if (
                        spec.mixer == "swa" or (self.long_serving and
                                                cfg.sliding_window)) else 0
                    out, c["kv"] = _attn_prefill(
                        cfg, p_blocks[i]["mixer"], h, window=window,
                        ring=ring, cache_len=cache_len)
                if cfg.post_norms:
                    out = rms_norm(out, p_blocks[i]["mixer_post_norm"],
                                   cfg.norm_eps)
                x = x + out
                if cfg.is_encdec:
                    hh = rms_norm(x, p_blocks[i]["cross_norm"], cfg.norm_eps)
                    x = x + attn.attn_apply(
                        p_blocks[i]["cross"], hh, cfg=cfg, causal=False,
                        window=0, memory=memory, use_rope=False)
                    c["cross"] = _cross_kv(cfg, p_blocks[i]["cross"], memory)
                if spec.mlp != "none":
                    hh = rms_norm(x, p_blocks[i]["mlp_norm"], cfg.norm_eps)
                    if spec.mlp == "moe":
                        out, _ = moe_mod.moe_apply(p_blocks[i]["mlp"], hh, cfg,
                                                   strategy=self.moe_strategy)
                    else:
                        out = L.mlp_apply(p_blocks[i]["mlp"], hh,
                                          cfg.mlp_activation)
                    if cfg.post_norms:
                        out = rms_norm(out, p_blocks[i]["mlp_post_norm"],
                                       cfg.norm_eps)
                    x = x + out
                caches.append(c)
            return x, tuple(caches)

        x, cache = jax.lax.scan(body, x, params["blocks"],
                                unroll=self.scan_unroll)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x[:, -1:])
        return logits, cache

    # ------------------------------------------------------------------
    # Input specs (ShapeDtypeStruct stand-ins; no allocation)
    # ------------------------------------------------------------------

    def input_specs(self, shape: InputShape) -> dict:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct
        if shape.kind == "train":
            batch: dict = {}
            if cfg.frontend == "vision":
                nt = cfg.n_frontend_tokens
                batch["tokens"] = tok((b, s - nt), jnp.int32)
                batch["labels"] = tok((b, s - nt), jnp.int32)
                batch["frontend_embeds"] = tok((b, nt, cfg.d_model),
                                               jnp.bfloat16)
            elif cfg.is_encdec:
                batch["tokens"] = tok((b, s), jnp.int32)
                batch["labels"] = tok((b, s), jnp.int32)
                batch["frames"] = tok((b, s // 4, cfg.encoder.d_model),
                                      jnp.bfloat16)
            else:
                batch["tokens"] = tok((b, s), jnp.int32)
                batch["labels"] = tok((b, s), jnp.int32)
            return batch
        if shape.kind == "prefill":
            batch = {"tokens": tok((b, s), jnp.int32)}
            if cfg.frontend == "vision":
                nt = cfg.n_frontend_tokens
                batch["tokens"] = tok((b, s - nt), jnp.int32)
                batch["frontend_embeds"] = tok((b, nt, cfg.d_model),
                                               jnp.bfloat16)
            elif cfg.is_encdec:
                batch["frames"] = tok((b, s // 4, cfg.encoder.d_model),
                                      jnp.bfloat16)
            return batch
        # decode: one new token against a cache of length s
        abstract_cache = jax.eval_shape(
            lambda: self.init_cache(b, s, n_frames=s // 4 if cfg.is_encdec
                                    else 0))
        return {
            "tokens": tok((b, 1), jnp.int32),
            "pos": tok((), jnp.int32),
            "cache": abstract_cache,
        }


# ---------------------------------------------------------------------------
# Prefill helpers (forward pass that also emits the decode cache)
# ---------------------------------------------------------------------------


def _ring_fill(full: jax.Array, window: int) -> jax.Array:
    """(B,S,...) -> (B,W,...): slot i holds the latest position t with
    t % W == i (gather formulation; no duplicate-scatter ambiguity)."""
    s = full.shape[1]
    w = window
    if s <= w:
        pad = [(0, 0), (0, w - s)] + [(0, 0)] * (full.ndim - 2)
        return jnp.pad(full, pad)
    i = jnp.arange(w)
    t = (s - 1) - ((s - 1 - i) % w)
    return jnp.take(full, t, axis=1)


def _attn_prefill(cfg: ArchConfig, p: dict, h: jax.Array, *, window: int,
                  ring: bool, cache_len: int):
    b, s, _ = h.shape
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    out = attn.attn_apply(p, h, cfg=cfg, causal=True, window=window)
    k = (h @ p["wk"]).reshape(b, s, kv, hd)
    v = (h @ p["wv"]).reshape(b, s, kv, hd)
    k = L.apply_rope(k, jnp.arange(s), cfg.rope_theta)
    if ring:
        k = _ring_fill(k, cfg.sliding_window)
        v = _ring_fill(v, cfg.sliding_window)
    elif s < cache_len:
        k = jnp.pad(k, ((0, 0), (0, cache_len - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, cache_len - s), (0, 0), (0, 0)))
    c = {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}
    return out, c


def _mla_prefill(cfg: ArchConfig, p: dict, h: jax.Array, cache_len: int):
    from repro.models import mla as mla_mod
    b, s, _ = h.shape
    out = mla_mod.mla_apply(p, h, cfg)
    c_kv = rms_norm(h @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)
    k_rope = L.apply_rope((h @ p["w_kr"])[:, :, None, :], jnp.arange(s),
                          cfg.rope_theta)[:, :, 0, :]
    if s < cache_len:
        c_kv = jnp.pad(c_kv, ((0, 0), (0, cache_len - s), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, cache_len - s), (0, 0)))
    return out, {"c_kv": c_kv.astype(jnp.bfloat16),
                 "k_rope": k_rope.astype(jnp.bfloat16)}


def _mamba_prefill(cfg: ArchConfig, p: dict, h: jax.Array):
    ssm = cfg.ssm
    d_inner, n_heads, _ = mamba2.mamba_dims(cfg)
    b, s, _ = h.shape
    z = h @ p["wz"]
    x_pre = h @ p["wx"]
    b_pre = h @ p["wb"]
    c_pre = h @ p["wc"]
    x = jax.nn.silu(mamba2._causal_conv(x_pre, p["conv_x"]))
    bmat = jax.nn.silu(mamba2._causal_conv(b_pre, p["conv_b"]))
    cmat = jax.nn.silu(mamba2._causal_conv(c_pre, p["conv_c"]))
    dt = jax.nn.softplus((h @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xh = x.reshape(b, s, n_heads, ssm.head_dim)
    bh = mamba2._broadcast_groups(bmat, cfg, n_heads)
    ch = mamba2._broadcast_groups(cmat, cfg, n_heads)
    y, final_state = mamba2.ssd_chunked(xh, dt, a, bh, ch,
                                        chunk=ssm.chunk_size)
    y = y + xh * p["d_skip"][:, None].astype(y.dtype)
    y = y.reshape(b, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["wo"]
    k = ssm.d_conv - 1
    cache = {
        "conv_x": _last_k(x_pre, k).astype(jnp.bfloat16),
        "conv_b": _last_k(b_pre, k).astype(jnp.bfloat16),
        "conv_c": _last_k(c_pre, k).astype(jnp.bfloat16),
        "state": final_state,
    }
    return out, cache


def _last_k(x: jax.Array, k: int) -> jax.Array:
    s = x.shape[1]
    if s >= k:
        return x[:, s - k:]
    return jnp.pad(x, ((0, 0), (k - s, 0), (0, 0)))


def _cross_kv(cfg: ArchConfig, p: dict, memory: jax.Array) -> dict:
    b, f, _ = memory.shape
    k = (memory @ p["wk"]).reshape(b, f, cfg.n_kv_heads, cfg.head_dim)
    v = (memory @ p["wv"]).reshape(b, f, cfg.n_kv_heads, cfg.head_dim)
    return {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}


def build_model(cfg: ArchConfig, **kw) -> Model:
    return Model(cfg, **kw)
