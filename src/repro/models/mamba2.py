"""Mamba-2 block (SSD — state-space duality), pure-JAX reference path.

The training/prefill path uses the chunked SSD algorithm: quadratic
attention-like compute inside fixed-size chunks plus a linear recurrent
state pass across chunks (``lax.scan``).  The decode path is the O(1)
recurrent update.  ``repro.kernels.ssd_scan`` provides the Pallas TPU
kernel for the chunk-level contraction; this module is the XLA oracle the
kernel is validated against (and the path used by the dry-run).

Shapes follow the paper [arXiv:2405.21060]:
    x  (B,S,H,P)   per-head inputs,  H = d_inner / head_dim
    dt (B,S,H)     positive step sizes (softplus)
    A  (H,)        negative decay rates
    B,C (B,S,G,N)  input/output projections per group (broadcast to heads)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import ParamDef, dense_def, rms_norm


# ---------------------------------------------------------------------------
# Core SSD math
# ---------------------------------------------------------------------------


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
                cmat: jax.Array, *, chunk: int,
                init_state: Optional[jax.Array] = None):
    """Chunked SSD scan.

    Args:
      x:    (B,S,H,P)
      dt:   (B,S,H), positive
      a:    (H,), negative
      bmat: (B,S,H,N)  (already broadcast from groups to heads)
      cmat: (B,S,H,N)
    Returns:
      y (B,S,H,P), final_state (B,H,P,N)
    """
    batch, s, h, p = x.shape
    n = bmat.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // chunk

    xc = x.reshape(batch, nc, chunk, h, p)
    dtc = dt.reshape(batch, nc, chunk, h).astype(jnp.float32)
    bc = bmat.reshape(batch, nc, chunk, h, n)
    cc = cmat.reshape(batch, nc, chunk, h, n)

    da = dtc * a.astype(jnp.float32)                 # (B,nc,L,H), <= 0
    cum = jnp.cumsum(da, axis=2)                     # within-chunk cumsum

    # --- intra-chunk (quadratic in chunk length) ---
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,L,L,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bclhn,bcshn->bclsh", cc, bc).astype(jnp.float32)
    y_diag = jnp.einsum("bclsh,bcsh,bcshp->bclhp",
                        cb * decay, dtc, xc.astype(jnp.float32))

    # --- chunk states ---
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # (B,nc,L,H)
    states = jnp.einsum("bcshn,bcsh,bcshp->bchpn",
                        bc.astype(jnp.float32), dtc * decay_to_end,
                        xc.astype(jnp.float32))            # (B,nc,H,P,N)

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (B,nc,H)
    h0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((batch, h, p, n), jnp.float32))

    def step(carry, inp):
        st, dec = inp                                      # (B,H,P,N),(B,H)
        prev = carry
        new = carry * dec[:, :, None, None] + st
        return new, prev

    final, prev_states = jax.lax.scan(
        step, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    )
    prev_states = prev_states.swapaxes(0, 1)               # (B,nc,H,P,N)

    # --- inter-chunk output ---
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       cc.astype(jnp.float32), prev_states, jnp.exp(cum))

    y = (y_diag + y_off).reshape(batch, nc * chunk, h, p)[:, :s]
    return y.astype(x.dtype), final


def ssd_step(state: jax.Array, x: jax.Array, dt: jax.Array, a: jax.Array,
             bmat: jax.Array, cmat: jax.Array):
    """O(1) recurrent decode step.

    state (B,H,P,N); x (B,H,P); dt (B,H); bmat/cmat (B,H,N).
    """
    dt = dt.astype(jnp.float32)
    da = jnp.exp(dt * a.astype(jnp.float32))               # (B,H)
    upd = jnp.einsum("bh,bhn,bhp->bhpn", dt, bmat.astype(jnp.float32),
                     x.astype(jnp.float32))
    new_state = state * da[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, cmat.astype(jnp.float32))
    return new_state, y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba2 block (projections + depthwise causal conv + SSD + gating)
# ---------------------------------------------------------------------------


def mamba_dims(cfg: ArchConfig):
    ssm = cfg.ssm
    assert ssm is not None
    d_inner = ssm.expand * cfg.d_model
    n_heads = d_inner // ssm.head_dim
    return d_inner, n_heads, ssm.n_groups * ssm.d_state


def mamba_defs(cfg: ArchConfig, model_shards: int = 1,
               dtype=jnp.float32) -> dict:
    ssm = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads, d_bc = mamba_dims(cfg)
    h_spec = (P(None, "model") if n_heads % model_shards == 0
              else P(None, None))
    h_vec = P("model") if n_heads % model_shards == 0 else P()
    return {
        "wz": dense_def(d, d_inner, h_spec, dtype=dtype),
        "wx": dense_def(d, d_inner, h_spec, dtype=dtype),
        "wb": dense_def(d, d_bc, P(None, None), dtype=dtype),
        "wc": dense_def(d, d_bc, P(None, None), dtype=dtype),
        "wdt": dense_def(d, n_heads, h_spec, dtype=dtype),
        "conv_x": ParamDef((ssm.d_conv, d_inner), spec=h_spec, scale=0.1,
                           dtype=dtype),
        "conv_b": ParamDef((ssm.d_conv, d_bc), spec=P(None, None), scale=0.1,
                           dtype=dtype),
        "conv_c": ParamDef((ssm.d_conv, d_bc), spec=P(None, None), scale=0.1,
                           dtype=dtype),
        "dt_bias": ParamDef((n_heads,), spec=h_vec, init="zeros",
                            dtype=jnp.float32),
        "a_log": ParamDef((n_heads,), spec=h_vec, init="zeros",
                          dtype=jnp.float32),
        "d_skip": ParamDef((n_heads,), spec=h_vec, init="ones",
                           dtype=jnp.float32),
        "norm": ParamDef((d_inner,), spec=h_vec, init="zeros",
                         dtype=jnp.float32),
        "wo": dense_def(d_inner, d, P("model", None) if n_heads % model_shards == 0
                        else P(None, None), dtype=dtype),
    }


def _causal_conv(x: jax.Array, kernel: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B,S,C) with kernel (K,C)."""
    k = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1], :] * kernel[i]
    return out


def _conv_step(window: jax.Array, x_new: jax.Array, kernel: jax.Array):
    """window (B,K-1,C) holds previous inputs; returns (new_window, y (B,C))."""
    full = jnp.concatenate([window, x_new[:, None, :]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", full, kernel)
    return full[:, 1:], y


def _broadcast_groups(t: jax.Array, cfg: ArchConfig, n_heads: int) -> jax.Array:
    """(..., G*N) -> (..., H, N) by repeating each group over its heads."""
    ssm = cfg.ssm
    g, n = ssm.n_groups, ssm.d_state
    t = t.reshape(*t.shape[:-1], g, n)
    return jnp.repeat(t, n_heads // g, axis=-2)


def mamba_apply(p: dict, hidden: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Full-sequence mamba2 block. hidden: (B,S,d_model)."""
    ssm = cfg.ssm
    d_inner, n_heads, _ = mamba_dims(cfg)
    b, s, _ = hidden.shape

    z = hidden @ p["wz"]
    x = jax.nn.silu(_causal_conv(hidden @ p["wx"], p["conv_x"]))
    bmat = jax.nn.silu(_causal_conv(hidden @ p["wb"], p["conv_b"]))
    cmat = jax.nn.silu(_causal_conv(hidden @ p["wc"], p["conv_c"]))
    dt = jax.nn.softplus(
        (hidden @ p["wdt"]).astype(jnp.float32) + p["dt_bias"]
    )
    a = -jnp.exp(p["a_log"])

    xh = x.reshape(b, s, n_heads, ssm.head_dim)
    bh = _broadcast_groups(bmat, cfg, n_heads)
    ch = _broadcast_groups(cmat, cfg, n_heads)

    y, _ = ssd_chunked(xh, dt, a, bh, ch, chunk=ssm.chunk_size)
    y = y + xh * p["d_skip"][:, None].astype(y.dtype)
    y = y.reshape(b, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["wo"]


# ---------------------------------------------------------------------------
# Decode with recurrent state
# ---------------------------------------------------------------------------


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    ssm = cfg.ssm
    d_inner, n_heads, d_bc = mamba_dims(cfg)
    k = ssm.d_conv - 1
    return {
        "conv_x": jnp.zeros((batch, k, d_inner), dtype),
        "conv_b": jnp.zeros((batch, k, d_bc), dtype),
        "conv_c": jnp.zeros((batch, k, d_bc), dtype),
        "state": jnp.zeros((batch, n_heads, ssm.head_dim, ssm.d_state),
                           jnp.float32),
    }


def mamba_cache_specs(batch_axes) -> dict:
    return {
        "conv_x": P(batch_axes, None, "model"),
        "conv_b": P(batch_axes, None, None),
        "conv_c": P(batch_axes, None, None),
        "state": P(batch_axes, "model", None, None),
    }


def mamba_decode(p: dict, hidden: jax.Array, cache: dict,
                 cfg: ArchConfig) -> tuple[jax.Array, dict]:
    """One-token decode. hidden: (B,1,d_model)."""
    ssm = cfg.ssm
    d_inner, n_heads, _ = mamba_dims(cfg)
    h1 = hidden[:, 0, :]

    z = h1 @ p["wz"]
    cw_x, x = _conv_step(cache["conv_x"], h1 @ p["wx"], p["conv_x"])
    cw_b, bmat = _conv_step(cache["conv_b"], h1 @ p["wb"], p["conv_b"])
    cw_c, cmat = _conv_step(cache["conv_c"], h1 @ p["wc"], p["conv_c"])
    x, bmat, cmat = map(jax.nn.silu, (x, bmat, cmat))
    dt = jax.nn.softplus(
        (h1 @ p["wdt"]).astype(jnp.float32) + p["dt_bias"]
    )
    a = -jnp.exp(p["a_log"])

    xh = x.reshape(-1, n_heads, ssm.head_dim)
    bh = _broadcast_groups(bmat, cfg, n_heads)
    ch = _broadcast_groups(cmat, cfg, n_heads)
    new_state, y = ssd_step(cache["state"], xh, dt, a, bh, ch)
    y = y + xh * p["d_skip"][:, None].astype(y.dtype)
    y = y.reshape(-1, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["wo"])[:, None, :]
    new_cache = {"conv_x": cw_x, "conv_b": cw_b, "conv_c": cw_c,
                 "state": new_state}
    return out, new_cache
