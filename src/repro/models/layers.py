"""Parameter definitions and primitive layers shared by the model zoo.

Design: every module is described by a pytree of :class:`ParamDef` leaves
(shape, dtype, init scale, PartitionSpec).  From one defs tree we derive

* concrete parameters  (``materialize``),
* abstract parameters for ``jax.eval_shape``/dry-run (``abstract``),
* the sharding tree for pjit (``pspecs``).

This guarantees params / specs never drift apart structurally.
"""
from __future__ import annotations

import dataclasses
import zlib
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Pytree = Any


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    spec: P = P()
    dtype: Any = jnp.float32
    init: str = "normal"        # normal | zeros | ones
    scale: float = 0.02

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def materialize(defs: Pytree, rng: jax.Array, dtype=None) -> Pytree:
    """Instantiate a defs tree into concrete parameters.

    Each leaf gets an independent key derived from its tree path, so
    adding/removing parameters does not reshuffle others.
    """

    def make(path, d: ParamDef):
        leaf_dtype = dtype if dtype is not None else d.dtype
        if d.init == "zeros":
            return jnp.zeros(d.shape, leaf_dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, leaf_dtype)
        # crc32, not hash(): Python str hashes are randomized per process,
        # which would make init non-reproducible across runs
        key = jax.random.fold_in(
            rng, zlib.crc32(jax.tree_util.keystr(path).encode()))
        return (jax.random.normal(key, d.shape, jnp.float32) * d.scale).astype(
            leaf_dtype
        )

    return jax.tree_util.tree_map_with_path(make, defs, is_leaf=_is_def)


def abstract(defs: Pytree, dtype=None) -> Pytree:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype or d.dtype),
        defs,
        is_leaf=_is_def,
    )


def pspecs(defs: Pytree) -> Pytree:
    return jax.tree.map(lambda d: d.spec, defs, is_leaf=_is_def)


def stack_defs(defs: Pytree, n: int) -> Pytree:
    """Prepend a layer-stack dimension (for scan-over-blocks)."""

    def f(d: ParamDef) -> ParamDef:
        return dataclasses.replace(
            d, shape=(n, *d.shape), spec=P(None, *d.spec)
        )

    return jax.tree.map(f, defs, is_leaf=_is_def)


# ---------------------------------------------------------------------------
# Primitive ops
# ---------------------------------------------------------------------------


# When False, only the variance statistic is computed in fp32 and the
# normalize/gain multiplies stay in the residual dtype — keeps backward
# cotangents (and hence TP partial-sum all-reduces) in bf16.  Toggled by
# the dry-run perf variants (EXPERIMENTS.md §Perf).
NORM_MULT_FP32 = True


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    if NORM_MULT_FP32:
        xf = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps).astype(dtype)
    return x * r * (1.0 + scale).astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def geglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.gelu(gate, approximate=True) * up


ACTIVATIONS = {"swiglu": swiglu, "geglu": geglu}


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    ang = ang[..., None, :]                            # (..., S, 1, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / MLP defs
# ---------------------------------------------------------------------------


def dense_def(d_in: int, d_out: int, spec: P, scale: Optional[float] = None,
              dtype=jnp.float32) -> ParamDef:
    if scale is None:
        scale = d_in ** -0.5
    return ParamDef((d_in, d_out), spec=spec, scale=scale, dtype=dtype)


def mlp_defs(d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    """Gated MLP (SwiGLU / GeGLU): hidden sharded over the model axis."""
    return {
        "w_gate": dense_def(d_model, d_ff, P(None, "model"), dtype=dtype),
        "w_up": dense_def(d_model, d_ff, P(None, "model"), dtype=dtype),
        "w_down": dense_def(d_ff, d_model, P("model", None), dtype=dtype),
    }


def mlp_apply(p: dict, x: jax.Array, activation: str) -> jax.Array:
    act = ACTIVATIONS[activation]
    h = act(x @ p["w_gate"], x @ p["w_up"])
    return h @ p["w_down"]
