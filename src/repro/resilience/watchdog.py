"""Per-pool watchdog with quarantine + probation (DESIGN.md §16,
stage 3).

The federated loop runs one solver per pool; a sick pool (solver
exceptions, or per-decision walls blowing the timeout) must not stall
the fleet.  The watchdog is a small per-pool state machine:

    healthy --(fail_threshold consecutive failures)--> quarantined
    quarantined --(quarantine_epochs elapsed)--> probation
    probation --(one failure)--> quarantined      (immediately)
    probation --(probation_epochs clean)--> healthy

While quarantined the pool's allocation map is frozen (its events are
still drained so membership stays honest) and its queued jobs are
evacuated to healthy pools by the
:class:`~repro.federation.rebalance.Rebalancer`.  The state machine is
pure bookkeeping — it never touches the loop — so it is trivially
deterministic and unit-testable.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

HEALTHY = "healthy"
QUARANTINED = "quarantined"
PROBATION = "probation"


@dataclass
class WatchdogStats:
    """Fleet-level counters across all pools."""
    failures: int = 0
    timeouts: int = 0
    quarantines: int = 0
    readmissions: int = 0
    epochs_quarantined: int = 0

    def as_dict(self) -> Dict[str, int]:
        return asdict(self)


@dataclass
class _PoolState:
    state: str = HEALTHY
    consecutive_failures: int = 0
    epochs_in_state: int = 0


class PoolWatchdog:
    """Track per-pool health across decision epochs.

    Per epoch the loop calls :meth:`record` once per pool with whether
    the pool's solve failed (raised, or exceeded ``timeout_s`` of
    per-decision solver wall).  :meth:`is_quarantined` gates the pool's
    loop; :meth:`tick` advances quarantine/probation clocks at the end
    of each epoch.
    """

    def __init__(self, *, fail_threshold: int = 3,
                 quarantine_epochs: int = 2,
                 probation_epochs: int = 2,
                 timeout_s: Optional[float] = None) -> None:
        if fail_threshold < 1:
            raise ValueError("fail_threshold must be >= 1")
        self.fail_threshold = int(fail_threshold)
        self.quarantine_epochs = int(quarantine_epochs)
        self.probation_epochs = int(probation_epochs)
        self.timeout_s = timeout_s
        self.stats = WatchdogStats()
        self._pools: Dict[int, _PoolState] = {}

    def _st(self, pool: int) -> _PoolState:
        return self._pools.setdefault(pool, _PoolState())

    # ------------------------------------------------------------------
    def record(self, pool: int, *, failed: bool = False,
               timed_out: bool = False) -> None:
        """Record one epoch's outcome for ``pool``.  ``timed_out`` is a
        failure flavour with its own counter."""
        st = self._st(pool)
        bad = failed or timed_out
        if timed_out:
            self.stats.timeouts += 1
        if bad:
            self.stats.failures += 1
            st.consecutive_failures += 1
            if (st.state == PROBATION or
                    (st.state == HEALTHY and
                     st.consecutive_failures >= self.fail_threshold)):
                st.state = QUARANTINED
                # -1: the end-of-epoch tick for the epoch that *caused*
                # the quarantine brings this to 0, so the pool is then
                # skipped for quarantine_epochs full epochs
                st.epochs_in_state = -1
                self.stats.quarantines += 1
        else:
            st.consecutive_failures = 0

    def tick(self, pool: int) -> None:
        """Advance ``pool``'s state clock by one epoch."""
        st = self._st(pool)
        st.epochs_in_state += 1
        if st.state == QUARANTINED:
            if st.epochs_in_state >= 1:     # a skipped epoch just ended
                self.stats.epochs_quarantined += 1
            if st.epochs_in_state >= self.quarantine_epochs:
                st.state = PROBATION
                st.epochs_in_state = 0
                st.consecutive_failures = 0
        elif st.state == PROBATION:
            if st.epochs_in_state >= self.probation_epochs:
                st.state = HEALTHY
                st.epochs_in_state = 0
                self.stats.readmissions += 1

    # ------------------------------------------------------------------
    def state(self, pool: int) -> str:
        return self._st(pool).state

    def is_quarantined(self, pool: int) -> bool:
        return self._st(pool).state == QUARANTINED

    def over_timeout(self, wall_s: float) -> bool:
        return self.timeout_s is not None and wall_s > self.timeout_s

    def quarantined_pools(self) -> List[int]:
        return sorted(k for k, st in self._pools.items()
                      if st.state == QUARANTINED)

    def as_dict(self) -> Dict[str, object]:
        d = dict(self.stats.as_dict())
        d["states"] = {k: st.state for k, st in sorted(self._pools.items())}
        return d
