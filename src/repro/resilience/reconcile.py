"""Anti-entropy reconciliation (DESIGN.md §16, stage 2).

Hygiene bounds what *arrives* wrong; it cannot recover what never
arrived.  A dropped join loses capacity, and — worse — a dropped leave
leaves *phantom capacity*: the control plane keeps allocating nodes that
are gone, which inflates believed utilization dishonestly.  The
:class:`Reconciler` closes that gap: every ``period_s`` seconds it diffs
the believed membership against a ground-truth oracle (in production the
scheduler's own node database; in the simulator the uncorrupted stream)
and emits one synthetic *repair event* that joins the missing nodes and
removes the extra ones.  Divergence is therefore bounded by one
reconcile period, whatever the corruption pattern.

``sanitize_stream`` composes hygiene + reconciliation into the offline
pipeline used by the chaos harness and benchmarks; ``membership_oracle``
builds the oracle from a clean stream; ``membership_divergence``
integrates |believed Δ truth| over time for the bench metrics.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.events import PoolEvent, apply_events, merge_events
from repro.resilience.hygiene import EventHygiene, HygieneStats


@dataclass
class ReconcileStats:
    """Counters for one reconciliation run."""
    reconciles: int = 0
    repair_events: int = 0
    nodes_added: int = 0
    nodes_removed: int = 0

    def as_dict(self) -> Dict[str, int]:
        return asdict(self)


class Reconciler:
    """Periodic believed-vs-truth diff emitting synthetic repair events.

    ``oracle(t)`` must return the ground-truth live set at time ``t``.
    ``check(believed, now)`` returns a repair :class:`PoolEvent` (or
    ``None``) when a reconcile is due and the believed set diverges;
    repairs carry no ``seq`` (they are born inside the control plane,
    not received from the monitor) and ``pool`` tagging is left to the
    caller.
    """

    def __init__(self, oracle: Callable[[float], Set[int]],
                 period_s: float = 300.0) -> None:
        if period_s <= 0:
            raise ValueError(f"period_s must be positive: {period_s}")
        self.oracle = oracle
        self.period_s = float(period_s)
        self.stats = ReconcileStats()
        self._next_due: Optional[float] = None

    def due(self, now: float) -> bool:
        if self._next_due is None:
            self._next_due = now + self.period_s
            return False
        return now >= self._next_due

    def check(self, believed: Set[int], now: float,
              *, force: bool = False) -> Optional[PoolEvent]:
        """Diff ``believed`` against truth at ``now`` if a reconcile is
        due (or ``force``); returns the repair event or ``None``."""
        if not force and not self.due(now):
            return None
        while self._next_due is not None and self._next_due <= now:
            self._next_due += self.period_s
        self.stats.reconciles += 1
        truth = set(self.oracle(now))
        missing = truth - believed
        extra = believed - truth
        if not missing and not extra:
            return None
        self.stats.repair_events += 1
        self.stats.nodes_added += len(missing)
        self.stats.nodes_removed += len(extra)
        return PoolEvent(time=now, joined=tuple(sorted(missing)),
                         left=tuple(sorted(extra)))


def membership_oracle(events: Sequence[PoolEvent],
                      initial: Set[int] = frozenset()
                      ) -> Callable[[float], Set[int]]:
    """Ground-truth oracle from a clean stream: ``oracle(t)`` is the
    live set after folding every event with ``time <= t``.

    Incremental cursor walk — repeated monotone queries (the common
    case: one query per reconcile period) cost O(events) total; a
    backward query rewinds by replaying from the start.
    """
    clean = merge_events(events)
    state: Set[int] = set(initial)
    cursor = 0

    def oracle(t: float) -> Set[int]:
        nonlocal state, cursor
        if cursor > 0 and clean[cursor - 1].time > t:
            state = set(initial)
            cursor = 0
        while cursor < len(clean) and clean[cursor].time <= t:
            e = clean[cursor]
            state.update(e.joined)
            state.difference_update(e.left)
            state.difference_update(e.failed)
            cursor += 1
        return set(state)

    return oracle


def sanitize_stream(events: Sequence[PoolEvent], *,
                    reorder_window: float = 0.0,
                    oracle: Optional[Callable[[float], Set[int]]] = None,
                    reconcile_period_s: float = 300.0,
                    initial: Set[int] = frozenset(),
                    ) -> Tuple[List[PoolEvent], HygieneStats,
                               ReconcileStats]:
    """Offline hygiene + anti-entropy pipeline over an arrival-ordered
    (possibly corrupted) stream.

    ``events`` must be in *arrival* order — their ``.time`` fields are
    the event times the monitor stamped, which may disagree with
    position when the feed reordered them.  Returns the cleaned,
    time-sorted stream plus both stat blocks.  With no oracle the
    reconcile stage is skipped (hygiene only).  A clean in-order stream
    comes back bit-identical with zero defect counts.
    """
    hyg = EventHygiene(reorder_window=reorder_window, initial=initial)
    rec = (Reconciler(oracle, period_s=reconcile_period_s)
           if oracle is not None else None)
    out: List[PoolEvent] = []
    for ev in events:
        released = hyg.push(ev)
        out.extend(released)
        # reconcile once per arrival, AFTER the released batch: believed
        # reflects every event in the batch, so the check must use the
        # batch's last timestamp — checking mid-batch would diff a
        # future believed state against an earlier truth and emit
        # self-contradictory repairs
        if rec is not None and released:
            repair = rec.check(hyg.believed, released[-1].time)
            if repair is not None:
                out.append(repair)
                hyg.believed.update(repair.joined)
                hyg.believed.difference_update(repair.left)
    tail = hyg.flush()
    out.extend(tail)
    if rec is not None and out:
        repair = rec.check(hyg.believed, out[-1].time, force=True)
        if repair is not None:
            out.append(repair)
            hyg.believed.update(repair.joined)
            hyg.believed.difference_update(repair.left)
    out.sort(key=lambda e: e.time)
    return out, hyg.stats, (rec.stats if rec is not None
                            else ReconcileStats())


def membership_divergence(clean: Sequence[PoolEvent],
                          dirty: Sequence[PoolEvent],
                          *, t_end: Optional[float] = None,
                          initial: Set[int] = frozenset()
                          ) -> Dict[str, float]:
    """Integrate |believed Δ truth| node-seconds between two streams.

    Returns ``divergence_node_s`` (the integral), ``truth_node_s``
    (∫|truth| dt, for normalising), ``divergence_frac`` (their ratio)
    and ``max_lag_s`` (longest contiguous interval with non-empty
    symmetric difference — the worst-case reconcile lag).
    """
    a = merge_events(clean)
    b = merge_events(dirty)
    times = sorted({e.time for e in a} | {e.time for e in b})
    if t_end is None:
        t_end = times[-1] if times else 0.0
    truth: Set[int] = set(initial)
    believed: Set[int] = set(initial)
    ia = ib = 0
    div = truth_int = 0.0
    lag = max_lag = 0.0
    lag_open: Optional[float] = None
    for i, t in enumerate(times):
        while ia < len(a) and a[ia].time <= t:
            truth = apply_events(truth, [a[ia]]); ia += 1
        while ib < len(b) and b[ib].time <= t:
            believed = apply_events(believed, [b[ib]]); ib += 1
        nxt = times[i + 1] if i + 1 < len(times) else t_end
        dt = max(0.0, nxt - t)
        d = len(truth ^ believed)
        div += d * dt
        truth_int += len(truth) * dt
        if d:
            if lag_open is None:
                lag_open = t
        else:
            if lag_open is not None:
                max_lag = max(max_lag, t - lag_open)
                lag_open = None
    if lag_open is not None:
        max_lag = max(max_lag, t_end - lag_open)
    return {
        "divergence_node_s": div,
        "truth_node_s": truth_int,
        "divergence_frac": (div / truth_int) if truth_int > 0 else 0.0,
        "max_lag_s": max_lag,
    }
