# Self-healing control plane (DESIGN.md §16): event-stream hygiene in
# front of the ControlLoop/EventRouter, anti-entropy reconciliation
# against a ground-truth membership oracle, and the per-pool watchdog
# that backs quarantine in the federated loop.
from repro.resilience.hygiene import EventHygiene, HygieneStats
from repro.resilience.reconcile import (
    Reconciler,
    ReconcileStats,
    membership_divergence,
    membership_oracle,
    sanitize_stream,
)
from repro.resilience.watchdog import PoolWatchdog, WatchdogStats

__all__ = [
    "EventHygiene", "HygieneStats",
    "Reconciler", "ReconcileStats", "membership_divergence",
    "membership_oracle", "sanitize_stream",
    "PoolWatchdog", "WatchdogStats",
]
