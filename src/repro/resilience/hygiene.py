"""Event-stream hygiene (DESIGN.md §16, stage 1 of the self-healing
control plane).

The resource monitor's feed is untrusted: events arrive duplicated,
reordered within a bounded window, late beyond that window, or not at
all.  ``EventHygiene`` is a streaming filter placed in front of the
``ControlLoop`` / ``EventRouter`` that turns that feed back into a
clean, time-ordered stream:

1. **Dedup** — events carry a monotone ``seq`` stamp; a seq already
   seen is dropped.
2. **Reorder buffer** — arrivals are held in a buffer sorted by
   ``(time, seq)`` and only released once the watermark
   (max arrival event-time − ``reorder_window``) passes them, so any
   reordering within the window is undone exactly.
3. **Membership filter** — released events are checked against the
   believed live set: a join of an already-live node is a *phantom
   join* (dropped), a leave/fail of an unknown node is an *orphan
   leave* (quarantined; if a matching join never shows up it is
   dropped at ``flush()``).  Both defects are counted and later healed
   by the :class:`~repro.resilience.reconcile.Reconciler`.
4. **Conflict resolution** — contradictory same-``(time, node)``
   actions are resolved last-writer-wins by ``seq`` (the monitor's
   emission order), counted in ``conflicts_resolved``.

A clean, in-order stream passes through **bit-identical**: no event is
modified, reordered, or dropped, which is what keeps the zero-corruption
replay parity tests exact.
"""
from __future__ import annotations

import bisect
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core.events import EventStreamError, PoolEvent


@dataclass
class HygieneStats:
    """Defect counters accumulated by one ``EventHygiene`` instance."""
    events_in: int = 0
    events_out: int = 0
    duplicates_dropped: int = 0
    reordered_fixed: int = 0
    late_dropped: int = 0
    phantom_joins: int = 0
    orphan_leaves: int = 0
    conflicts_resolved: int = 0

    def as_dict(self) -> Dict[str, int]:
        return asdict(self)

    @property
    def defects(self) -> int:
        return (self.duplicates_dropped + self.reordered_fixed +
                self.late_dropped + self.phantom_joins +
                self.orphan_leaves + self.conflicts_resolved)


class EventHygiene:
    """Streaming hygiene filter: ``push`` arrivals in, get released
    clean events back; ``flush`` drains the reorder buffer at the end.

    ``reorder_window`` bounds admissible lateness *in event time*: an
    arrival whose ``time`` is older than the current watermark is
    beyond repair here and is dropped (``late_dropped``) — the
    reconciler heals whatever state divergence that causes.  With
    ``strict=True`` membership defects raise
    :class:`~repro.core.events.EventStreamError` instead of being
    counted, for feeds that are *supposed* to be clean.
    """

    def __init__(self, *, reorder_window: float = 0.0,
                 initial: Set[int] = frozenset(),
                 strict: bool = False) -> None:
        self.reorder_window = float(reorder_window)
        self.strict = bool(strict)
        self.believed: Set[int] = set(initial)
        self.stats = HygieneStats()
        self._seen_seq: Set[int] = set()
        # reorder buffer sorted by (time, seq) — seq ties give the
        # monitor's emission order, making release deterministic
        self._buffer: List[Tuple[float, int, PoolEvent]] = []
        self._watermark = float("-inf")
        self._last_released = float("-inf")
        # leaves/fails of unknown nodes parked until flush: the matching
        # join may still be in flight
        self._quarantined: List[PoolEvent] = []

    # ------------------------------------------------------------------
    def push(self, event: PoolEvent) -> List[PoolEvent]:
        """Ingest one arrival; return the (possibly empty) list of clean
        events this arrival released past the watermark."""
        self.stats.events_in += 1
        seq = event.seq
        if seq is not None:
            if seq in self._seen_seq:
                self.stats.duplicates_dropped += 1
                return []
            self._seen_seq.add(seq)
        if event.time < self._watermark:
            # beyond the admissible-lateness window: unrecoverable here
            self.stats.late_dropped += 1
            return []
        key = (event.time, seq if seq is not None else self.stats.events_in)
        pos = bisect.bisect_right(self._buffer, key,
                                  key=lambda it: (it[0], it[1]))
        if pos < len(self._buffer):
            self.stats.reordered_fixed += 1
        self._buffer.insert(pos, (key[0], key[1], event))
        self._watermark = max(self._watermark,
                              event.time - self.reorder_window)
        return self._release(self._watermark)

    def flush(self) -> List[PoolEvent]:
        """Release everything still buffered (end of stream) and retire
        quarantined orphans that never found their join."""
        out = self._release(float("inf"))
        self.stats.orphan_leaves += len(self._quarantined)
        self._quarantined.clear()
        return out

    # ------------------------------------------------------------------
    def _release(self, upto: float) -> List[PoolEvent]:
        released: List[PoolEvent] = []
        n = 0
        while n < len(self._buffer) and self._buffer[n][0] <= upto:
            n += 1
        if n == 0:
            return released
        batch, self._buffer = self._buffer[:n], self._buffer[n:]
        # conflict resolution: contradictory same-(time, node) actions
        # are last-writer-wins by seq — batch is already (time, seq)
        # sorted, so a later write simply overwrites an earlier one
        i = 0
        while i < len(batch):
            j = i
            while j < len(batch) and batch[j][0] == batch[i][0]:
                j += 1
            group = [ev for _, _, ev in batch[i:j]]
            merged = self._resolve_conflicts(group)
            for ev in merged:
                clean = self._membership_filter(ev)
                if clean is not None:
                    released.append(clean)
            i = j
        if released:
            self._last_released = released[-1].time
        self.stats.events_out += len(released)
        return released

    def _resolve_conflicts(self, group: List[PoolEvent]) -> List[PoolEvent]:
        """Within one timestamp, detect nodes acted on contradictorily
        and keep only the last action per node (by seq order).  Events
        without contradictions pass through untouched so a clean stream
        is not rewritten."""
        if len(group) == 1:
            return group
        action: Dict[int, Tuple[int, str]] = {}   # node -> (idx, kind)
        conflict = False
        for idx, ev in enumerate(group):
            for kind in ("joined", "left", "failed"):
                for n in getattr(ev, kind):
                    prev = action.get(n)
                    if prev is not None and prev[1] != kind:
                        conflict = True
                        self.stats.conflicts_resolved += 1
                    action[n] = (idx, kind)
        if not conflict:
            return group
        out: List[PoolEvent] = []
        for idx, ev in enumerate(group):
            joined = tuple(n for n in ev.joined
                           if action[n] == (idx, "joined"))
            left = tuple(n for n in ev.left if action[n] == (idx, "left"))
            failed = tuple(n for n in ev.failed
                           if action[n] == (idx, "failed"))
            if joined or left or failed:
                out.append(PoolEvent(time=ev.time, joined=joined,
                                     left=left, failed=failed,
                                     pool=ev.pool, seq=ev.seq))
        return out

    def _membership_filter(self, ev: PoolEvent) -> Optional[PoolEvent]:
        """Drop phantom joins, quarantine orphan leaves, update the
        believed set.  Returns the event to emit (possibly trimmed), or
        ``None`` if nothing in it survived."""
        phantom = tuple(n for n in ev.joined if n in self.believed)
        orphan_l = tuple(n for n in ev.left if n not in self.believed)
        orphan_f = tuple(n for n in ev.failed if n not in self.believed)
        if not phantom and not orphan_l and not orphan_f:
            self.believed.update(ev.joined)
            self.believed.difference_update(ev.left)
            self.believed.difference_update(ev.failed)
            return ev
        if self.strict:
            n = (phantom + orphan_l + orphan_f)[0]
            kind = ("join" if phantom else "leave/fail")
            raise EventStreamError(
                f"t={ev.time}: inadmissible {kind} of node {n}")
        self.stats.phantom_joins += len(phantom)
        if orphan_l or orphan_f:
            # parked, not counted yet: the matching join may still be in
            # flight — flush() counts whatever never found one
            self._quarantined.append(PoolEvent(
                time=ev.time, left=orphan_l, failed=orphan_f,
                pool=ev.pool, seq=ev.seq))
        joined = tuple(n for n in ev.joined if n not in phantom)
        left = tuple(n for n in ev.left if n not in orphan_l)
        failed = tuple(n for n in ev.failed if n not in orphan_f)
        self.believed.update(joined)
        self.believed.difference_update(left)
        self.believed.difference_update(failed)
        if not (joined or left or failed):
            return None
        return PoolEvent(time=ev.time, joined=joined, left=left,
                         failed=failed, pool=ev.pool, seq=ev.seq)
