from repro.core.backend import LiveBackend
from repro.elastic.runtime import BFTrainerRuntime, ManagedTrainer, RuntimeReport
from repro.elastic.trainer import ElasticTrainer, TrainMetrics

__all__ = ["BFTrainerRuntime", "LiveBackend", "ManagedTrainer",
           "RuntimeReport", "ElasticTrainer", "TrainMetrics"]
