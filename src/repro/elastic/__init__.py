from repro.elastic.runtime import BFTrainerRuntime, ManagedTrainer, RuntimeReport
from repro.elastic.trainer import ElasticTrainer, TrainMetrics

__all__ = ["BFTrainerRuntime", "ManagedTrainer", "RuntimeReport",
           "ElasticTrainer", "TrainMetrics"]
