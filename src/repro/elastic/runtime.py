"""BFTrainerRuntime: the full system — real ElasticTrainers driven by the
MILP allocator over a replayed idle-node trace.

This is the deployable composition, now a thin facade over the shared
``ControlLoop`` with the ``LiveBackend`` (DESIGN.md §9): the *same*
policy engine that powers the trace-driven ``Simulator`` — FCFS admission
up to ``pj_max``, event coalescing, preemption handling, rescale-stall
accounting, adaptive ``t_fwd`` — executes each decision against live JAX
Trainers (rescale + train steps).  Trace time is scaled by ``time_scale``
so a week-long trace can be exercised in seconds of wall time while still
performing real training steps at each interval.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

from repro.core.allocator import Allocator, MILPAllocator
from repro.core.backend import LiveBackend
from repro.core.events import PoolEvent
from repro.core.loop import ControlLoop, LoopStats
from repro.core.scaling import ScalingCurve
from repro.elastic.trainer import ElasticTrainer


@dataclass
class ManagedTrainer:
    """One live Trainer under BFTrainer management.

    ``weight`` (dimensionless), ``deadline`` (absolute trace-clock
    seconds) and ``budget`` (node-seconds) are per-job policy fields
    read by the matching objectives (``repro.core.objectives``); they are
    inert under the default throughput policy.
    """

    id: int
    trainer: ElasticTrainer
    curve: ScalingCurve
    n_min: int = 1
    n_max: int = 8
    steps_done: int = 0
    samples_done: int = 0
    target_steps: Optional[int] = None
    weight: float = 1.0
    deadline: Optional[float] = None
    budget: Optional[float] = None

    @property
    def finished(self) -> bool:
        return (self.target_steps is not None
                and self.steps_done >= self.target_steps)


@dataclass
class RuntimeReport:
    steps: Dict[int, int]
    samples: Dict[int, int]
    losses: Dict[int, List[float]]
    rescales: Dict[int, int]
    events: int
    wall_time_s: float
    solver_wall_s: float
    # the shared policy-side report core (same shape the Simulator returns)
    stats: Optional[LoopStats] = None


class BFTrainerRuntime:
    def __init__(self, managed: Sequence[ManagedTrainer],
                 allocator: Optional[Allocator] = None, *,
                 t_fwd: Union[float, str] = 120.0,
                 steps_per_second: float = 1.0,
                 metric: str = "throughput", pj_max: int = 10,
                 coalesce_window: float = 0.0, sos2_points: int = 8,
                 objective=None, telemetry=None):
        self.managed = list(managed)
        self.allocator = allocator or MILPAllocator("fast")
        self.t_fwd = t_fwd
        self.steps_per_second = steps_per_second
        self.metric = metric
        self.pj_max = pj_max
        self.coalesce_window = coalesce_window
        self.sos2_points = sos2_points
        # allocation policy (repro.core.objectives); None = throughput
        self.objective = objective
        # observation sink (repro.obs); None = disabled
        self.telemetry = telemetry

    def run(self, events: Sequence[PoolEvent], *, time_scale: float = 1.0,
            max_steps_per_interval: int = 4,
            horizon: Optional[float] = None,
            measure_rescale_costs: bool = True) -> RuntimeReport:
        t0 = time.perf_counter()
        backend = LiveBackend(
            self.managed, time_scale=time_scale,
            steps_per_second=self.steps_per_second,
            max_steps_per_interval=max_steps_per_interval,
            metric=self.metric,
            measure_rescale_costs=measure_rescale_costs)
        loop = ControlLoop(events, backend.jobs(), self.allocator, backend,
                           t_fwd=self.t_fwd, pj_max=self.pj_max,
                           horizon=horizon, sos2_points=self.sos2_points,
                           coalesce_window=self.coalesce_window,
                           objective=self.objective,
                           telemetry=self.telemetry)
        stats = loop.run()
        return RuntimeReport(
            steps={m.id: m.steps_done for m in self.managed},
            samples={m.id: m.samples_done for m in self.managed},
            losses=backend.losses,
            rescales={m.id: len(m.trainer.rescale_history)
                      for m in self.managed},
            events=stats.events_processed,
            wall_time_s=time.perf_counter() - t0,
            solver_wall_s=stats.solver_wall_total,
            stats=stats)
