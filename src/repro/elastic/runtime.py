"""BFTrainerRuntime: the full system — real ElasticTrainers driven by the
MILP allocator over a replayed idle-node trace.

This is the deployable composition: the discrete-event layer decides *who
gets which nodes when* (paper §3), and each decision is executed against
live JAX Trainers (rescale + train steps).  Trace time is scaled by
``time_scale`` so a week-long trace can be exercised in seconds of wall
time while still performing real training steps at each interval.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax

from repro.core.allocator import Allocator, MILPAllocator
from repro.core.events import PoolEvent
from repro.core.milp import AllocationProblem, TrainerSpec
from repro.core.scaling import ScalingCurve
from repro.elastic.trainer import ElasticTrainer


@dataclass
class ManagedTrainer:
    id: int
    trainer: ElasticTrainer
    curve: ScalingCurve
    n_min: int = 1
    n_max: int = 8
    steps_done: int = 0
    samples_done: int = 0
    target_steps: Optional[int] = None

    def spec(self, metric: str = "throughput") -> TrainerSpec:
        r_up, r_dw = self.trainer.measured_rescale_costs()
        pts, vals = self.curve.breakpoints(self.n_min, self.n_max,
                                           metric=metric)
        return TrainerSpec(id=self.id, n_min=self.n_min, n_max=self.n_max,
                           r_up=r_up, r_dw=r_dw, points=tuple(pts),
                           values=tuple(vals))

    @property
    def finished(self) -> bool:
        return (self.target_steps is not None
                and self.steps_done >= self.target_steps)


@dataclass
class RuntimeReport:
    steps: Dict[int, int]
    samples: Dict[int, int]
    losses: Dict[int, List[float]]
    rescales: Dict[int, int]
    events: int
    wall_time_s: float
    solver_wall_s: float


class BFTrainerRuntime:
    def __init__(self, managed: Sequence[ManagedTrainer],
                 allocator: Optional[Allocator] = None, *,
                 t_fwd: float = 120.0, steps_per_second: float = 1.0,
                 metric: str = "throughput"):
        self.managed = list(managed)
        self.allocator = allocator or MILPAllocator("fast")
        self.t_fwd = t_fwd
        self.steps_per_second = steps_per_second
        self.metric = metric

    def run(self, events: Sequence[PoolEvent], *, time_scale: float = 1.0,
            max_steps_per_interval: int = 4) -> RuntimeReport:
        t0 = time.perf_counter()
        pool: set[int] = set()
        current: Dict[int, List[int]] = {m.id: [] for m in self.managed}
        losses: Dict[int, List[float]] = {m.id: [] for m in self.managed}
        solver_wall = 0.0
        n_events = 0

        events = sorted(events, key=lambda e: e.time)
        for k, ev in enumerate(events):
            pool |= set(ev.joined)
            pool -= set(ev.left)
            active = [m for m in self.managed if not m.finished]
            if not active:
                break
            for m in active:   # preempt lost nodes
                current[m.id] = [n for n in current[m.id] if n in pool]

            prob = AllocationProblem(
                nodes=sorted(pool),
                trainers=[m.spec(self.metric) for m in active],
                current={m.id: current[m.id] for m in active},
                t_fwd=self.t_fwd)
            res = self.allocator.allocate(prob)
            solver_wall += res.wall_time
            n_events += 1

            for m in active:
                new_nodes = res.allocation.get(m.id, [])
                current[m.id] = list(new_nodes)
                if len(new_nodes) != m.trainer.n_nodes:
                    m.trainer.rescale(len(new_nodes))

            # real training during the interval (scaled time)
            dt = (events[k + 1].time - ev.time) if k + 1 < len(events) else 0.0
            n_steps = min(max_steps_per_interval,
                          max(0, int(dt * time_scale * self.steps_per_second)))
            for m in active:
                if m.trainer.n_nodes > 0:
                    for _ in range(n_steps):
                        if m.finished:
                            break
                        met = m.trainer.train_step()
                        m.steps_done += 1
                        m.samples_done += met.samples
                        losses[m.id].append(met.loss)

        return RuntimeReport(
            steps={m.id: m.steps_done for m in self.managed},
            samples={m.id: m.samples_done for m in self.managed},
            losses=losses,
            rescales={m.id: len(m.trainer.rescale_history)
                      for m in self.managed},
            events=n_events,
            wall_time_s=time.perf_counter() - t0,
            solver_wall_s=solver_wall)
