"""ElasticTrainer: JAX-native elastic data-parallel training.

The JAX analogue of Elastic Horovod (paper §4.3): a Trainer can be
rescaled to any node count in [n_min, n_max] at runtime.  Rescale =
host-snapshot params/optimizer state → build a mesh over the new node set
→ re-shard (device_put with new NamedShardings) → re-jit the train step.
No durable-storage round trip.  The measured rescale wall time is exposed
so the MILP can be driven by real ``R^up/R^dw`` values.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager, Snapshot
from repro.data import DataConfig, TokenPipeline
from repro.models import Model
from repro.optim import AdamW, AdamWState, linear_scaling, warmup_cosine

Pytree = Any


@dataclass
class TrainMetrics:
    step: int
    n_nodes: int
    loss: float
    samples: int
    step_time_s: float


class ElasticTrainer:
    """One Trainer: a model + optimizer + data pipeline that can run at any
    node count (devices_per_node devices each) and be rescaled cheaply."""

    def __init__(self, model: Model, *, optimizer: Optional[AdamW] = None,
                 per_node_batch: int = 8, devices_per_node: int = 1,
                 base_lr_nodes: int = 1, seed: int = 0,
                 warmup_steps: int = 20, total_steps: int = 10_000):
        self.model = model
        self.optimizer = optimizer or AdamW()
        self.per_node_batch = per_node_batch
        self.devices_per_node = devices_per_node
        self.base_lr_nodes = base_lr_nodes
        self.warmup_steps = warmup_steps
        self.total_steps = total_steps
        self.pipeline = TokenPipeline(DataConfig(
            vocab_size=model.cfg.vocab_size, seq_len=256,
            per_node_batch=per_node_batch, seed=seed))

        self.params = model.init(jax.random.key(seed))
        self.opt_state = self.optimizer.init(self.params)
        self.step_count = 0
        self.n_nodes = 0
        self.mesh: Optional[Mesh] = None
        self._jitted: Dict[int, Callable] = {}
        self.last_rescale_s = 0.0
        self.rescale_history: list[tuple[int, int, float]] = []

    # ------------------------------------------------------------------

    def seq_len(self, seq_len: int) -> None:
        self.pipeline.cfg.seq_len = seq_len

    def _train_step(self, params: Pytree, opt_state: AdamWState,
                    batch: Dict[str, jax.Array], lr_scale: jax.Array):
        def loss_fn(p):
            return self.model.loss(p, batch)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        sched = warmup_cosine(opt_state.step, warmup_steps=self.warmup_steps,
                              total_steps=self.total_steps)
        new_params, new_opt = self.optimizer.update(
            grads, opt_state, params, lr_scale=lr_scale * sched)
        return new_params, new_opt, loss

    def _build(self, n_nodes: int):
        n_dev = n_nodes * self.devices_per_node
        devices = jax.devices()[:n_dev]
        mesh = Mesh(np.asarray(devices).reshape(n_dev), ("data",))
        repl = NamedSharding(mesh, P())
        batch_sh = NamedSharding(mesh, P("data"))
        fn = jax.jit(
            self._train_step,
            in_shardings=(jax.tree.map(lambda _: repl, self.params),
                          jax.tree.map(lambda _: repl, self.opt_state),
                          {"tokens": batch_sh, "labels": batch_sh}, repl),
            out_shardings=(jax.tree.map(lambda _: repl, self.params),
                           jax.tree.map(lambda _: repl, self.opt_state),
                           repl),
        )
        return mesh, fn

    # ------------------------------------------------------------------

    def rescale(self, n_nodes: int) -> float:
        """Rescale to ``n_nodes`` (0 = waiting).  Returns wall seconds."""
        t0 = time.perf_counter()
        old = self.n_nodes
        if n_nodes == old:
            return 0.0
        if n_nodes == 0:
            # hold state on host; release device mesh
            self.params = Snapshot.take(self.params, self.step_count).tree
            self.opt_state = Snapshot.take(self.opt_state,
                                           self.step_count).tree
            self.mesh = None
            self.n_nodes = 0
            dt = time.perf_counter() - t0
            self.rescale_history.append((old, 0, dt))
            return dt
        n_dev = n_nodes * self.devices_per_node
        if n_dev > len(jax.devices()):
            raise ValueError(
                f"rescale to {n_nodes} nodes needs {n_dev} devices, "
                f"only {len(jax.devices())} available")
        if n_nodes not in self._jitted:
            self.mesh, fn = self._build(n_nodes)
            self._jitted[n_nodes] = (self.mesh, fn)
        self.mesh, _ = self._jitted[n_nodes]
        repl = NamedSharding(self.mesh, P())
        self.params = jax.tree.map(lambda x: jax.device_put(x, repl),
                                   self.params)
        self.opt_state = jax.tree.map(lambda x: jax.device_put(x, repl),
                                      self.opt_state)
        self.n_nodes = n_nodes
        dt = time.perf_counter() - t0
        self.last_rescale_s = dt
        self.rescale_history.append((old, n_nodes, dt))
        return dt

    def train_step(self) -> TrainMetrics:
        assert self.n_nodes > 0, "Trainer is waiting (0 nodes)"
        mesh, fn = self._jitted[self.n_nodes]
        batch_np = self.pipeline.next_batch(self.n_nodes)
        batch_sh = NamedSharding(mesh, P("data"))
        batch = {k: jax.device_put(v, batch_sh) for k, v in batch_np.items()}
        lr_scale = jnp.float32(linear_scaling(self.n_nodes,
                                              self.base_lr_nodes))
        t0 = time.perf_counter()
        self.params, self.opt_state, loss = fn(
            self.params, self.opt_state, batch, lr_scale)
        loss = float(loss)
        dt = time.perf_counter() - t0
        self.step_count += 1
        return TrainMetrics(step=self.step_count, n_nodes=self.n_nodes,
                            loss=loss,
                            samples=batch_np["tokens"].shape[0],
                            step_time_s=dt)

    # ------------------------------------------------------------------

    def save_checkpoint(self, manager: CheckpointManager,
                        meta: Optional[Dict] = None) -> str:
        """Write a durable, integrity-checked checkpoint of params +
        optimizer state at the current step.  Returns the npz path."""
        tree = {
            "params": Snapshot.take(self.params).tree,
            "opt_state": Snapshot.take(self.opt_state).tree,
        }
        return manager.save(tree, step=self.step_count, meta=meta)

    def restore_checkpoint(self, manager: CheckpointManager) -> int:
        """Restore from the newest checkpoint that passes verification.

        A corrupt latest checkpoint silently falls back to the previous
        good one (``CheckpointManager.load_latest_good``) — the trainer
        resumes from an older step rather than failing, which is the
        restore-from-last-good semantics the chaos fault model assumes
        (``ChaosBackend.on_fail``).  Returns the restored step count;
        raises ``CorruptCheckpointError`` if no checkpoint survives."""
        like = {"params": self.params, "opt_state": self.opt_state}
        tree, meta, step = manager.load_latest_good(like)
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        if self.n_nodes > 0:
            # re-shard the restored host arrays onto the live mesh
            repl = NamedSharding(self.mesh, P())
            self.params = jax.tree.map(
                lambda x: jax.device_put(x, repl), self.params)
            self.opt_state = jax.tree.map(
                lambda x: jax.device_put(x, repl), self.opt_state)
        self.step_count = int(meta.get("step", step))
        return self.step_count

    # ------------------------------------------------------------------

    def measured_rescale_costs(self) -> tuple[float, float]:
        """(r_up, r_dw) estimates from observed rescales.

        A transition to 0 nodes is a *kill/park* (state snapshots to
        host and the device mesh is released), not a scale-down of a
        running mesh — its wall time is dominated by the host transfer
        and would contaminate the ``r_dw`` fed back into the MILP's
        Eqn-16 cost term, so it is excluded from the estimate.
        """
        ups = [dt for a, b, dt in self.rescale_history if b > a > 0]
        dws = [dt for a, b, dt in self.rescale_history if 0 < b < a]
        r_up = float(np.mean(ups)) if ups else 0.5
        r_dw = float(np.mean(dws)) if dws else 0.1
        return r_up, r_dw
