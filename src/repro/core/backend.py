"""Execution backends for the ControlLoop (DESIGN.md §9).

The ``ControlLoop`` decides *who gets which nodes when*; an
``ExecutionBackend`` is where Trainer progress actually happens between
decisions.  Two substrates implement the protocol:

* ``AnalyticBackend`` — trace-driven simulation: progress is the integral
  of the Trainer's scaling curve over the interval (minus rescale stalls),
  and completion times are predicted analytically so the loop can cut an
  interval at the exact finish instant.
* ``LiveBackend`` — the deployable path: every allocation decision is
  executed against real ``ElasticTrainer``s (``rescale()`` +
  ``train_step()``), with trace time mapped to a per-interval step budget
  via ``time_scale`` and measured rescale costs fed back into the MILP.

The loop owns all cost *accounting* (stalls, rescale/preemption costs,
records); backends only execute.  Keeping both behind one protocol is
what makes the live path policy-complete: FCFS admission, ``pj_max``,
coalescing and preemption-stall bookkeeping apply identically.
"""
from __future__ import annotations

import math
import time
from typing import Dict, List, Optional, Sequence

from repro.core.loop import TrainerJob
from repro.obs.telemetry import NULL_TELEMETRY


class ExecutionBackend:
    """Protocol (as an overridable base) between ControlLoop and the
    substrate that executes its decisions.  All hooks receive the
    ``TrainerJob`` whose policy state (``nodes``, ``busy_until``,
    ``done``/``work``) the loop maintains."""

    name = "base"
    #: observation sink (repro.obs); ``ControlLoop.run`` hands its own
    #: hub to a backend still carrying the null default, so substrate
    #: spans (live rescale walls, chaos faults) share the loop's trace
    telemetry = NULL_TELEMETRY

    def bind(self, jobs: Sequence[TrainerJob]) -> None:
        """Called once at loop start with the full (sorted) job list."""

    def refresh(self, job: TrainerJob, now: float) -> None:
        """Update job parameters (e.g. measured r_up/r_dw) before a solve."""

    def apply_allocation(self, job: TrainerJob, old_n: int,
                         now: float) -> None:
        """Execute the allocator's decision; ``job.nodes`` is already the
        new assignment, ``old_n`` the previous node count."""

    def on_preempt(self, job: TrainerJob, taken: List[int],
                   now: float) -> None:
        """Nodes ``taken`` left the pool mid-run; ``job.nodes`` is already
        the surviving set."""

    def on_fail(self, job: TrainerJob, failed: List[int],
                now: float) -> Optional[float]:
        """Nodes ``failed`` were hard-killed mid-run (DESIGN.md §12).

        Returns the progress value to restore ``job.done`` to — the last
        durable checkpoint on the ``ckpt_every`` lattice by default — or
        ``None`` to keep progress (continuous checkpointing).  The loop
        owns the rollback bookkeeping (``lost_progress``, restart-penalty
        stall); substrates override this to consult real checkpoint
        state (LiveBackend) or to inject corrupt-restore faults
        (``repro.chaos.ChaosBackend``)."""
        if not (math.isfinite(job.ckpt_every) and job.ckpt_every > 0):
            return None
        return job.last_checkpoint()

    def eta(self, job: TrainerJob, now: float,
            horizon: float) -> Optional[float]:
        """Predicted completion time (absolute trace-clock seconds)
        under the current allocation, or ``None`` if unknown (the loop
        then integrates to the horizon)."""
        return None

    def advance(self, job: TrainerJob, start: float, end: float) -> float:
        """Execute/integrate progress over ``[start, end)`` (trace-clock
        seconds); returns progress units processed (samples analytic,
        samples-per-real-step live).  Must respect ``job.busy_until``
        (rescale stall) and update ``job.done``."""
        return 0.0

    def on_finish(self, job: TrainerJob, now: float) -> None:
        """``job.done`` reached ``job.work``; release execution resources."""


class AnalyticBackend(ExecutionBackend):
    """Scaling-curve integration — the simulation substrate (paper §4)."""

    name = "analytic"

    def eta(self, job: TrainerJob, now: float,
            horizon: float) -> Optional[float]:
        thr = job.throughput()
        if thr <= 0:
            return None
        start = max(now, job.busy_until)
        return start + (job.work - job.done) / thr

    def advance(self, job: TrainerJob, start: float, end: float) -> float:
        thr = job.throughput()
        t0 = max(start, min(job.busy_until, end))
        delta = max(0.0, end - t0) * thr
        delta = min(delta, job.work - job.done)   # clamp at completion
        job.done += delta
        return delta


class ServingBackend(AnalyticBackend):
    """Analytic substrate plus request-level serving (DESIGN.md §15).

    Jobs carrying a ``replica`` (``repro.serving.ServingJob`` — duck-
    typed so core/ never imports the serving package) advance through
    their :class:`~repro.serving.replica.ReplicaSet` discrete-event
    simulation instead of the scaling-curve integral: ``advance``
    ingests arrivals, batches queued requests at the capacity the
    current allocation provides, and returns requests served; ``done``
    counts served requests.  Training jobs in the same loop fall through
    to :class:`AnalyticBackend` untouched, so a mixed pool — and, in
    particular, a pool with *zero* serving jobs — behaves bit-identically
    to the analytic path (the zero-serving parity test pins this down).

    Per decision, ``refresh`` re-estimates the job's offered request
    rate over its forward ``rate_window`` and publishes it via
    ``job.rate`` — the demand signal ``LatencySLO`` provisions for.
    Drain semantics live in the replica: a graceful shrink/preemption
    never discards the in-flight batch; a hard failure (``on_fail``)
    drops exactly that batch and nothing else.
    """

    name = "serving"

    @staticmethod
    def _replica(job: TrainerJob):
        return getattr(job, "replica", None)

    def bind(self, jobs: Sequence[TrainerJob]) -> None:
        for job in jobs:
            ensure = getattr(job, "ensure_replica", None)
            if callable(ensure):
                ensure()
            rep = self._replica(job)
            if rep is not None:
                rep.telemetry = self.telemetry

    def refresh(self, job: TrainerJob, now: float) -> None:
        rep = self._replica(job)
        if rep is None:
            return super().refresh(job, now)
        window = float(getattr(job, "rate_window", 120.0))
        job.rate = rep.offered_rate(now, now + window)

    def eta(self, job: TrainerJob, now: float,
            horizon: float) -> Optional[float]:
        if self._replica(job) is not None:
            return None                  # a service never finishes
        return super().eta(job, now, horizon)

    def advance(self, job: TrainerJob, start: float, end: float) -> float:
        rep = self._replica(job)
        if rep is None:
            return super().advance(job, start, end)
        served = rep.run(start, end, rate=job.throughput(),
                         n_nodes=len(job.nodes),
                         busy_until=job.busy_until)
        job.done += float(served)
        return float(served)

    def on_fail(self, job: TrainerJob, failed: List[int],
                now: float) -> Optional[float]:
        rep = self._replica(job)
        if rep is None:
            return super().on_fail(job, failed, now)
        rep.drop_inflight(now)
        return None                      # served requests never roll back


class LiveBackend(ExecutionBackend):
    """Real elastic training — the deployable substrate (paper §4.3).

    Wraps ``ManagedTrainer``-like objects (duck-typed: ``id``, ``curve``,
    ``n_min``/``n_max``, ``target_steps``, ``steps_done``, ``samples_done``
    and a ``trainer`` with ``rescale``/``train_step``/``n_nodes``/
    ``measured_rescale_costs``) so core/ carries no JAX import.

    Trace time maps to execution via ``time_scale``: an interval of ``dt``
    trace seconds grants ``min(max_steps_per_interval,
    int(dt · time_scale · steps_per_second))`` real train steps, after
    deducting any rescale-stall overlap (``job.busy_until``, trace
    seconds).  ``job.work``/``job.done`` are counted in *steps* here
    (``target_steps``); per-interval outcome is real samples processed.
    """

    name = "live"

    def __init__(self, managed: Sequence, *, time_scale: float = 1.0,
                 steps_per_second: float = 1.0,
                 max_steps_per_interval: int = 4,
                 metric: str = "throughput",
                 measure_rescale_costs: bool = True):
        self.managed = {m.id: m for m in managed}
        self.time_scale = time_scale
        self.steps_per_second = steps_per_second
        self.max_steps_per_interval = max_steps_per_interval
        self.metric = metric
        # off → specs keep their initial r_up/r_dw (deterministic problem
        # sequences, e.g. for backend-parity tests)
        self.measure_rescale_costs = measure_rescale_costs
        self.losses: Dict[int, List[float]] = {m.id: [] for m in managed}

    def jobs(self) -> List[TrainerJob]:
        """TrainerJobs mirroring the managed trainers, for the loop.

        Per-job policy fields (``weight``/``deadline``/``budget`` — see
        ``repro.core.objectives``) are carried over when the managed
        object declares them (duck-typed, defaults otherwise)."""
        out = []
        for m in self.managed.values():
            r_up, r_dw = m.trainer.measured_rescale_costs()
            job = TrainerJob(
                id=m.id, curve=m.curve,
                work=(float(m.target_steps) if m.target_steps is not None
                      else math.inf),
                n_min=m.n_min, n_max=m.n_max, r_up=r_up, r_dw=r_dw,
                metric=self.metric,
                weight=float(getattr(m, "weight", 1.0)),
                deadline=getattr(m, "deadline", None),
                budget=getattr(m, "budget", None))
            job.done = float(m.steps_done)
            out.append(job)
        return out

    def refresh(self, job: TrainerJob, now: float) -> None:
        if self.measure_rescale_costs:
            job.r_up, job.r_dw = \
                self.managed[job.id].trainer.measured_rescale_costs()

    def _sync(self, job: TrainerJob, now: float = 0.0) -> None:
        tr = self.managed[job.id].trainer
        if tr.n_nodes != len(job.nodes):
            old = tr.n_nodes
            t0 = time.perf_counter()
            tr.rescale(len(job.nodes))
            tel = self.telemetry
            if tel:
                # measured physical rescale duration — the live-path
                # analogue of the analytic r_up/r_dw model costs
                wall = time.perf_counter() - t0
                tel.observe("backend.rescale_ms", wall * 1e3)
                tel.instant("backend", "rescale", now, job=job.id,
                            old=old, new=len(job.nodes), wall_s=wall)

    def apply_allocation(self, job: TrainerJob, old_n: int,
                         now: float) -> None:
        self._sync(job, now)

    def on_preempt(self, job: TrainerJob, taken: List[int],
                   now: float) -> None:
        # departed nodes are gone now — shrink (or park) immediately, even
        # if the re-allocation itself is coalesced
        self._sync(job, now)

    def on_fail(self, job: TrainerJob, failed: List[int],
                now: float) -> Optional[float]:
        """Hard kill on the live path: roll the managed trainer's step
        counter back to the last checkpoint-lattice step so execution
        and policy state agree.  If the managed object exposes a
        ``restore_to_step(step)`` hook (e.g. backed by a
        ``repro.checkpoint.CheckpointManager``), it is invoked so model/
        optimizer state really rewinds; otherwise only the counters do
        (the toy trainers are stateless enough for replay purposes)."""
        restored = super().on_fail(job, failed, now)
        if restored is None:
            return None
        m = self.managed[job.id]
        step = int(restored)
        hook = getattr(m, "restore_to_step", None)
        if callable(hook):
            step = int(hook(step))
        m.steps_done = min(m.steps_done, step)
        return float(m.steps_done)

    def advance(self, job: TrainerJob, start: float, end: float) -> float:
        m = self.managed[job.id]
        if m.trainer.n_nodes <= 0:
            return 0.0
        t0 = max(start, min(job.busy_until, end))
        dt = max(0.0, end - t0)
        n_steps = min(self.max_steps_per_interval,
                      max(0, int(dt * self.time_scale
                                 * self.steps_per_second)))
        samples = 0
        for _ in range(n_steps):
            if job.done >= job.work:
                break
            met = m.trainer.train_step()
            m.steps_done += 1
            m.samples_done += met.samples
            samples += met.samples
            self.losses[m.id].append(met.loss)
            job.done = float(m.steps_done)
        return float(samples)

    def on_finish(self, job: TrainerJob, now: float) -> None:
        m = self.managed[job.id]
        if m.trainer.n_nodes > 0:
            job.nodes = []
            self._sync(job, now)      # park: snapshot to host, free devices
        job.nodes = []
