"""Idle-node traces: Summit-calibrated synthetic generator + CSV loader.

Real Summit LSF logs are not redistributable, so the generator is
calibrated to the paper's published statistics (§2.1, Tab. 1, Fig. 1):

* ~9% of node×time idle and unfillable (paper: 8.6% over two weeks,
  ~11% ratio in Tab. 1);
* ~58% of fragments shorter than 10 minutes;
* those short fragments carry only ~10% of idle node×time.

``trace_stats`` recomputes these quantities; tests assert the calibration.
A loader for real ``node,start,end`` CSV logs is provided for deployments
with access to scheduler logs.
"""
from __future__ import annotations

import csv
import gzip
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.events import (
    Fragment,
    PoolEvent,
    fragments_to_events,
    validate_fragments,
)

# Mixture calibration (seconds).  Short fragments: median ~3 min; long:
# median ~1.4 h.  Busy periods tuned for ~9% idle fraction.
SHORT_W = 0.58
SHORT_MU, SHORT_SIGMA = math.log(180.0), 0.9
LONG_MU, LONG_SIGMA = math.log(5000.0), 0.8
BUSY_MU, BUSY_SIGMA = math.log(24000.0), 0.7


def generate_summit_like(n_nodes: int = 1024, duration: float = 7 * 86400.0,
                         seed: int = 0) -> List[Fragment]:
    """Per-node alternating busy/idle renewal process."""
    rng = np.random.default_rng(seed)
    fragments: List[Fragment] = []
    for node in range(n_nodes):
        # random initial phase: start mid-busy
        t = -float(rng.uniform(0, math.exp(BUSY_MU)))
        while t < duration:
            busy = float(rng.lognormal(BUSY_MU, BUSY_SIGMA))
            t += busy
            if t >= duration:
                break
            if rng.uniform() < SHORT_W:
                idle = float(rng.lognormal(SHORT_MU, SHORT_SIGMA))
            else:
                idle = float(rng.lognormal(LONG_MU, LONG_SIGMA))
            start = max(t, 0.0)
            end = min(t + idle, duration)
            if end > start:
                fragments.append(Fragment(node=node, start=start, end=end))
            t += idle
    fragments.sort(key=lambda f: (f.start, f.node))
    return fragments


def open_maybe_gz(path, mode: str = "rt"):
    """Open a text file, transparently gunzipping ``.gz`` paths."""
    p = str(path)
    return gzip.open(p, mode) if p.endswith(".gz") else open(p, mode)


def load_trace_csv(path: str, *, validate: bool = True) -> List[Fragment]:
    """Load fragments from a ``node,start,end`` CSV (real scheduler logs).

    Accepts plain or gzipped (``.gz``) files.  Each row is validated —
    integer non-negative node id, ``end > start`` — and malformed rows
    raise ``ValueError`` naming the offending line, rather than silently
    corrupting the pool replay downstream.  ``validate=True`` additionally
    rejects overlapping per-node fragments.
    """
    out = []
    with open_maybe_gz(path) as f:
        reader = csv.DictReader(f)
        missing = {"node", "start", "end"} - set(reader.fieldnames or ())
        if missing:
            raise ValueError(
                f"{path}: missing column(s) {sorted(missing)} "
                f"(header must contain node,start,end)")
        for lineno, row in enumerate(reader, start=2):
            try:
                node = int(row["node"])
                start = float(row["start"])
                end = float(row["end"])
            except (TypeError, ValueError) as exc:
                raise ValueError(
                    f"{path}:{lineno}: malformed row {row}: {exc}") from exc
            if node < 0:
                raise ValueError(
                    f"{path}:{lineno}: negative node id {node}")
            if not end > start:
                raise ValueError(
                    f"{path}:{lineno}: end ({end}) must be > start ({start})")
            out.append(Fragment(node=node, start=start, end=end))
    if validate:
        validate_fragments(out)
    out.sort(key=lambda fr: (fr.start, fr.node))
    return out


@dataclass
class TraceStats:
    n_fragments: int
    n_events: int
    events_per_hour: float
    joins_per_hour: float
    leaves_per_hour: float
    pct_fragments_short: float        # < 10 min, by count
    share_nodetime_short: float       # < 10 min, by node x time
    idle_fraction: float              # of n_nodes x duration
    eq_nodes: float                   # paper Tab. 1 "eq-Nodes"
    mean_pool_size: float


def trace_stats(fragments: Sequence[Fragment], n_nodes: int,
                duration: float) -> TraceStats:
    lengths = np.array([f.length for f in fragments])
    total = lengths.sum()
    short = lengths < 600.0
    events = fragments_to_events(fragments)
    inner = [e for e in events if 0.0 < e.time < duration]
    hours = duration / 3600.0
    return TraceStats(
        n_fragments=len(fragments),
        n_events=len(inner),
        events_per_hour=len(inner) / hours,
        joins_per_hour=sum(1 for e in inner if e.joined) / hours,
        leaves_per_hour=sum(1 for e in inner if e.left) / hours,
        pct_fragments_short=float(short.mean()) if len(lengths) else 0.0,
        share_nodetime_short=float(lengths[short].sum() / total) if total else 0.0,
        idle_fraction=float(total / (n_nodes * duration)),
        eq_nodes=float(total / duration),
        mean_pool_size=float(total / duration),
    )


def clip_fragments(fragments: Sequence[Fragment], t0: float,
                   t1: float) -> List[Fragment]:
    out = []
    for f in fragments:
        s, e = max(f.start, t0), min(f.end, t1)
        if e > s:
            out.append(Fragment(node=f.node, start=s, end=e))
    return out


# Scheduler-derived traces (repro.sched) are re-exported here lazily so
# ``repro.core.trace`` stays the one-stop module for obtaining a trace;
# a top-level import would be circular (sched computes its TraceStats
# through this module).
_SCHED_REEXPORTS = ("SCENARIOS", "build_scenario", "all_scenarios",
                    "run_scenario", "simulate_schedule",
                    "synthetic_workload")


def __getattr__(name):
    if name in _SCHED_REEXPORTS:
        import repro.sched as _sched
        return getattr(_sched, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
