"""Evaluation metrics (paper §4.1): resource integral (Eqn 17), eq-nodes
(Eqn 18), utilization efficiency U = A_e / A_s, ROI (Fig 8), and the
policy-portfolio metrics (DESIGN.md §10): Jain fairness over normalized
progress, the max-min floor, and deadline miss rate."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

from repro.core.events import PoolEvent, pool_sizes


def resource_integral(events: Sequence[PoolEvent], t0: float,
                      t1: float) -> float:
    """Node-hours of idle resource between t0 and t1 (Eqn 17)."""
    sizes = pool_sizes(events)
    total = 0.0
    for i, (t, n) in enumerate(sizes):
        seg_start = max(t, t0)
        seg_end = min(sizes[i + 1][0] if i + 1 < len(sizes) else t1, t1)
        if seg_end > seg_start:
            total += n * (seg_end - seg_start)
    return total / 3600.0


def eq_nodes(events: Sequence[PoolEvent], t0: float, t1: float) -> float:
    """Equivalent static node count delivering the same node-time (Eqn 18)."""
    if t1 <= t0:
        return 0.0
    return resource_integral(events, t0, t1) * 3600.0 / (t1 - t0)


@dataclass
class Efficiency:
    a_e: float          # outcome with BFTrainer (samples)
    a_s: float          # outcome on static eq-nodes (samples)

    @property
    def u(self) -> float:
        return self.a_e / self.a_s if self.a_s > 0 else 0.0


@dataclass
class ROI:
    """Per-event return on rescaling investment (paper Fig 8)."""
    investment: float   # rescale cost, samples
    ret: float          # outcome until next event, samples

    @property
    def value(self) -> float:
        return self.ret / self.investment if self.investment > 0 else float("inf")


# ---------------------------------------------------------------------------
# Policy-portfolio metrics (DESIGN.md §10) — shared by the objectives
# benchmark and tests so the definitions cannot drift apart.
# ---------------------------------------------------------------------------


def jain_fairness(xs: Sequence[float]) -> float:
    """Jain fairness index (Σx)² / (n·Σx²); 1.0 when perfectly even.

    Negative inputs are clamped to 0 (progress cannot be negative); an
    empty or all-zero population scores 0.0."""
    xs = [max(x, 0.0) for x in xs]
    if not xs or sum(xs) == 0:
        return 0.0
    return sum(xs) ** 2 / (len(xs) * sum(x * x for x in xs))


def normalized_progress(jobs: Sequence) -> List[float]:
    """Per-job x_j = min(done_j / work_j, 1) — the unit every fairness
    metric below is computed over.  Jobs with non-finite or non-positive
    ``work`` (run-forever Trainers) score 1.0: they cannot be "behind"."""
    out = []
    for j in jobs:
        w = getattr(j, "work", None)
        if w is None or not (w > 0) or w == float("inf"):
            out.append(1.0)
        else:
            out.append(min(j.done / w, 1.0))
    return out


def min_normalized_progress(jobs: Sequence) -> float:
    """min_j x_j — the floor ``MaxMinFairness`` maximizes; 0.0 when the
    population is empty."""
    xs = normalized_progress(jobs)
    return min(xs) if xs else 0.0


def deadline_miss_rate(jobs: Sequence, horizon: float) -> float:
    """Fraction of jobs whose soft deadline fell inside the horizon but
    passed unfinished (``finished_at`` unset or after the deadline) —
    what ``DeadlineAware`` minimizes.  Jobs without a deadline, or with
    one beyond the horizon, count toward the denominator but can never
    miss (matching the objectives benchmark's definition)."""
    if not jobs:
        return 0.0
    missed = [j for j in jobs
              if getattr(j, "deadline", None) is not None
              and j.deadline <= horizon
              and (getattr(j, "finished_at", None) is None
                   or j.finished_at > j.deadline)]
    return len(missed) / len(jobs)
