"""Evaluation metrics (paper §4.1): resource integral (Eqn 17), eq-nodes
(Eqn 18), utilization efficiency U = A_e / A_s, and ROI (Fig 8)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

from repro.core.events import PoolEvent, pool_sizes


def resource_integral(events: Sequence[PoolEvent], t0: float,
                      t1: float) -> float:
    """Node-hours of idle resource between t0 and t1 (Eqn 17)."""
    sizes = pool_sizes(events)
    total = 0.0
    for i, (t, n) in enumerate(sizes):
        seg_start = max(t, t0)
        seg_end = min(sizes[i + 1][0] if i + 1 < len(sizes) else t1, t1)
        if seg_end > seg_start:
            total += n * (seg_end - seg_start)
    return total / 3600.0


def eq_nodes(events: Sequence[PoolEvent], t0: float, t1: float) -> float:
    """Equivalent static node count delivering the same node-time (Eqn 18)."""
    if t1 <= t0:
        return 0.0
    return resource_integral(events, t0, t1) * 3600.0 / (t1 - t0)


@dataclass
class Efficiency:
    a_e: float          # outcome with BFTrainer (samples)
    a_s: float          # outcome on static eq-nodes (samples)

    @property
    def u(self) -> float:
        return self.a_e / self.a_s if self.a_s > 0 else 0.0


@dataclass
class ROI:
    """Per-event return on rescaling investment (paper Fig 8)."""
    investment: float   # rescale cost, samples
    ret: float          # outcome until next event, samples

    @property
    def value(self) -> float:
        return self.ret / self.investment if self.investment > 0 else float("inf")
