"""The BFTrainer control loop (paper §3–§5), shared by simulation and
live execution.

``ControlLoop`` owns the *policy*: the merged timeline of pool events and
Trainer arrivals, FCFS admission up to ``pj_max``, the event-coalescing
window, preemption handling, rescale-stall (``busy_until``) bookkeeping,
adaptive ``t_fwd`` estimation, and the per-event records.  What it does
*not* own is execution: progress integration and physical rescales are
delegated to an ``ExecutionBackend`` (core/backend.py) — analytic
scaling-curve integration for trace-driven simulation, or real
``ElasticTrainer`` steps for live runs.  One policy, two substrates
(DESIGN.md §9).

Cost semantics (paper §2.1/§3.4), identical for both backends:
* scale-up of Trainer j stalls all its nodes for ``r_up`` seconds,
  scale-down for ``r_dw`` seconds (costs measured both in seconds and in
  foregone samples O_j(C_j)·R);
* nodes leaving mid-run force a scale-down at cost ``r_dw`` (preemption);
  the preempted node-time itself is counted as preemption cost;
* nodes *failing* mid-run (``PoolEvent.failed``, DESIGN.md §12) are a
  preemption plus a restart: progress rolls back to the last good
  checkpoint (``ckpt_every`` lattice; the backend's ``on_fail`` picks
  the restore point) and ``restart_penalty`` extra stall seconds apply;
* a forced scale-down (preemption or kill) supersedes any in-flight
  rescale stall — the aborted rescale's residual stall is not served;
* Trainers are admitted FCFS, at most ``pj_max`` concurrently (§5.3).
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

from repro.core.allocator import Allocator
from repro.core.events import PoolEvent, merge_events
from repro.core.milp import AllocationProblem, TrainerSpec
from repro.core.scaling import ScalingCurve
from repro.core.tfwd import TfwdEstimator, resolve_tfwd
from repro.obs.telemetry import NULL_TELEMETRY, Telemetry


@dataclass
class TrainerJob:
    """One Trainer (a DNN training job) submitted to BFTrainer.

    ``work``/``done`` are in the backend's progress unit: samples for the
    analytic backend, train steps for the live backend.  Times
    (``arrival``, ``deadline``, ``r_up``/``r_dw``) are in trace-clock
    seconds; ``budget`` is in node-seconds.

    The optional policy fields (``weight``, ``deadline``, ``budget``,
    ``rate``/``slo``) are read by the matching objectives in
    ``repro.core.objectives`` (WeightedPriority / DeadlineAware /
    CostCap / LatencySLO) and are inert under the default Throughput
    policy.
    """

    id: int
    curve: ScalingCurve
    work: float                     # total progress units to process
    n_min: int = 1
    n_max: int = 64
    r_up: float = 20.0              # seconds (paper §2.1 example)
    r_dw: float = 5.0
    arrival: float = 0.0
    metric: str = "throughput"      # objective metric for the MILP
    # --- per-job policy fields (repro.core.objectives) ---
    weight: float = 1.0             # admin priority weight (dimensionless)
    deadline: Optional[float] = None  # absolute trace-clock soft deadline (s)
    budget: Optional[float] = None    # node-seconds the job may consume
    # offered request rate (requests/s), None for training jobs; kept
    # fresh by ServingBackend.refresh and read by LatencySLO
    rate: Optional[float] = None
    slo: Optional[float] = None       # request-latency SLO target (s)
    # --- fault model (DESIGN.md §12) ---
    # checkpoint interval in progress units: a hard node failure rolls
    # ``done`` back to the last multiple of ``ckpt_every``.  The default
    # (inf) models continuous checkpointing — a kill loses no progress —
    # which keeps fault-free replays bit-identical to the pre-chaos loop.
    ckpt_every: float = math.inf
    # extra stall seconds charged per hard node failure (restart/restore
    # wall time), on top of the forced scale-down r_dw
    restart_penalty: float = 0.0

    # --- runtime state ---
    done: float = 0.0
    nodes: List[int] = field(default_factory=list)
    busy_until: float = 0.0         # rescale stall deadline
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    rescale_cost_s: float = 0.0
    rescale_cost_samples: float = 0.0
    preempt_cost_s: float = 0.0
    n_rescales: int = 0
    n_preemptions: int = 0
    n_failures: int = 0             # hard node failures survived
    lost_progress: float = 0.0      # progress units rolled back by kills
    restart_cost_s: float = 0.0     # restart-penalty stall seconds paid
    node_seconds: float = 0.0       # node-seconds consumed so far
    _bp_cache: Optional[tuple] = field(default=None, repr=False)

    def spec(self, max_points: int = 8, now: float = 0.0) -> TrainerSpec:
        """Project this job into the allocator's ``TrainerSpec`` as seen
        at trace time ``now``: the deadline becomes relative
        (seconds-from-now), the budget becomes the unspent remainder
        (node-seconds), and progress the completed work fraction.

        The SOS2 breakpoints are a pure function of the (frozen) curve
        and the size bounds, so they are computed once and memoized —
        ``spec()`` is called once per active Trainer per re-allocation,
        which makes it hot on month-scale replays.
        """
        key = (max_points, self.metric, self.n_min, self.n_max)
        if self._bp_cache is None or self._bp_cache[0] != key:
            self._bp_cache = (key, self.curve.breakpoints(
                self.n_min, self.n_max, metric=self.metric,
                max_points=max_points))
        pts, vals = self._bp_cache[1]
        finite_work = self.work if math.isfinite(self.work) else None
        progress = (min(self.done / self.work, 1.0)
                    if finite_work and self.work > 0 else 0.0)
        return TrainerSpec(
            id=self.id, n_min=self.n_min, n_max=self.n_max,
            r_up=self.r_up, r_dw=self.r_dw,
            points=tuple(pts), values=tuple(vals),
            weight=self.weight,
            deadline=(max(self.deadline - now, 0.0)
                      if self.deadline is not None else None),
            budget=(max(self.budget - self.node_seconds, 0.0)
                    if self.budget is not None else None),
            work=finite_work, progress=progress,
            rate=(round(max(self.rate, 0.0), 6)
                  if self.rate is not None else None),
            slo=self.slo)

    @property
    def finished(self) -> bool:
        return self.done >= self.work

    def throughput(self) -> float:
        return self.curve(len(self.nodes))

    def last_checkpoint(self) -> float:
        """Progress at the most recent durable checkpoint: the largest
        multiple of ``ckpt_every`` not exceeding ``done`` (``done``
        itself under the default continuous-checkpoint discipline)."""
        if not (math.isfinite(self.ckpt_every) and self.ckpt_every > 0):
            return self.done
        return math.floor(self.done / self.ckpt_every) * self.ckpt_every


@dataclass
class EventRecord:
    time: float
    pool_size: int
    rescale_cost_samples: float
    outcome_until_next: float
    solver_wall: float
    allocated: int = 0              # Σ nodes held by Trainers after the event


@dataclass
class LoopStats:
    """The shared report core: everything the ControlLoop itself measures,
    regardless of backend.  ``SimReport``/``RuntimeReport`` build on it."""

    total_samples: float
    makespan: float
    events_processed: int
    allocator: str
    per_trainer_runtime: Dict[int, float]
    rescale_cost_samples: float
    rescale_cost_s: float
    preempt_cost_s: float
    solver_wall_total: float
    event_records: List[EventRecord] = field(default_factory=list)
    unfinished: int = 0
    # fault-model totals (DESIGN.md §12); all zero on fault-free replays
    n_failures: int = 0
    lost_progress: float = 0.0
    restart_cost_s: float = 0.0

    def as_dict(self) -> Dict:
        """Dataclasses-derived serialization (``event_records`` become
        nested dicts): a new stats field cannot silently drift out of
        reports (regression-tested keys == fields)."""
        return dataclasses.asdict(self)


class ControlLoop:
    """The single policy engine behind ``Simulator`` and
    ``BFTrainerRuntime``.

    Parameters
    ----------
    events : sequence of PoolEvent
        Idle-pool join/leave timeline (trace-clock seconds).
    jobs : sequence of TrainerJob
        Trainers, admitted FCFS by ``arrival``.
    allocator : Allocator
        Per-event allocation solver (engine, MILP, heuristic, ...).
    backend : ExecutionBackend
        Where progress happens between decisions (core/backend.py).
    t_fwd : float or "adaptive"
        Forward-looking window (seconds) or the online estimator.
    pj_max : int
        Max concurrently admitted Trainers (paper §5.3).
    horizon : float, optional
        Stop time (trace-clock seconds); default = last timeline point.
    sos2_points : int
        Max SOS2 breakpoints per Trainer curve.
    coalesce_window : float
        Defer re-allocation while further pool events land within this
        window (seconds); 0 disables (DESIGN.md §3.4).
    objective : Objective | str, optional
        Allocation policy passed to every solve (repro.core.objectives);
        ``None`` = the paper's Eqn-16 throughput (DESIGN.md §10).
    telemetry : repro.obs.Telemetry, optional
        Observation sink for decision spans, per-job lifecycle events
        and pool counter tracks (DESIGN.md §13).  Default is the no-op
        ``NULL_TELEMETRY``; the loop never *reads* telemetry, so an
        enabled hub cannot change any decision or stat.
    t_start : float, optional
        Resume the loop mid-trace: integration starts at ``t_start``
        (events before it are dropped, arrivals before it admit at it)
        instead of the first timeline point.  Used by the federated
        epoch replay (DESIGN.md §14) to run one decision epoch per call
        while job state carries across calls.  ``None`` (default) keeps
        the from-the-top semantics bit-identical.
    initial_pool : sequence of int, optional
        Idle-pool membership at ``t_start`` (nodes that joined before
        the window).  Only meaningful with ``t_start``; default empty.
    """

    def __init__(self, events: Sequence[PoolEvent],
                 jobs: Sequence[TrainerJob], allocator: Allocator,
                 backend, *, t_fwd: Union[float, str] = 120.0,
                 pj_max: int = 10, horizon: Optional[float] = None,
                 sos2_points: int = 8, coalesce_window: float = 0.0,
                 objective=None, telemetry: Optional[Telemetry] = None,
                 t_start: Optional[float] = None,
                 initial_pool: Sequence[int] = ()):
        self.events = sorted(events, key=lambda e: e.time)
        self.t_start = t_start
        self.initial_pool = tuple(initial_pool)
        self.jobs = sorted(jobs, key=lambda j: (j.arrival, j.id))
        self.allocator = allocator
        self.backend = backend
        # t_fwd: a constant (paper) or "adaptive" (beyond-paper online
        # quantile estimator over leave-event gaps, core/tfwd.py)
        self.t_fwd_estimator, self.t_fwd = resolve_tfwd(t_fwd)
        # allocation policy (repro.core.objectives): an Objective, a
        # registry name, or None for the paper's Eqn-16 throughput
        self.objective = objective
        self.pj_max = pj_max
        self.horizon = horizon
        self.sos2_points = sos2_points
        # coalesce_window > 0: defer re-allocation while further pool events
        # land within the window, so a join/leave burst triggers one solve
        # instead of N (DESIGN.md §3.4).  Preemption of departed nodes is
        # never deferred — only the hand-out of new assignments is.
        self.coalesce_window = coalesce_window
        self.telemetry = telemetry or NULL_TELEMETRY

    # ------------------------------------------------------------------

    def run(self) -> LoopStats:
        backend = self.backend
        jobs = self.jobs
        tel = self.telemetry
        # hand the hub to an unwired backend so substrate-level spans
        # (live rescale walls, chaos faults) land in the same trace
        if tel and getattr(backend, "telemetry", None) in (None,
                                                           NULL_TELEMETRY):
            backend.telemetry = tel
        backend.bind(jobs)
        pool: set[int] = set(self.initial_pool)
        qi = 0                                        # FCFS admission pointer
        active: List[TrainerJob] = []
        finished: List[TrainerJob] = []
        records: List[EventRecord] = []
        solver_wall = 0.0
        total_outcome = 0.0

        # one event per time point (hand-built streams may carry several
        # events at one timestamp; sequential last-action-wins semantics)
        events = merge_events(self.events)
        t0 = self.t_start
        if t0 is not None:
            events = [e for e in events if e.time >= t0]
        # merged timeline: pool events + job arrivals (+ completions found
        # during integration).  On a windowed run, arrivals before the
        # window admit at its opening instant (FCFS order is preserved:
        # jobs stay sorted by their true arrival).
        times = sorted({e.time for e in events}
                       | {j.arrival if t0 is None else max(j.arrival, t0)
                          for j in jobs})
        ev_by_time: Dict[float, PoolEvent] = {e.time: e for e in events}
        if not times:
            return LoopStats(0.0, 0.0, 0, self.allocator.name, {}, 0.0, 0.0,
                             0.0, 0.0)
        t_end = self.horizon if self.horizon is not None else times[-1]

        ev_times = [e.time for e in events]
        i = 0
        now = times[0]
        n_events = 0
        pending_realloc = True
        pending_since: Optional[float] = None
        while now < t_end and (i < len(times) or active or qi < len(jobs)):
            # 1) apply pool event at `now`, if any: join/leave + preemption
            ev = ev_by_time.get(now)
            if ev is not None:
                if self.t_fwd_estimator is not None:
                    self.t_fwd_estimator.observe(now,
                                                 len(ev.left) + len(ev.failed))
                for nid in ev.joined:
                    pool.add(nid)
                failed = set(ev.failed)
                lost = set(ev.left) | failed
                pool -= lost
                if tel:
                    tel.instant("loop", "pool-event", now,
                                joined=len(ev.joined), left=len(ev.left),
                                failed=len(ev.failed))
                for j in active:
                    taken = [n for n in j.nodes if n in lost]
                    if taken:
                        j.nodes = [n for n in j.nodes if n not in lost]
                        j.n_preemptions += 1
                        j.preempt_cost_s += len(taken) * j.r_dw
                        if tel:
                            tel.instant("job", "preempt", now, job=j.id,
                                        taken=len(taken))
                        penalty = 0.0
                        dead = [n for n in taken if n in failed]
                        if dead:
                            # hard kill: roll progress back to the last
                            # good checkpoint (the backend picks it — a
                            # corrupt latest checkpoint restores one
                            # interval further back) and charge the
                            # restart penalty (DESIGN.md §12)
                            j.n_failures += 1
                            restored = backend.on_fail(j, dead, now)
                            lost_now = 0.0
                            if restored is not None and restored < j.done:
                                lost_now = j.done - restored
                                j.lost_progress += lost_now
                                j.done = restored
                            penalty = j.restart_penalty
                            j.restart_cost_s += penalty
                            if tel:
                                tel.instant("job", "fail", now, job=j.id,
                                            lost=lost_now, penalty_s=penalty)
                        if j.nodes:
                            # forced scale-down stall.  It *supersedes*
                            # any in-flight rescale stall instead of
                            # stacking on top of it: the interrupted
                            # rescale is aborted, and serving its
                            # residual stall after the kill would charge
                            # R_up twice (the kill-during-rescale
                            # double-count, tests/test_loop.py)
                            j.busy_until = now + j.r_dw + penalty
                            j.rescale_cost_s += j.r_dw
                            if tel:
                                tel.span("job", "stall", now, j.busy_until,
                                         job=j.id, why="preempt",
                                         cost_s=j.r_dw + penalty)
                        elif penalty > 0.0:
                            # fully killed: the restart penalty is served
                            # when (before) it next gets nodes
                            j.busy_until = now + penalty
                            if tel:
                                tel.span("job", "stall", now, j.busy_until,
                                         job=j.id, why="restart",
                                         cost_s=penalty)
                        backend.on_preempt(j, taken, now)
                pending_realloc = True

            # 2) admit arrivals FCFS up to pj_max; a job that is already
            #    finished (e.g. a resumed live run) never takes a slot
            while qi < len(jobs) and jobs[qi].arrival <= now and \
                    len(active) < self.pj_max:
                job = jobs[qi]
                qi += 1
                if job.finished:
                    finished.append(job)
                    continue
                active.append(job)
                if tel:
                    tel.instant("job", "admit", now, job=job.id,
                                arrival=job.arrival,
                                wait=now - job.arrival)
                pending_realloc = True

            # 3) reallocate — unless a coalescing window says another pool
            #    event is imminent, in which case defer (bounded by one
            #    window from the first deferred event)
            realloc_cost_samples = 0.0
            ev_solver_wall = 0.0
            defer = False
            if pending_realloc and pending_since is None:
                pending_since = now
            if pending_realloc and self.coalesce_window > 0.0:
                k = bisect.bisect_right(ev_times, now)
                nxt_ev = ev_times[k] if k < len(ev_times) else None
                # never defer while a preemption left a Trainer below its
                # minimum size — running there violates Eqn 4 feasibility
                feasible = all(len(j.nodes) == 0 or len(j.nodes) >= j.n_min
                               for j in active)
                if feasible and nxt_ev is not None and nxt_ev < t_end and \
                        nxt_ev - now <= self.coalesce_window and \
                        now - pending_since < self.coalesce_window:
                    defer = True
            if pending_realloc and active and not defer:
                t_fwd = (self.t_fwd_estimator.estimate()
                         if self.t_fwd_estimator is not None else self.t_fwd)
                for j in active:
                    backend.refresh(j, now)
                prob = AllocationProblem(
                    nodes=sorted(pool),
                    trainers=[j.spec(self.sos2_points, now=now)
                              for j in active],
                    current={j.id: list(j.nodes) for j in active},
                    t_fwd=t_fwd,
                    objective=self.objective,
                    now=now,
                )
                res = self.allocator.allocate(prob)
                solver_wall += res.wall_time
                ev_solver_wall = res.wall_time
                for j in active:
                    new_nodes = res.allocation.get(j.id, [])
                    old = len(j.nodes)
                    new = len(new_nodes)
                    j.nodes = list(new_nodes)
                    if new != old:
                        cost = j.r_up if new > old else j.r_dw
                        j.busy_until = max(j.busy_until, now) + cost
                        j.rescale_cost_s += cost
                        c_samples = j.curve(old) * cost
                        j.rescale_cost_samples += c_samples
                        realloc_cost_samples += c_samples
                        j.n_rescales += 1
                        if tel:
                            tel.instant("job", "rescale", now, job=j.id,
                                        old=old, new=new, cost_s=cost)
                            tel.span("job", "stall", now, j.busy_until,
                                     job=j.id,
                                     why="grow" if new > old else "shrink",
                                     cost_s=cost)
                    if j.nodes and j.started_at is None:
                        j.started_at = now
                    backend.apply_allocation(j, old, now)
                n_events += 1
                if tel:
                    # one decision span per solve: position = trace-clock
                    # instant, cost = solver wall (the dual clock)
                    tel.observe("loop.decision_ms", res.wall_time * 1e3)
                    tel.span("solver", res.solver_status, now, now,
                             wall_s=res.wall_time, pool=len(pool),
                             jobs=len(active),
                             allocated=sum(len(j.nodes) for j in active))
            if not defer:
                pending_realloc = False
                pending_since = None

            # 4) integrate progress to the next timeline point (or a job
            #    completion, whichever comes first)
            nxt = t_end
            k = bisect.bisect_right(times, now, i)
            if k < len(times):
                nxt = min(nxt, times[k])
            for j in active:
                if j.nodes and not j.finished:
                    eta = backend.eta(j, now, nxt)
                    if eta is not None and now < eta < nxt:
                        nxt = eta
            outcome = 0.0
            for j in active:
                if j.nodes and not j.finished:
                    outcome += backend.advance(j, now, nxt)
                if j.nodes:
                    # node-seconds consumed (budget accounting, CostCap)
                    j.node_seconds += len(j.nodes) * (nxt - now)
            total_outcome += outcome
            records.append(EventRecord(
                time=now, pool_size=len(pool),
                rescale_cost_samples=realloc_cost_samples,
                outcome_until_next=outcome, solver_wall=ev_solver_wall,
                allocated=sum(len(j.nodes) for j in active)))
            if tel:
                rec = records[-1]
                tel.sample("pool_size", now, rec.pool_size)
                tel.sample("allocated", now, rec.allocated)
                if nxt > now:
                    for j in active:
                        if j.nodes:
                            tel.span("job", "run", now, nxt, job=j.id,
                                     n=len(j.nodes))

            # 5) retire finished jobs
            newly_done = [j for j in active if j.finished]
            if newly_done:
                for j in newly_done:
                    j.finished_at = nxt
                    backend.on_finish(j, nxt)
                    if tel:
                        tel.instant("job", "finish", nxt, job=j.id)
                    finished.append(j)
                active = [j for j in active if not j.finished]
                pending_realloc = True

            # advance
            while i < len(times) and times[i] <= nxt:
                i += 1
            now = nxt
            if not active and qi >= len(jobs):
                break            # no job left; replaying more events is idle
            if not ev_by_time.get(now) and not newly_done and \
                    not (qi < len(jobs) and jobs[qi].arrival <= now) and \
                    i >= len(times):
                break

        all_jobs = finished + active + jobs[qi:]
        # pre-finished jobs still queued (never admitted) are not unfinished
        queued = [j for j in jobs[qi:] if not j.finished]
        per_rt = {j.id: (j.finished_at - j.arrival)
                  for j in finished if j.finished_at is not None}
        stats = LoopStats(
            total_samples=total_outcome,
            makespan=now - times[0],
            events_processed=n_events,
            allocator=self.allocator.name,
            per_trainer_runtime=per_rt,
            rescale_cost_samples=sum(j.rescale_cost_samples for j in all_jobs),
            rescale_cost_s=sum(j.rescale_cost_s for j in all_jobs),
            preempt_cost_s=sum(j.preempt_cost_s for j in all_jobs),
            solver_wall_total=solver_wall,
            event_records=records,
            unfinished=len(active) + len(queued),
            n_failures=sum(j.n_failures for j in all_jobs),
            lost_progress=sum(j.lost_progress for j in all_jobs),
            restart_cost_s=sum(j.restart_cost_s for j in all_jobs),
        )
        if tel:
            # mirror the scalar report fields as hub gauges, so the hub
            # alone reconstructs the run summary (LoopStats stays the
            # canonical report object — this is the thin-view mirror)
            for f in dataclasses.fields(LoopStats):
                v = getattr(stats, f.name)
                if isinstance(v, (int, float)):
                    tel.gauge(f"loop.{f.name}", v)
        return stats
