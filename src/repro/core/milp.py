"""The paper's MILP resource-allocation model (§3), node-level (faithful).

Decision variable ``x_jn ∈ {0,1}``: node n allocated to Trainer j.  On each
event the solver transfers the current map ``c_jn`` into ``x_jn`` to
maximize the problem's policy objective — by default the paper's
Σ_j T_fwd·O_j(N_j) − Σ_j O_j(C_j)·R_j   (Eqn 16), or any
administrator-/user-defined metric from ``repro.core.objectives`` (§3.5's
promised adaptation point) — subject to job-size (Eqn 4),
node-exclusivity (Eqn 5) and no-migration (Eqns 6–10) constraints, with
O_j piecewise-linearized via SOS2 (Eqn 11–12) and rescale costs via
indicator binaries (Eqn 13–15).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.lp import MILPBuilder, sos2_block


@dataclass(frozen=True)
class TrainerSpec:
    """Static description of one Trainer as seen by the allocator.

    Attributes
    ----------
    id : int
        Trainer id (stable across events).
    n_min, n_max : int
        Feasible node-count range (nodes); outside it only ``N_j = 0``
        (the waiting state) is allowed (Eqn 4).
    r_up, r_dw : float
        Scale-up / scale-down stall costs ``R_j^up`` / ``R_j^dw``
        (seconds).
    points, values : tuple
        SOS2 breakpoints (nodes, must include 0) and the objective
        metric ``O_j`` at each (progress units / second).
    weight : float
        Admin priority weight (dimensionless, default 1.0); read by
        :class:`repro.core.objectives.WeightedPriority`.
    deadline : float, optional
        Seconds from *now* until the job's soft deadline; read by
        :class:`repro.core.objectives.DeadlineAware`.
    budget : float, optional
        Node-seconds the job may still consume; read by
        :class:`repro.core.objectives.CostCap`.
    work : float, optional
        Total work in progress units (samples/steps), ``None`` when
        open-ended; normalizes progress-based policies.
    progress : float
        Completed fraction of ``work`` in [0, 1] (0.0 when unknown);
        read by progress-aware policies (max-min fairness, deadlines).
    rate : float, optional
        Offered request rate (requests/second) for serving jobs,
        ``None`` for training jobs; read by
        :class:`repro.core.objectives.LatencySLO`.
    slo : float, optional
        Request-latency SLO target (seconds).  Informational at the
        allocator level (the replica simulation measures attainment);
        excluded from every objective's ``spec_key``.
    """

    id: int
    n_min: int
    n_max: int
    r_up: float                 # scale-up cost, seconds (R_j^up)
    r_dw: float                 # scale-down cost, seconds (R_j^dw)
    points: Tuple[int, ...]     # SOS2 breakpoints (must include 0)
    values: Tuple[float, ...]   # objective metric at each breakpoint
    # per-job policy fields (see repro.core.objectives)
    weight: float = 1.0
    deadline: Optional[float] = None
    budget: Optional[float] = None
    work: Optional[float] = None
    progress: float = 0.0
    rate: Optional[float] = None
    slo: Optional[float] = None

    def value_at(self, n: int) -> float:
        """Interpolated objective metric ``O_j(n)`` (progress units / s)
        at integer node count ``n``."""
        pts, vals = self.points, self.values
        if n <= pts[0]:
            return vals[0]
        if n >= pts[-1]:
            return vals[-1]
        for i in range(len(pts) - 1):
            if pts[i] <= n <= pts[i + 1]:
                t = (n - pts[i]) / (pts[i + 1] - pts[i])
                return vals[i] + t * (vals[i + 1] - vals[i])
        return vals[-1]


@dataclass
class AllocationProblem:
    """One allocation instance: the idle pool, the Trainers, the current
    map, and the policy to optimize.

    Attributes
    ----------
    nodes : list[int]
        Idle node ids (set N).
    trainers : list[TrainerSpec]
        The Trainers competing for nodes (set J).
    current : dict[int, list[int]]
        Current map ``c``: Trainer id -> node ids it holds now.
    t_fwd : float
        Forward-looking time window (seconds, paper §3.4.3).
    racks : dict[int, int], optional
        Topology (paper §7 future work): node id -> rack/switch id.
    objective : Objective | str, optional
        The policy to maximize (repro.core.objectives); ``None`` means
        the paper's Eqn-16 throughput objective.
    """

    nodes: List[int]                       # idle node ids (set N)
    trainers: List[TrainerSpec]            # set J
    current: Dict[int, List[int]]          # c: trainer id -> node ids
    t_fwd: float = 120.0                   # forward-looking time (seconds)
    # optional topology (paper §7 future work): node id -> rack/switch id
    racks: Optional[Dict[int, int]] = None
    # allocation policy (repro.core.objectives); None = Throughput (Eqn 16)
    objective: Optional[object] = None
    # trace-clock time of the event that produced this problem (seconds).
    # Ignored by every solver and by the engine's cache signature; read by
    # time-aware allocator wrappers (repro.chaos.RestartingAllocator's
    # crash/snapshot schedule).
    now: float = 0.0


def project_current(prob: "AllocationProblem") -> Dict[int, List[int]]:
    """Current map restricted to nodes still in the pool (nodes that left
    were preempted; they must not appear in C when transferring state)."""
    node_set = set(prob.nodes)
    return {t.id: [nid for nid in prob.current.get(t.id, [])
                   if nid in node_set] for t in prob.trainers}


@dataclass
class AllocationResult:
    """One solver's answer to an :class:`AllocationProblem`.

    Attributes
    ----------
    allocation : dict[int, list[int]]
        Trainer id -> concrete node ids assigned.
    counts : dict[int, int]
        Trainer id -> node count (``len`` of the above).
    objective : float, optional
        Achieved objective value in the *policy's* units (progress units
        for throughput-style policies, dimensionless for fairness);
        ``None`` for heuristics that do not score, and on fallback.
    wall_time : float
        Solver wall-clock time (seconds).
    solver_status : str
        Human-readable solver outcome.
    fell_back : bool
        True when the §3.6 fallback kept the current map
        (timeout/infeasible).
    """

    allocation: Dict[int, List[int]]       # trainer id -> node ids
    counts: Dict[int, int]
    objective: Optional[float]
    wall_time: float
    solver_status: str
    fell_back: bool = False                # kept current map (timeout/infeasible)


def solve_node_milp(prob: AllocationProblem, *, time_limit: float = 30.0,
                    topo_coef: float = 0.0) -> AllocationResult:
    """Paper-faithful node-level MILP.

    The feasible set is the paper's §3 model (Eqns 4–15); the objective
    is built by the problem's policy (``prob.objective``, default Eqn 16
    throughput — see repro.core.objectives), which may also impose
    per-Trainer count caps.

    With ``topo_coef > 0`` and ``prob.racks`` set, implements the paper's
    §7 future-work item: rack-locality-aware allocation.  Auxiliary
    binaries ``y_jr`` (Trainer j touches rack r) are constrained by
    ``x_jn <= y_j,rack(n)`` and penalized in the objective by
    ``topo_coef · T_fwd · (per-node gain)`` per rack touched — so spreading
    a Trainer across racks must buy at least that much throughput.

    Parameters
    ----------
    time_limit : float
        Solver wall-clock limit (seconds); on timeout the §3.6 fallback
        keeps the current map (``fell_back=True``).
    """
    from repro.core.objectives import JobTerms, resolve_objective

    objective = resolve_objective(prob.objective)
    nodes = list(prob.nodes)
    n = len(nodes)
    node_pos = {nid: i for i, nid in enumerate(nodes)}
    trainers = prob.trainers
    j_cnt = len(trainers)
    big_m = n + 1
    # Eqn 10 needs M > Σx + Σu (up to 2|N|): the paper's "M > |N|" guidance
    # is insufficient there and would silently cap fresh Trainers at |N|/2.
    big_m_mig = 2 * n + 2

    # current map as binary constants (projected to surviving nodes)
    c = np.zeros((j_cnt, n), dtype=int)
    for ji, t in enumerate(trainers):
        for nid in prob.current.get(t.id, []):
            if nid in node_pos:
                c[ji, node_pos[nid]] = 1
    c_count = c.sum(axis=1)

    b = MILPBuilder()
    x = [b.add_vars(f"x[{t.id}]", n, binary=True) for t in trainers]
    u = [b.add_vars(f"u[{t.id}]", n, binary=True) for t in trainers]
    y_l = b.add_vars("y_l", j_cnt, binary=True)
    y_u = b.add_vars("y_u", j_cnt, binary=True)
    z = b.add_vars("z", j_cnt, binary=True)
    z_up = b.add_vars("z_up", j_cnt, binary=True)
    z_dw = b.add_vars("z_dw", j_cnt, binary=True)

    # Eqn 5: node exclusivity
    for ni in range(n):
        b.add_row({x[ji][ni]: 1.0 for ji in range(j_cnt)}, ub=1.0)

    job_terms = []
    for ji, t in enumerate(trainers):
        xr = {v: 1.0 for v in x[ji]}
        cj = float(c_count[ji])

        # policy-imposed hard cap on N_j (e.g. CostCap budgets)
        cap = objective.count_cap(t, prob.t_fwd)
        if cap is not None and cap < t.n_max:
            b.add_row(dict(xr), ub=float(max(cap, 0)))

        # Eqn 4: N_j = 0 or N_min <= N_j <= N_max.  The relaxation
        # constant must cover n_min even when n_min > |N| (a Trainer
        # whose minimum exceeds the current pool — a normal transient
        # in hole harvesting must force N_j = 0, not infeasibility).
        m4 = float(max(big_m, t.n_min))
        b.add_row({**xr, y_l[ji]: m4}, lb=float(t.n_min))
        b.add_row({**xr, y_l[ji]: m4}, ub=m4)
        b.add_row({**xr, y_u[ji]: -big_m}, ub=float(t.n_max))
        b.add_row({**xr, y_u[ji]: big_m}, ub=float(big_m))

        # Eqn 9: u_jn = x_jn XOR c_jn  (c constant)
        for ni in range(n):
            cc = float(c[ji, ni])
            b.add_row({u[ji][ni]: 1.0, x[ji][ni]: -1.0}, ub=cc)      # u<=x+c
            b.add_row({u[ji][ni]: 1.0, x[ji][ni]: -1.0}, lb=-cc)     # u>=x-c
            b.add_row({u[ji][ni]: 1.0, x[ji][ni]: 1.0}, lb=cc)       # u>=c-x
            b.add_row({u[ji][ni]: 1.0, x[ji][ni]: 1.0}, ub=2.0 - cc) # u<=2-x-c
        # Eqn 10: no-migration (|N_j - C_j| = sum u)
        row = dict(xr)
        for v in u[ji]:
            row[v] = row.get(v, 0.0) - 1.0
        row[z[ji]] = big_m_mig
        b.add_row(row, lb=cj)                  # sum x - sum u + M z >= C_j
        row = dict(xr)
        for v in u[ji]:
            row[v] = row.get(v, 0.0) + 1.0
        row[z[ji]] = big_m_mig
        b.add_row(row, ub=cj + big_m_mig)      # sum x + sum u + M z <= C_j + M

        # Eqn 15: rescale indicators
        b.add_row({**xr, z_up[ji]: -(big_m - cj)}, ub=cj)
        b.add_row({**xr, z_up[ji]: -(cj + 1.0)}, lb=0.0)
        b.add_row({**xr, z_dw[ji]: big_m - cj + 1.0}, ub=float(big_m))
        b.add_row({**xr, z_dw[ji]: cj}, lb=cj)

        # Eqn 11/12: SOS2 piecewise objective metric
        _, value_coeffs = sos2_block(
            b, f"t{t.id}", list(t.points), list(t.values), dict(xr))
        job_terms.append(JobTerms(spec=t, cj=int(c_count[ji]),
                                  count_expr=dict(xr),
                                  value_expr=value_coeffs,
                                  z_up=z_up[ji], z_dw=z_dw[ji]))

        # topology extension (paper §7): rack-spread penalty
        if topo_coef > 0.0 and prob.racks is not None:
            rack_ids = sorted({prob.racks[nid] for nid in nodes})
            y_rack = {r: b.add_var(f"yrack[{t.id}][{r}]", binary=True)
                      for r in rack_ids}
            for ni, nid in enumerate(nodes):
                b.add_row({x[ji][ni]: 1.0,
                           y_rack[prob.racks[nid]]: -1.0}, ub=0.0)
            per_node_gain = t.values[-1] / max(t.points[-1], 1)
            for r in rack_ids:
                b.set_obj(y_rack[r],
                          -topo_coef * prob.t_fwd * per_node_gain)

    # policy objective (Eqn 16 by default; see repro.core.objectives)
    obj_offset = objective.build(b, job_terms, prob.t_fwd)
    res = b.solve(maximize=True, time_limit=time_limit)

    if not res.success or res.x is None:
        # §3.6 fallback: keep the current map
        alloc = {j: sorted(ns) for j, ns in project_current(prob).items()}
        return AllocationResult(
            allocation=alloc,
            counts={t.id: len(alloc[t.id]) for t in trainers},
            objective=None, wall_time=res.wall_time,
            solver_status=res.message, fell_back=True)

    xv = res.x
    alloc: Dict[int, List[int]] = {}
    for ji, t in enumerate(trainers):
        alloc[t.id] = sorted(nodes[ni] for ni in range(n)
                             if xv[x[ji][ni]] > 0.5)
    return AllocationResult(
        allocation=alloc,
        counts={t.id: len(v) for t, v in zip(trainers, alloc.values())},
        objective=(res.objective + obj_offset
                   if res.objective is not None else None),
        wall_time=res.wall_time,
        solver_status=res.message)
