"""The paper's MILP resource-allocation model (§3), node-level (faithful).

Decision variable ``x_jn ∈ {0,1}``: node n allocated to Trainer j.  On each
event the solver transfers the current map ``c_jn`` into ``x_jn`` to
maximize  Σ_j T_fwd·O_j(N_j) − Σ_j O_j(C_j)·R_j   (Eqn 16)
subject to job-size (Eqn 4), node-exclusivity (Eqn 5) and no-migration
(Eqns 6–10) constraints, with O_j piecewise-linearized via SOS2 (Eqn 11–12)
and rescale costs via indicator binaries (Eqn 13–15).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.lp import MILPBuilder, sos2_block


@dataclass(frozen=True)
class TrainerSpec:
    """Static description of one Trainer as seen by the allocator."""

    id: int
    n_min: int
    n_max: int
    r_up: float                 # scale-up cost, seconds (R_j^up)
    r_dw: float                 # scale-down cost, seconds (R_j^dw)
    points: Tuple[int, ...]     # SOS2 breakpoints (must include 0)
    values: Tuple[float, ...]   # objective metric at each breakpoint

    def value_at(self, n: int) -> float:
        """Interpolated objective metric at integer n."""
        pts, vals = self.points, self.values
        if n <= pts[0]:
            return vals[0]
        if n >= pts[-1]:
            return vals[-1]
        for i in range(len(pts) - 1):
            if pts[i] <= n <= pts[i + 1]:
                t = (n - pts[i]) / (pts[i + 1] - pts[i])
                return vals[i] + t * (vals[i + 1] - vals[i])
        return vals[-1]


@dataclass
class AllocationProblem:
    nodes: List[int]                       # idle node ids (set N)
    trainers: List[TrainerSpec]            # set J
    current: Dict[int, List[int]]          # c: trainer id -> node ids
    t_fwd: float = 120.0                   # forward-looking time (seconds)
    # optional topology (paper §7 future work): node id -> rack/switch id
    racks: Optional[Dict[int, int]] = None


def project_current(prob: "AllocationProblem") -> Dict[int, List[int]]:
    """Current map restricted to nodes still in the pool (nodes that left
    were preempted; they must not appear in C when transferring state)."""
    node_set = set(prob.nodes)
    return {t.id: [nid for nid in prob.current.get(t.id, [])
                   if nid in node_set] for t in prob.trainers}


@dataclass
class AllocationResult:
    allocation: Dict[int, List[int]]       # trainer id -> node ids
    counts: Dict[int, int]
    objective: Optional[float]
    wall_time: float
    solver_status: str
    fell_back: bool = False                # kept current map (timeout/infeasible)


def solve_node_milp(prob: AllocationProblem, *, time_limit: float = 30.0,
                    topo_coef: float = 0.0) -> AllocationResult:
    """Paper-faithful node-level MILP.

    With ``topo_coef > 0`` and ``prob.racks`` set, implements the paper's
    §7 future-work item: rack-locality-aware allocation.  Auxiliary
    binaries ``y_jr`` (Trainer j touches rack r) are constrained by
    ``x_jn <= y_j,rack(n)`` and penalized in the objective by
    ``topo_coef · T_fwd · (per-node gain)`` per rack touched — so spreading
    a Trainer across racks must buy at least that much throughput.
    """
    nodes = list(prob.nodes)
    n = len(nodes)
    node_pos = {nid: i for i, nid in enumerate(nodes)}
    trainers = prob.trainers
    j_cnt = len(trainers)
    big_m = n + 1
    # Eqn 10 needs M > Σx + Σu (up to 2|N|): the paper's "M > |N|" guidance
    # is insufficient there and would silently cap fresh Trainers at |N|/2.
    big_m_mig = 2 * n + 2

    # current map as binary constants (projected to surviving nodes)
    c = np.zeros((j_cnt, n), dtype=int)
    for ji, t in enumerate(trainers):
        for nid in prob.current.get(t.id, []):
            if nid in node_pos:
                c[ji, node_pos[nid]] = 1
    c_count = c.sum(axis=1)

    b = MILPBuilder()
    x = [b.add_vars(f"x[{t.id}]", n, binary=True) for t in trainers]
    u = [b.add_vars(f"u[{t.id}]", n, binary=True) for t in trainers]
    y_l = b.add_vars("y_l", j_cnt, binary=True)
    y_u = b.add_vars("y_u", j_cnt, binary=True)
    z = b.add_vars("z", j_cnt, binary=True)
    z_up = b.add_vars("z_up", j_cnt, binary=True)
    z_dw = b.add_vars("z_dw", j_cnt, binary=True)

    # Eqn 5: node exclusivity
    for ni in range(n):
        b.add_row({x[ji][ni]: 1.0 for ji in range(j_cnt)}, ub=1.0)

    for ji, t in enumerate(trainers):
        xr = {v: 1.0 for v in x[ji]}
        cj = float(c_count[ji])

        # Eqn 4: N_j = 0 or N_min <= N_j <= N_max
        b.add_row({**xr, y_l[ji]: big_m}, lb=float(t.n_min))
        b.add_row({**xr, y_l[ji]: big_m}, ub=float(big_m))
        b.add_row({**xr, y_u[ji]: -big_m}, ub=float(t.n_max))
        b.add_row({**xr, y_u[ji]: big_m}, ub=float(big_m))

        # Eqn 9: u_jn = x_jn XOR c_jn  (c constant)
        for ni in range(n):
            cc = float(c[ji, ni])
            b.add_row({u[ji][ni]: 1.0, x[ji][ni]: -1.0}, ub=cc)      # u<=x+c
            b.add_row({u[ji][ni]: 1.0, x[ji][ni]: -1.0}, lb=-cc)     # u>=x-c
            b.add_row({u[ji][ni]: 1.0, x[ji][ni]: 1.0}, lb=cc)       # u>=c-x
            b.add_row({u[ji][ni]: 1.0, x[ji][ni]: 1.0}, ub=2.0 - cc) # u<=2-x-c
        # Eqn 10: no-migration (|N_j - C_j| = sum u)
        row = dict(xr)
        for v in u[ji]:
            row[v] = row.get(v, 0.0) - 1.0
        row[z[ji]] = big_m_mig
        b.add_row(row, lb=cj)                  # sum x - sum u + M z >= C_j
        row = dict(xr)
        for v in u[ji]:
            row[v] = row.get(v, 0.0) + 1.0
        row[z[ji]] = big_m_mig
        b.add_row(row, ub=cj + big_m_mig)      # sum x + sum u + M z <= C_j + M

        # Eqn 15: rescale indicators
        b.add_row({**xr, z_up[ji]: -(big_m - cj)}, ub=cj)
        b.add_row({**xr, z_up[ji]: -(cj + 1.0)}, lb=0.0)
        b.add_row({**xr, z_dw[ji]: big_m - cj + 1.0}, ub=float(big_m))
        b.add_row({**xr, z_dw[ji]: cj}, lb=cj)

        # Eqn 11/12: SOS2 piecewise objective metric
        _, value_coeffs = sos2_block(
            b, f"t{t.id}", list(t.points), list(t.values), dict(xr))

        # Eqn 16 objective
        for var, coef in value_coeffs.items():
            b.set_obj(var, prob.t_fwd * coef)
        o_cj = t.value_at(int(c_count[ji]))
        b.set_obj(z_up[ji], -o_cj * t.r_up)
        b.set_obj(z_dw[ji], -o_cj * t.r_dw)

        # topology extension (paper §7): rack-spread penalty
        if topo_coef > 0.0 and prob.racks is not None:
            rack_ids = sorted({prob.racks[nid] for nid in nodes})
            y_rack = {r: b.add_var(f"yrack[{t.id}][{r}]", binary=True)
                      for r in rack_ids}
            for ni, nid in enumerate(nodes):
                b.add_row({x[ji][ni]: 1.0,
                           y_rack[prob.racks[nid]]: -1.0}, ub=0.0)
            per_node_gain = t.values[-1] / max(t.points[-1], 1)
            for r in rack_ids:
                b.set_obj(y_rack[r],
                          -topo_coef * prob.t_fwd * per_node_gain)

    res = b.solve(maximize=True, time_limit=time_limit)

    if not res.success or res.x is None:
        # §3.6 fallback: keep the current map
        alloc = {j: sorted(ns) for j, ns in project_current(prob).items()}
        return AllocationResult(
            allocation=alloc,
            counts={t.id: len(alloc[t.id]) for t in trainers},
            objective=None, wall_time=res.wall_time,
            solver_status=res.message, fell_back=True)

    xv = res.x
    alloc: Dict[int, List[int]] = {}
    for ji, t in enumerate(trainers):
        alloc[t.id] = sorted(nodes[ni] for ni in range(n)
                             if xv[x[ji][ni]] > 0.5)
    return AllocationResult(
        allocation=alloc,
        counts={t.id: len(v) for t, v in zip(trainers, alloc.values())},
        objective=res.objective, wall_time=res.wall_time,
        solver_status=res.message)
