"""Aggregate (count-based) reformulation of the paper's MILP — beyond-paper
optimization (see DESIGN.md §2 and EXPERIMENTS.md §Perf-MILP).

Observation: idle nodes are homogeneous and migration is disallowed, so the
solution is fully determined by the *count* vector (N_1..N_J):

* feasibility — any count vector with Σ N_j ≤ |N| and N_j ∈ {0} ∪
  [N^min_j, N^max_j] is realizable without migration: a Trainer that shrinks
  keeps a subset of its own nodes; one that grows keeps all of them and
  takes free/released ones.  This is exactly the feasible set of the
  node-level model (Eqns 4–10): the XOR/no-migration constraints only force
  |Δ| = Σ u, i.e. keep-your-own-nodes, never *which* nodes;
* objective — Eqn 16 depends only on N_j and C_j.

Hence the optimal objective is identical while the variable count drops
from O(J·|N|) binaries to O(J) integers (+ SOS2 weights).  Property tests
(tests/test_milp.py) assert objective equality against the node-level
model on randomized instances.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Tuple

import numpy as np

from repro.core.lp import MILPBuilder, sos2_block
from repro.core.milp import (
    AllocationProblem,
    AllocationResult,
    TrainerSpec,
    project_current,
)

#: Constraint-skeleton memo (DESIGN.md §11): everything in the aggregate
#: model except the C_j-dependent rescale-indicator rows and the policy
#: objective is a pure function of (|N|, per-Trainer curve/bounds/cap) —
#: so the variable layout, capacity row, Eqn-4 rows and SOS2 blocks are
#: built once per such structure and restored per solve with a flat
#: ``MILPBuilder.clone()``.  The key deliberately excludes ``C_j``,
#: ``t_fwd`` (modulo policy caps), ``r_up``/``r_dw`` and per-job policy
#: fields, which is exactly what drifts event-to-event in a replay.
_SKELETONS: "OrderedDict[Tuple, Tuple]" = OrderedDict()
_SKELETONS_SIZE = 256


def clear_skeleton_cache() -> None:
    _SKELETONS.clear()


def _skeleton(trainers: List[TrainerSpec], n: int, caps: List):
    key = (n, tuple((t.n_min, t.n_max, t.points, t.values, cap)
                    for t, cap in zip(trainers, caps)))
    hit = _SKELETONS.get(key)
    if hit is not None:
        _SKELETONS.move_to_end(key)
        return hit
    j_cnt = len(trainers)
    big_m = n + 1
    b = MILPBuilder()
    n_j = [b.add_var(f"N[{t.id}]", integer=True, lb=0.0, ub=float(t.n_max))
           for t in trainers]
    y_l = b.add_vars("y_l", j_cnt, binary=True)
    z_up = b.add_vars("z_up", j_cnt, binary=True)
    z_dw = b.add_vars("z_dw", j_cnt, binary=True)

    # capacity: sum_j N_j <= |N|
    b.add_row({v: 1.0 for v in n_j}, ub=float(n))

    value_exprs = []
    for ji, t in enumerate(trainers):
        # N_j = 0 or N_min <= N_j (upper bound via var bound).  The
        # relaxation constant must cover n_min even when n_min > |N|
        # (pool transiently smaller than a Trainer's minimum: force
        # N_j = 0, not infeasibility).
        m4 = float(max(big_m, t.n_min))
        b.add_row({n_j[ji]: 1.0, y_l[ji]: m4}, lb=float(t.n_min))
        b.add_row({n_j[ji]: 1.0, y_l[ji]: m4}, ub=m4)
        # policy-imposed hard cap on N_j (e.g. CostCap budgets)
        cap = caps[ji]
        if cap is not None and cap < t.n_max:
            b.add_row({n_j[ji]: 1.0}, ub=float(max(cap, 0)))
        # SOS2 objective metric
        _, value_coeffs = sos2_block(
            b, f"t{t.id}", list(t.points), list(t.values), {n_j[ji]: 1.0})
        value_exprs.append(value_coeffs)

    entry = (b, n_j, z_up, z_dw, value_exprs)
    _SKELETONS[key] = entry
    if len(_SKELETONS) > _SKELETONS_SIZE:
        _SKELETONS.popitem(last=False)
    return entry


def solve_fast_milp(prob: AllocationProblem, *, time_limit: float = 30.0,
                    ) -> AllocationResult:
    """Aggregate (count-based) MILP over the problem's policy objective.

    Identical optimum to ``solve_node_milp`` (see module docstring) at a
    fraction of the variable count.  The objective — Eqn 16 throughput by
    default, or any policy from ``repro.core.objectives`` carried on
    ``prob.objective`` — is built from the same ``JobTerms`` handles as
    the node-level model, so the two stay consistent by construction.

    Assembly is two-phase (DESIGN.md §11): the C_j/policy-independent
    constraint skeleton is cloned from a per-structure memo, then the
    per-event pieces (Eqn-15 rescale rows, policy objective) are appended
    on top.

    Parameters
    ----------
    time_limit : float
        Solver wall-clock limit (seconds); on timeout the §3.6 fallback
        keeps the current map (``fell_back=True``).
    """
    from repro.core.objectives import JobTerms, resolve_objective

    objective = resolve_objective(prob.objective)
    nodes = list(prob.nodes)
    n = len(nodes)
    trainers = prob.trainers
    big_m = n + 1

    current = project_current(prob)
    c_count = {t.id: len(current[t.id]) for t in trainers}

    caps = [objective.count_cap(t, prob.t_fwd) for t in trainers]
    skel, n_j, z_up, z_dw, value_exprs = _skeleton(trainers, n, caps)
    b = skel.clone()

    job_terms = []
    for ji, t in enumerate(trainers):
        cj = float(c_count[t.id])
        # rescale indicators (Eqn 15) — the C_j-dependent rows
        b.add_row({n_j[ji]: 1.0, z_up[ji]: -(big_m - cj)}, ub=cj)
        b.add_row({n_j[ji]: 1.0, z_up[ji]: -(cj + 1.0)}, lb=0.0)
        b.add_row({n_j[ji]: 1.0, z_dw[ji]: big_m - cj + 1.0}, ub=float(big_m))
        b.add_row({n_j[ji]: 1.0, z_dw[ji]: cj}, lb=cj)
        job_terms.append(JobTerms(spec=t, cj=c_count[t.id],
                                  count_expr={n_j[ji]: 1.0},
                                  value_expr=value_exprs[ji],
                                  z_up=z_up[ji], z_dw=z_dw[ji]))

    # policy objective (Eqn 16 by default; see repro.core.objectives)
    obj_offset = objective.build(b, job_terms, prob.t_fwd)
    res = b.solve(maximize=True, time_limit=time_limit)

    if not res.success or res.x is None:
        alloc = {t.id: sorted(current[t.id]) for t in trainers}
        return AllocationResult(
            allocation=alloc,
            counts={t.id: len(alloc[t.id]) for t in trainers},
            objective=None, wall_time=res.wall_time,
            solver_status=res.message, fell_back=True)

    counts = {t.id: int(round(res.x[n_j[ji]]))
              for ji, t in enumerate(trainers)}
    allocation = reconstruct_map(nodes, trainers, current, counts)
    return AllocationResult(allocation=allocation, counts=counts,
                            objective=(res.objective + obj_offset
                                       if res.objective is not None
                                       else None),
                            wall_time=res.wall_time,
                            solver_status=res.message)


def reconstruct_map(nodes: List[int], trainers: List[TrainerSpec],
                    current: Dict[int, List[int]],
                    counts: Dict[int, int]) -> Dict[int, List[int]]:
    """Counts -> concrete node map, keeping current nodes first (so the map
    satisfies the node-level no-migration constraints by construction)."""
    allocation: Dict[int, List[int]] = {}
    used = set()
    for t in trainers:
        keep = sorted(current.get(t.id, []))[: counts.get(t.id, 0)]
        allocation[t.id] = list(keep)
        used.update(keep)
    free = sorted(set(nodes) - used)
    for t in trainers:
        need = counts.get(t.id, 0) - len(allocation[t.id])
        if need > 0:
            take, free = free[:need], free[need:]
            allocation[t.id].extend(take)
            allocation[t.id].sort()
    return allocation
