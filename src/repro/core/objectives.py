"""Pluggable allocation objectives ("policies") for the BFTrainer MILP.

The paper's abstract promises the MILP "can be adapted to optimize for
administrator- or user-defined metrics" (§3.5); this module is that
adaptation point.  An :class:`Objective` tells every solver in the
portfolio — the node-level MILP (``milp.solve_node_milp``), the aggregate
MILP (``milp_fast.solve_fast_milp``) and the greedy water-filling
heuristic (``greedy.solve_greedy``) — what to maximize, through three
coordinated views of the same function:

* ``build(b, jobs, t_fwd)`` — emit the objective as linear terms (plus any
  auxiliary variables/rows it needs) into a ``MILPBuilder``;
* ``job_value(t, n, cj, t_fwd)`` / ``combine(values)`` — the same
  function as a per-Trainer scalar plus an aggregation, which is what the
  greedy solver's marginal-gain search climbs;
* ``count_cap(t, t_fwd)`` — optional per-Trainer hard cap on the node
  count (used by budget-style policies), applied as a constraint by the
  MILPs and as a target filter by the greedy solver.

Solvers report ``AllocationResult.objective`` in the *policy's* units, so
the engine's best-of-portfolio comparison and the greedy-vs-MILP parity
tests are policy-agnostic.  Memoization safety comes from two more hooks:
``cache_key()`` (the policy's identity + parameters) and ``spec_key(t)``
(exactly the per-Trainer fields this policy reads — see
:func:`repro.core.engine.problem_signature`), so e.g. ``Throughput`` keeps
its high cache-hit rate even though ``TrainerSpec`` now carries progress
and deadline fields it never looks at.

Units used throughout: node counts in nodes, times (``t_fwd``,
``deadline``, ``r_up``/``r_dw``) in seconds, ``budget`` in node-seconds,
throughput ``O_j(N)`` in progress units (samples or steps) per second,
``work`` in progress units, ``progress`` dimensionless in [0, 1].

Adding a sixth policy is documented in DESIGN.md §10.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, TYPE_CHECKING

import numpy as np

from repro.core.lp import MILPBuilder, epigraph_min

if TYPE_CHECKING:  # avoid a runtime cycle: milp.py imports this module
    from repro.core.milp import TrainerSpec

_EPS = 1e-9


@dataclass
class JobTerms:
    """Linear-expression handles for one Trainer inside a MILP build.

    Both MILP formulations (node-level and aggregate) reduce a Trainer to
    the same four handles, so one ``Objective.build`` serves both.

    Attributes
    ----------
    spec : TrainerSpec
        The Trainer's static description (curve breakpoints, costs, and
        the per-job policy fields).
    cj : int
        Current node count ``C_j`` (nodes), after projection onto the
        surviving pool.
    count_expr : dict[int, float]
        Variable -> coefficient expression summing to ``N_j`` (nodes).
    value_expr : dict[int, float]
        Variable -> coefficient expression summing to ``O_j(N_j)``
        (progress units / second), from the SOS2 block.
    z_up, z_dw : int
        Rescale indicator binaries (Eqn 15): 1 iff the Trainer grows /
        shrinks relative to ``cj``.
    """

    spec: "TrainerSpec"
    cj: int
    count_expr: Dict[int, float]
    value_expr: Dict[int, float]
    z_up: int
    z_dw: int


def _rescale_penalty(t: "TrainerSpec", n: int, cj: int) -> float:
    """Foregone progress units for moving Trainer ``t`` from ``cj`` to
    ``n`` nodes: ``O_j(C_j) * R_up`` on grow, ``O_j(C_j) * R_dw`` on
    shrink (paper Eqn 16's cost term)."""
    if n > cj:
        return t.value_at(cj) * t.r_up
    if n < cj:
        return t.value_at(cj) * t.r_dw
    return 0.0


def _eqn16_terms(b: MILPBuilder, jt: JobTerms, t_fwd: float,
                 weight: float = 1.0) -> None:
    """Emit one Trainer's Eqn-16 terms, scaled by ``weight``:
    ``weight * (t_fwd * O_j(N_j) - O_j(C_j) * R_up * z_up
    - O_j(C_j) * R_dw * z_dw)``."""
    for var, coef in jt.value_expr.items():
        b.set_obj(var, weight * t_fwd * coef)
    o_cj = jt.spec.value_at(jt.cj)
    b.set_obj(jt.z_up, -weight * o_cj * jt.spec.r_up)
    b.set_obj(jt.z_dw, -weight * o_cj * jt.spec.r_dw)


# ---------------------------------------------------------------------------
# Vectorized value tables (the greedy/repair hot path, DESIGN.md §11)
# ---------------------------------------------------------------------------


def _interp_table(t: "TrainerSpec", n_hi: int) -> np.ndarray:
    """``O_j(m)`` for m = 0..n_hi as a dense vector (progress units/s),
    linearly interpolated over the SOS2 breakpoints — the vectorized
    counterpart of ``TrainerSpec.value_at``."""
    ns = np.arange(n_hi + 1, dtype=float)
    return np.interp(ns, np.asarray(t.points, dtype=float),
                     np.asarray(t.values, dtype=float))


def _penalty_table(t: "TrainerSpec", cj: int, n_hi: int) -> np.ndarray:
    """``rescale_penalty(t, m, cj)`` for m = 0..n_hi as a dense vector."""
    o_cj = t.value_at(cj)
    pen = np.zeros(n_hi + 1)
    if cj < n_hi:
        pen[cj + 1:] = o_cj * t.r_up
    if cj > 0:
        pen[:min(cj, n_hi + 1)] = o_cj * t.r_dw
    return pen


#: module-level LRU of materialized value tables.  Keys are id-free (the
#: policy's own cache_key/spec_key plus the Trainer's curve/cost fields),
#: so tables are shared across events exactly when the engine's
#: memoization signature would match that Trainer — one materialization
#: per engine signature (ISSUE: vectorized greedy).
_VT_CACHE: "OrderedDict[Tuple, np.ndarray]" = OrderedDict()
_VT_CACHE_SIZE = 4096


def cached_value_table(objective: "Objective", t: "TrainerSpec", cj: int,
                       t_fwd: float) -> np.ndarray:
    """Memoized ``objective.value_table(t, cj, t_fwd)`` (read-only array)."""
    key = (objective.cache_key(), objective.spec_key(t), t.n_min, t.n_max,
           t.r_up, t.r_dw, t.points, t.values, cj, t_fwd)
    tab = _VT_CACHE.get(key)
    if tab is not None:
        _VT_CACHE.move_to_end(key)
        return tab
    tab = np.asarray(objective.value_table(t, cj, t_fwd), dtype=float)
    tab.setflags(write=False)
    _VT_CACHE[key] = tab
    if len(_VT_CACHE) > _VT_CACHE_SIZE:
        _VT_CACHE.popitem(last=False)
    return tab


def clear_value_table_cache() -> None:
    _VT_CACHE.clear()


def _feasible_hull(tab: np.ndarray, n_min: int, hi: int):
    """Upper concave hull of ``tab`` over the feasible counts
    ``{0} ∪ [n_min, hi]``.

    Returns ``(base, slopes, widths)``: the hull value at 0 plus the
    hull's segments left-to-right (slopes strictly decreasing).  Any
    feasible ``v(m)`` satisfies ``v(m) <= base + Σ`` of the first ``m``
    node-widths of segments, which is what makes the water-filling
    relaxation below a true upper bound.
    """
    if hi < n_min:
        return float(tab[0]), np.empty(0), np.empty(0)
    idx = np.concatenate(([0], np.arange(n_min, hi + 1)))
    ys = tab[idx]
    hull: List[Tuple[int, float]] = []
    for x, y in zip(idx.tolist(), ys.tolist()):
        while len(hull) >= 2:
            (x1, y1), (x2, y2) = hull[-2], hull[-1]
            if (y2 - y1) * (x - x2) <= (y - y2) * (x2 - x1):
                hull.pop()          # middle vertex under the chord
            else:
                break
        hull.append((x, y))
    xs = np.array([p[0] for p in hull], dtype=float)
    vs = np.array([p[1] for p in hull], dtype=float)
    slopes = np.diff(vs) / np.diff(xs)
    widths = np.diff(xs)
    keep = slopes > 0.0             # a maximizer never takes a downhill segment
    return float(vs[0]), slopes[keep], widths[keep]


class Objective:
    """Base policy: what the allocation portfolio maximizes.

    Subclasses implement the three coordinated views documented in the
    module docstring.  ``separable=True`` declares that the total
    objective is ``sum(job_value(...))`` — the greedy solver then uses
    exact per-Trainer deltas (and is bit-for-bit identical to the
    historical single-objective code path for :class:`Throughput`);
    non-separable policies are climbed through ``combine``.
    """

    name = "base"
    #: True iff combine(values) == sum(values); enables the greedy fast path.
    separable = True

    # -- identity (memoization) ----------------------------------------

    def cache_key(self) -> Tuple:
        """Hashable identity of the policy *and its parameters*; part of
        the engine's memoization signature."""
        return (self.name,)

    def spec_key(self, t: "TrainerSpec") -> Tuple:
        """The per-Trainer policy fields this objective actually reads
        (beyond the base curve/cost fields, which are always keyed).
        Conservative default: all of them."""
        return (round(t.weight, 9),
                None if t.deadline is None else round(t.deadline, 9),
                None if t.budget is None else round(t.budget, 9),
                None if t.work is None else round(t.work, 9),
                round(t.progress, 9),
                None if t.rate is None else round(t.rate, 9),
                None if t.slo is None else round(t.slo, 9))

    # -- constraints ----------------------------------------------------

    def count_cap(self, t: "TrainerSpec", t_fwd: float) -> Optional[int]:
        """Optional hard upper bound on ``N_j`` (nodes) this policy
        imposes, or ``None``.  A cap below ``n_min`` forces ``N_j = 0``."""
        return None

    # -- greedy view ----------------------------------------------------

    def job_value(self, t: "TrainerSpec", n: int, cj: int,
                  t_fwd: float) -> float:
        """Per-Trainer scalar value of holding ``n`` nodes for the next
        ``t_fwd`` seconds, in the policy's objective units."""
        raise NotImplementedError

    def value_table(self, t: "TrainerSpec", cj: int,
                    t_fwd: float) -> np.ndarray:
        """Dense per-Trainer value vector ``[job_value(t, m, cj, t_fwd)
        for m in 0..n_max]``.

        The base implementation loops ``job_value`` (always correct);
        the built-in policies override it with closed-form numpy so the
        vectorized greedy/repair path materializes tables in O(n_max)
        numpy time.  Overrides must agree with ``job_value`` to float
        interpolation accuracy (parity-tested in tests/test_engine.py).
        """
        return np.array([self.job_value(t, m, cj, t_fwd)
                         for m in range(t.n_max + 1)], dtype=float)

    def upper_bound(self, trainers: Sequence["TrainerSpec"],
                    cjs: Sequence[int], n_nodes: int,
                    t_fwd: float) -> Optional[float]:
        """Cheap upper bound on the optimal objective, or ``None``.

        Used by the engine's incremental re-solve to decide whether a
        warm-start repair is good enough or must escalate (DESIGN.md
        §11).  The separable default relaxes the problem to its upper
        concave envelope and water-fills ``n_nodes`` fractionally over
        the merged hull segments — a classic LP-style bound, exact when
        every value curve is concave.  Returns ``None`` when no cheap
        bound exists (non-separable policies without an override), which
        makes the engine always escalate — conservative, never wrong.
        """
        if not self.separable:
            return None
        base = 0.0
        seg_s: List[np.ndarray] = []
        seg_w: List[np.ndarray] = []
        for t, cj in zip(trainers, cjs):
            cap = self.count_cap(t, t_fwd)
            hi = t.n_max if cap is None else min(t.n_max, cap)
            b, s, w = _feasible_hull(cached_value_table(self, t, cj, t_fwd),
                                     t.n_min, hi)
            base += b
            seg_s.append(s)
            seg_w.append(w)
        slopes = np.concatenate(seg_s) if seg_s else np.empty(0)
        widths = np.concatenate(seg_w) if seg_w else np.empty(0)
        if not len(slopes):
            return base
        order = np.argsort(-slopes)
        slopes, widths = slopes[order], widths[order]
        take = np.minimum(widths,
                          np.maximum(0.0, n_nodes - (np.cumsum(widths)
                                                     - widths)))
        return base + float(np.dot(slopes, take))

    def combine(self, values: Sequence[float],
                trainers: Optional[Sequence["TrainerSpec"]] = None) -> float:
        """Aggregate per-Trainer values into the scalar objective.

        ``trainers`` is the spec list parallel to ``values``; separable
        policies ignore it, non-separable ones may read per-job
        constants (e.g. progress ranks) from it.
        """
        return float(sum(values))

    def combiner(self, trainers: Sequence["TrainerSpec"]):
        """Bind ``combine`` to a fixed Trainer list for a whole solve.

        The greedy solver evaluates thousands of candidate moves against
        one unchanging Trainer set; policies whose aggregation needs
        per-instance constants (e.g. max-min's progress ranks) override
        this to precompute them once instead of per ``combine`` call.
        """
        return lambda values: self.combine(values, trainers)

    def move_evaluator(self, trainers: Sequence["TrainerSpec"]):
        """Bind an exact move-gain evaluator for the greedy solver.

        Returns ``f(vals, changes) -> gain`` where ``changes`` is a list
        of ``(index, new_value)`` pairs and ``gain`` is any totally
        ordered improvement measure (floats and tuples both work; zero
        gain is ``f(vals, [])``).  The default — the summed value delta —
        is exact for separable policies.  Non-separable policies override
        this rather than relying on ``combine(new) - combine(old)``,
        whose floating-point cancellation silently zeroes out gain
        components much smaller than the aggregate (e.g. deep-rank
        leximin tiebreaks).
        """
        def f(vals, changes):
            return sum(v - vals[i] for i, v in changes)
        return f

    # -- MILP view -------------------------------------------------------

    def build(self, b: MILPBuilder, jobs: List[JobTerms],
              t_fwd: float) -> float:
        """Emit objective terms (and any auxiliary vars/rows) into ``b``.

        Returns a constant offset to add to the solver's reported
        objective so it matches ``combine([job_value(...)])`` exactly
        (MILP objectives cannot carry constants).
        """
        raise NotImplementedError


class Throughput(Objective):
    """The paper's Eqn 16 (default): maximize forward-looking progress
    ``sum_j t_fwd * O_j(N_j)`` minus rescale costs.  Reproduces the
    pre-policy allocator bit-for-bit."""

    name = "throughput"

    def spec_key(self, t: "TrainerSpec") -> Tuple:
        return ()                     # reads no per-job policy fields

    def job_value(self, t, n, cj, t_fwd):
        return t_fwd * t.value_at(n) - _rescale_penalty(t, n, cj)

    def value_table(self, t, cj, t_fwd):
        return (t_fwd * _interp_table(t, t.n_max)
                - _penalty_table(t, cj, t.n_max))

    def build(self, b, jobs, t_fwd):
        for jt in jobs:
            _eqn16_terms(b, jt, t_fwd)
        return 0.0


class WeightedPriority(Objective):
    """Admin-weighted throughput: ``sum_j w_j * (Eqn 16 term)_j``.

    Weights resolve per Trainer as ``weights[id]`` if an explicit mapping
    was given, else the Trainer's own ``spec.weight`` (default 1.0 —
    identical to :class:`Throughput`).  A Trainer with weight 2 buys nodes
    at half the marginal price of a weight-1 Trainer; weight <= 0 removes
    a job from the allocation entirely — ``count_cap`` pins it to 0
    nodes (an objective coefficient of 0 alone would leave the MILPs
    *indifferent*, free to park surplus nodes on the job and charge it
    real rescale stalls the admin zeroed it out to avoid).

    Parameters
    ----------
    weights : mapping[int, float], optional
        Admin-side override: Trainer id -> weight.  Ids absent from the
        mapping fall back to ``spec.weight``.
    """

    name = "weighted"

    def __init__(self, weights: Optional[Mapping[int, float]] = None):
        self.weights = dict(weights) if weights else None

    def _weight(self, t: "TrainerSpec") -> float:
        if self.weights is not None and t.id in self.weights:
            return float(self.weights[t.id])
        return float(t.weight)

    def cache_key(self):
        w = (tuple(sorted(self.weights.items()))
             if self.weights is not None else None)
        return (self.name, w)

    def spec_key(self, t):
        return (round(self._weight(t), 9),)

    def count_cap(self, t, t_fwd):
        # weight <= 0: pin to zero nodes, don't leave the solver indifferent
        return 0 if self._weight(t) <= 0.0 else None

    def job_value(self, t, n, cj, t_fwd):
        return self._weight(t) * (
            t_fwd * t.value_at(n) - _rescale_penalty(t, n, cj))

    def value_table(self, t, cj, t_fwd):
        return self._weight(t) * (t_fwd * _interp_table(t, t.n_max)
                                  - _penalty_table(t, cj, t.n_max))

    def build(self, b, jobs, t_fwd):
        for jt in jobs:
            _eqn16_terms(b, jt, t_fwd, weight=self._weight(jt.spec))
        return 0.0


def _norm_denom(t: "TrainerSpec", t_fwd: float) -> float:
    """Progress-unit denominator normalizing one forward window: the
    Trainer's total ``work`` when known, else ``t_fwd * O_j(n_max)`` (so
    open-ended jobs are scored by normalized rate instead)."""
    if t.work is not None and t.work > 0:
        return float(t.work)
    return max(t_fwd * t.value_at(t.n_max), _EPS)


class MaxMinFairness(Objective):
    """Max-min fairness over projected normalized progress.

    Each Trainer's score is its *projected normalized progress* at the
    end of the forward window (dimensionless):

        p_j(N) = progress_j + (t_fwd * O_j(N) - rescale_penalty_j(N)) / D_j

    with ``D_j = work_j`` (or ``t_fwd * O_j(n_max)`` for open-ended jobs,
    reducing p_j to a normalized rate).  The objective is

        max  min_j p_j(N_j)  +  sum_j kappa_j * p_j(N_j)

    where the min is linearized with an epigraph variable
    ``f <= p_j(N_j)`` for every j (``lp.epigraph_min``) and the
    ``kappa_j`` are *leximin tiebreak* constants: jobs ranked by current
    progress (lowest first, ties by id) get geometrically decaying
    weights ``kappa_j = tiebreak^(rank_j + 1)``.  The plain epigraph
    alone goes blind whenever some job must receive zero nodes (the min
    is then pinned at that job's progress, and a uniform tiebreak would
    collapse back to throughput — starving slow-scaling DNNs forever);
    the rank-weighted tiebreak approximates leximin instead: whatever
    nodes cannot raise the minimum go preferentially to the
    furthest-behind job that *can* use them.  Ranks are constants at
    solve time, so the MILP stays linear and the greedy climbs the
    identical function through ``combine`` (DESIGN.md §10).

    Because ``progress_j`` enters both the score and the ranks, a job
    starved at one event attracts nodes at the next — the policy
    equalizes *accumulated* progress over a trace, not just
    instantaneous rates (tested in tests/test_objectives.py).

    Parameters
    ----------
    tiebreak : float
        Base of the rank-decayed tiebreak weights (dimensionless,
        default 1e-2; keep << 1 so the true minimum dominates).
    """

    name = "maxmin"
    separable = False

    def __init__(self, tiebreak: float = 1e-2):
        self.tiebreak = float(tiebreak)

    def cache_key(self):
        return (self.name, round(self.tiebreak, 12))

    def spec_key(self, t):
        return (None if t.work is None else round(t.work, 9),
                round(t.progress, 9))

    def _kappas(self, trainers: Sequence["TrainerSpec"]) -> List[float]:
        """Leximin tiebreak weights, parallel to ``trainers``: rank by
        progress ascending, weight ``tiebreak^(rank+1)``.

        Progress ties break on the full spec *content* (curve, bounds,
        costs, weight, work) rather than on Trainer id: the engine's
        memoization signature is id-free, so the rank assignment must be
        too — trainers that still tie after the content key are fully
        interchangeable and the final id tiebreak is harmless.
        """
        def key(t: "TrainerSpec"):
            return (t.progress, t.n_min, t.n_max, t.points, t.values,
                    t.r_up, t.r_dw, t.weight,
                    t.work if t.work is not None else -1.0, t.id)

        order = sorted(range(len(trainers)), key=lambda i: key(trainers[i]))
        kap = [0.0] * len(trainers)
        for rank, i in enumerate(order):
            kap[i] = self.tiebreak ** (rank + 1)
        return kap

    def job_value(self, t, n, cj, t_fwd):
        d = _norm_denom(t, t_fwd)
        return t.progress + (t_fwd * t.value_at(n)
                             - _rescale_penalty(t, n, cj)) / d

    def value_table(self, t, cj, t_fwd):
        d = _norm_denom(t, t_fwd)
        return t.progress + (t_fwd * _interp_table(t, t.n_max)
                             - _penalty_table(t, cj, t.n_max)) / d

    def upper_bound(self, trainers, cjs, n_nodes, t_fwd):
        """``max min_j p_j <= min_j max_m p_j(m)`` plus the maximal
        tiebreak term — loose (it ignores the shared-pool coupling), so
        maxmin repairs usually escalate; correctness over speed here."""
        if not trainers:
            return 0.0
        kap = self._kappas(trainers)
        maxes = []
        for t, cj in zip(trainers, cjs):
            tab = cached_value_table(self, t, cj, t_fwd)
            feas = np.concatenate(([tab[0]], tab[t.n_min:]))
            maxes.append(float(feas.max()))
        return float(min(maxes)) + sum(k * m for k, m in zip(kap, maxes))

    def combine(self, values, trainers=None):
        if not values:
            return 0.0
        if trainers is None:
            raise ValueError(
                "MaxMinFairness.combine needs the trainers list: the "
                "leximin tiebreak weights are derived from per-Trainer "
                "progress ranks")
        kap = self._kappas(trainers)
        return float(min(values)) + sum(k * v for k, v in zip(kap, values))

    def combiner(self, trainers):
        kap = self._kappas(trainers)    # ranks are solve-time constants

        def combine(values):
            if not values:
                return 0.0
            return (float(min(values))
                    + sum(k * v for k, v in zip(kap, values)))
        return combine

    def move_evaluator(self, trainers):
        """Lexicographic (Δmin, Δtiebreak) move gains.

        Both components are computed as *exact deltas*: Δtiebreak is
        ``Σ κ_i·(v_new − v_old)`` over only the changed entries, never
        ``combine(new) − combine(old)`` — a κ of ``tiebreak^9 ≈ 1e-18``
        is far below one ulp of the O(1) aggregate, so the subtraction
        form would round deep-rank gains to exactly 0 and re-starve the
        jobs the policy protects.  Comparing ``(Δmin, Δtiebreak)``
        tuples makes any true lift of the minimum dominate and keeps
        arbitrarily deep tiebreak gains ordered correctly.
        """
        kap = self._kappas(trainers)

        def f(vals, changes):
            if not changes:
                return (0.0, 0.0)
            old_min = min(vals)
            changed = dict(changes)
            new_min = min(changed.get(i, v) for i, v in enumerate(vals))
            d_tie = sum(kap[i] * (v - vals[i]) for i, v in changes)
            return (new_min - old_min, d_tie)
        return f

    def build(self, b, jobs, t_fwd):
        if not jobs:
            return 0.0
        exprs = []
        offset = 0.0
        kappas = self._kappas([jt.spec for jt in jobs])
        for jt, kap in zip(jobs, kappas):
            t = jt.spec
            d = _norm_denom(t, t_fwd)
            o_cj = t.value_at(jt.cj)
            # p_j(N_j) = progress_j + (t_fwd*O - pen_up*z_up - pen_dw*z_dw)/d
            coeffs = {var: t_fwd * coef / d
                      for var, coef in jt.value_expr.items()}
            coeffs[jt.z_up] = coeffs.get(jt.z_up, 0.0) - o_cj * t.r_up / d
            coeffs[jt.z_dw] = coeffs.get(jt.z_dw, 0.0) - o_cj * t.r_dw / d
            exprs.append((float(t.progress), coeffs))
            # leximin tiebreak: kappa_j * p_j
            for var, coef in coeffs.items():
                b.set_obj(var, kap * coef)
            offset += kap * float(t.progress)
        f = epigraph_min(b, "f_minprog", exprs)
        b.set_obj(f, 1.0)
        return offset


class DeadlineAware(Objective):
    """Throughput with a soft-deadline penalty on projected finish time.

    A Trainer with ``deadline`` (seconds from now) and known remaining
    work ``(1 - progress) * work`` finishes by its deadline iff its rate
    clears the *required rate*

        req_j = (1 - progress_j) * work_j / max(deadline_j, eps)

    (progress units / second) — so "projected finish <= deadline" is the
    linear condition ``O_j(N_j) >= req_j``, and the soft penalty is the
    hinge ``penalty_weight * t_fwd * max(0, req_j - O_j(N_j))``
    subtracted from the Eqn-16 objective.  In the MILPs the hinge is one
    slack variable ``s_j >= req_j - O_j(N_j), s_j >= 0`` per deadlined
    Trainer.  ``req_j`` is clamped to ``2 * O_j(n_max)``: a deadline that
    is already unreachable contributes a bounded (sunk) penalty instead
    of drowning the objective.  Trainers with no deadline (or unknown
    work) score plain throughput.

    Parameters
    ----------
    penalty_weight : float
        Progress units charged per unit of rate shortfall per forward
        window, relative to throughput gain (dimensionless, default 2.0:
        missing deadlines costs twice what raw throughput buys).
    """

    name = "deadline"

    def __init__(self, penalty_weight: float = 2.0):
        self.penalty_weight = float(penalty_weight)

    def cache_key(self):
        return (self.name, round(self.penalty_weight, 12))

    def spec_key(self, t):
        return (None if t.deadline is None else round(t.deadline, 9),
                None if t.work is None else round(t.work, 9),
                round(t.progress, 9))

    def _req_rate(self, t: "TrainerSpec") -> Optional[float]:
        """Required rate (progress units/s) to finish by the deadline, or
        ``None`` when no deadline applies."""
        if t.deadline is None or t.work is None or t.work <= 0:
            return None
        remaining = max(0.0, (1.0 - t.progress) * t.work)
        if remaining <= 0:
            return None
        req = remaining / max(float(t.deadline), _EPS)
        return min(req, 2.0 * t.value_at(t.n_max))

    def job_value(self, t, n, cj, t_fwd):
        v = t_fwd * t.value_at(n) - _rescale_penalty(t, n, cj)
        req = self._req_rate(t)
        if req is not None:
            v -= self.penalty_weight * t_fwd * max(0.0, req - t.value_at(n))
        return v

    def value_table(self, t, cj, t_fwd):
        o = _interp_table(t, t.n_max)
        v = t_fwd * o - _penalty_table(t, cj, t.n_max)
        req = self._req_rate(t)
        if req is not None:
            v = v - self.penalty_weight * t_fwd * np.maximum(0.0, req - o)
        return v

    def build(self, b, jobs, t_fwd):
        for jt in jobs:
            _eqn16_terms(b, jt, t_fwd)
            req = self._req_rate(jt.spec)
            if req is None:
                continue
            # hinge slack: s >= req - O(N), s >= 0
            s = b.add_var(f"dl_slack[{jt.spec.id}]", lb=0.0, ub=float("inf"))
            row = {s: 1.0}
            for var, coef in jt.value_expr.items():
                row[var] = row.get(var, 0.0) + coef
            b.add_row(row, lb=req)
            b.set_obj(s, -self.penalty_weight * t_fwd)
        return 0.0


class CostCap(Throughput):
    """Throughput under a per-job node-second budget.

    A Trainer with ``budget`` node-seconds remaining may hold at most
    ``floor(budget / t_fwd)`` nodes over the next forward window — spend
    rate capped so the budget survives the window; below ``n_min`` the
    Trainer must idle.  The cap is a hard constraint (MILP row /
    greedy target filter), the objective is plain Eqn 16.  Budgets are
    enforced at decision points only: between sparse pool events a
    Trainer keeps its allocation, so enforcement granularity is
    ``max(t_fwd, inter-event gap)`` (DESIGN.md §10).

    Parameters
    ----------
    default_budget : float, optional
        Node-seconds applied to Trainers whose spec carries no budget
        (``None`` = such Trainers are uncapped).
    """

    name = "costcap"

    def __init__(self, default_budget: Optional[float] = None):
        self.default_budget = default_budget

    def cache_key(self):
        return (self.name,
                None if self.default_budget is None
                else round(self.default_budget, 9))

    def spec_key(self, t):
        return (None if t.budget is None else round(t.budget, 9),)

    def count_cap(self, t, t_fwd):
        budget = t.budget if t.budget is not None else self.default_budget
        if budget is None:
            return None
        cap = int(max(0.0, float(budget)) // max(float(t_fwd), _EPS))
        return cap if cap >= t.n_min else 0


class LatencySLO(Objective):
    """SLO-attainment-weighted goodput for elastic serving Trainers.

    A serving Trainer advertises its *offered request rate* via
    ``spec.rate`` (requests/second, measured over a trailing window by
    :class:`repro.core.backend.ServingBackend`).  Requests served beyond
    the offered load are worthless (nobody is asking), and capacity
    shortfall is what queues requests past their latency SLO — so the
    per-Trainer value saturates at the *required capacity*

        req_j = headroom * rate_j      (requests / second)

    and shortfall below it is charged ``miss_weight`` times what surplus
    capacity earns:

        v_j(N) = t_fwd * (min(O_j, req_j) - miss_weight * max(0, req_j - O_j)
                          + tie_eps * O_j) - rescale_penalty

    where ``O_j = O_j(N_j)`` is the replica capacity curve
    (requests/second at N nodes).  ``headroom`` buys queueing slack: a
    replica running exactly at the arrival rate has unbounded queues
    (utilization 1), so the policy provisions ``headroom``× the offered
    load, which is what keeps p99 latency under the SLO.  ``req_j`` is
    clamped to ``2 * O_j(n_max)`` exactly like
    :class:`DeadlineAware`: unreachable demand contributes a bounded
    (sunk) penalty instead of drowning the objective.  ``tie_eps`` adds a
    vanishing throughput slope past saturation so the MILP is never
    indifferent between node counts the saturated term cannot separate
    (and the greedy/MILP views stay in exact parity — both include it).

    Trainers with ``rate is None`` (training jobs sharing the pool)
    score the plain Eqn-16 throughput objective, so mixed
    serving+training pools work out of the box.  ``spec.slo`` (the
    latency target itself) is *not* read here — attainment against it is
    measured by the replica simulation (``repro.serving``); the
    allocator only sees its capacity-rate proxy.

    In the MILPs the saturating hinge is one slack variable per serving
    Trainer, via the identity (``s_j = max(0, req_j - O_j)``):

        min(O_j, req_j) - miss_weight * max(0, req_j - O_j)
            = req_j - (1 + miss_weight) * s_j

    with ``s_j >= req_j - O_j(N_j), s_j >= 0`` and the constant
    ``t_fwd * req_j`` returned as the build offset.

    Parameters
    ----------
    headroom : float
        Capacity provisioned per unit of offered load (dimensionless,
        default 1.25 — 25% queueing slack).
    miss_weight : float
        Penalty per unit of capacity shortfall relative to what surplus
        earns (dimensionless, default 4.0: an under-provisioned replica
        outbids any tie_eps surplus elsewhere).
    tie_eps : float
        Residual throughput slope past saturation (dimensionless,
        default 1e-6).
    """

    name = "latency_slo"

    def __init__(self, headroom: float = 1.25, miss_weight: float = 4.0,
                 tie_eps: float = 1e-6):
        self.headroom = float(headroom)
        self.miss_weight = float(miss_weight)
        self.tie_eps = float(tie_eps)

    def cache_key(self):
        return (self.name, round(self.headroom, 12),
                round(self.miss_weight, 12), round(self.tie_eps, 12))

    def spec_key(self, t):
        return (None if t.rate is None else round(t.rate, 9),)

    def _req_rate(self, t: "TrainerSpec") -> Optional[float]:
        """Required capacity (requests/s) for Trainer ``t``, or ``None``
        when it is not a serving job."""
        if t.rate is None:
            return None
        req = self.headroom * max(0.0, float(t.rate))
        return min(req, 2.0 * t.value_at(t.n_max))

    def job_value(self, t, n, cj, t_fwd):
        o = t.value_at(n)
        req = self._req_rate(t)
        if req is None:
            return t_fwd * o - _rescale_penalty(t, n, cj)
        v = (min(o, req) - self.miss_weight * max(0.0, req - o)
             + self.tie_eps * o)
        return t_fwd * v - _rescale_penalty(t, n, cj)

    def value_table(self, t, cj, t_fwd):
        o = _interp_table(t, t.n_max)
        pen = _penalty_table(t, cj, t.n_max)
        req = self._req_rate(t)
        if req is None:
            return t_fwd * o - pen
        v = (np.minimum(o, req)
             - self.miss_weight * np.maximum(0.0, req - o)
             + self.tie_eps * o)
        return t_fwd * v - pen

    def build(self, b, jobs, t_fwd):
        offset = 0.0
        for jt in jobs:
            req = self._req_rate(jt.spec)
            if req is None:
                _eqn16_terms(b, jt, t_fwd)
                continue
            # rescale-cost terms, identical to Eqn 16's
            o_cj = jt.spec.value_at(jt.cj)
            b.set_obj(jt.z_up, -o_cj * jt.spec.r_up)
            b.set_obj(jt.z_dw, -o_cj * jt.spec.r_dw)
            # saturating hinge: s >= req - O(N), s >= 0, objective
            # t_fwd * (req - (1 + miss_weight) * s + tie_eps * O(N))
            s = b.add_var(f"slo_slack[{jt.spec.id}]", lb=0.0,
                          ub=float("inf"))
            row = {s: 1.0}
            for var, coef in jt.value_expr.items():
                row[var] = row.get(var, 0.0) + coef
            b.add_row(row, lb=req)
            b.set_obj(s, -(1.0 + self.miss_weight) * t_fwd)
            for var, coef in jt.value_expr.items():
                b.set_obj(var, self.tie_eps * t_fwd * coef)
            offset += t_fwd * req
        return offset


#: Registry of named policies (string -> zero-arg constructor); strings
#: are accepted anywhere an Objective is (``resolve_objective``).
OBJECTIVES = {
    "throughput": Throughput,
    "weighted": WeightedPriority,
    "maxmin": MaxMinFairness,
    "deadline": DeadlineAware,
    "costcap": CostCap,
    "latency_slo": LatencySLO,
}


def resolve_objective(obj) -> Objective:
    """Coerce ``None`` (-> :class:`Throughput`), a registry name, or an
    :class:`Objective` instance into an instance.

    Raises ``KeyError`` for unknown names and ``TypeError`` for anything
    else.
    """
    if obj is None:
        return Throughput()
    if isinstance(obj, Objective):
        return obj
    if isinstance(obj, str):
        try:
            return OBJECTIVES[obj]()
        except KeyError:
            raise KeyError(f"unknown objective {obj!r}; "
                           f"available: {sorted(OBJECTIVES)}") from None
    raise TypeError(f"objective must be None, a name or an Objective, "
                    f"got {type(obj).__name__}")
