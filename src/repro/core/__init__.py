# The paper's primary contribution: MILP-based elastic resource allocation
# for DNN Trainers on unfillable idle nodes, plus the event-driven
# BFTrainer scheduler/simulator around it.
from repro.core.allocator import Allocator, EqualShareAllocator, MILPAllocator
from repro.core.backend import (
    AnalyticBackend,
    ExecutionBackend,
    LiveBackend,
    ServingBackend,
)
from repro.core.engine import AllocationEngine, EngineStats, problem_signature
from repro.core.events import (
    EventStreamError,
    Fragment,
    PoolEvent,
    fragments_to_events,
    merge_events,
    merge_fragments,
    pool_sizes,
    validate_events,
    validate_fragments,
)
from repro.core.greedy import PAIR_REPAIR_MAX_TRAINERS, solve_greedy
from repro.core.loop import ControlLoop, EventRecord, LoopStats
from repro.core.metrics import (
    Efficiency,
    ROI,
    deadline_miss_rate,
    eq_nodes,
    jain_fairness,
    min_normalized_progress,
    normalized_progress,
    resource_integral,
)
from repro.core.milp import (
    AllocationProblem,
    AllocationResult,
    TrainerSpec,
    project_current,
    solve_node_milp,
)
from repro.core.milp_fast import reconstruct_map, solve_fast_milp
from repro.core.objectives import (
    OBJECTIVES,
    CostCap,
    DeadlineAware,
    LatencySLO,
    MaxMinFairness,
    Objective,
    Throughput,
    WeightedPriority,
    cached_value_table,
    resolve_objective,
)
from repro.core.scaling import ScalingCurve, all_tab2_curves, amdahl_curve, model_zoo_curves, tab2_curve
from repro.core.simulator import SimReport, Simulator, TrainerJob, static_outcome
from repro.core.tfwd import TfwdEstimator, resolve_tfwd
from repro.core.trace import TraceStats, clip_fragments, generate_summit_like, load_trace_csv, trace_stats

__all__ = [
    "Allocator", "EqualShareAllocator", "MILPAllocator",
    "AnalyticBackend", "ExecutionBackend", "LiveBackend", "ServingBackend",
    "ControlLoop", "EventRecord", "LoopStats",
    "AllocationEngine", "EngineStats", "problem_signature", "solve_greedy",
    "PAIR_REPAIR_MAX_TRAINERS", "cached_value_table",
    "EventStreamError", "Fragment", "PoolEvent", "fragments_to_events",
    "merge_events", "merge_fragments", "pool_sizes", "validate_events",
    "validate_fragments",
    "Efficiency", "ROI", "eq_nodes", "resource_integral",
    "jain_fairness", "normalized_progress", "min_normalized_progress",
    "deadline_miss_rate",
    "AllocationProblem", "AllocationResult", "TrainerSpec",
    "project_current", "solve_node_milp",
    "reconstruct_map", "solve_fast_milp",
    "OBJECTIVES", "CostCap", "DeadlineAware", "LatencySLO", "MaxMinFairness",
    "Objective", "Throughput", "WeightedPriority", "resolve_objective",
    "ScalingCurve", "all_tab2_curves", "amdahl_curve", "model_zoo_curves", "tab2_curve",
    "SimReport", "Simulator", "TrainerJob", "static_outcome",
    "TfwdEstimator", "resolve_tfwd",
    "TraceStats", "clip_fragments", "generate_summit_like", "load_trace_csv", "trace_stats",
]
