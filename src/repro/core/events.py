"""Idle-node pool events and fragments (paper §2.1 terminology).

A *fragment* is a period during which one node is idle; an *event* is a
time at which the idle pool N changes (nodes join and/or leave; multiple
simultaneous changes are one event).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Sequence, Set, Tuple


@dataclass(frozen=True)
class Fragment:
    node: int
    start: float
    end: float

    @property
    def length(self) -> float:
        return self.end - self.start


@dataclass(frozen=True)
class PoolEvent:
    time: float
    joined: Tuple[int, ...] = ()
    left: Tuple[int, ...] = ()


def fragments_to_events(fragments: Sequence[Fragment]) -> List[PoolEvent]:
    """Convert fragments into a merged, time-sorted event stream."""
    changes: Dict[float, Tuple[List[int], List[int]]] = {}
    for f in fragments:
        changes.setdefault(f.start, ([], []))[0].append(f.node)
        changes.setdefault(f.end, ([], []))[1].append(f.node)
    out = []
    for t in sorted(changes):
        joined, left = changes[t]
        out.append(PoolEvent(time=t, joined=tuple(sorted(joined)),
                             left=tuple(sorted(left))))
    return out


def merge_events(events: Sequence[PoolEvent]) -> List[PoolEvent]:
    """Sort events and merge those sharing a timestamp into one event per
    time point, preserving sequential-application semantics: events at the
    same instant are applied in their given order, and the *last* action
    on a node wins (a leave followed by a rejoin keeps the node; a join
    followed by a leave drops it)."""
    out: List[PoolEvent] = []
    for e in sorted(events, key=lambda e: e.time):
        if out and out[-1].time == e.time:
            delta: Dict[int, bool] = {}
            for ev in (out[-1], e):
                for n in ev.joined:
                    delta[n] = True
                for n in ev.left:
                    delta[n] = False
            out[-1] = PoolEvent(
                time=e.time,
                joined=tuple(sorted(n for n, v in delta.items() if v)),
                left=tuple(sorted(n for n, v in delta.items() if not v)))
        else:
            out.append(e)
    return out


def pool_sizes(events: Sequence[PoolEvent]) -> List[Tuple[float, int]]:
    """(time, |N|) step function after each event."""
    size = 0
    out = []
    for e in events:
        size += len(e.joined) - len(e.left)
        out.append((e.time, size))
    return out


def validate_fragments(fragments: Iterable[Fragment]) -> None:
    """Raise ``ValueError`` on malformed fragments.

    Checks the invariants every trace producer must uphold: ``end > start``,
    non-negative node ids, and no two fragments of the same node overlapping
    (overlaps would double-count a node in the idle pool).
    """
    last_end: Dict[int, float] = {}
    for f in sorted(fragments, key=lambda f: (f.node, f.start)):
        if f.node < 0:
            raise ValueError(f"fragment has negative node id: {f}")
        if not f.end > f.start:
            raise ValueError(f"fragment has end <= start: {f}")
        prev = last_end.get(f.node)
        if prev is not None and f.start < prev:
            raise ValueError(
                f"fragments overlap on node {f.node}: "
                f"[{f.start}, {f.end}) starts before {prev}")
        last_end[f.node] = f.end


def merge_fragments(fragments: Iterable[Fragment],
                    gap: float = 0.0) -> List[Fragment]:
    """Merge same-node fragments separated by at most ``gap`` seconds."""
    by_node: Dict[int, List[Fragment]] = {}
    for f in fragments:
        by_node.setdefault(f.node, []).append(f)
    out: List[Fragment] = []
    for node, frs in by_node.items():
        frs.sort(key=lambda f: f.start)
        cur_s, cur_e = frs[0].start, frs[0].end
        for f in frs[1:]:
            if f.start <= cur_e + gap:
                cur_e = max(cur_e, f.end)
            else:
                out.append(Fragment(node=node, start=cur_s, end=cur_e))
                cur_s, cur_e = f.start, f.end
        out.append(Fragment(node=node, start=cur_s, end=cur_e))
    out.sort(key=lambda f: (f.start, f.node))
    return out
