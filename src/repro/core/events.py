"""Idle-node pool events and fragments (paper §2.1 terminology).

A *fragment* is a period during which one node is idle; an *event* is a
time at which the idle pool N changes (nodes join and/or leave; multiple
simultaneous changes are one event).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Sequence, Set, Tuple


@dataclass(frozen=True)
class Fragment:
    node: int
    start: float
    end: float

    @property
    def length(self) -> float:
        return self.end - self.start


@dataclass(frozen=True)
class PoolEvent:
    time: float
    joined: Tuple[int, ...] = ()
    left: Tuple[int, ...] = ()


def fragments_to_events(fragments: Sequence[Fragment]) -> List[PoolEvent]:
    """Convert fragments into a merged, time-sorted event stream."""
    changes: Dict[float, Tuple[List[int], List[int]]] = {}
    for f in fragments:
        changes.setdefault(f.start, ([], []))[0].append(f.node)
        changes.setdefault(f.end, ([], []))[1].append(f.node)
    out = []
    for t in sorted(changes):
        joined, left = changes[t]
        out.append(PoolEvent(time=t, joined=tuple(sorted(joined)),
                             left=tuple(sorted(left))))
    return out


def pool_sizes(events: Sequence[PoolEvent]) -> List[Tuple[float, int]]:
    """(time, |N|) step function after each event."""
    size = 0
    out = []
    for e in events:
        size += len(e.joined) - len(e.left)
        out.append((e.time, size))
    return out
