"""Idle-node pool events and fragments (paper §2.1 terminology).

A *fragment* is a period during which one node is idle; an *event* is a
time at which the idle pool N changes (nodes join and/or leave; multiple
simultaneous changes are one event).

Beyond the paper's join/leave kinds, an event may carry *failed* nodes
(DESIGN.md §12): a hard kill removes the node like a leave but without
the drain grace — the holding Trainer rolls its progress back to its
last checkpoint and pays a restart penalty on top of the forced
scale-down.  ``failed`` tuples are produced by the fault-injection layer
(``repro.chaos``); trace-derived streams never carry them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np


@dataclass(frozen=True)
class Fragment:
    node: int
    start: float
    end: float

    @property
    def length(self) -> float:
        return self.end - self.start


@dataclass(frozen=True)
class PoolEvent:
    time: float
    joined: Tuple[int, ...] = ()
    left: Tuple[int, ...] = ()
    # hard node failures (kill, not drain): removed from the pool like
    # ``left``, but the loop additionally applies restart-penalty /
    # checkpoint-rollback semantics (DESIGN.md §12)
    failed: Tuple[int, ...] = ()
    # owning pool shard on the federated path (DESIGN.md §14); ``None``
    # on single-pool streams.  Set by ``split_events_by_pool`` — every
    # node in a tagged event belongs to that pool.
    pool: Optional[int] = None
    # monotone per-stream sequence number stamped by the resource
    # monitor (DESIGN.md §16); ``None`` on trusted offline streams.
    # The hygiene layer uses it for dedup and same-instant conflict
    # resolution; everything downstream ignores it.
    seq: Optional[int] = None


class EventStreamError(ValueError):
    """A malformed control-plane event stream: a leave/fail of a node
    that is not in the believed pool, a join of a node already live, or
    a negative pool size.  Raised only in ``strict=True`` paths — the
    default folds stay permissive for backward compatibility."""


def fragments_to_events(fragments: Sequence[Fragment]) -> List[PoolEvent]:
    """Convert fragments into a merged, time-sorted event stream.

    Vectorized (one lexsort over all endpoints + grouped slicing) so
    month-scale traces with 10⁵⁺ fragments convert in numpy time.
    """
    if not fragments:
        return []
    nodes = np.fromiter((f.node for f in fragments), dtype=np.int64,
                        count=len(fragments))
    starts = np.fromiter((f.start for f in fragments), dtype=float,
                         count=len(fragments))
    ends = np.fromiter((f.end for f in fragments), dtype=float,
                       count=len(fragments))
    times = np.concatenate([starts, ends])
    kind = np.concatenate([np.zeros(len(nodes), dtype=np.int8),
                           np.ones(len(nodes), dtype=np.int8)])
    nids = np.concatenate([nodes, nodes])
    order = np.lexsort((nids, kind, times))
    times, kind, nids = times[order], kind[order], nids[order]
    bounds = np.flatnonzero(np.diff(times)) + 1
    out: List[PoolEvent] = []
    lo = 0
    for hi in list(bounds) + [len(times)]:
        k = kind[lo:hi]
        nd = nids[lo:hi]
        out.append(PoolEvent(time=float(times[lo]),
                             joined=tuple(int(x) for x in nd[k == 0]),
                             left=tuple(int(x) for x in nd[k == 1])))
        lo = hi
    return out


def merge_events(events: Sequence[PoolEvent]) -> List[PoolEvent]:
    """Sort events and merge those sharing a timestamp into one event per
    time point, preserving sequential-application semantics: events at the
    same instant are applied in their given order, and the *last* action
    on a node wins (a leave followed by a rejoin keeps the node; a join
    followed by a leave drops it; a fail after any action kills the
    node).  Within one event joins apply before leaves before fails, so
    an injected kill always beats the trace's own same-instant action."""
    out: List[PoolEvent] = []
    for e in sorted(events, key=lambda e: e.time):
        if out and out[-1].time == e.time:
            delta: Dict[int, str] = {}
            for ev in (out[-1], e):
                for n in ev.joined:
                    delta[n] = "join"
                for n in ev.left:
                    delta[n] = "leave"
                for n in ev.failed:
                    delta[n] = "fail"
            out[-1] = PoolEvent(
                time=e.time,
                joined=tuple(sorted(n for n, v in delta.items()
                                    if v == "join")),
                left=tuple(sorted(n for n, v in delta.items()
                                  if v == "leave")),
                failed=tuple(sorted(n for n, v in delta.items()
                                    if v == "fail")))
        else:
            out.append(e)
    return out


def split_events_by_pool(events: Sequence[PoolEvent],
                         pool_of: Callable[[int], int]
                         ) -> Dict[int, List[PoolEvent]]:
    """Split a fleet event stream into per-pool, pool-tagged substreams.

    This is the federated ingestion primitive (DESIGN.md §14): an event
    touching nodes of pools {1, 3} becomes one sub-event in pool 1's
    stream and one in pool 3's — the other K−2 pools never see it, so a
    pool's decision cadence depends only on its own churn, never on the
    fleet's merged timeline.  Each sub-event carries ``pool=k`` and only
    that pool's nodes; within each substream, relative event order (and
    therefore sequential-application semantics) is preserved.
    """
    out: Dict[int, List[PoolEvent]] = {}
    for e in events:
        buckets: Dict[int, Dict[str, List[int]]] = {}
        for attr in ("joined", "left", "failed"):
            for n in getattr(e, attr):
                b = buckets.setdefault(pool_of(n), {"joined": [], "left": [],
                                                    "failed": []})
                b[attr].append(n)
        for k in sorted(buckets):
            b = buckets[k]
            out.setdefault(k, []).append(PoolEvent(
                time=e.time, joined=tuple(b["joined"]),
                left=tuple(b["left"]), failed=tuple(b["failed"]), pool=k))
    return out


def apply_events(live: Set[int], events: Sequence[PoolEvent], *,
                 strict: bool = False) -> Set[int]:
    """Fold ``events`` over a live-node set: joins add, leaves and
    failures remove.  Returns a new set (``live`` is not mutated) — the
    federated layer uses this to carry each pool's membership across
    decision epochs even when the pool's loop short-circuits.

    With ``strict=True`` a join of an already-live node or a leave/fail
    of an unknown node raises :class:`EventStreamError` instead of
    folding silently — the resilience layer (DESIGN.md §16) runs its
    believed-membership state machine in this mode so corruption cannot
    hide inside set semantics.
    """
    out = set(live)
    for e in events:
        if strict:
            for n in e.joined:
                if n in out:
                    raise EventStreamError(
                        f"t={e.time}: join of already-live node {n}")
            for n in e.left:
                if n not in out:
                    raise EventStreamError(
                        f"t={e.time}: leave of unknown node {n}")
            for n in e.failed:
                if n not in out:
                    raise EventStreamError(
                        f"t={e.time}: failure of unknown node {n}")
        out.update(e.joined)
        out.difference_update(e.left)
        out.difference_update(e.failed)
    return out


def pool_sizes(events: Sequence[PoolEvent], *,
               strict: bool = False) -> List[Tuple[float, int]]:
    """(time, |N|) step function after each event.

    With ``strict=True`` a negative running size raises
    :class:`EventStreamError` — a stream that removes more nodes than
    ever joined is corrupt, and the permissive default would silently
    report impossible pool sizes.
    """
    size = 0
    out = []
    for e in events:
        size += len(e.joined) - len(e.left) - len(e.failed)
        if strict and size < 0:
            raise EventStreamError(
                f"t={e.time}: pool size went negative ({size})")
        out.append((e.time, size))
    return out


def validate_events(events: Sequence[PoolEvent],
                    initial: Iterable[int] = ()) -> List[str]:
    """Return a list of human-readable problems in an event stream
    (empty when clean).  Non-raising companion to the ``strict=`` modes:
    the hygiene layer calls this to *count and classify* defects while
    still making progress, whereas ``apply_events(..., strict=True)``
    hard-fails on the first one.

    Checks, folding in order: non-monotone timestamps, joins of live
    nodes, leaves/failures of unknown nodes, duplicate ``seq`` stamps,
    and a node appearing in more than one action of a single event.
    """
    problems: List[str] = []
    live = set(initial)
    seen_seq: Set[int] = set()
    last_t = float("-inf")
    for e in events:
        if e.time < last_t:
            problems.append(
                f"t={e.time}: timestamp regresses (prev {last_t})")
        last_t = max(last_t, e.time)
        if e.seq is not None:
            if e.seq in seen_seq:
                problems.append(f"t={e.time}: duplicate seq {e.seq}")
            seen_seq.add(e.seq)
        sets = (set(e.joined), set(e.left), set(e.failed))
        for i in range(3):
            for j in range(i + 1, 3):
                for n in sorted(sets[i] & sets[j]):
                    problems.append(
                        f"t={e.time}: node {n} in multiple actions "
                        f"of one event")
        for n in e.joined:
            if n in live:
                problems.append(
                    f"t={e.time}: join of already-live node {n}")
        for n in e.left:
            if n not in live:
                problems.append(f"t={e.time}: leave of unknown node {n}")
        for n in e.failed:
            if n not in live:
                problems.append(
                    f"t={e.time}: failure of unknown node {n}")
        live.update(e.joined)
        live.difference_update(e.left)
        live.difference_update(e.failed)
    return problems


def validate_fragments(fragments: Iterable[Fragment]) -> None:
    """Raise ``ValueError`` on malformed fragments.

    Checks the invariants every trace producer must uphold: ``end > start``,
    non-negative node ids, and no two fragments of the same node overlapping
    (overlaps would double-count a node in the idle pool).
    """
    last_end: Dict[int, float] = {}
    for f in sorted(fragments, key=lambda f: (f.node, f.start)):
        if f.node < 0:
            raise ValueError(f"fragment has negative node id: {f}")
        if not f.end > f.start:
            raise ValueError(f"fragment has end <= start: {f}")
        prev = last_end.get(f.node)
        if prev is not None and f.start < prev:
            raise ValueError(
                f"fragments overlap on node {f.node}: "
                f"[{f.start}, {f.end}) starts before {prev}")
        last_end[f.node] = f.end


def merge_fragments(fragments: Iterable[Fragment],
                    gap: float = 0.0) -> List[Fragment]:
    """Merge same-node fragments separated by at most ``gap`` seconds.

    Vectorized sweep: fragments are lexsorted by (node, start) and each
    node's timeline is shifted onto its own disjoint band of the real
    line, so one global running-max of the end times finds every merge
    boundary without a per-node Python loop.
    """
    frs = list(fragments)
    if not frs:
        return []
    nd = np.fromiter((f.node for f in frs), dtype=np.int64, count=len(frs))
    s = np.fromiter((f.start for f in frs), dtype=float, count=len(frs))
    e = np.fromiter((f.end for f in frs), dtype=float, count=len(frs))
    order = np.lexsort((s, nd))
    nd, s, e = nd[order], s[order], e[order]
    lo = min(float(s.min()), 0.0)
    band = (float(e.max()) - lo) + gap + 1.0     # > any same-node span + gap
    off = nd.astype(float) * band - lo
    s2, e2 = s + off, e + off
    run_end = np.maximum.accumulate(e2)
    new_run = np.ones(len(s2), dtype=bool)
    new_run[1:] = s2[1:] > run_end[:-1] + gap
    heads = np.flatnonzero(new_run)
    out_node = nd[heads]
    out_start = s[heads]
    out_end = np.maximum.reduceat(e2, heads) - off[heads]
    view = np.lexsort((out_node, out_start))
    return [Fragment(node=int(out_node[i]), start=float(out_start[i]),
                     end=float(out_end[i])) for i in view]
