"""Adaptive forward-looking time estimation.

The paper (§3.4.3): "In practice, T_fwd is not predictable because of the
uncertainty of job submission to the main queue.  For a new system,
however, we can look into the scheduler logs to extract a representative
T_fwd statistically … an estimation (with reduced variance) based on the
current state of scheduler queue … may benefit the optimization."

This module implements that suggestion (beyond-paper, recorded in
EXPERIMENTS.md): an online quantile estimator over the observed gaps
between *shrink* events (nodes leaving N) — the events that actually
invalidate a forward-looking assumption.  Using a conservative quantile
(default q=0.35) of the recent gap distribution reproduces the paper's
observation that mild under-estimates of T_fwd are safer than
over-estimates (Fig 8 ROI), while adapting when the machine's churn
changes instead of requiring manual tuning.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Optional, Tuple, Union

import numpy as np


@dataclass
class TfwdEstimator:
    """Online T_fwd from observed leave-event gaps."""

    quantile: float = 0.35
    window: int = 64                # recent gaps kept
    t_min: float = 10.0             # clamp (paper sweeps 10..600 s)
    t_max: float = 600.0
    default: float = 120.0          # before any observation (paper's knee)

    _gaps: Deque[float] = field(default_factory=deque)
    _last_leave: Optional[float] = None

    def observe(self, time: float, nodes_left: int) -> None:
        """Feed every pool event; only shrink events advance the estimate."""
        if nodes_left <= 0:
            return
        if self._last_leave is not None:
            gap = time - self._last_leave
            if gap > 0:
                self._gaps.append(gap)
                while len(self._gaps) > self.window:
                    self._gaps.popleft()
        self._last_leave = time

    def estimate(self) -> float:
        if len(self._gaps) < 4:
            return self.default
        q = float(np.quantile(np.asarray(self._gaps), self.quantile))
        return float(np.clip(q, self.t_min, self.t_max))


def resolve_tfwd(t_fwd: Union[float, str]
                 ) -> Tuple[Optional[TfwdEstimator], float]:
    """Parse a ``t_fwd`` config value as the ControlLoop accepts it: a
    constant (the paper's fixed forward-looking time) returns
    ``(None, value)``; the string ``"adaptive"`` returns a fresh estimator
    and its pre-observation default."""
    if t_fwd == "adaptive":
        est = TfwdEstimator()
        return est, est.default
    return None, float(t_fwd)
