"""Allocator interface: the MILP allocators (paper) and the equal-share
heuristic baseline (paper §5.1's comparison scheme)."""
from __future__ import annotations

from abc import ABC, abstractmethod
from dataclasses import dataclass
from typing import Dict, List

from repro.core.milp import (
    AllocationProblem,
    AllocationResult,
    TrainerSpec,
    project_current,
    solve_node_milp,
)
from repro.core.milp_fast import reconstruct_map, solve_fast_milp


class Allocator(ABC):
    name = "base"

    @abstractmethod
    def allocate(self, prob: AllocationProblem) -> AllocationResult:
        ...


class MILPAllocator(Allocator):
    """Paper allocator.  ``mode='node'`` is the faithful §3 model;
    ``mode='fast'`` is the count-based reformulation (identical optimum,
    orders of magnitude faster — DESIGN.md beyond-paper item 1)."""

    def __init__(self, mode: str = "fast", time_limit: float = 30.0):
        assert mode in ("node", "fast")
        self.mode = mode
        self.time_limit = time_limit
        self.name = f"milp-{mode}"

    def allocate(self, prob: AllocationProblem) -> AllocationResult:
        if self.mode == "node":
            return solve_node_milp(prob, time_limit=self.time_limit)
        return solve_fast_milp(prob, time_limit=self.time_limit)


class EqualShareAllocator(Allocator):
    """Heuristic baseline: distribute idle nodes equally among Trainers
    (respecting each Trainer's min/max), FCFS for the remainder."""

    name = "equal-share"

    def allocate(self, prob: AllocationProblem) -> AllocationResult:
        nodes = sorted(prob.nodes)
        trainers = prob.trainers
        n = len(nodes)
        counts: Dict[int, int] = {t.id: 0 for t in trainers}
        if trainers:
            base = n // len(trainers)
            for t in trainers:
                counts[t.id] = min(t.n_max, base)
            # hand out the remainder FCFS (trainer order = arrival order)
            left = n - sum(counts.values())
            for t in trainers:
                if left <= 0:
                    break
                extra = min(left, t.n_max - counts[t.id])
                counts[t.id] += extra
                left -= extra
            # below-minimum shares go back to the pool, redistributed FCFS
            for t in trainers:
                if 0 < counts[t.id] < t.n_min:
                    left = counts[t.id]
                    counts[t.id] = 0
                    for t2 in trainers:
                        if left <= 0:
                            break
                        extra = min(left, t2.n_max - counts[t2.id])
                        if counts[t2.id] > 0 or extra >= t2.n_min:
                            counts[t2.id] += extra
                            left -= extra
        current = project_current(prob)
        allocation = reconstruct_map(nodes, trainers, current, counts)
        return AllocationResult(allocation=allocation, counts=counts,
                                objective=None, wall_time=0.0,
                                solver_status="heuristic")
