"""Greedy count-based heuristic allocator (DESIGN.md §3.2).

Solves the aggregate allocation problem of ``milp_fast`` —

    max  Σ_j v_j(N_j)    s.t.  Σ_j N_j ≤ |N|,   N_j ∈ {0} ∪ [N^min_j, N^max_j]

    v_j(N) = T_fwd·O_j(N) − rescale_penalty_j(N)
    rescale_penalty_j(N) = O_j(C_j)·R^up_j  if N > C_j
                           O_j(C_j)·R^dw_j  if N < C_j,  else 0

— by marginal-gain water-filling over each Trainer's SOS2 breakpoints.
Starting from the all-zero count vector, the solver repeatedly applies the
single-Trainer grow move with the best *average gain per node*, where the
candidate targets for a Trainer at count c are: the activation jump
(0 → N^min), c+1, every breakpoint above c, the current count C_j (the
penalty-free point, so the rescale kink can be jumped over in one move) and
the free-capacity cap.  Average-gain jump selection walks the concave
envelope of each v_j, which makes plain water-filling exact for concave
curves and near-exact around the activation/rescale kinks; a bounded
single-Trainer polish pass plus a pairwise shrink-to-grow repair pass
(small instances only) cleans up the remaining local optima.

No LP/MILP machinery is involved: a solve is a few hundred Python-level
arithmetic ops (tens of microseconds), versus milliseconds for the
aggregate MILP and seconds for the node-level model.  Objective parity
against ``solve_fast_milp`` on randomized instances is asserted in
tests/test_engine.py.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.milp import (
    AllocationProblem,
    AllocationResult,
    TrainerSpec,
    project_current,
)
from repro.core.milp_fast import reconstruct_map

_EPS = 1e-9


def _value(t: TrainerSpec, n: int, cj: int, t_fwd: float) -> float:
    """v_j(n): forward-looking gain minus the rescale penalty (Eqn 16)."""
    if n > cj:
        pen = t.value_at(cj) * t.r_up
    elif n < cj:
        pen = t.value_at(cj) * t.r_dw
    else:
        pen = 0.0
    return t_fwd * t.value_at(n) - pen


def _grow_targets(t: TrainerSpec, c: int, free: int, cj: int) -> List[int]:
    """Candidate counts strictly above ``c`` reachable with ``free`` nodes."""
    hi = min(t.n_max, c + free)
    lo = t.n_min if c == 0 else c + 1
    if lo > hi:
        return []
    targets = {lo, hi}
    for p in t.points:
        if lo <= p <= hi:
            targets.add(int(p))
    if lo <= cj <= hi:
        targets.add(cj)          # penalty-free point: lets a move skip the kink
    return sorted(targets)


def _shrink_targets(t: TrainerSpec, c: int, cj: int) -> List[int]:
    """Candidate counts strictly below ``c`` (breakpoint grid + 0 + C_j)."""
    targets = {0}
    for p in t.points:
        if 0 < p < c and p >= t.n_min:
            targets.add(int(p))
    if 0 < cj < c and cj >= t.n_min:
        targets.add(cj)
    return sorted(targets)


def solve_greedy(prob: AllocationProblem, *, polish_rounds: int = 4,
                 pair_repair_limit: int = 12) -> AllocationResult:
    t0 = time.perf_counter()
    nodes = list(prob.nodes)
    n = len(nodes)
    trainers = prob.trainers

    current = project_current(prob)
    cj = {t.id: len(current[t.id]) for t in trainers}
    counts: Dict[int, int] = {t.id: 0 for t in trainers}
    free = n

    # value tables v_j(0..n_max): O(Σ n_max) interpolations up front, O(1)
    # lookups in the search loops below
    val_tab = {t.id: [_value(t, m, cj[t.id], prob.t_fwd)
                      for m in range(t.n_max + 1)] for t in trainers}

    def val(t: TrainerSpec, m: int) -> float:
        return val_tab[t.id][m]

    # --- water-filling: best average-gain grow move until none improves ---
    while free > 0:
        best = None                      # (per_node_gain, gain, trainer, target)
        for t in trainers:
            c = counts[t.id]
            for tgt in _grow_targets(t, c, free, cj[t.id]):
                gain = val(t, tgt) - val(t, c)
                if gain <= _EPS:
                    continue
                per = gain / (tgt - c)
                if best is None or per > best[0] + _EPS:
                    best = (per, gain, t, tgt)
        if best is None:
            break
        _, _, t, tgt = best
        free -= tgt - counts[t.id]
        counts[t.id] = tgt

    # --- single-Trainer polish: any feasible retarget that improves ---
    for _ in range(polish_rounds):
        improved = False
        for t in trainers:
            c = counts[t.id]
            cap = min(t.n_max, c + free)
            cand = [0] + [m for m in range(t.n_min, cap + 1)]
            best_m, best_v = c, val(t, c)
            for m in cand:
                v = val(t, m)
                if v > best_v + _EPS:
                    best_m, best_v = m, v
            if best_m != c:
                free -= best_m - c
                counts[t.id] = best_m
                improved = True
        if not improved:
            break

    # --- pairwise repair (small J only): shrink one Trainer to fund another ---
    if len(trainers) <= pair_repair_limit:
        improved = True
        rounds = 0
        while improved and rounds < polish_rounds:
            improved = False
            rounds += 1
            for td in trainers:
                cd = counts[td.id]
                if cd == 0:
                    continue
                for down in _shrink_targets(td, cd, cj[td.id]):
                    released = cd - down
                    d_loss = val(td, down) - val(td, cd)
                    for tu in trainers:
                        if tu.id == td.id:
                            continue
                        cu = counts[tu.id]
                        for up in _grow_targets(tu, cu, free + released,
                                                cj[tu.id]):
                            gain = val(tu, up) - val(tu, cu) + d_loss
                            if gain > _EPS:
                                free += released - (up - cu)
                                counts[td.id] = down
                                counts[tu.id] = up
                                improved = True
                                break
                        if improved:
                            break
                    if improved:
                        break
                if improved:
                    break

    objective = sum(val(t, counts[t.id]) for t in trainers)
    allocation = reconstruct_map(nodes, trainers, current, counts)
    return AllocationResult(allocation=allocation, counts=dict(counts),
                            objective=objective,
                            wall_time=time.perf_counter() - t0,
                            solver_status="greedy")
