"""Greedy count-based heuristic allocator (DESIGN.md §3.2, §10, §11).

Solves the aggregate allocation problem of ``milp_fast`` —

    max  combine(v_1(N_1), ..., v_J(N_J))
    s.t.  Σ_j N_j ≤ |N|,   N_j ∈ {0} ∪ [N^min_j, min(N^max_j, cap_j)]

where the per-Trainer value ``v_j`` and the aggregation ``combine`` come
from the problem's policy (``repro.core.objectives``; the default
``Throughput`` policy has ``v_j(N) = T_fwd·O_j(N) − rescale_penalty_j(N)``
and ``combine = sum``, i.e. the paper's Eqn 16) — by marginal-gain
water-filling over each Trainer's SOS2 breakpoints.

Starting from the all-zero count vector (or, for the engine's
incremental re-solve, from a warm-start count vector — ``start_counts``),
the solver repeatedly applies the single-Trainer grow move with the best
*average objective gain per node*, where the candidate targets for a
Trainer at count c are: the activation jump (0 → N^min), c+1, every
breakpoint above c, the current count C_j (the penalty-free point, so
the rescale kink can be jumped over in one move) and the
free-capacity/policy cap.  A bounded single-Trainer polish pass plus a
pairwise shrink-to-grow repair pass (small instances only, see
``PAIR_REPAIR_MAX_TRAINERS``) cleans up the remaining local optima.

Two implementations of the same search share this module:

* **vectorized** (separable policies, i.e. ``combine = sum``) — the
  per-Trainer value tables ``v_j(0..n_max)`` are materialized once per
  engine signature as dense numpy rows
  (``objectives.cached_value_table``), and each water-filling step is a
  single argmax over a (J × K) candidate-move gain matrix instead of
  nested Python loops.  At supercomputer scale (4,096 nodes × 64 jobs) a
  solve drops from ~1.2 s of Python loops to a few milliseconds
  (EXPERIMENTS.md §Scale);
* **scalar** (non-separable policies, e.g. max-min fairness) — move
  gains come from the policy's ``move_evaluator`` as *exact deltas* in
  any totally ordered type (lexicographic ``(d_min, d_tiebreak)`` pairs
  for max-min), so the search water-fills the minimum while arbitrarily
  deep leximin tiebreak gains stay ordered correctly instead of
  vanishing into float cancellation — the greedy climbs the same
  epigraph the MILP linearizes (DESIGN.md §10 consistency argument).

No LP/MILP machinery is involved.  Objective parity against
``solve_fast_milp`` per policy is asserted in tests/test_engine.py and
tests/test_objectives.py; vectorized-vs-scalar parity in
tests/test_engine.py as well.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.milp import (
    AllocationProblem,
    AllocationResult,
    TrainerSpec,
    project_current,
)
from repro.core.milp_fast import reconstruct_map
from repro.core.objectives import cached_value_table, resolve_objective

_EPS = 1e-9

#: Pairwise shrink-to-grow repair is O(J² · breakpoints²) per round, so
#: it runs only when the Trainer count is at most this.  Beyond it the
#: water-filling + single-Trainer polish result stands unrepaired — the
#: pass exists to fix rare two-Trainer local optima on small instances,
#: and its cost at J = 64 (≈ 40k move evaluations per round) would
#: dominate the whole solve; termination within the polish budget on
#: large instances is asserted in tests/test_engine.py.
PAIR_REPAIR_MAX_TRAINERS = 12


def _grow_targets(t: TrainerSpec, c: int, free: int, cj: int,
                  cap: Optional[int]) -> List[int]:
    """Candidate counts strictly above ``c`` reachable with ``free``
    nodes, respecting the policy cap."""
    hi = min(t.n_max, c + free)
    if cap is not None:
        hi = min(hi, cap)
    lo = t.n_min if c == 0 else c + 1
    if lo > hi:
        return []
    targets = {lo, hi}
    for p in t.points:
        if lo <= p <= hi:
            targets.add(int(p))
    if lo <= cj <= hi:
        targets.add(cj)          # penalty-free point: lets a move skip the kink
    return sorted(targets)


def _shrink_targets(t: TrainerSpec, c: int, cj: int) -> List[int]:
    """Candidate counts strictly below ``c`` (breakpoint grid + 0 + C_j)."""
    targets = {0}
    for p in t.points:
        if 0 < p < c and p >= t.n_min:
            targets.add(int(p))
    if 0 < cj < c and cj >= t.n_min:
        targets.add(cj)
    return sorted(targets)


def _clamp_start(trainers: List[TrainerSpec], start: Dict[int, int],
                 caps: Dict[int, Optional[int]], n: int) -> Dict[int, int]:
    """Snap a warm-start count vector onto the feasible lattice: counts
    above the policy/size cap shrink to it, counts stranded below
    ``n_min`` (e.g. after a preemption) evict to 0, and — if the vector
    still oversubscribes the pool (a caller passing a stale allocation
    without projecting it first) — the largest holders shrink/evict
    until Σ counts ≤ |N|, so the search never starts infeasible."""
    out = {}
    for t in trainers:
        c = int(start.get(t.id, 0))
        hi = t.n_max if caps[t.id] is None else min(t.n_max, caps[t.id])
        c = min(c, hi)
        if c < t.n_min:
            c = 0
        out[t.id] = c
    total = sum(out.values())
    n_min_of = {t.id: t.n_min for t in trainers}
    order = sorted(out, key=lambda tid: (-out[tid], tid))
    for tid in order:                 # largest holder first, deterministic
        if total <= n:
            break
        fit = out[tid] - (total - n)
        new = fit if fit >= n_min_of[tid] else 0
        total -= out[tid] - new
        out[tid] = new
    return out


def _pair_repair(trainers, cj, caps, polish_rounds, *, count_of, free_of,
                 gain2, better, zero, apply2) -> None:
    """Pairwise shrink-to-grow repair, shared by the vectorized and
    scalar paths (they differ only in how a two-Trainer move is scored
    and applied): shrink one Trainer to one of its shrink targets to
    fund a grow move on another; first improving move wins, restart the
    scan, bounded by ``polish_rounds`` rounds.

    ``gain2(td, down, tu, up)`` scores the combined move, ``better``
    compares it against ``zero``, ``apply2(t, m)`` commits one leg;
    ``count_of``/``free_of`` read current state.
    """
    improved = True
    rounds = 0
    while improved and rounds < polish_rounds:
        improved = False
        rounds += 1
        for td in trainers:
            cd = count_of(td.id)
            if cd == 0:
                continue
            for down in _shrink_targets(td, cd, cj[td.id]):
                released = cd - down
                for tu in trainers:
                    if tu.id == td.id:
                        continue
                    cu = count_of(tu.id)
                    for up in _grow_targets(tu, cu, free_of() + released,
                                            cj[tu.id], caps[tu.id]):
                        if better(gain2(td, down, tu, up), zero):
                            apply2(td, down)
                            apply2(tu, up)
                            improved = True
                            break
                    if improved:
                        break
                if improved:
                    break
            if improved:
                break


# ---------------------------------------------------------------------------
# Vectorized path (separable policies)
# ---------------------------------------------------------------------------


def _solve_separable_vec(prob: AllocationProblem, objective, nodes, trainers,
                         cj: Dict[int, int], caps, start: Dict[int, int],
                         polish_rounds: int, pair_repair_limit: int):
    """Water-filling / polish / pairwise repair over dense numpy value
    tables.  Returns the final ``counts`` dict and objective value."""
    j_cnt = len(trainers)
    if j_cnt == 0:
        return {}, 0.0
    n = len(nodes)
    hi = np.empty(j_cnt, dtype=np.int64)
    n_min = np.empty(j_cnt, dtype=np.int64)
    for i, t in enumerate(trainers):
        h = t.n_max if caps[t.id] is None else min(t.n_max, caps[t.id])
        hi[i] = max(h, 0)
        n_min[i] = t.n_min
    m_max = int(hi.max(initial=0))

    # dense value matrix; infeasible counts (1..n_min-1, > hi) at -inf so
    # they can never win an argmax
    v = np.full((j_cnt, m_max + 1), -np.inf)
    for i, t in enumerate(trainers):
        tab = cached_value_table(objective, t, cj[t.id], prob.t_fwd)
        v[i, :hi[i] + 1] = tab[:hi[i] + 1]
        if t.n_min > 1:
            v[i, 1:min(t.n_min, hi[i] + 1)] = -np.inf

    # static candidate targets per Trainer: breakpoints, n_min, C_j, hi.
    # 0 is a safe pad value — a grow target must exceed the current count.
    cand_sets = []
    for i, t in enumerate(trainers):
        s = {int(p) for p in t.points if t.n_min <= p <= hi[i]}
        if t.n_min <= hi[i]:
            s.add(int(t.n_min))
        s.add(int(hi[i]))
        if t.n_min <= cj[t.id] <= hi[i]:
            s.add(cj[t.id])
        cand_sets.append(sorted(s))
    k = max((len(s) for s in cand_sets), default=1)
    cand = np.zeros((j_cnt, k + 2), dtype=np.int64)
    for i, s in enumerate(cand_sets):
        cand[i, :len(s)] = s

    rows = np.arange(j_cnt)
    counts = np.array([start[t.id] for t in trainers], dtype=np.int64)
    free = n - int(counts.sum())
    curval = v[rows, counts]

    def grow_until_stuck():
        nonlocal free
        while free > 0:
            reach = np.minimum(hi, counts + free)
            cand[:, k] = np.minimum(counts + 1, m_max)
            cand[:, k + 1] = reach
            d = cand - counts[:, None]
            valid = (d > 0) & (cand <= reach[:, None])
            gains = np.where(valid, v[rows[:, None], cand] - curval[:, None],
                             -np.inf)
            per = np.where(gains > _EPS, gains / np.maximum(d, 1), -np.inf)
            flat = int(np.argmax(per))
            i, c = divmod(flat, per.shape[1])
            if not np.isfinite(per[i, c]):
                break
            tgt = int(cand[i, c])
            free -= tgt - int(counts[i])
            counts[i] = tgt
            curval[i] = v[i, tgt]

    # --- water-filling: best average-gain grow move until none improves ---
    grow_until_stuck()

    # --- single-Trainer polish: any feasible retarget that improves ---
    for _ in range(polish_rounds):
        improved = False
        for i in range(j_cnt):
            reach = int(min(hi[i], counts[i] + free))
            g = v[i, :reach + 1] - curval[i]
            m = int(np.argmax(g))
            if g[m] > _EPS and m != counts[i]:
                free -= m - int(counts[i])
                counts[i] = m
                curval[i] = v[i, m]
                improved = True
        if not improved:
            break
        grow_until_stuck()      # a polish evict may free nodes others can use

    # --- pairwise repair (small J only): shrink one Trainer to fund another
    if j_cnt <= pair_repair_limit:
        idx = {t.id: i for i, t in enumerate(trainers)}

        def apply2(t, m):
            nonlocal free
            i = idx[t.id]
            free -= m - int(counts[i])
            counts[i] = m
            curval[i] = v[i, m]

        _pair_repair(
            trainers, cj, caps, polish_rounds,
            count_of=lambda tid: int(counts[idx[tid]]),
            free_of=lambda: free,
            gain2=lambda td, down, tu, up:
                (v[idx[td.id], down] - curval[idx[td.id]])
                + (v[idx[tu.id], up] - curval[idx[tu.id]]),
            better=lambda g, z: g > z + _EPS, zero=0.0, apply2=apply2)

    out = {t.id: int(counts[i]) for i, t in enumerate(trainers)}
    return out, float(curval.sum()) if j_cnt else 0.0


# ---------------------------------------------------------------------------
# Scalar path (non-separable policies: exact move-gain deltas)
# ---------------------------------------------------------------------------


def _solve_scalar(prob: AllocationProblem, objective, nodes, trainers,
                  cj: Dict[int, int], caps, start: Dict[int, int],
                  polish_rounds: int, pair_repair_limit: int):
    n = len(nodes)
    counts: Dict[int, int] = dict(start)
    free = n - sum(counts.values())
    separable = objective.separable

    val_tab = {t.id: cached_value_table(objective, t, cj[t.id], prob.t_fwd)
               for t in trainers}

    def val(t: TrainerSpec, m: int) -> float:
        return float(val_tab[t.id][m])

    # per-Trainer value vector, maintained so the policy's move
    # evaluator can score candidate moves as exact deltas
    idx = {t.id: i for i, t in enumerate(trainers)}
    vals = [val(t, counts[t.id]) for t in trainers]

    # Move gains come from the policy (exact deltas — never
    # combine(new) - combine(old), whose cancellation would round away
    # gain components below one ulp of the aggregate, e.g. deep-rank
    # leximin tiebreaks).  Gains are any totally ordered type: floats
    # for separable policies, (d_min, d_tiebreak) tuples for max-min.
    gain_of = objective.move_evaluator(trainers)
    zero = gain_of(vals, [])

    def better(g, ref) -> bool:
        """g strictly better than ref (+noise epsilon when the gains
        are raw-unit floats; exact deltas need no epsilon)."""
        if separable:
            return g > ref + _EPS
        return g > ref

    def scale(g, s: float):
        return g * s if separable else tuple(x * s for x in g)

    def apply(t: TrainerSpec, m: int) -> None:
        nonlocal free
        free -= m - counts[t.id]
        counts[t.id] = m
        vals[idx[t.id]] = val(t, m)

    # --- water-filling: best average-gain grow move until none improves ---
    while free > 0:
        best = None                      # (per_node_gain, trainer, target)
        for t in trainers:
            c = counts[t.id]
            for tgt in _grow_targets(t, c, free, cj[t.id], caps[t.id]):
                gain = gain_of(vals, [(idx[t.id], val(t, tgt))])
                if not better(gain, zero):
                    continue
                per = scale(gain, 1.0 / (tgt - c))
                if best is None or better(per, best[0]):
                    best = (per, t, tgt)
        if best is None:
            break
        _, t, tgt = best
        apply(t, tgt)

    # --- single-Trainer polish: any feasible retarget that improves ---
    for _ in range(polish_rounds):
        improved = False
        for t in trainers:
            c = counts[t.id]
            cap = min(t.n_max, c + free)
            if caps[t.id] is not None:
                cap = min(cap, caps[t.id])
            cand = [0] + [m for m in range(t.n_min, cap + 1)]
            best_m, best_g = c, zero
            for m in cand:
                g = gain_of(vals, [(idx[t.id], val(t, m))])
                if better(g, best_g):
                    best_m, best_g = m, g
            if best_m != c:
                apply(t, best_m)
                improved = True
        if not improved:
            break

    # --- pairwise repair (small J only): shrink one Trainer to fund another
    if len(trainers) <= pair_repair_limit:
        _pair_repair(
            trainers, cj, caps, polish_rounds,
            count_of=lambda tid: counts[tid],
            free_of=lambda: free,
            gain2=lambda td, down, tu, up:
                gain_of(vals, [(idx[td.id], val(td, down)),
                               (idx[tu.id], val(tu, up))]),
            better=better, zero=zero, apply2=apply)

    return dict(counts), objective.combiner(trainers)(vals)


# ---------------------------------------------------------------------------


def solve_greedy(prob: AllocationProblem, *, polish_rounds: int = 4,
                 pair_repair_limit: int = PAIR_REPAIR_MAX_TRAINERS,
                 start_counts: Optional[Dict[int, int]] = None,
                 vectorize: bool = True) -> AllocationResult:
    """Objective-aware greedy solve of ``prob`` (see module docstring).

    Parameters
    ----------
    polish_rounds : int
        Max rounds of the single-Trainer polish / pairwise repair loops.
    pair_repair_limit : int
        Pairwise repair runs only when ``len(trainers)`` is at most this
        (default ``PAIR_REPAIR_MAX_TRAINERS``; it is
        O(J² · breakpoints²) per round).
    start_counts : dict[int, int], optional
        Warm-start count vector (Trainer id -> count), e.g. the previous
        allocation for the engine's incremental re-solve.  Counts are
        snapped onto the feasible lattice (above-cap shrinks, stranded
        below-``n_min`` evicts to 0) and the search then applies bounded
        grow/evict moves from there instead of filling from zero.
    vectorize : bool
        Use the numpy matrix path for separable policies (default).
        ``False`` forces the scalar reference path — the two are
        parity-tested against each other.

    Returns
    -------
    AllocationResult
        ``objective`` is the policy's ``combine`` over per-Trainer
        values, directly comparable with the MILP solvers' objectives.
    """
    t0 = time.perf_counter()
    objective = resolve_objective(prob.objective)
    nodes = list(prob.nodes)
    trainers = prob.trainers

    current = project_current(prob)
    cj = {t.id: len(current[t.id]) for t in trainers}
    caps = {t.id: objective.count_cap(t, prob.t_fwd) for t in trainers}
    start = _clamp_start(trainers, start_counts or {}, caps, len(nodes))

    if objective.separable and vectorize:
        counts, obj = _solve_separable_vec(
            prob, objective, nodes, trainers, cj, caps, start,
            polish_rounds, pair_repair_limit)
    else:
        counts, obj = _solve_scalar(
            prob, objective, nodes, trainers, cj, caps, start,
            polish_rounds, pair_repair_limit)

    allocation = reconstruct_map(nodes, trainers, current, counts)
    return AllocationResult(allocation=allocation, counts=dict(counts),
                            objective=obj,
                            wall_time=time.perf_counter() - t0,
                            solver_status="greedy")
