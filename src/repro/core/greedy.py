"""Greedy count-based heuristic allocator (DESIGN.md §3.2, §10).

Solves the aggregate allocation problem of ``milp_fast`` —

    max  combine(v_1(N_1), ..., v_J(N_J))
    s.t.  Σ_j N_j ≤ |N|,   N_j ∈ {0} ∪ [N^min_j, min(N^max_j, cap_j)]

where the per-Trainer value ``v_j`` and the aggregation ``combine`` come
from the problem's policy (``repro.core.objectives``; the default
``Throughput`` policy has ``v_j(N) = T_fwd·O_j(N) − rescale_penalty_j(N)``
and ``combine = sum``, i.e. the paper's Eqn 16) — by marginal-gain
water-filling over each Trainer's SOS2 breakpoints.

Starting from the all-zero count vector, the solver repeatedly applies
the single-Trainer grow move with the best *average objective gain per
node*, where the candidate targets for a Trainer at count c are: the
activation jump (0 → N^min), c+1, every breakpoint above c, the current
count C_j (the penalty-free point, so the rescale kink can be jumped over
in one move) and the free-capacity/policy cap.  Move gains come from the
policy's ``move_evaluator`` as *exact deltas* in any totally ordered
type: for separable policies (``combine = sum``) a move's gain is the
per-Trainer value delta — bit-for-bit the historical single-objective
algorithm; for max-min fairness it is a lexicographic
``(d_min, d_tiebreak)`` pair, so the search becomes water-filling on the
minimum (any true lift of the lagging Trainer dominates) while
arbitrarily deep leximin tiebreak gains stay ordered correctly instead
of vanishing into float cancellation — the greedy climbs the same
epigraph the MILP linearizes (DESIGN.md §10 consistency argument).
A bounded single-Trainer polish pass plus a pairwise shrink-to-grow
repair pass (small instances only) cleans up the remaining local optima.

No LP/MILP machinery is involved: a solve is a few hundred Python-level
arithmetic ops (tens of microseconds), versus milliseconds for the
aggregate MILP and seconds for the node-level model.  Objective parity
against ``solve_fast_milp`` per policy is asserted in
tests/test_engine.py and tests/test_objectives.py.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from repro.core.milp import (
    AllocationProblem,
    AllocationResult,
    TrainerSpec,
    project_current,
)
from repro.core.milp_fast import reconstruct_map

_EPS = 1e-9


def _grow_targets(t: TrainerSpec, c: int, free: int, cj: int,
                  cap: Optional[int]) -> List[int]:
    """Candidate counts strictly above ``c`` reachable with ``free``
    nodes, respecting the policy cap."""
    hi = min(t.n_max, c + free)
    if cap is not None:
        hi = min(hi, cap)
    lo = t.n_min if c == 0 else c + 1
    if lo > hi:
        return []
    targets = {lo, hi}
    for p in t.points:
        if lo <= p <= hi:
            targets.add(int(p))
    if lo <= cj <= hi:
        targets.add(cj)          # penalty-free point: lets a move skip the kink
    return sorted(targets)


def _shrink_targets(t: TrainerSpec, c: int, cj: int) -> List[int]:
    """Candidate counts strictly below ``c`` (breakpoint grid + 0 + C_j)."""
    targets = {0}
    for p in t.points:
        if 0 < p < c and p >= t.n_min:
            targets.add(int(p))
    if 0 < cj < c and cj >= t.n_min:
        targets.add(cj)
    return sorted(targets)


def solve_greedy(prob: AllocationProblem, *, polish_rounds: int = 4,
                 pair_repair_limit: int = 12) -> AllocationResult:
    """Objective-aware greedy solve of ``prob`` (see module docstring).

    Parameters
    ----------
    polish_rounds : int
        Max rounds of the single-Trainer polish / pairwise repair loops.
    pair_repair_limit : int
        Pairwise repair runs only when ``len(trainers)`` is at most this
        (it is O(J^2 · breakpoints^2) per round).

    Returns
    -------
    AllocationResult
        ``objective`` is the policy's ``combine`` over per-Trainer
        values, directly comparable with the MILP solvers' objectives.
    """
    from repro.core.objectives import resolve_objective

    t0 = time.perf_counter()
    objective = resolve_objective(prob.objective)
    nodes = list(prob.nodes)
    n = len(nodes)
    trainers = prob.trainers

    current = project_current(prob)
    cj = {t.id: len(current[t.id]) for t in trainers}
    counts: Dict[int, int] = {t.id: 0 for t in trainers}
    caps = {t.id: objective.count_cap(t, prob.t_fwd) for t in trainers}
    free = n
    separable = objective.separable

    # value tables v_j(0..n_max): O(Σ n_max) interpolations up front, O(1)
    # lookups in the search loops below
    val_tab = {t.id: [objective.job_value(t, m, cj[t.id], prob.t_fwd)
                      for m in range(t.n_max + 1)] for t in trainers}

    def val(t: TrainerSpec, m: int) -> float:
        return val_tab[t.id][m]

    # per-Trainer value vector, maintained so the policy's move
    # evaluator can score candidate moves as exact deltas
    idx = {t.id: i for i, t in enumerate(trainers)}
    vals = [val(t, 0) for t in trainers]

    # Move gains come from the policy (exact deltas — never
    # combine(new) - combine(old), whose cancellation would round away
    # gain components below one ulp of the aggregate, e.g. deep-rank
    # leximin tiebreaks).  Gains are any totally ordered type: floats
    # for separable policies, (d_min, d_tiebreak) tuples for max-min.
    gain_of = objective.move_evaluator(trainers)
    zero = gain_of(vals, [])

    def better(g, ref) -> bool:
        """g strictly better than ref (+noise epsilon when the gains
        are raw-unit floats; exact deltas need no epsilon)."""
        if separable:
            return g > ref + _EPS
        return g > ref

    def scale(g, s: float):
        return g * s if separable else tuple(x * s for x in g)

    def apply(t: TrainerSpec, m: int) -> None:
        nonlocal free
        free -= m - counts[t.id]
        counts[t.id] = m
        vals[idx[t.id]] = val(t, m)

    # --- water-filling: best average-gain grow move until none improves ---
    while free > 0:
        best = None                      # (per_node_gain, trainer, target)
        for t in trainers:
            c = counts[t.id]
            for tgt in _grow_targets(t, c, free, cj[t.id], caps[t.id]):
                gain = gain_of(vals, [(idx[t.id], val(t, tgt))])
                if not better(gain, zero):
                    continue
                per = scale(gain, 1.0 / (tgt - c))
                if best is None or better(per, best[0]):
                    best = (per, t, tgt)
        if best is None:
            break
        _, t, tgt = best
        apply(t, tgt)

    # --- single-Trainer polish: any feasible retarget that improves ---
    for _ in range(polish_rounds):
        improved = False
        for t in trainers:
            c = counts[t.id]
            cap = min(t.n_max, c + free)
            if caps[t.id] is not None:
                cap = min(cap, caps[t.id])
            cand = [0] + [m for m in range(t.n_min, cap + 1)]
            best_m, best_g = c, zero
            for m in cand:
                g = gain_of(vals, [(idx[t.id], val(t, m))])
                if better(g, best_g):
                    best_m, best_g = m, g
            if best_m != c:
                apply(t, best_m)
                improved = True
        if not improved:
            break

    # --- pairwise repair (small J only): shrink one Trainer to fund another ---
    if len(trainers) <= pair_repair_limit:
        improved = True
        rounds = 0
        while improved and rounds < polish_rounds:
            improved = False
            rounds += 1
            for td in trainers:
                cd = counts[td.id]
                if cd == 0:
                    continue
                for down in _shrink_targets(td, cd, cj[td.id]):
                    released = cd - down
                    for tu in trainers:
                        if tu.id == td.id:
                            continue
                        cu = counts[tu.id]
                        for up in _grow_targets(tu, cu, free + released,
                                                cj[tu.id], caps[tu.id]):
                            g = gain_of(vals, [(idx[td.id], val(td, down)),
                                               (idx[tu.id], val(tu, up))])
                            if better(g, zero):
                                apply(td, down)
                                apply(tu, up)
                                improved = True
                                break
                        if improved:
                            break
                    if improved:
                        break
                if improved:
                    break

    allocation = reconstruct_map(nodes, trainers, current, counts)
    return AllocationResult(allocation=allocation, counts=dict(counts),
                            objective=objective.combiner(trainers)(vals),
                            wall_time=time.perf_counter() - t0,
                            solver_status="greedy")
