"""Trainer scaling curves ``O_j(N_j)``.

The paper (Tab. 2) measures weak-scaling throughput (samples/s) of seven
ImageNet DNNs on Summit at 1..64 nodes; those rows are embedded verbatim
and drive the faithful reproduction experiments.  For the assigned
JAX model zoo we synthesize curves from an Amdahl-style communication
model (and ``benchmarks/bench_throughput.py`` measures real curves for
the smoke variants).
"""
from __future__ import annotations

import bisect
import math
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

# Paper Tab. 2 — samples/second (x1000) vs nodes, minibatch 32/GPU, Summit.
TAB2_NODES = [1, 2, 4, 8, 16, 32, 64]
TAB2 = {
    "AlexNet":    [7.1, 13.1, 21.1, 40.5, 74.0, 130.8, 202.1],
    "ResNet18":   [5.2, 10.6, 20.4, 39.6, 78.0, 144.8, 262.7],
    "MnasNet":    [3.2, 6.0, 11.5, 23.1, 43.9, 83.5, 160.5],
    "MobileNets": [3.0, 5.9, 11.4, 22.0, 42.5, 82.3, 155.2],
    "ShuffleNet": [2.8, 5.3, 10.0, 20.4, 38.9, 74.1, 145.1],
    "VGG-16":     [1.2, 2.4, 4.7, 9.3, 18.3, 36.2, 70.2],
    "DenseNet":   [1.0, 2.0, 3.8, 7.6, 15.0, 28.8, 57.8],
}


@dataclass(frozen=True)
class ScalingCurve:
    """Piecewise-linear throughput curve through (nodes, samples/s) points."""

    nodes: Tuple[int, ...]
    throughput: Tuple[float, ...]   # samples/s at each node count
    name: str = ""

    def __post_init__(self):
        assert len(self.nodes) == len(self.throughput) >= 2
        assert all(a < b for a, b in zip(self.nodes, self.nodes[1:]))

    # -- evaluation ----------------------------------------------------

    def __call__(self, n: float) -> float:
        """Interpolated throughput at n nodes (0 when n == 0)."""
        if n <= 0:
            return 0.0
        xs, ys = self.nodes, self.throughput
        if n <= xs[0]:
            return ys[0] * n / xs[0]
        if n >= xs[-1]:
            return ys[-1]
        i = bisect.bisect_right(xs, n) - 1
        t = (n - xs[i]) / (xs[i + 1] - xs[i])
        return ys[i] + t * (ys[i + 1] - ys[i])

    def efficiency(self, n: float) -> float:
        """Scaling efficiency: throughput normalized by perfect scaling."""
        if n <= 0:
            return 0.0
        per1 = self.throughput[0] / self.nodes[0]
        return self(n) / (n * per1)

    # -- MILP discretization --------------------------------------------

    def breakpoints(self, n_min: int, n_max: int, metric: str = "throughput",
                    max_points: int = 8) -> Tuple[List[int], List[float]]:
        """Discretization points for the SOS2 approximation, always
        including 0 (the waiting state, gain 0), n_min and n_max."""
        pts = {0, n_min, n_max}
        for x in self.nodes:
            if n_min <= x <= n_max:
                pts.add(int(x))
        pts = sorted(pts)
        # thin out to max_points, keeping endpoints
        while len(pts) > max_points:
            # drop the interior point whose removal changes the curve least
            best_i, best_err = None, None
            for i in range(1, len(pts) - 1):
                y0, y1, y2 = (self(pts[i - 1]), self(pts[i]), self(pts[i + 1]))
                t = (pts[i] - pts[i - 1]) / (pts[i + 1] - pts[i - 1])
                err = abs(y1 - (y0 + t * (y2 - y0)))
                if best_err is None or err < best_err:
                    best_i, best_err = i, err
            pts.pop(best_i)
        vals = [self._metric_value(p, metric) for p in pts]
        return pts, vals

    def _metric_value(self, n: float, metric: str) -> float:
        if n <= 0:
            return 0.0
        if metric == "throughput":
            return self(n)
        if metric == "efficiency":
            # paper §5.2: "scaling efficiency, a normalized version of
            # throughput that is agnostic to DNN throughput" — throughput in
            # units of the DNN's own single-node rate, so AlexNet's raw-rate
            # advantage over DenseNet disappears (fair share, Tab 4).
            per1 = self.throughput[0] / self.nodes[0]
            return self(n) / per1
        raise ValueError(metric)


def tab2_curve(name: str) -> ScalingCurve:
    return ScalingCurve(tuple(TAB2_NODES),
                        tuple(v * 1000.0 for v in TAB2[name]), name=name)


def all_tab2_curves() -> Dict[str, ScalingCurve]:
    return {k: tab2_curve(k) for k in TAB2}


def amdahl_curve(name: str, thr1: float, comm_frac: float,
                 max_nodes: int = 128) -> ScalingCurve:
    """Synthetic weak-scaling curve: per-step time = compute + comm where the
    all-reduce term grows as (n-1)/n (ring) — Amdahl-style saturation."""
    nodes, thr = [], []
    n = 1
    while n <= max_nodes:
        ring = (n - 1) / n if n > 1 else 0.0
        step_time = (1 - comm_frac) + comm_frac * (0.3 + 0.7 * ring) * (
            1 + 0.15 * math.log2(n))
        thr.append(thr1 * n / step_time / 1.0)
        nodes.append(n)
        n *= 2
    return ScalingCurve(tuple(nodes), tuple(thr), name=name)


def model_zoo_curves() -> Dict[str, ScalingCurve]:
    """Synthetic curves for the 10 assigned architectures.

    comm_frac is estimated from bytes-per-step / flops-per-step of each
    family (MoE all-to-all and SSM scans raise it; see DESIGN.md).
    """
    spec = {
        # name: (relative single-node throughput, comm fraction)
        "yi-6b": (1.00, 0.22),
        "jamba-v0.1-52b": (0.18, 0.38),
        "seamless-m4t-medium": (3.0, 0.15),
        "deepseek-v2-lite-16b": (0.55, 0.33),
        "minitron-8b": (0.80, 0.24),
        "gemma2-27b": (0.26, 0.30),
        "internvl2-76b": (0.09, 0.42),
        "granite-moe-3b-a800m": (2.0, 0.28),
        "mamba2-2.7b": (1.6, 0.18),
        "gemma-2b": (2.4, 0.14),
    }
    return {k: amdahl_curve(k, thr1 * 1000.0, cf)
            for k, (thr1, cf) in spec.items()}
