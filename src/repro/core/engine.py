"""AllocationEngine: memoized solver portfolio behind the Allocator
protocol (DESIGN.md §3).

Per-event allocation cost is the binding constraint for event-driven
re-allocation at scale (MalleTrain, arXiv:2404.15668).  The engine makes it
cheap with three layers:

1. **Memoization** — solves are cached under a canonical problem signature
   (pool size, T_fwd, per-Trainer spec + current count, node ids abstracted
   away), so the many repeated/near-identical events in week-long traces
   return in O(signature) time.  The cached *count vector* is re-grounded
   onto the event's concrete node ids with ``reconstruct_map``.
2. **Greedy first** — the water-filling heuristic (greedy.py) solves every
   instance in microseconds and is near-optimal (see EXPERIMENTS.md
   §Perf-Engine).
3. **Escalation** — when the predicted solver cost fits the per-event time
   budget, the engine escalates greedy → ``solve_fast_milp`` →
   ``solve_node_milp`` and keeps the best objective.  The cost predictors
   are deliberately crude linear/quadratic models; they only have to rank
   instances as cheap/expensive.

If every attempted solver fails (timeout/infeasible), the paper's §3.6
policy applies: keep the current map (``fell_back=True``).
"""
from __future__ import annotations

import dataclasses
import json
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.allocator import Allocator
from repro.obs.telemetry import NULL_TELEMETRY, Telemetry
from repro.core.greedy import solve_greedy
from repro.core.milp import (
    AllocationProblem,
    AllocationResult,
    project_current,
    solve_node_milp,
)
from repro.core.milp_fast import reconstruct_map, solve_fast_milp

Signature = Tuple

# Versioned schema tag for engine warm-state snapshots (DESIGN.md §12).
# Bump the suffix on any incompatible change to the payload layout.
SNAPSHOT_SCHEMA = "bftrainer-engine-snapshot/1"


def _tuplify(x):
    """Recursively convert lists back into tuples (JSON round-trip).

    Signature keys and count vectors are nested tuples of
    int/float/str/None, all of which survive JSON exactly; only the
    list-vs-tuple distinction is lost, which this restores."""
    if isinstance(x, list):
        return tuple(_tuplify(v) for v in x)
    return x


def dumps_snapshot(snap: Dict) -> str:
    """Serialize an engine snapshot to JSON text."""
    return json.dumps(snap)


def loads_snapshot(text: str) -> Dict:
    """Parse JSON text produced by :func:`dumps_snapshot`.  Tuple
    restoration happens inside ``AllocationEngine.restore``, so the
    returned dict can be fed to it (or ``from_snapshot``) directly."""
    return json.loads(text)


def problem_signature(prob: AllocationProblem) -> Tuple[Signature, List[int]]:
    """Canonical, node-id-free signature of an allocation problem.

    The key covers everything that can change the optimal *count vector*:
    pool size, ``t_fwd``, each Trainer's curve/cost spec and current
    count, the policy identity + parameters (``objective.cache_key()``)
    and — via ``objective.spec_key(t)`` — exactly the per-Trainer policy
    fields (weight/deadline/budget/work/progress) that policy reads.
    Policies that ignore a field (e.g. ``Throughput`` ignores progress)
    therefore keep their cache-hit rate even while the field drifts
    every event (DESIGN.md §10 cache-key semantics).

    Returns
    -------
    (key, order)
        ``order`` maps canonical position → index into ``prob.trainers``
        (Trainers sorted by their spec tuple, so two interchangeable
        Trainers are interchangeable in the cache too).
    """
    from repro.core.objectives import resolve_objective

    objective = resolve_objective(prob.objective)
    node_set = set(prob.nodes)
    items = []
    for t in prob.trainers:
        c = sum(1 for nid in prob.current.get(t.id, []) if nid in node_set)
        # optional policy fields encode as (present, value) so mixed
        # None/float spec keys stay sortable
        pol = tuple((0, 0.0) if v is None else (1, v)
                    for v in objective.spec_key(t))
        items.append((t.n_min, t.n_max, round(t.r_up, 9), round(t.r_dw, 9),
                      tuple(t.points), tuple(round(v, 9) for v in t.values),
                      c) + pol)
    order = sorted(range(len(items)), key=lambda i: items[i])
    key = (len(node_set), round(prob.t_fwd, 6), objective.cache_key(),
           tuple(items[i] for i in order))
    return key, order


@dataclass
class EngineStats:
    """Engine counters.  The engine maintains these through
    ``AllocationEngine._count``, which mirrors every increment into the
    attached telemetry hub (counter ``engine.<field>``) — the dataclass
    is the always-on cheap view, the hub the superset (histograms,
    per-arm latency) when telemetry is enabled (DESIGN.md §13)."""

    events: int = 0
    cache_hits: int = 0
    repairs: int = 0              # incremental warm-start repairs accepted
    repair_escalations: int = 0   # repairs whose bound gap forced a fresh solve
    greedy_solves: int = 0
    fast_milp_solves: int = 0
    node_milp_solves: int = 0
    fallbacks: int = 0
    wall_time: float = 0.0
    restores: int = 0             # warm-state snapshot restores applied
    restored_entries: int = 0     # cache entries recovered across restores
    # deadline-ladder counters (DESIGN.md §16): populated only when
    # ``decision_deadline_s`` is set.  ``deadline_hits`` counts decisions
    # where the ladder had to skip at least one portfolio stage;
    # ``rung_*`` counts which ladder rung produced each decision.
    deadline_hits: int = 0
    rung_cache: int = 0
    rung_repair: int = 0
    rung_greedy: int = 0
    rung_milp: int = 0
    rung_project: int = 0         # projected previous map (clamped)
    rung_equal: int = 0           # equal-share bottom rung
    upgrades: int = 0             # async re-solves of degraded decisions

    def as_dict(self) -> Dict[str, float]:
        # dataclasses-derived: a new counter field automatically appears
        # in every report (regression-tested keys == fields)
        return dataclasses.asdict(self)

    @classmethod
    def from_telemetry(cls, tel: Telemetry) -> "EngineStats":
        """Reconstruct the stats view from a telemetry hub's mirrored
        ``engine.*`` counters (e.g. inside ``repro.obs.report``)."""
        vals = {}
        for f in dataclasses.fields(cls):
            v = tel.counters.get(f"engine.{f.name}", 0.0)
            vals[f.name] = float(v) if f.name == "wall_time" else int(v)
        return cls(**vals)

    def merged(self, other: "EngineStats") -> "EngineStats":
        """Field-wise sum — the composition law for fleet-level stats."""
        return EngineStats(**{
            f.name: getattr(self, f.name) + getattr(other, f.name)
            for f in dataclasses.fields(EngineStats)})

    @classmethod
    def sum_of(cls, stats: "List[EngineStats]") -> "EngineStats":
        """Compose per-pool engine stats into one fleet view — every
        counter is a plain sum (used by ``repro.federation``)."""
        out = cls()
        for s in stats:
            out = out.merged(s)
        return out


#: precomputed hub counter names — the per-decision mirror must not pay
#: an f-string per increment on the hot path (EXPERIMENTS.md §Telemetry)
_MIRROR_NAMES = {f.name: f"engine.{f.name}"
                 for f in dataclasses.fields(EngineStats)}
#: precomputed per-arm decision-latency histogram names
_ARM_HIST = {arm: f"engine.decision_ms.{arm}"
             for arm in ("cache", "repair", "greedy", "milp", "fallback",
                         "project", "equal")}
#: ladder rung -> EngineStats counter field (precomputed: no f-string on
#: the per-decision path)
_RUNG_FIELD = {r: f"rung_{r}" for r in
               ("cache", "repair", "greedy", "milp", "project", "equal")}


def _decision_arm(solver_status: str) -> str:
    """Classify a result's producing solver arm for the per-arm
    decision-latency histograms (``engine.decision_ms.<arm>``)."""
    s = solver_status.split("+rung:", 1)[0]
    if s.startswith("cache("):
        return "cache"
    if s == "greedy-repair":
        return "repair"
    if s == "greedy":
        return "greedy"
    if s == "deadline-project":
        return "project"
    if s == "deadline-equal":
        return "equal"
    if s == "engine-fallback":
        return "fallback"
    return "milp"


def _rung_of(solver_status: str) -> str:
    """Map a result's status to its deadline-ladder rung.  The §3.6
    fallback keeps the current map, which *is* the project rung."""
    s = solver_status
    if s.startswith("cache("):
        return "cache"
    if s == "greedy-repair":
        return "repair"
    if s == "greedy":
        return "greedy"
    if s == "deadline-project" or s == "engine-fallback":
        return "project"
    if s == "deadline-equal":
        return "equal"
    return "milp"


# Crude per-instance cost predictors (seconds), calibrated on the CPU
# container (EXPERIMENTS.md §Perf-Engine).  They only need to *rank*
# instances against the time budget, not predict wall time accurately.
def _est_fast_milp(n_nodes: int, n_jobs: int) -> float:
    return 2e-3 + 4e-4 * n_jobs + 2e-6 * n_nodes * n_jobs


def _est_node_milp(n_nodes: int, n_jobs: int) -> float:
    return 5e-3 + 2e-5 * n_nodes * n_nodes * max(1, n_jobs)


def _est_greedy(n_nodes: int, n_jobs: int) -> float:
    # vectorized water-filling: ~46 ms at 4096 nodes x 64 jobs
    return 2e-4 + 5e-5 * n_jobs + 2e-7 * n_nodes * n_jobs


class AllocationEngine(Allocator):
    """Portfolio allocator: cache → incremental repair → greedy → fast
    MILP → node MILP.

    Memoization is keyed per ``(problem signature, policy)`` — see
    :func:`problem_signature` — so one engine instance can safely serve
    problems carrying different ``objective`` policies.

    On a cache miss the engine first tries an **incremental warm-start
    repair** (DESIGN.md §11): the previous allocation is embedded in the
    problem as the current map ``C``, so the repair is the greedy search
    warm-started from ``C`` — bounded grow moves absorb joined nodes,
    bounded evict moves release capacity — instead of water-filling the
    whole pool from zero.  Acceptance is two-tier, against the policy's
    cheap upper bound (``Objective.upper_bound``, a concave-envelope
    relaxation):

    * gap ≤ ``repair_exact_gap`` (≈ solver tolerance): the repair has
      *reached the bound*, so no solver can improve on it — accept
      without any further work.  This is the incremental fast path, and
      it is parity-exact by construction: repair ≥ bound − ε ≥ optimum
      − ε, so a fresh solve could do no better than ε;
    * gap ≤ ``repair_gap``: plausibly optimal but not provably — run
      the fresh greedy as well (cheap, vectorized) and keep the better
      of the two, still skipping the MILPs;
    * otherwise (or when the policy has no bound): escalate to the full
      fresh portfolio including the MILPs and keep the best result.

    Enabling ``incremental`` therefore never degrades solution quality
    beyond ``repair_gap``, and in practice matches the fresh portfolio
    to solver tolerance (the 6-scenario × 5-policy parity sweep in
    tests/test_engine.py).

    Parameters
    ----------
    time_budget : float
        Per-event solver budget (seconds); MILP escalation only runs
        when its predicted cost fits.  0 disables escalation (greedy +
        cache only, fully deterministic).
    use_greedy : bool
        Run the water-filling heuristic first (default True).
    use_node_milp : bool
        Allow escalation to the node-level MILP (default False; the
        aggregate MILP reaches the same optimum).
    cache_size : int
        Max memoized signatures (LRU eviction).
    incremental : bool
        Enable the warm-start repair fast path (default True).
    repair_gap : float
        Max relative bound gap for a (greedy-best) solution to skip the
        MILP escalation (dimensionless, default 1e-3 — tight
        enough that the 6-scenario × 5-policy sweep stays within 1e-6
        of the fresh portfolio, see tests/test_engine.py).
    repair_exact_gap : float
        Relative bound gap at or below which a repair counts as having
        *reached* the upper bound and is accepted outright
        (dimensionless, default 1e-9 — solver-tolerance scale).
    """

    def __init__(self, *, time_budget: float = 0.050,
                 use_greedy: bool = True, use_node_milp: bool = False,
                 cache_size: int = 4096, incremental: bool = True,
                 repair_gap: float = 1e-3, repair_exact_gap: float = 1e-9,
                 decision_deadline_s: Optional[float] = None,
                 upgrade_backlog: int = 64,
                 telemetry: Optional[Telemetry] = None):
        self.time_budget = time_budget
        self.use_greedy = use_greedy
        self.use_node_milp = use_node_milp
        self.cache_size = cache_size
        self.incremental = incremental
        self.repair_gap = repair_gap
        self.repair_exact_gap = repair_exact_gap
        # hard per-decision deadline (DESIGN.md §16): when set, each
        # portfolio stage only runs if its static cost estimate fits the
        # measured remaining time, degrading down the ladder
        # cache -> repair -> greedy -> MILP -> project -> equal-share so
        # *some* feasible map always returns within the deadline.  None
        # (the default) disables the ladder entirely — behaviour and
        # results are then bit-identical to the pre-ladder engine.
        self.decision_deadline_s = decision_deadline_s
        self.upgrade_backlog = int(upgrade_backlog)
        # telemetry is observation-only (repro.obs): decisions never read
        # it, so an enabled hub cannot perturb allocations.  The default
        # NULL_TELEMETRY sink is falsy and drops everything.
        self.telemetry = telemetry or NULL_TELEMETRY
        self.name = "engine"
        self.stats = EngineStats()
        self._cache: "OrderedDict[Signature, Tuple[Tuple[int, ...], Optional[float], str]]" = OrderedDict()
        # per-decision mirror buffer: increments land here (plain dict,
        # no string formatting) and flush into the hub once per decision
        # — batching the hub traffic out of the engine inner loop
        self._pending: Dict[str, float] = {}
        # degraded decisions awaiting their async full re-solve
        # (signature -> problem, FIFO, bounded by upgrade_backlog)
        self._pending_upgrades: "OrderedDict[Signature, AllocationProblem]" = OrderedDict()
        # set by _solve when the deadline forced it to skip a stage
        self._degraded = False
        self._equal_share = None    # lazy EqualShareAllocator

    def _count(self, name: str, delta=1) -> None:
        """Bump an ``EngineStats`` counter; the hub mirror is batched
        (``_flush_counts``) so the inner loop never formats names or
        touches the hub per increment."""
        setattr(self.stats, name, getattr(self.stats, name) + delta)
        if self.telemetry:
            self._pending[name] = self._pending.get(name, 0) + delta

    def _flush_counts(self) -> None:
        """Push the buffered per-decision increments into the hub in one
        pass (precomputed names; see EXPERIMENTS.md §Telemetry)."""
        if self._pending:
            count = self.telemetry.count
            for name, delta in self._pending.items():
                count(_MIRROR_NAMES[name], delta)
            self._pending.clear()

    # ------------------------------------------------------------------

    def allocate(self, prob: AllocationProblem) -> AllocationResult:
        t0 = time.perf_counter()
        self._count("events")
        key, order = problem_signature(prob)
        deadline = self.decision_deadline_s

        cached = self._cache.get(key)
        if cached is not None:
            self._cache.move_to_end(key)
            self._count("cache_hits")
            res = self._ground(prob, order, *cached)
            res.wall_time = time.perf_counter() - t0
            if deadline is not None:
                self._finish_rung(res)
            self._finish_decision(res)
            return res

        self._degraded = False
        res = self._solve(prob, t0=t0, deadline=deadline)
        if self._degraded:
            # a skipped stage means this answer may trail the full
            # portfolio's: never memoize it, queue the async upgrade so
            # the next epoch's identical problem is a fresh cache hit
            self._count("deadline_hits")
            self._pending_upgrades[key] = prob
            while len(self._pending_upgrades) > self.upgrade_backlog:
                self._pending_upgrades.popitem(last=False)
        elif not res.fell_back:
            counts = tuple(res.counts[prob.trainers[i].id] for i in order)
            self._cache[key] = (counts, res.objective, res.solver_status)
            if len(self._cache) > self.cache_size:
                self._cache.popitem(last=False)
        res.wall_time = time.perf_counter() - t0
        if deadline is not None:
            self._finish_rung(res)
        self._finish_decision(res)
        return res

    def _finish_rung(self, res: AllocationResult) -> None:
        """Stamp the ladder rung into the result's ``solver_status`` and
        bump its counter — only under an active deadline, so inactive
        runs keep their historical statuses byte-for-byte."""
        rung = _rung_of(res.solver_status)
        self._count(_RUNG_FIELD[rung])
        res.solver_status = f"{res.solver_status}+rung:{rung}"

    def upgrade(self, max_items: Optional[int] = None) -> int:
        """Async re-solve of deadline-degraded decisions (DESIGN.md
        §16): run the *full* portfolio (no deadline) on each queued
        problem and memoize the result, so the next identical event is
        an optimal cache hit.  Called off the hot path — e.g. by
        ``FederatedLoop`` at epoch boundaries.  Returns the number of
        problems upgraded."""
        done = 0
        while self._pending_upgrades and (max_items is None or
                                          done < max_items):
            key, prob = self._pending_upgrades.popitem(last=False)
            self._degraded = False
            res = self._solve(prob)
            if not res.fell_back:
                _, order = problem_signature(prob)
                counts = tuple(res.counts[prob.trainers[i].id]
                               for i in order)
                self._cache[key] = (counts, res.objective,
                                    res.solver_status)
                while len(self._cache) > self.cache_size:
                    self._cache.popitem(last=False)
            self._count("upgrades")
            done += 1
        if self.telemetry:
            self._flush_counts()
        return done

    def _finish_decision(self, res: AllocationResult) -> None:
        """Account one decision: the ``wall_time`` sum stays (report
        compatibility) and the hub additionally gets the per-arm
        decision-latency histograms the sum could never show."""
        self._count("wall_time", res.wall_time)
        tel = self.telemetry
        if tel:
            self._flush_counts()
            ms = res.wall_time * 1e3
            tel.observe("engine.decision_ms", ms)
            tel.observe(_ARM_HIST[_decision_arm(res.solver_status)], ms)

    def clear_cache(self) -> None:
        self._cache.clear()

    # -- warm-state snapshot / recovery (DESIGN.md §12) ----------------

    def snapshot(self) -> Dict:
        """Serializable warm state of this engine: config + the full
        memoization cache (canonical signatures → count vectors) + a
        copy of the running stats for post-mortem inspection.

        The payload is versioned (``schema``) and JSON-round-trippable
        via :func:`dumps_snapshot` / :func:`loads_snapshot`.  Restoring
        it into a fresh engine (allocator restart) makes every problem
        the old engine had solved a cache hit again; problems the
        snapshot missed re-converge through the incremental warm-start
        repair path, since the current map survives in the problems
        themselves."""
        return {
            "schema": SNAPSHOT_SCHEMA,
            "config": {
                "time_budget": self.time_budget,
                "use_greedy": self.use_greedy,
                "use_node_milp": self.use_node_milp,
                "cache_size": self.cache_size,
                "incremental": self.incremental,
                "repair_gap": self.repair_gap,
                "repair_exact_gap": self.repair_exact_gap,
                "decision_deadline_s": self.decision_deadline_s,
                "upgrade_backlog": self.upgrade_backlog,
            },
            "cache": [[key, list(val)] for key, val in self._cache.items()],
            "stats": self.stats.as_dict(),
        }

    def restore(self, snap: Dict) -> int:
        """Load a :meth:`snapshot` into this engine (cache only — the
        stats of a restarted engine start fresh, with ``restores`` /
        ``restored_entries`` recording the recovery).  Returns the
        number of cache entries recovered.  Raises ``ValueError`` on an
        unknown snapshot schema."""
        if snap.get("schema") != SNAPSHOT_SCHEMA:
            raise ValueError(
                f"unknown engine-snapshot schema {snap.get('schema')!r} "
                f"(expected {SNAPSHOT_SCHEMA!r})")
        self._cache.clear()
        for key, val in snap["cache"]:
            counts, objective, status = val
            self._cache[_tuplify(key)] = (_tuplify(counts), objective, status)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)
        self._count("restores")
        self._count("restored_entries", len(self._cache))
        if self.telemetry:
            self._flush_counts()
        return len(self._cache)

    @classmethod
    def from_snapshot(cls, snap: Dict) -> "AllocationEngine":
        """Build a fresh engine configured and warmed from ``snap``."""
        if snap.get("schema") != SNAPSHOT_SCHEMA:
            raise ValueError(
                f"unknown engine-snapshot schema {snap.get('schema')!r} "
                f"(expected {SNAPSHOT_SCHEMA!r})")
        eng = cls(**snap["config"])
        eng.restore(snap)
        return eng

    # ------------------------------------------------------------------

    def _ground(self, prob: AllocationProblem, order: List[int],
                canon_counts: Tuple[int, ...], objective: Optional[float],
                status: str) -> AllocationResult:
        """Re-ground a cached canonical count vector on concrete node ids."""
        current = project_current(prob)
        counts = {prob.trainers[i].id: canon_counts[pos]
                  for pos, i in enumerate(order)}
        allocation = reconstruct_map(list(prob.nodes), prob.trainers,
                                     current, counts)
        return AllocationResult(allocation=allocation, counts=counts,
                                objective=objective, wall_time=0.0,
                                solver_status=f"cache({status})")

    def _solve(self, prob: AllocationProblem, *,
               t0: Optional[float] = None,
               deadline: Optional[float] = None) -> AllocationResult:
        n, j = len(prob.nodes), len(prob.trainers)
        budget = self.time_budget
        best: Optional[AllocationResult] = None

        # deadline ladder (DESIGN.md §16): each stage runs only if its
        # static cost estimate fits the measured remaining time.  With
        # no deadline every fits() is True and the portfolio below is
        # byte-identical to the pre-ladder engine.
        if deadline is not None and t0 is not None:
            def fits(est: float) -> bool:
                return est <= deadline - (time.perf_counter() - t0)
        else:
            def fits(est: float) -> bool:
                return True

        if deadline is not None and not fits(_est_greedy(n, j)):
            # not even the greedy fits: take the O(n + j) bottom rungs
            self._degraded = True
            return self._degrade(prob)

        # incremental warm-start repair (DESIGN.md §11): the previous
        # allocation *is* the problem's current map, so repair = greedy
        # warm-started from it.  Two-tier acceptance against the
        # policy's cheap upper bound (see class docstring).
        repair: Optional[AllocationResult] = None
        skip_milp = False
        if self.incremental and self.use_greedy and prob.trainers:
            from repro.core.objectives import resolve_objective

            current = project_current(prob)
            start = {t.id: len(current[t.id]) for t in prob.trainers}
            if any(start.values()):
                repair = solve_greedy(prob, start_counts=start)
                objective = resolve_objective(prob.objective)
                ub = objective.upper_bound(
                    prob.trainers, [start[t.id] for t in prob.trainers],
                    n, prob.t_fwd)
                if ub is not None and repair.objective is not None:
                    scale = max(1.0, abs(ub))
                    gap = ub - repair.objective
                    if gap <= self.repair_exact_gap * scale:
                        # repair reached the bound: provably optimal
                        self._count("repairs")
                        repair.solver_status = "greedy-repair"
                        return repair
                    if gap <= self.repair_gap * scale:
                        # plausibly optimal: add the fresh greedy, skip
                        # the MILPs
                        skip_milp = True
                if not skip_milp:
                    self._count("repair_escalations")

        if self.use_greedy:
            best = solve_greedy(prob)
            self._count("greedy_solves")
            if repair is not None:
                best = _better(best, repair)
            if skip_milp:
                self._count("repairs")
                if best is not None and not best.fell_back:
                    return best

        # Escalation gates and solver time limits use only the static cost
        # estimators and the configured budget — never measured wall-clock —
        # so identical problem sequences make identical decisions run-to-run.
        if budget > 0 and _est_fast_milp(n, j) <= budget:
            if fits(_est_fast_milp(n, j)):
                r = solve_fast_milp(prob, time_limit=max(budget, 1e-3))
                self._count("fast_milp_solves")
                best = _better(best, r)
            else:
                self._degraded = True

        if self.use_node_milp and budget > 0 and \
                _est_node_milp(n, j) <= budget:
            if fits(_est_node_milp(n, j)):
                r = solve_node_milp(prob, time_limit=max(budget, 1e-3))
                self._count("node_milp_solves")
                best = _better(best, r)
            else:
                self._degraded = True

        if best is None or best.fell_back:
            # §3.6: keep the current map
            self._count("fallbacks")
            alloc = {j: sorted(ns)
                     for j, ns in project_current(prob).items()}
            return AllocationResult(
                allocation=alloc,
                counts={t.id: len(alloc[t.id]) for t in prob.trainers},
                objective=None, wall_time=0.0,
                solver_status="engine-fallback", fell_back=True)
        return best

    def _degrade(self, prob: AllocationProblem) -> AllocationResult:
        """Deadline bottom rungs.  **project**: keep the previous map,
        clamped into feasibility (counts capped at ``n_max``; a count
        stranded in ``(0, n_min)`` drops to 0) — minimal churn, O(n).
        When there is no previous map to project (cold start), fall to
        **equal-share**, which is feasible by construction."""
        current = project_current(prob)
        counts = {}
        for t in prob.trainers:
            c = min(len(current[t.id]), t.n_max)
            if 0 < c < t.n_min:
                c = 0
            counts[t.id] = c
        if any(counts.values()):
            allocation = reconstruct_map(list(prob.nodes), prob.trainers,
                                         current, counts)
            return AllocationResult(
                allocation=allocation, counts=counts, objective=None,
                wall_time=0.0, solver_status="deadline-project")
        if self._equal_share is None:
            from repro.core.allocator import EqualShareAllocator
            self._equal_share = EqualShareAllocator()
        res = self._equal_share.allocate(prob)
        res.solver_status = "deadline-equal"
        return res


def _better(a: Optional[AllocationResult],
            b: AllocationResult) -> AllocationResult:
    if b.fell_back or b.objective is None:
        return a if a is not None else b
    if a is None or a.fell_back or a.objective is None:
        return b
    return b if b.objective > a.objective else a
