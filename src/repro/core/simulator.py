"""Event-driven BFTrainer simulator (paper §4–5): a thin facade over the
shared ``ControlLoop`` with the ``AnalyticBackend``.

The policy — merged timeline, FCFS admission up to ``pj_max``, event
coalescing, preemption handling, rescale-stall bookkeeping, adaptive
``t_fwd`` — lives in core/loop.py and is identical to what
``BFTrainerRuntime`` runs against live trainers; only progress
integration differs (scaling-curve integral here, real train steps
there).  See DESIGN.md §9.
"""
from __future__ import annotations

from dataclasses import dataclass, fields
from typing import Optional, Sequence, Union

from repro.core.allocator import Allocator
from repro.core.backend import AnalyticBackend
from repro.core.events import PoolEvent
from repro.core.loop import ControlLoop, EventRecord, LoopStats, TrainerJob

__all__ = ["EventRecord", "SimReport", "Simulator", "TrainerJob",
           "static_outcome"]


@dataclass
class SimReport(LoopStats):
    """Simulation report — exactly the shared ``LoopStats`` core."""

    @classmethod
    def from_stats(cls, stats: LoopStats) -> "SimReport":
        return cls(**{f.name: getattr(stats, f.name)
                      for f in fields(LoopStats)})


class Simulator:
    """Trace-driven simulation facade: ``ControlLoop`` + ``AnalyticBackend``.

    Accepts the same knobs as ``ControlLoop`` (including ``objective=``,
    the allocation policy from ``repro.core.objectives``); see its
    docstring for parameter units and semantics.
    """

    def __init__(self, events: Sequence[PoolEvent], jobs: Sequence[TrainerJob],
                 allocator: Allocator, *, t_fwd: Union[float, str] = 120.0,
                 pj_max: int = 10, horizon: Optional[float] = None,
                 sos2_points: int = 8, coalesce_window: float = 0.0,
                 objective=None, telemetry=None):
        self.loop = ControlLoop(events, jobs, allocator, AnalyticBackend(),
                                t_fwd=t_fwd, pj_max=pj_max, horizon=horizon,
                                sos2_points=sos2_points,
                                coalesce_window=coalesce_window,
                                objective=objective, telemetry=telemetry)
    def run(self) -> SimReport:
        return SimReport.from_stats(self.loop.run())


# every pre-refactor Simulator attribute delegates to the loop, so
# post-construction mutation (sim.pj_max = 3, sim.allocator = other)
# keeps taking effect
def _delegate(attr):
    return property(lambda self: getattr(self.loop, attr),
                    lambda self, v: setattr(self.loop, attr, v))


for _attr in ("events", "jobs", "allocator", "t_fwd", "t_fwd_estimator",
              "pj_max", "horizon", "sos2_points", "coalesce_window",
              "objective", "telemetry"):
    setattr(Simulator, _attr, _delegate(_attr))


# ---------------------------------------------------------------------------
# Static baseline for the efficiency metric U = A_e / A_s (paper §4.1.2)
# ---------------------------------------------------------------------------


def static_outcome(jobs: Sequence[TrainerJob], n_static: int,
                   duration: float, allocator: Allocator, *,
                   pj_max: int = 10) -> float:
    """Outcome A_s of running the same Trainers on ``n_static`` dedicated
    nodes for ``duration`` seconds (no preemption, no rescale costs other
    than initial starts — matching the paper's cost-free static baseline).

    Runs through the same ``ControlLoop`` as the elastic paths, so the
    baseline and elastic policies cannot drift apart.  Arrivals before the
    static pool opens at t=0 are clamped to 0.  The baseline always uses
    the default throughput objective (policy-independent denominator, so
    U values stay comparable across policies); per-job policy fields are
    deliberately not copied.
    """
    ev = [PoolEvent(time=0.0, joined=tuple(range(n_static)))]
    jobs2 = [TrainerJob(id=j.id, curve=j.curve, work=j.work, n_min=j.n_min,
                        n_max=j.n_max, r_up=0.0, r_dw=0.0,
                        arrival=max(j.arrival, 0.0),
                        metric=j.metric)
             for j in jobs]
    loop = ControlLoop(ev, jobs2, allocator, AnalyticBackend(),
                       t_fwd=duration, pj_max=pj_max, horizon=duration)
    return loop.run().total_samples
