"""Tiny sparse MILP assembly layer over scipy.optimize.milp (HiGHS).

The paper solves its model with Gurobi; HiGHS is an exact branch-and-cut
MILP solver, so optimal objective values are solver-independent.

Constraint storage is COO-direct: ``add_row`` appends straight onto flat
``(data, row, col)`` triplet lists, so ``solve`` assembles the sparse
matrix without re-walking per-row dicts — and ``clone()`` is a handful
of C-speed list copies, which is what makes the per-signature constraint
skeleton cache in ``milp_fast`` cheap (DESIGN.md §11).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np
import scipy.sparse as sp
from scipy.optimize import Bounds, LinearConstraint, milp


@dataclass
class MILPBuilder:
    n_vars: int = 0
    names: List[str] = field(default_factory=list)
    integrality: List[int] = field(default_factory=list)
    lb: List[float] = field(default_factory=list)
    ub: List[float] = field(default_factory=list)
    obj: Dict[int, float] = field(default_factory=dict)
    n_rows: int = 0
    row_lb: List[float] = field(default_factory=list)
    row_ub: List[float] = field(default_factory=list)
    # constraint matrix as flat COO triplets (parallel lists)
    coo_data: List[float] = field(default_factory=list)
    coo_row: List[int] = field(default_factory=list)
    coo_col: List[int] = field(default_factory=list)

    def add_var(self, name: str, *, binary: bool = False, integer: bool = False,
                lb: float = 0.0, ub: float = 1.0) -> int:
        idx = self.n_vars
        self.n_vars += 1
        self.names.append(name)
        self.integrality.append(1 if (binary or integer) else 0)
        self.lb.append(0.0 if binary else lb)
        self.ub.append(1.0 if binary else ub)
        return idx

    def add_vars(self, prefix: str, n: int, **kw) -> List[int]:
        return [self.add_var(f"{prefix}[{i}]", **kw) for i in range(n)]

    def set_obj(self, idx: int, coef: float) -> None:
        self.obj[idx] = self.obj.get(idx, 0.0) + coef

    def add_row(self, coeffs: Dict[int, float], lb: float = -np.inf,
                ub: float = np.inf) -> None:
        r = self.n_rows
        self.n_rows += 1
        self.coo_row.extend([r] * len(coeffs))
        self.coo_col.extend(coeffs.keys())
        self.coo_data.extend(coeffs.values())
        self.row_lb.append(lb)
        self.row_ub.append(ub)

    def clone(self) -> "MILPBuilder":
        """Independent copy — the skeleton-cache restore path: flat list
        copies only, no per-row dict rebuilding."""
        return MILPBuilder(
            n_vars=self.n_vars, names=list(self.names),
            integrality=list(self.integrality),
            lb=list(self.lb), ub=list(self.ub), obj=dict(self.obj),
            n_rows=self.n_rows, row_lb=list(self.row_lb),
            row_ub=list(self.row_ub), coo_data=list(self.coo_data),
            coo_row=list(self.coo_row), coo_col=list(self.coo_col))

    # ------------------------------------------------------------------

    def solve(self, *, maximize: bool = True, time_limit: float = 30.0,
              mip_rel_gap: float = 1e-6):
        c = np.zeros(self.n_vars)
        for i, v in self.obj.items():
            c[i] = -v if maximize else v

        a = sp.csr_matrix((self.coo_data, (self.coo_row, self.coo_col)),
                          shape=(self.n_rows, self.n_vars))
        cons = LinearConstraint(a, np.array(self.row_lb), np.array(self.row_ub))
        t0 = time.perf_counter()
        res = milp(
            c,
            constraints=[cons],
            integrality=np.array(self.integrality),
            bounds=Bounds(np.array(self.lb), np.array(self.ub)),
            options={"time_limit": time_limit, "mip_rel_gap": mip_rel_gap,
                     "disp": False},
        )
        wall = time.perf_counter() - t0
        value = (-res.fun if maximize else res.fun) if res.x is not None else None
        return MILPResult(status=int(res.status), success=bool(res.success),
                          x=res.x, objective=value, wall_time=wall,
                          message=str(res.message))


@dataclass
class MILPResult:
    status: int
    success: bool
    x: Optional[np.ndarray]
    objective: Optional[float]
    wall_time: float
    message: str = ""


def epigraph_min(b: MILPBuilder, name: str,
                 exprs: List[Tuple[float, Dict[int, float]]]) -> int:
    """Append an epigraph variable ``f = min_i (const_i + coeffs_i · x)``.

    The standard linearization of maximizing a minimum: a free continuous
    variable ``f`` with one row ``f <= const_i + coeffs_i · x`` per
    expression.  ``f`` equals the min only at optimality of a maximize
    objective that rewards ``f`` — callers must put a positive objective
    coefficient on the returned variable.

    Parameters
    ----------
    exprs : list of (const, coeffs)
        Each expression is a constant plus a sparse linear form
        (variable index -> coefficient).

    Returns
    -------
    int
        The index of the epigraph variable ``f``.
    """
    f = b.add_var(name, lb=-np.inf, ub=np.inf)
    for const, coeffs in exprs:
        row = {f: 1.0}
        for v, cf in coeffs.items():
            row[v] = row.get(v, 0.0) - cf
        b.add_row(row, ub=const)
    return f


def sos2_block(b: MILPBuilder, prefix: str, points: List[int],
               values: List[float], n_var_coeffs: Dict[int, float]):
    """Append an SOS2 piecewise-linear block.

    Encodes  value = O(n)  where n = sum(n_var_coeffs) and O interpolates
    (points, values).  SOS2 (<=2 adjacent nonzero weights) is enforced with
    segment-selection binaries — the standard λ-formulation, equivalent to
    native solver SOS2 sets (which scipy's HiGHS interface lacks).

    Returns (w_indices, value_coeffs: dict var->coef contributing O(n)).
    """
    d = len(points)
    w = b.add_vars(f"w_{prefix}", d, lb=0.0, ub=1.0)
    seg = b.add_vars(f"seg_{prefix}", d - 1, binary=True)
    # sum w = 1
    b.add_row({i: 1.0 for i in w}, lb=1.0, ub=1.0)
    # sum seg = 1
    b.add_row({i: 1.0 for i in seg}, lb=1.0, ub=1.0)
    # w_i <= seg_{i-1} + seg_i  (adjacency)
    for i in range(d):
        row = {w[i]: 1.0}
        if i > 0:
            row[seg[i - 1]] = -1.0
        if i < d - 1:
            row[seg[i]] = -1.0
        b.add_row(row, ub=0.0)
    # sum w_i * points_i == n
    row = {w[i]: float(points[i]) for i in range(d)}
    for var, coef in n_var_coeffs.items():
        row[var] = row.get(var, 0.0) - coef
    b.add_row(row, lb=0.0, ub=0.0)
    value_coeffs = {w[i]: float(values[i]) for i in range(d)}
    return w, value_coeffs
