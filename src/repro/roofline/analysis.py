"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

``cost_analysis()`` provides FLOPs and bytes; collective bytes are parsed
from the post-partition HLO text (``compiled.as_text()``) with a per-op
traffic model:  all-reduce ≈ 2×size (ring), all-gather / reduce-scatter ≈
size×(k-1)/k, all-to-all / collective-permute ≈ size.  Sizes are
per-device shard bytes, i.e. bytes crossing each chip's ICI links.
"""
from __future__ import annotations

import json
import math
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link (~ per-chip usable)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(.*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_ID_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shapes_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype == "tuple" or dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    link_bytes: float = 0.0     # traffic-model bytes crossing each chip


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        line = line.strip()
        if "-done(" in line:        # avoid double counting async pairs
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(shapes_str)
        # group size k for the (k-1)/k factor
        k = 2
        gm = _GROUPS_RE.search(line)
        if gm:
            k = max(2, len(gm.group(1).split(",")))
        else:
            gm2 = _GROUPS_ID_RE.search(line)
            if gm2:
                k = max(2, int(gm2.group(2)))
        if kind == "all-reduce":
            moved = 2.0 * size * (k - 1) / k
        elif kind in ("all-gather", "reduce-scatter"):
            moved = size * (k - 1) / k
        else:  # all-to-all, collective-permute
            moved = float(size)
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + size
        stats.link_bytes += moved
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    hlo_flops: float               # whole-program FLOPs (all devices)
    hlo_bytes: float               # whole-program bytes accessed
    collective_link_bytes: float   # per-chip link traffic
    model_flops: float             # 6·N·D (train) / 2·N_active·D (serve)
    n_params: int
    n_active_params: int
    bytes_per_device: Optional[float] = None
    collective_counts: Dict[str, int] = field(default_factory=dict)
    collective_bytes: Dict[str, int] = field(default_factory=dict)

    # --- derived terms (seconds) ---
    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.n_devices * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.n_devices * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_link_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Upper bound on MFU implied by the roofline (useful FLOPs over
        peak at the dominant term's duration)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return self.model_flops / (self.n_devices * PEAK_FLOPS_BF16 * t)

    def to_json(self) -> dict:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio,
                 mfu_bound=self.mfu_bound)
        return d


def model_flops_estimate(n_params: int, n_active: int, tokens: int,
                         kind: str) -> float:
    """6·N·D for training, 2·N_active·D for single forward/decode."""
    if kind == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens


def markdown_table(rows: List[Roofline]) -> str:
    hdr = ("| arch | shape | mesh | t_compute | t_memory | t_collective | "
           "bottleneck | MODEL/HLO FLOPs | MFU bound |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.t_compute:.4f}s | "
            f"{r.t_memory:.4f}s | {r.t_collective:.4f}s | {r.bottleneck} | "
            f"{r.useful_flops_ratio:.2f} | {r.mfu_bound:.2f} |\n")
    return "".join(out)
