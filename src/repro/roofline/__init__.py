from repro.roofline.analysis import (
    CollectiveStats,
    Roofline,
    markdown_table,
    model_flops_estimate,
    parse_collectives,
)

__all__ = ["CollectiveStats", "Roofline", "markdown_table",
           "model_flops_estimate", "parse_collectives"]
