"""Roofline report: reads dry-run JSONs and emits the §Roofline markdown
table + hillclimb-pair selection.

Run:  PYTHONPATH=src python -m repro.roofline.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import List


def load(dir_: str) -> List[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def fmt_row(r: dict) -> str:
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['t_compute']*1e3:9.2f} | {r['t_memory']*1e3:9.2f} | "
            f"{r['t_collective']*1e3:9.2f} | {r['bottleneck']:10s} | "
            f"{r['useful_flops_ratio']:5.2f} | {r['mfu_bound']:5.3f} |")


def table(rows: List[dict]) -> str:
    out = ["| arch | shape | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) "
           "| bottleneck | MODEL/HLO | MFU bound |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(fmt_row(r))
    return "\n".join(out)


def pick_hillclimb(rows: List[dict]) -> dict:
    single = [r for r in rows if r["mesh"].startswith("1pod")]
    trains = [r for r in single if r["shape"] == "train_4k"]
    worst = min(single, key=lambda r: r["mfu_bound"])
    coll = max(single, key=lambda r: r["t_collective"] /
               max(r["t_compute"], r["t_memory"], 1e-12))
    representative = max(trains, key=lambda r: r["n_active_params"])
    return {"worst_mfu": worst, "most_collective_bound": coll,
            "paper_representative": representative}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    rows = load(args.dir)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print(table(rows))
    print()
    picks = pick_hillclimb(rows)
    for why, r in picks.items():
        print(f"HILLCLIMB[{why}]: {r['arch']} x {r['shape']} "
              f"(bottleneck={r['bottleneck']}, mfu_bound={r['mfu_bound']:.3f})")


if __name__ == "__main__":
    main()
