from repro.distributed.sharding import (
    batch_spec,
    opt_state_specs,
    param_specs,
    sanitize,
    sanitize_tree,
    to_named,
    zero1_spec,
)

__all__ = ["batch_spec", "opt_state_specs", "param_specs", "sanitize",
           "sanitize_tree", "to_named", "zero1_spec"]
