"""Sharding rules: divisibility-aware spec sanitization, ZeRO-1 optimizer
sharding, and the in/out sharding trees for train/serve steps.

Parameter specs are attached at definition time (``ParamDef.spec``); this
module adapts them to a concrete mesh:

* ``sanitize`` drops mesh axes that do not divide the corresponding dim
  (e.g. granite's 49155-row vocab on a 16-way model axis);
* ``zero1_spec`` additionally shards optimizer moments (and, optionally,
  parameters — FSDP-style) over the data axes on the first divisible
  unsharded dim, which is what lets 76B-scale configs fit v5e HBM.
"""
from __future__ import annotations

import math
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import ParamDef

Pytree = Any


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        return math.prod(_axis_size(mesh, n) for n in name)
    return mesh.shape[name]


def sanitize(shape: Tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop spec entries that don't evenly divide their dim."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, name in zip(shape, entries):
        if name is not None and dim % _axis_size(mesh, name) != 0:
            name = None
        out.append(name)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_specs(defs: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(
        lambda d: sanitize(d.shape, d.spec, mesh), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


def zero1_spec(shape: Tuple[int, ...], spec: P, mesh: Mesh,
               dp_axes: Tuple[str, ...]) -> P:
    """Extend a (sanitized) spec by sharding the first divisible unsharded
    dim over the data-parallel axes (ZeRO-1 / optimizer-state sharding)."""
    spec = sanitize(shape, spec, mesh)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    dp_total = math.prod(mesh.shape[a] for a in dp_axes)
    for i, (dim, name) in enumerate(zip(shape, entries)):
        if name is None:
            if dim % dp_total == 0:
                entries[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                break
            if len(dp_axes) > 1 and dim % mesh.shape[dp_axes[-1]] == 0:
                entries[i] = dp_axes[-1]
                break
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def opt_state_specs(defs: Pytree, mesh: Mesh, dp_axes: Tuple[str, ...],
                    zero1: bool = True) -> Pytree:
    def one(d: ParamDef) -> P:
        if zero1:
            return zero1_spec(d.shape, d.spec, mesh, dp_axes)
        return sanitize(d.shape, d.spec, mesh)

    return jax.tree.map(one, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def batch_spec(shape: Tuple[int, ...], mesh: Mesh,
               dp_axes: Tuple[str, ...]) -> P:
    """Shard the leading (batch) dim over data axes, divisibility-aware."""
    b = shape[0]
    dp_total = math.prod(mesh.shape[a] for a in dp_axes)
    if b % dp_total == 0:
        return P(dp_axes if len(dp_axes) > 1 else dp_axes[0])
    for k in range(len(dp_axes), 0, -1):
        size = math.prod(mesh.shape[a] for a in dp_axes[:k])
        if b % size == 0:
            return P(dp_axes[:k] if k > 1 else dp_axes[0])
    return P(None)


def sanitize_tree(shapes: Pytree, specs: Pytree, mesh: Mesh) -> Pytree:
    """Sanitize a tree of PartitionSpecs against a matching tree of
    abstract arrays (divisibility-aware, e.g. decode caches)."""
    return jax.tree.map(
        lambda a, s: sanitize(a.shape, s, mesh), shapes, specs,
        is_leaf=lambda x: isinstance(x, P))


def to_named(tree_specs: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
