"""Flash attention Pallas TPU kernel.

Grid ``(B, H, nq, nk)`` with the KV-block dimension innermost and
sequential: online-softmax running state (m, l, acc) lives in VMEM scratch
and is carried across the nk iterations — the canonical TPU flash
structure.  GQA is handled in the kernel's BlockSpec index maps
(``kv_head = h // group``), so grouped KV heads are never materialized.

Block shapes are picked so the working set fits VMEM and matmul dims stay
MXU-aligned: q/k tiles (block_q × D) and (block_k × D) with D a multiple
of 128 for the assigned architectures (128/192/256).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

NEG_INF = -2.0 ** 30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, logit_cap: float,
            block_q: int, block_k: int, seq_k: int):
    ki = pl.program_id(3)
    nk = pl.num_programs(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    ok = k_pos < seq_k
    if causal:
        ok &= k_pos <= q_pos
    if window:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    logit_cap: float = 0.0, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B,H,S,D); k,v: (B,KV,Sk,D); H % KV == 0. Returns (B,H,S,D)."""
    b, h, sq, d = q.shape
    kv, sk = k.shape[1], k.shape[2]
    g = h // kv
    scale = d ** -0.5 if scale is None else scale
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)

    # pad sequence dims to block multiples (masked out via seq_k)
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = q.shape[2] // block_q
    nk = k.shape[2] // block_k

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        logit_cap=logit_cap, block_q=block_q, block_k=block_k, seq_k=sk)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, qi, ki, g=g: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, qi, ki, g=g: (b_, h_ // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nq * block_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq, :]
