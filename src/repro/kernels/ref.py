"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels
are validated against, and the path models use by default)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        logit_cap: float = 0.0,
                        scale: float | None = None) -> jax.Array:
    """q: (B,H,S,D); k,v: (B,KV,S,D) with H % KV == 0. Returns (B,H,S,D)."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    scale = d ** -0.5 if scale is None else scale
    qh = q.reshape(b, kv, g, s, d)
    scores = jnp.einsum("bkgqd,bktd->bkgqt", qh, k).astype(jnp.float32) * scale
    if logit_cap:
        scores = logit_cap * jnp.tanh(scores / logit_cap)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(k.shape[2])[None, :]
    ok = jnp.ones((s, k.shape[2]), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window:
        ok &= k_pos > q_pos - window
    scores = jnp.where(ok, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqt,bktd->bkgqd", probs, v)
    return out.reshape(b, h, s, d)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
                 cmat: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Naive sequential SSD recurrence (exact oracle).

    x: (B,S,H,P); dt: (B,S,H); a: (H,); bmat/cmat: (B,S,H,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    batch, s, h, p = x.shape
    n = bmat.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp        # (B,H,P), (B,H), (B,H,N), (B,H,N)
        da = jnp.exp(dtt * a)        # (B,H)
        upd = jnp.einsum("bh,bhn,bhp->bhpn", dtt, bt, xt)
        state = state * da[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    init = jnp.zeros((batch, h, p, n), jnp.float32)
    xs = (x.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          bmat.swapaxes(0, 1).astype(jnp.float32),
          cmat.swapaxes(0, 1).astype(jnp.float32))
    final, ys = jax.lax.scan(step, init, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), final


def rms_norm_ref(x: jax.Array, scale: jax.Array,
                 eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) *
            (1.0 + scale.astype(jnp.float32))).astype(dtype)


def ce_loss_ref(x: jax.Array, table: jax.Array,
                labels: jax.Array) -> jax.Array:
    """Per-token CE oracle. x: (T,d); table: (V,d); labels: (T,)."""
    logits = (x.astype(jnp.float32) @ table.astype(jnp.float32).T)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return logz - gold
