"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the kernels execute (and are
tested) on CPU; on a TPU backend pass ``interpret=False`` (or rely on the
default, which detects the backend) to run the compiled kernels.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ce_loss as _ce
from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd_scan as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "logit_cap", "scale",
                                   "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    logit_cap: float = 0.0, scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               logit_cap=logit_cap, scale=scale,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, bmat, cmat, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssd.ssd_scan(x, dt, a, bmat, cmat, chunk=chunk,
                         interpret=interpret)


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rms_norm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
             interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _rn.rms_norm(x, scale, eps=eps, block_rows=block_rows,
                        interpret=interpret)


@partial(jax.jit, static_argnames=("block_rows", "block_v", "interpret"))
def ce_loss(x, table, labels, *, block_rows: int = 256, block_v: int = 2048,
            interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ce.ce_loss(x, table, labels, block_rows=block_rows,
                       block_v=block_v, interpret=interpret)
