"""Version tolerance for the Pallas TPU API.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` in
newer releases; the container pins an older jax.  ``tpu_compiler_params``
resolves whichever name exists so the kernels run on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    return _COMPILER_PARAMS(**kwargs)
