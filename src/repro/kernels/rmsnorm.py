"""Fused RMSNorm Pallas TPU kernel: one pass over rows, fp32 statistics,
(1 + scale) gain — fuses what XLA would otherwise emit as several HBM
round-trips for large d_model."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)             # (rows, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + s_ref[...].astype(jnp.float32))).astype(
        o_ref.dtype)


def rms_norm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
             block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    nr = x2.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
            pl.BlockSpec((d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2, scale)
    return out[:rows].reshape(orig_shape)
