"""Fused cross-entropy Pallas TPU kernel.

Motivated by §Perf: the loss head materializes (B·S, V) logits in fp32
(e.g. 256 GB/step for yi-6b train_4k).  This kernel streams the vocab
dimension through VMEM with an online logsumexp (the flash-attention
pattern applied to the loss): grid ``(rows, nv)`` with the vocab-block
dimension innermost and sequential; running (m, l, gold) state in VMEM
scratch; the (rows, V) logits tile never round-trips to HBM in fp32.

Inputs are the hidden states and the (vocab-sharded-friendly) embedding
table, so the kernel also fuses the final projection matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

NEG_INF = -2.0 ** 30


def _kernel(x_ref, w_ref, lbl_ref, loss_ref, m_ref, l_ref, gold_ref, *,
            block_v: int, vocab: int):
    vi = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(vi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        gold_ref[...] = jnp.zeros_like(gold_ref)

    x = x_ref[...].astype(jnp.float32)              # (rows, d)
    w = w_ref[...].astype(jnp.float32)              # (block_v, d)
    logits = jax.lax.dot_general(x, w, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    v_pos = vi * block_v + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 1)
    logits = jnp.where(v_pos < vocab, logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.exp(
        logits - m_new[:, None]).sum(axis=-1)
    m_ref[...] = m_new

    # gold logit for labels that fall in this vocab block
    lbl = lbl_ref[...]                              # (rows,)
    hit = (v_pos == lbl[:, None])
    gold_ref[...] += jnp.where(hit, logits, 0.0).sum(axis=-1)

    @pl.when(vi == nv - 1)
    def _finish():
        logz = jnp.log(jnp.maximum(l_ref[...], 1e-30)) + m_ref[...]
        loss_ref[...] = logz - gold_ref[...]


def ce_loss(x: jax.Array, table: jax.Array, labels: jax.Array, *,
            block_rows: int = 256, block_v: int = 2048,
            interpret: bool = False) -> jax.Array:
    """Per-token cross-entropy. x: (T, d); table: (V, d); labels: (T,).
    Returns (T,) fp32 losses (mean-reduce outside)."""
    t, d = x.shape
    v = table.shape[0]
    block_rows = min(block_rows, t)
    block_v = min(block_v, v)
    pr = (-t) % block_rows
    pv = (-v) % block_v
    if pr:
        x = jnp.pad(x, ((0, pr), (0, 0)))
        labels = jnp.pad(labels, (0, pr))
    if pv:
        table = jnp.pad(table, ((0, pv), (0, 0)))
    nr = x.shape[0] // block_rows
    nv = table.shape[0] // block_v

    out = pl.pallas_call(
        functools.partial(_kernel, block_v=block_v, vocab=v),
        grid=(nr, nv),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda r, vi: (r, 0)),
            pl.BlockSpec((block_v, d), lambda r, vi: (vi, 0)),
            pl.BlockSpec((block_rows,), lambda r, vi: (r,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda r, vi: (r,)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0],), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_rows,), jnp.float32),
            pltpu.VMEM((block_rows,), jnp.float32),
            pltpu.VMEM((block_rows,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, table, labels)
    return out[:t]
