"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

Grid ``(B, H, nc)`` with the chunk dimension innermost and sequential: the
inter-chunk recurrent state (P × N) lives in VMEM scratch and is carried
across chunk iterations.  Within a chunk the computation is the quadratic
SSD form (decay-masked C·Bᵀ) — MXU matmuls over (L × N) and (L × L) tiles
— which is exactly how the paper's GPU algorithm adapts to the TPU memory
hierarchy: chunk tiles in VMEM, long-range state as a tiny carried
accumulator instead of a warp-level scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
            chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (L,)
    a = a_ref[0]                                   # scalar
    bm = b_ref[0, :, 0, :].astype(jnp.float32)     # (L, N)
    cm = c_ref[0, :, 0, :].astype(jnp.float32)     # (L, N)

    da = dt * a                                    # (L,), <= 0
    cum = jnp.cumsum(da)                           # (L,)

    # intra-chunk: decay-masked (C Bᵀ) against dt-weighted x
    diff = cum[:, None] - cum[None, :]             # (L, L)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(si <= li, jnp.exp(diff), 0.0)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    w = cb * decay * dt[None, :]
    y = jax.lax.dot(w, x, preferred_element_type=jnp.float32)     # (L, P)

    # inter-chunk: contribution of the carried state
    state = state_ref[...]                         # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (L, N)x(P, N) -> (L, P)

    # state update: decay-to-end weighted outer products
    decay_end = jnp.exp(cum[-1] - cum)             # (L,)
    xw = x * (dt * decay_end)[:, None]             # (L, P)
    new_contrib = jax.lax.dot_general(
        xw, bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # (P, N)
    state_ref[...] = state * jnp.exp(cum[-1]) + new_contrib

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
             cmat: jax.Array, *, chunk: int = 128,
             interpret: bool = False) -> jax.Array:
    """Chunked SSD. x: (B,S,H,P); dt: (B,S,H); a: (H,); bmat/cmat:
    (B,S,H,N).  S must be a multiple of ``chunk`` (pad upstream).
    Returns y: (B,S,H,P)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    assert s % chunk == 0, f"seq {s} not a multiple of chunk {chunk}"
    nc = s // chunk

    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, chunk, 1),
                         lambda b_, h_, ci: (b_, ci, h_)),
            pl.BlockSpec((1,), lambda b_, h_, ci: (h_,)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda b_, h_, ci: (b_, ci, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p),
                               lambda b_, h_, ci: (b_, ci, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a, bmat, cmat)
