"""Scenario harness for elastic serving: ControlLoop + ServingBackend.

``run_serving`` replays a serving scenario (a node-hole trace paired
with ``RequestSpec`` demand, see ``repro.sched.scenarios``) through the
shared ControlLoop under the ``latency_slo`` policy and reports
request-level outcomes: requests/s, p50/p95/p99 latency, SLO
attainment.  ``dedicated_baseline`` serves the *same* request traces on
a static, peak-provisioned pool — the serving analogue of the paper's
dedicated-nodes baseline for training U — so attainment on harvested
holes is always read against what dedicated hardware would have
delivered.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.backend import ServingBackend
from repro.core.events import PoolEvent, fragments_to_events
from repro.core.loop import ControlLoop, LoopStats, TrainerJob
from repro.obs.telemetry import Histogram
from repro.serving.job import ServingJob, make_serving_jobs
from repro.serving.workload import RequestSpec

__all__ = ["ServingReport", "dedicated_baseline", "run_serving",
           "summarize_serving", "peak_rate", "dedicated_nodes"]

#: capacity provisioned per unit of peak demand by the dedicated
#: baseline (mirrors LatencySLO's default headroom)
_HEADROOM = 1.25


def summarize_serving(jobs: Sequence[ServingJob]) -> Dict:
    """Aggregate request-level outcomes over ``jobs`` (latency
    percentiles from the exact merged histogram, milliseconds)."""
    lat = Histogram()
    arrived = served = dropped_q = dropped_k = dropped_t = 0
    pending = slo_ok = offered = 0
    for job in jobs:
        rep = job.replica
        if rep is None:
            continue
        lat.merge(rep.latency)
        arrived += rep.idx
        served += rep.served
        dropped_q += rep.dropped_queue
        dropped_k += rep.dropped_kill
        dropped_t += rep.dropped_timeout
        pending += rep.pending
        slo_ok += rep.slo_ok
        offered += len(rep.trace)
    dropped = dropped_q + dropped_k + dropped_t
    return {
        "offered": offered,              # requests in the traces
        "arrived": arrived,              # ingested by the event loop
        "served": served,
        "dropped": dropped,
        "dropped_queue": dropped_q,
        "dropped_kill": dropped_k,
        "dropped_timeout": dropped_t,
        "pending": pending,
        "served_frac": served / arrived if arrived else 1.0,
        "dropped_frac": dropped / arrived if arrived else 0.0,
        "slo_attainment": slo_ok / served if served else 1.0,
        "latency_ms_p50": lat.percentile(50) if lat.count else 0.0,
        "latency_ms_p95": lat.percentile(95) if lat.count else 0.0,
        "latency_ms_p99": lat.percentile(99) if lat.count else 0.0,
    }


@dataclass
class ServingReport:
    """One serving replay: loop stats + request-level aggregates."""

    stats: LoopStats
    jobs: List[ServingJob]
    duration: float
    requests: int                        # requests ingested
    served: int
    dropped: int
    requests_per_sec: float              # served / duration
    served_frac: float
    dropped_frac: float
    slo_attainment: float                # over served requests
    latency_ms_p50: float
    latency_ms_p95: float
    latency_ms_p99: float
    summary: Dict = field(default_factory=dict)

    @classmethod
    def build(cls, stats: LoopStats, jobs: Sequence[ServingJob],
              duration: float) -> "ServingReport":
        s = summarize_serving(jobs)
        return cls(stats=stats, jobs=list(jobs), duration=duration,
                   requests=s["arrived"], served=s["served"],
                   dropped=s["dropped"],
                   requests_per_sec=(s["served"] / duration
                                     if duration > 0 else 0.0),
                   served_frac=s["served_frac"],
                   dropped_frac=s["dropped_frac"],
                   slo_attainment=s["slo_attainment"],
                   latency_ms_p50=s["latency_ms_p50"],
                   latency_ms_p95=s["latency_ms_p95"],
                   latency_ms_p99=s["latency_ms_p99"],
                   summary=s)


def _finalize(jobs: Sequence[ServingJob], horizon: float) -> None:
    """Ingest any arrivals the loop's last interval did not reach (jobs
    that ended the replay with no nodes never got an ``advance`` call),
    so report counters cover the whole trace span."""
    for job in jobs:
        rep = job.replica
        if rep is not None:
            rep.run(horizon, horizon, rate=0.0, n_nodes=0)


def _serving_scenario(scenario, scale: float, seed: int):
    from repro.sched.scenarios import Scenario, build_scenario
    if isinstance(scenario, str):
        scenario = build_scenario(scenario, scale=scale, seed=seed)
    if not getattr(scenario, "requests", None):
        raise ValueError(f"scenario {scenario.name!r} carries no "
                         f"RequestSpec demand (Scenario.requests)")
    return scenario


def run_serving(scenario, *, scale: float = 1.0, seed: int = 0,
                trainers: Sequence[TrainerJob] = (),
                allocator=None, t_fwd: float = 120.0, pj_max: int = 10,
                coalesce_window: float = 0.0,
                horizon: Optional[float] = None, objective="latency_slo",
                telemetry=None, audit: bool = False) -> ServingReport:
    """Replay a serving scenario's hole trace with its request demand.

    ``scenario`` is a ``Scenario`` with ``requests`` set, or a name from
    ``repro.sched.scenarios.SERVING_SCENARIOS`` (built at
    ``scale``/``seed``).  ``trainers`` optionally adds training
    TrainerJobs sharing the pool (mixed serving+training under one
    policy).  The default policy is ``latency_slo``.
    """
    from repro.core import AllocationEngine

    scenario = _serving_scenario(scenario, scale, seed)
    if horizon is None:
        horizon = scenario.duration
    jobs = make_serving_jobs(scenario.requests, horizon, seed=seed,
                             id_offset=(max((t.id for t in trainers),
                                            default=-1) + 1),
                             audit=audit)
    all_jobs = list(trainers) + list(jobs)
    events = fragments_to_events(scenario.fragments)
    if allocator is None:
        allocator = AllocationEngine()
    loop = ControlLoop(events, all_jobs, allocator, ServingBackend(),
                       t_fwd=t_fwd, pj_max=pj_max, horizon=horizon,
                       coalesce_window=coalesce_window,
                       objective=objective, telemetry=telemetry)
    stats = loop.run()
    _finalize(jobs, horizon)
    return ServingReport.build(stats, jobs, horizon)


def peak_rate(trace, window: float = 300.0) -> float:
    """Peak offered rate (requests/s) of a trace over sliding windows of
    ``window`` seconds (what a dedicated deployment provisions for)."""
    arr = np.asarray(trace.arrivals, dtype=float)
    if not len(arr):
        return 0.0
    # count arrivals in [t, t+window) for every arrival-aligned window
    hi = np.searchsorted(arr, arr + window)
    lo = np.arange(len(arr))
    return float((hi - lo).max()) / window


def dedicated_nodes(job: ServingJob, *, headroom: float = _HEADROOM,
                    window: float = 300.0) -> int:
    """Smallest node count whose capacity clears ``headroom`` × the
    trace's peak rate (clamped to the job's feasible range)."""
    need = headroom * peak_rate(job.trace, window)
    for n in range(max(job.n_min, 1), job.n_max + 1):
        if job.curve(n) >= need:
            return n
    return job.n_max


def dedicated_baseline(scenario, *, scale: float = 1.0, seed: int = 0,
                       t_fwd: float = 120.0, pj_max: int = 10,
                       horizon: Optional[float] = None,
                       headroom: float = _HEADROOM,
                       telemetry=None) -> ServingReport:
    """Serve the same request traces on a static, peak-provisioned pool.

    Node count is the sum over services of the smallest replica size
    whose capacity clears ``headroom`` × the trace's peak 5-minute rate
    — the always-on deployment a serving team would buy without hole
    harvesting.  Rescale costs are zeroed (the pool never changes),
    matching the cost-free static baseline of the training-U metric.
    """
    from repro.core import AllocationEngine

    scenario = _serving_scenario(scenario, scale, seed)
    if horizon is None:
        horizon = scenario.duration
    jobs = make_serving_jobs(scenario.requests, horizon, seed=seed,
                             r_up=0.0, r_dw=0.0)
    n_static = sum(dedicated_nodes(j, headroom=headroom) for j in jobs)
    events = [PoolEvent(time=0.0, joined=tuple(range(n_static)))]
    loop = ControlLoop(events, jobs, AllocationEngine(), ServingBackend(),
                       t_fwd=t_fwd, pj_max=pj_max, horizon=horizon,
                       objective="latency_slo", telemetry=telemetry)
    stats = loop.run()
    _finalize(jobs, horizon)
    report = ServingReport.build(stats, jobs, horizon)
    report.summary["dedicated_nodes"] = n_static
    return report
