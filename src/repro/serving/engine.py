"""Batched serving engine: prefill + greedy decode over the model zoo.

Supports every architecture family's cache type (dense KV, sliding-window
ring, MLA latent, SSM recurrent state, enc-dec cross KV).  ``decode_32k``
and ``long_500k`` dry-run shapes lower exactly this ``serve_step``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model

Pytree = Any


@dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, n_new)
    prefill_time_s: float
    decode_time_s: float
    tokens_per_s: float


class ServeEngine:
    def __init__(self, model: Model, params: Pytree, *,
                 max_len: int = 512, cache_dtype=jnp.float32):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=max_len))
        self._decode = jax.jit(model.decode_step)

    def generate(self, batch: Dict[str, jax.Array], n_new: int,
                 *, greedy: bool = True,
                 rng: Optional[jax.Array] = None) -> GenerationResult:
        tokens = jnp.asarray(batch["tokens"], jnp.int32)
        bsz, prompt_len = tokens.shape
        assert prompt_len + n_new <= self.max_len

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        cache = jax.tree.map(
            lambda a: a.astype(self.cache_dtype)
            if a.dtype == jnp.bfloat16 else a, cache)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        out = []
        t0 = time.perf_counter()
        pos = prompt_len
        offset = (self.model.cfg.n_frontend_tokens
                  if self.model.cfg.frontend == "vision" else 0)
        for i in range(n_new):
            if greedy:
                nxt = jnp.argmax(logits[:, -1, :], axis=-1)
            else:
                rng, sub = jax.random.split(rng)
                nxt = jax.random.categorical(sub, logits[:, -1, :])
            nxt = nxt.astype(jnp.int32)[:, None]
            out.append(np.asarray(nxt))
            logits, cache = self._decode(self.params, cache, nxt,
                                         jnp.int32(pos + offset))
            pos += 1
        jax.block_until_ready(logits)
        t_decode = time.perf_counter() - t0
        toks = np.concatenate(out, axis=1)
        return GenerationResult(
            tokens=toks, prefill_time_s=t_prefill, decode_time_s=t_decode,
            tokens_per_s=bsz * n_new / max(t_decode, 1e-9))
