"""Elastic serving on unfillable holes (DESIGN.md §15).

Numpy-only pieces (request traces, the continuous-batching replica
model, ServingJob, the scenario harness) import eagerly; the JAX
batched-generation engine (``ServeEngine``/``GenerationResult``) is
lazy so the control-plane path works on hosts without an accelerator
stack.
"""
from repro.serving.job import ServingJob, make_serving_jobs, serving_curve
from repro.serving.replica import Batch, ReplicaSet
from repro.serving.sim import (
    ServingReport,
    dedicated_baseline,
    run_serving,
    summarize_serving,
)
from repro.serving.workload import (
    REQUEST_PROFILES,
    RequestSpec,
    RequestTrace,
    profile_rate,
    synthesize_requests,
)

__all__ = [
    "Batch", "ReplicaSet",
    "ServingJob", "make_serving_jobs", "serving_curve",
    "ServingReport", "dedicated_baseline", "run_serving",
    "summarize_serving",
    "REQUEST_PROFILES", "RequestSpec", "RequestTrace", "profile_rate",
    "synthesize_requests",
    "GenerationResult", "ServeEngine",           # lazy (JAX)
]


def __getattr__(name):
    if name in ("GenerationResult", "ServeEngine"):
        from repro.serving import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
