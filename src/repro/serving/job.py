"""ServingJob: an elastic inference service as a ControlLoop Trainer.

A serving job is a ``TrainerJob`` whose "scaling curve" is replica
capacity (requests/second at N nodes), whose "progress" is requests
served, and whose work is open-ended (``work = inf`` — a service never
finishes).  Request-level behavior (queueing, batching, latency, drain)
lives in the attached :class:`repro.serving.replica.ReplicaSet`, driven
by :class:`repro.core.backend.ServingBackend`; the allocator sees only
the capacity curve plus the ``rate``/``slo`` policy fields that
:class:`repro.core.objectives.LatencySLO` reads.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.core.loop import TrainerJob
from repro.core.scaling import ScalingCurve, amdahl_curve
from repro.serving.replica import ReplicaSet
from repro.serving.workload import RequestSpec, RequestTrace

__all__ = ["ServingJob", "make_serving_jobs", "serving_curve"]


def serving_curve(name: str, thr1: float, comm_frac: float,
                  n_max: int) -> ScalingCurve:
    """Replica capacity curve (requests/s at N nodes): Amdahl speedup
    over the single-node capacity ``thr1`` — batching/routing overhead
    plays the role of the serial fraction."""
    return amdahl_curve(name, thr1, comm_frac, max_nodes=max(n_max, 1))


@dataclass
class ServingJob(TrainerJob):
    """One elastic service inside the ControlLoop (see module docstring).

    ``work`` defaults to ``inf`` (open-ended); ``done`` counts requests
    served.  ``slo`` (inherited, seconds) is the latency target the
    replica simulation measures attainment against; ``rate`` (inherited)
    is refreshed each solve by ``ServingBackend`` from the trace's
    forward window and starts at 0.0 so the job is a *serving* job to
    the ``LatencySLO`` policy from the first decision on.
    """

    work: float = math.inf
    slo: Optional[float] = 0.5
    trace: Optional[RequestTrace] = None
    max_batch: int = 8
    max_queue: int = 256
    queue_timeout: Optional[float] = None
    # forward window (seconds) over which refresh estimates offered rate
    rate_window: float = 120.0
    replica: Optional[ReplicaSet] = field(default=None, repr=False)

    def __post_init__(self):
        if self.rate is None:
            self.rate = 0.0

    def ensure_replica(self, *, audit: bool = False) -> ReplicaSet:
        """Build (once) the request-level simulation for this service."""
        if self.replica is None:
            if self.trace is None:
                raise ValueError(f"ServingJob {self.id} has no RequestTrace")
            self.replica = ReplicaSet(
                self.trace, slo=self.slo, max_batch=self.max_batch,
                max_queue=self.max_queue, queue_timeout=self.queue_timeout,
                job_id=self.id, audit=audit)
        return self.replica


def make_serving_jobs(requests: Sequence[RequestSpec], duration: float,
                      *, seed: int = 0, id_offset: int = 0,
                      r_up: float = 20.0, r_dw: float = 5.0,
                      audit: bool = False) -> List[ServingJob]:
    """Materialize a scenario's ``RequestSpec`` list into ServingJobs
    (deterministic in ``seed``; ids ``id_offset + k``)."""
    jobs: List[ServingJob] = []
    for k, spec in enumerate(requests):
        trace = RequestTrace.synthesize(spec.profile, duration,
                                        spec.base_rate, seed=seed + k)
        job = ServingJob(
            id=id_offset + k,
            curve=serving_curve(f"serve-{spec.profile}", spec.thr1,
                                spec.comm_frac, spec.n_max),
            n_min=spec.n_min, n_max=spec.n_max, r_up=r_up, r_dw=r_dw,
            slo=spec.slo, trace=trace, max_batch=spec.max_batch,
            max_queue=spec.max_queue, queue_timeout=spec.queue_timeout)
        job.ensure_replica(audit=audit)
        jobs.append(job)
    return jobs
