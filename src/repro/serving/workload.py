"""Request-trace generator for the elastic serving tier (DESIGN.md §15).

Serving demand is an inhomogeneous Poisson arrival process: a profile
shapes the instantaneous rate ``lam(t) = base_rate * m(t)`` and arrivals
are sampled by thinning a homogeneous process at the profile's peak
rate.  The six profiles mirror the six node-trace scenarios in
``repro.sched.scenarios`` — the request side of the same machine-room
story (steady/diurnal load, submission storms, weekly modulation,
flash crowds) — so a serving scenario pairs a *hole* trace with the
*demand* trace that co-occurs with it.

Everything here is numpy-only and deterministic in ``seed``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

_HOUR = 3600.0
_DAY = 86400.0

__all__ = ["RequestTrace", "RequestSpec", "REQUEST_PROFILES",
           "profile_rate", "synthesize_requests"]


# ---------------------------------------------------------------------------
# Rate profiles: m(t) multipliers over the base rate
# ---------------------------------------------------------------------------


def _steady(t: np.ndarray, dur: float, rng) -> np.ndarray:
    return np.ones_like(t)


def _diurnal(t: np.ndarray, dur: float, rng) -> np.ndarray:
    # midday peak, small-hours trough: m in [0.2, 1.8]
    return 1.0 + 0.8 * np.sin(2.0 * math.pi * (t / _DAY - 0.25))


def _ramp(t: np.ndarray, dur: float, rng) -> np.ndarray:
    # launch-day growth: 0.3x -> 1.7x over the trace
    return 0.3 + 1.4 * t / max(dur, 1.0)


def _weekend(t: np.ndarray, dur: float, rng) -> np.ndarray:
    # weekday/weekend modulation with a diurnal overlay (trace starts
    # Monday 00:00); weekends run at a third of weekday demand
    day = np.floor(t / _DAY) % 7
    weekday = np.where(day < 5, 1.2, 0.4)
    return weekday * (1.0 + 0.6 * np.sin(2.0 * math.pi * (t / _DAY - 0.25)))


def _windows(t: np.ndarray, starts: np.ndarray, width: float) -> np.ndarray:
    """Indicator of ``t`` falling in any ``[s, s+width)`` window."""
    hit = np.zeros_like(t, dtype=bool)
    for s in starts:
        hit |= (t >= s) & (t < s + width)
    return hit


def _bursty(t: np.ndarray, dur: float, rng) -> np.ndarray:
    # quiet base + ~20-minute request storms every ~2h at 5x
    n = max(1, int(dur / (2.0 * _HOUR)))
    starts = np.sort(rng.uniform(0.0, max(dur - 1200.0, 1.0), size=n))
    return np.where(_windows(t, starts, 1200.0), 5.0, 0.6)


def _flash(t: np.ndarray, dur: float, rng) -> np.ndarray:
    # steady base + rare 5-minute flash crowds at 10x (one per ~8h)
    n = max(1, int(dur / (8.0 * _HOUR)))
    starts = np.sort(rng.uniform(0.0, max(dur - 300.0, 1.0), size=n))
    return np.where(_windows(t, starts, 300.0), 10.0, 0.8)


#: profile name -> (rate-shape fn, peak multiplier).  The peak bounds the
#: thinning envelope; shape fns may consult ``rng`` (storm placement) —
#: each synthesis hands them a dedicated, seed-derived generator, so the
#: storm schedule and the thinning draws are independently reproducible.
REQUEST_PROFILES: Dict[str, Tuple[Callable, float]] = {
    "steady": (_steady, 1.0),
    "diurnal": (_diurnal, 1.8),
    "bursty": (_bursty, 5.0),
    "ramp": (_ramp, 1.7),
    "weekend": (_weekend, 1.92),
    "flash": (_flash, 10.0),
}


def profile_rate(profile: str, t: np.ndarray, duration: float,
                 seed: int = 0) -> np.ndarray:
    """Rate multiplier ``m(t)`` for a profile (storm windows re-derived
    from ``seed``, matching what ``synthesize_requests`` sampled)."""
    shape, _ = REQUEST_PROFILES[profile]
    rng = np.random.default_rng((seed, 0xC0FFEE))
    return np.maximum(0.0, shape(np.asarray(t, dtype=float), duration, rng))


def synthesize_requests(profile: str, duration: float, base_rate: float,
                        seed: int = 0) -> np.ndarray:
    """Sorted request arrival times (seconds) over ``[0, duration)``.

    Inhomogeneous Poisson via thinning: candidates at the profile's peak
    rate, each kept with probability ``m(t)/peak``.  Deterministic in
    ``(profile, duration, base_rate, seed)``.
    """
    if profile not in REQUEST_PROFILES:
        raise KeyError(f"unknown request profile {profile!r}; "
                       f"available: {sorted(REQUEST_PROFILES)}")
    shape, peak = REQUEST_PROFILES[profile]
    lam_max = base_rate * peak
    if lam_max <= 0 or duration <= 0:
        return np.empty(0)
    # storm placement must match profile_rate -> same derived stream
    shape_rng = np.random.default_rng((seed, 0xC0FFEE))
    thin_rng = np.random.default_rng((seed, 0xA11CE))
    n_cand = thin_rng.poisson(lam_max * duration)
    cand = np.sort(thin_rng.uniform(0.0, duration, size=n_cand))
    m = np.maximum(0.0, shape(cand, duration, shape_rng))
    keep = thin_rng.uniform(0.0, 1.0, size=n_cand) < m * base_rate / lam_max
    return cand[keep]


# ---------------------------------------------------------------------------
# Traces and per-service specs
# ---------------------------------------------------------------------------


@dataclass
class RequestTrace:
    """One service's arrival stream: sorted times plus provenance."""

    name: str
    arrivals: np.ndarray            # sorted arrival times (seconds)
    duration: float                 # trace span (seconds)
    base_rate: float                # requests/second before modulation
    seed: int = 0

    def __len__(self) -> int:
        return int(len(self.arrivals))

    def rate_in(self, t0: float, t1: float) -> float:
        """Offered rate (requests/s) over ``[t0, t1)`` — the forward
        demand estimate ``ServingBackend.refresh`` feeds the allocator."""
        if t1 <= t0:
            return 0.0
        lo, hi = np.searchsorted(self.arrivals, [t0, t1])
        return float(hi - lo) / (t1 - t0)

    @classmethod
    def synthesize(cls, profile: str, duration: float, base_rate: float,
                   seed: int = 0) -> "RequestTrace":
        return cls(name=profile, duration=float(duration),
                   base_rate=float(base_rate), seed=seed,
                   arrivals=synthesize_requests(profile, duration,
                                                base_rate, seed))


@dataclass(frozen=True)
class RequestSpec:
    """Declarative description of one elastic service in a scenario
    (``Scenario.requests``): demand shape plus replica parameters.
    ``repro.serving.make_serving_jobs`` turns these into ``ServingJob``s.
    """

    profile: str                    # REQUEST_PROFILES key
    base_rate: float                # requests/second before modulation
    slo: float = 0.5                # request-latency target (seconds)
    thr1: float = 2.0               # single-node capacity (requests/s)
    comm_frac: float = 0.05         # Amdahl serial fraction of the curve
    n_min: int = 1
    n_max: int = 16
    max_batch: int = 8              # continuous-batching batch bound
    max_queue: int = 256            # admission-control queue bound
    queue_timeout: Optional[float] = None   # client patience (seconds)
