"""Continuous-batching replica model with admission control and drain
semantics (DESIGN.md §15).

``ReplicaSet`` is the serving analogue of ``AnalyticBackend``'s scaling-
curve integral: a discrete-event simulation of one elastic service's
replicas over the node allocation the ControlLoop grants it.  State is a
bounded FIFO queue of request arrival times plus at most one in-flight
batch; the event loop interleaves request arrivals (from a
``RequestTrace``) with batch completions, so per-request latency — and
therefore SLO attainment — is exact, not an M/M/1 approximation.

Semantics the serving test tier pins down (tests/test_serving_loop.py):

* **conservation** — at every instant, arrivals ingested ==
  served + dropped (queue overflow) + dropped (kill) + queued +
  in-flight;
* **no stolen node-seconds** — a batch only *starts* when the current
  allocation has nodes and the rescale stall (``busy_until``) has
  passed; its start is recorded in ``audit``;
* **drain on shrink** — a graceful shrink (or full preemption) never
  discards the in-flight batch: it completes at the service rate it was
  started with, the replica-side mirror of a checkpointed scale-down
  (new batches are what wait out the stall);
* **kill loses at most one batch** — a hard node failure drops only the
  in-flight batch (``drop_inflight``), never the queue.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, List, Optional, Tuple

from repro.obs.telemetry import Histogram, NULL_TELEMETRY

__all__ = ["Batch", "ReplicaSet"]


@dataclass
class Batch:
    """One in-flight batch: completion time, member arrival times, and
    the allocation it started on (for the audit trail)."""

    done_at: float
    arrivals: List[float]
    started_at: float
    n_nodes: int


class ReplicaSet:
    """Event-driven continuous-batching simulation of one service.

    Parameters
    ----------
    trace : RequestTrace
        The arrival stream (sorted times, seconds).
    slo : float
        Per-request latency target (seconds); a served request attains
        the SLO iff ``finish - arrival <= slo``.
    max_batch : int
        Largest batch a replica forms per service cycle.
    max_queue : int
        Admission bound: arrivals beyond a full queue are dropped
        (counted in ``dropped_queue``), never queued unboundedly.
    queue_timeout : float, optional
        Client patience (seconds): a queued request that has waited
        longer by the time a batch forms is abandoned (counted in
        ``dropped_timeout``) instead of being served hopelessly late —
        the time-axis half of admission control.  ``None`` disables.
    job_id : int
        Owning ``ServingJob`` id (telemetry labels).
    audit : bool
        Record every batch start as ``(start_t, batch_size, n_nodes)``
        — the evidence the conservation/no-stolen-nodes tests check.
    """

    #: observation sink; ``ServingBackend.bind`` swaps in the loop's hub
    telemetry = NULL_TELEMETRY

    def __init__(self, trace, *, slo: float = 0.5, max_batch: int = 8,
                 max_queue: int = 256, queue_timeout: Optional[float] = None,
                 job_id: int = -1, audit: bool = False):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        self.trace = trace
        self.slo = float(slo)
        self.max_batch = int(max_batch)
        self.max_queue = int(max_queue)
        self.queue_timeout = (None if queue_timeout is None
                              else float(queue_timeout))
        self.job_id = job_id
        # --- state ---
        self.idx = 0                        # arrivals ingested so far
        self.queue: Deque[float] = deque()  # waiting request arrival times
        self.inflight: Optional[Batch] = None
        # --- counters ---
        self.served = 0
        self.dropped_queue = 0              # admission-control drops
        self.dropped_kill = 0               # hard-failure drops
        self.dropped_timeout = 0            # client-patience drops
        self.slo_ok = 0                     # served within the SLO
        self.latency = Histogram()          # served latency (milliseconds)
        self.audit: List[Tuple[float, int, int]] = [] if audit else None

    # -- derived ---------------------------------------------------------

    @property
    def inflight_size(self) -> int:
        return 0 if self.inflight is None else len(self.inflight.arrivals)

    @property
    def pending(self) -> int:
        """Requests admitted but not yet resolved (queued + in-flight)."""
        return len(self.queue) + self.inflight_size

    def conserved(self) -> bool:
        """The conservation invariant (always true by construction;
        asserted at every event by the property tests)."""
        return self.idx == (self.served + self.dropped_queue
                            + self.dropped_kill + self.dropped_timeout
                            + self.pending)

    def slo_attainment(self) -> float:
        """Fraction of *served* requests inside the SLO (1.0 when none
        served yet — dropped requests are reported separately)."""
        return self.slo_ok / self.served if self.served else 1.0

    def offered_rate(self, t0: float, t1: float) -> float:
        return self.trace.rate_in(t0, t1)

    # -- event loop ------------------------------------------------------

    def _complete(self, batch: Batch) -> None:
        tel = self.telemetry
        for arr in batch.arrivals:
            lat = batch.done_at - arr
            self.served += 1
            if lat <= self.slo:
                self.slo_ok += 1
            self.latency.observe(lat * 1e3)
            if tel:
                tel.observe("serving.latency_ms", lat * 1e3)
        if tel:
            tel.count("serving.served", len(batch.arrivals))

    def run(self, start: float, end: float, *, rate: float, n_nodes: int,
            busy_until: float = 0.0) -> int:
        """Advance the simulation over ``[start, end)``; returns requests
        served in the interval.

        ``rate`` is the replica capacity (requests/s) of the *current*
        allocation of ``n_nodes`` nodes; a batch of ``k`` requests
        started at ``t0`` completes at ``t0 + k/rate`` and keeps that
        completion time even if the allocation later shrinks (drain).
        New batches start no earlier than ``busy_until`` (rescale
        stall).  Arrivals are ingested regardless of capacity — demand
        does not pause because the service lost its nodes.
        """
        arrivals = self.trace.arrivals
        n_arr = len(arrivals)
        tel = self.telemetry
        t = start
        served0 = self.served
        while True:
            # start a batch at the current instant when possible
            if (self.inflight is None and self.queue and rate > 0.0
                    and n_nodes > 0):
                t0 = max(t, busy_until)
                if t0 < end:
                    if self.queue_timeout is not None:
                        while (self.queue and
                               t0 - self.queue[0] > self.queue_timeout):
                            self.queue.popleft()
                            self.dropped_timeout += 1
                            if tel:
                                tel.count("serving.dropped_timeout")
                        if not self.queue:
                            continue
                    k = min(self.max_batch, len(self.queue))
                    batch = [self.queue.popleft() for _ in range(k)]
                    self.inflight = Batch(done_at=t0 + k / rate,
                                          arrivals=batch, started_at=t0,
                                          n_nodes=n_nodes)
                    if self.audit is not None:
                        self.audit.append((t0, k, n_nodes))
                    continue
            t_arr = arrivals[self.idx] if self.idx < n_arr else float("inf")
            t_done = (self.inflight.done_at if self.inflight is not None
                      else float("inf"))
            # interval convention [start, end): completions at exactly
            # ``end`` resolve now, arrivals at ``end`` belong to the next
            # interval (idx is monotonic, so nothing double-ingests)
            if t_done > end and t_arr >= end:
                break
            if t_done <= t_arr:
                t = t_done
                self._complete(self.inflight)
                self.inflight = None
            else:
                t = t_arr
                self.idx += 1
                if tel:
                    tel.count("serving.arrived")
                if len(self.queue) < self.max_queue:
                    self.queue.append(t_arr)
                else:
                    self.dropped_queue += 1
                    if tel:
                        tel.count("serving.dropped_queue")
        return self.served - served0

    def drop_inflight(self, now: float) -> int:
        """Hard-kill semantics: the in-flight batch is lost (at most one
        batch, never the queue).  Returns the number of requests lost."""
        if self.inflight is None:
            return 0
        lost = len(self.inflight.arrivals)
        self.inflight = None
        self.dropped_kill += lost
        tel = self.telemetry
        if tel:
            tel.count("serving.dropped_kill", lost)
        return lost

    def summary(self) -> dict:
        """Aggregate counters + latency percentiles (milliseconds)."""
        lat = self.latency.summary() if self.latency.count else {}
        return {
            "arrived": self.idx,
            "served": self.served,
            "dropped_queue": self.dropped_queue,
            "dropped_kill": self.dropped_kill,
            "dropped_timeout": self.dropped_timeout,
            "pending": self.pending,
            "slo_ok": self.slo_ok,
            "slo_attainment": self.slo_attainment(),
            "latency_ms_p50": lat.get("p50", 0.0),
            "latency_ms_p95": lat.get("p95", 0.0),
            "latency_ms_p99": lat.get("p99", 0.0),
        }
