"""Scenario library: named cluster/workload profiles → unfillable-hole
traces.

Each builder synthesizes a job log (``swf.synthetic_workload``), runs it
through the FCFS+EASY simulator (``backfill.simulate_schedule``) and
returns a ``Scenario`` carrying the per-node unfillable fragments plus
the shared ``TraceStats`` (core/trace.py) — directly consumable by
``fragments_to_events`` → ``Simulator`` / ``AllocationEngine``.

``scale`` shrinks node count and (except the weekly profile) duration so
tests and ``--smoke`` benchmarks stay cheap; submission rates re-derive
from the target offered load, so the *character* of each scenario is
scale-invariant.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

from repro.core.events import Fragment
from repro.core.trace import TraceStats, trace_stats
from repro.sched.backfill import SchedResult, SchedStats, simulate_schedule
from repro.sched.swf import BatchJob, synthetic_workload

_HOUR = 3600.0
_DAY = 86400.0


@dataclass
class Scenario:
    name: str
    description: str
    n_nodes: int
    duration: float
    fragments: List[Fragment]       # the unfillable-hole trace
    stats: TraceStats               # shared trace statistics
    sched: SchedStats               # batch-scheduler-side statistics
    result: SchedResult             # full simulation (records, holes, ...)
    # optional fault environment (repro.chaos.ChaosSpec); None for the
    # fault-free profiles — set only by the CHAOS_SCENARIOS builders so
    # existing sweeps over SCENARIOS are untouched
    chaos: Optional[object] = None
    # federated profiles (FLEET_SCENARIOS): ascending node-id offsets of
    # each pool's sub-cluster, and the composed sub-scenarios themselves.
    # None/empty on single-pool profiles (DESIGN.md §14)
    pool_bounds: Optional[Tuple[int, ...]] = None
    subs: List["Scenario"] = field(default_factory=list)
    # serving profiles (SERVING_SCENARIOS): request demand co-occurring
    # with the hole trace — a list of repro.serving.RequestSpec; None on
    # training-only profiles (DESIGN.md §15)
    requests: Optional[List] = None

    def pool_map(self):
        """``repro.federation.PoolMap`` for a fleet profile (or None)."""
        if not self.pool_bounds:
            return None
        from repro.federation import PoolMap
        return PoolMap.from_bounds(self.pool_bounds)


def _interarrival(load: float, mean_nodes: float, mean_runtime: float,
                  n_nodes: int) -> float:
    """Mean interarrival achieving the target offered load."""
    return mean_nodes * mean_runtime / (load * n_nodes)


def _lognormal_mean(median: float, sigma: float) -> float:
    return median * math.exp(sigma * sigma / 2.0)


def _build(name: str, description: str, *, n_nodes: int, duration: float,
           seed: int, drains: Sequence[Tuple[float, float]] = (),
           min_fragment: float = 0.0, **wl) -> Scenario:
    jobs = synthetic_workload(duration=duration, seed=seed, **wl)
    res = simulate_schedule(jobs, n_nodes, horizon=duration, drains=drains,
                            min_fragment=min_fragment)
    frags = res.fragments()
    return Scenario(name=name, description=description, n_nodes=n_nodes,
                    duration=duration, fragments=frags,
                    stats=trace_stats(frags, n_nodes, duration),
                    sched=res.stats, result=res)


def _dims(base_nodes: int, base_hours: float, scale: float,
          *, fixed_duration: bool = False) -> Tuple[int, float]:
    n = max(8, int(round(base_nodes * scale)))
    hours = base_hours if fixed_duration else max(4.0, base_hours * scale)
    return n, hours * _HOUR


# ---------------------------------------------------------------------------
# Profiles
# ---------------------------------------------------------------------------


def capability(scale: float = 1.0, seed: int = 0) -> Scenario:
    """Summit-like capability mix: few large, long jobs; holes appear when
    a wide head job drains the machine waiting for its reservation."""
    n, dur = _dims(128, 24.0, scale)
    keep = [(s, w) for s, w in zip((8, 16, 32, 64),
                                   (0.35, 0.30, 0.25, 0.10)) if s <= n]
    sizes = tuple(s for s, _ in keep)
    weights = tuple(w for _, w in keep)
    mean_nodes = sum(s * w for s, w in keep) / sum(weights)
    rt_med, rt_sig = 4 * _HOUR, 0.8
    return _build(
        "capability", "capability cluster, large long jobs, load ~0.9",
        n_nodes=n, duration=dur, seed=seed,
        mean_interarrival=_interarrival(0.9, mean_nodes,
                                        _lognormal_mean(rt_med, rt_sig), n),
        size_choices=sizes, size_weights=weights,
        runtime_median=rt_med, runtime_sigma=rt_sig,
        overestimate=2.0)


def capacity(scale: float = 1.0, seed: int = 0) -> Scenario:
    """Capacity cluster: many small short jobs — high event churn, mostly
    short fragments (the MalleTrain-style stress case)."""
    n, dur = _dims(64, 24.0, scale)
    sizes, weights = (1, 2, 4), (0.5, 0.3, 0.2)
    mean_nodes = sum(s * w for s, w in zip(sizes, weights))
    rt_med, rt_sig = 0.5 * _HOUR, 1.0
    return _build(
        "capacity", "capacity cluster, many small short jobs, load ~0.85",
        n_nodes=n, duration=dur, seed=seed,
        mean_interarrival=_interarrival(0.85, mean_nodes,
                                        _lognormal_mean(rt_med, rt_sig), n),
        size_choices=sizes, size_weights=weights,
        runtime_median=rt_med, runtime_sigma=rt_sig,
        overestimate=2.0)


def bursty(scale: float = 1.0, seed: int = 0) -> Scenario:
    """Submission storms: a quiet Poisson base overlaid with bursts of
    jobs every ~2 h — alternating deep backlog and post-storm holes."""
    n, dur = _dims(64, 24.0, scale)
    sizes, weights = (1, 2, 4, 8), (0.4, 0.3, 0.2, 0.1)
    mean_nodes = sum(s * w for s, w in zip(sizes, weights))
    rt_med, rt_sig = 0.5 * _HOUR, 0.9
    return _build(
        "bursty", "burst storms every ~2h over a light Poisson base",
        n_nodes=n, duration=dur, seed=seed,
        mean_interarrival=_interarrival(0.35, mean_nodes,
                                        _lognormal_mean(rt_med, rt_sig), n),
        size_choices=sizes, size_weights=weights,
        runtime_median=rt_med, runtime_sigma=rt_sig,
        burst_every=2 * _HOUR, burst_size=max(8, int(round(0.4 * n))),
        overestimate=2.0)


def maintenance(scale: float = 1.0, seed: int = 0) -> Scenario:
    """Periodic maintenance drains: no job may straddle a window, so the
    ramp-down ahead of each drain yields wide sawtooth holes."""
    n, dur = _dims(96, 24.0, scale)
    hours = dur / _HOUR
    drains = [(s * _HOUR, (s + 1.0) * _HOUR)
              for s in _drain_starts(hours)]
    sizes, weights = (2, 4, 8, 16), (0.3, 0.3, 0.25, 0.15)
    mean_nodes = sum(s * w for s, w in zip(sizes, weights))
    rt_med, rt_sig = 1.5 * _HOUR, 0.8
    return _build(
        "maintenance", "1h machine drains with pre-drain ramp-down holes",
        n_nodes=n, duration=dur, seed=seed, drains=drains,
        mean_interarrival=_interarrival(0.85, mean_nodes,
                                        _lognormal_mean(rt_med, rt_sig), n),
        size_choices=sizes, size_weights=weights,
        runtime_median=rt_med, runtime_sigma=rt_sig,
        overestimate=2.0)


def _drain_starts(hours: float) -> List[float]:
    """One 1h drain every ~8h, placed away from the trace edges."""
    starts, s = [], 6.0
    while s + 1.0 < hours:
        starts.append(s)
        s += 8.0
    return starts or [max(1.0, hours / 2.0)]


def weekend(scale: float = 1.0, seed: int = 0) -> Scenario:
    """Low-load weekends: a full synthetic week with day/night/weekend
    submission-rate modulation — long low-load holes, mostly queue-empty."""
    n, dur = _dims(32, 7 * 24.0, scale, fixed_duration=True)
    sizes, weights = (1, 2, 4, 8), (0.4, 0.3, 0.2, 0.1)
    mean_nodes = sum(s * w for s, w in zip(sizes, weights))
    rt_med, rt_sig = 1.0 * _HOUR, 0.9
    return _build(
        "weekend", "7-day trace, weekday/weekend modulated submissions",
        n_nodes=n, duration=dur, seed=seed,
        mean_interarrival=_interarrival(0.75, mean_nodes,
                                        _lognormal_mean(rt_med, rt_sig), n),
        size_choices=sizes, size_weights=weights,
        runtime_median=rt_med, runtime_sigma=rt_sig,
        weekly_modulation=True, overestimate=2.0)


def overestimate(scale: float = 1.0, seed: int = 0) -> Scenario:
    """High walltime overestimation (~8x): EASY turns conservative, so
    backfill misses holes that were in fact usable — more unfillable
    node-time at the same load."""
    n, dur = _dims(64, 24.0, scale)
    sizes, weights = (1, 2, 4, 8), (0.35, 0.3, 0.2, 0.15)
    mean_nodes = sum(s * w for s, w in zip(sizes, weights))
    rt_med, rt_sig = 0.75 * _HOUR, 0.9
    return _build(
        "overestimate", "8x requested-walltime overestimation",
        n_nodes=n, duration=dur, seed=seed,
        mean_interarrival=_interarrival(0.85, mean_nodes,
                                        _lognormal_mean(rt_med, rt_sig), n),
        size_choices=sizes, size_weights=weights,
        runtime_median=rt_med, runtime_sigma=rt_sig,
        overestimate=8.0, overestimate_sigma=0.3)


SCENARIOS: Dict[str, Callable[..., Scenario]] = {
    "capability": capability,
    "capacity": capacity,
    "bursty": bursty,
    "maintenance": maintenance,
    "weekend": weekend,
    "overestimate": overestimate,
}


# ---------------------------------------------------------------------------
# Chaos profiles (DESIGN.md §12): fault-free base trace + a ChaosSpec.
# Kept in their own registry so sweeps over SCENARIOS stay fault-free.
# ---------------------------------------------------------------------------


def flaky(scale: float = 1.0, seed: int = 0, *,
          mtbf: float = 4 * _HOUR) -> Scenario:
    """Capacity profile on flaky hardware: independent per-node hard
    kills at the given MTBF, occasionally with a corrupt latest
    checkpoint; the allocator itself crashes twice a day."""
    from repro.chaos import ChaosSpec
    sc = capacity(scale=scale, seed=seed)
    sc.name, sc.description = "flaky", \
        f"capacity trace + per-node kills (MTBF {mtbf / _HOUR:g}h)"
    # periods cap at a fraction of the trace so scaled-down (smoke/test)
    # runs still exercise allocator restarts
    sc.chaos = ChaosSpec(seed=seed, mtbf=mtbf, drain_frac=0.25,
                         corrupt_prob=0.1,
                         crash_every=min(12 * _HOUR, sc.duration / 2.0),
                         restart_penalty=30.0)
    return sc


def straggler(scale: float = 1.0, seed: int = 0) -> Scenario:
    """Bursty profile with straggler episodes: every few hours rescale
    costs inflate 4x for 15 minutes — the MILP's r_up/r_dw terms must
    push it toward keeping allocations still during episodes."""
    from repro.chaos import ChaosSpec
    sc = bursty(scale=scale, seed=seed)
    sc.name, sc.description = "straggler", \
        "bursty trace + 4x rescale-cost episodes (~2/12h, 15 min)"
    sc.chaos = ChaosSpec(seed=seed, straggler_rate=1.0 / 6.0,
                         straggler_factor=4.0, straggler_duration=900.0)
    return sc


def blackout(scale: float = 1.0, seed: int = 0) -> Scenario:
    """Capability profile with correlated blackouts: half the live pool
    hard-fails at once every ~8h (rack/power-domain loss)."""
    from repro.chaos import ChaosSpec
    sc = capability(scale=scale, seed=seed)
    sc.name, sc.description = "blackout", \
        "capability trace + 50% pool kill every ~8h"
    sc.chaos = ChaosSpec(seed=seed,
                         blackout_every=min(8 * _HOUR, sc.duration / 3.0),
                         blackout_frac=0.5, restart_penalty=60.0)
    return sc


CHAOS_SCENARIOS: Dict[str, Callable[..., Scenario]] = {
    "flaky": flaky,
    "straggler": straggler,
    "blackout": blackout,
}


# ---------------------------------------------------------------------------
# Federated profiles (DESIGN.md §14): several sub-clusters composed into
# one fleet on disjoint node-id ranges, each a natural pool shard.
# ---------------------------------------------------------------------------

#: sub-cluster profiles a fleet cycles through (day-scale, equal duration)
_FLEET_MIX: Tuple[Callable[..., Scenario], ...] = (
    capacity, bursty, capability, maintenance)


def fleet(scale: float = 1.0, seed: int = 0, *, pools: int = 4) -> Scenario:
    """A federated fleet: ``pools`` sub-clusters with disjoint node-id
    ranges, cycling through the day-scale profiles (capacity, bursty,
    capability, maintenance) with per-pool seeds.  ``pool_bounds`` gives
    the natural ``PoolMap`` (``Scenario.pool_map()``); the fragments are
    the union of the sub-traces shifted onto each pool's id range."""
    subs: List[Scenario] = []
    bounds: List[int] = []
    frags: List[Fragment] = []
    offset = 0
    for k in range(pools):
        builder = _FLEET_MIX[k % len(_FLEET_MIX)]
        sub = builder(scale=scale, seed=seed + k)
        bounds.append(offset)
        frags.extend(Fragment(node=f.node + offset, start=f.start,
                              end=f.end) for f in sub.fragments)
        subs.append(sub)
        offset += sub.n_nodes
    duration = max(s.duration for s in subs)
    frags.sort(key=lambda f: (f.start, f.node))
    return Scenario(
        name="fleet",
        description=(f"{pools}-pool fleet: "
                     + " + ".join(s.name for s in subs)),
        n_nodes=offset, duration=duration, fragments=frags,
        stats=trace_stats(frags, offset, duration),
        # scheduler-side stats are per-sub-cluster (each ran its own
        # batch scheduler); the fleet keeps the first as representative
        # and the full per-pool set in ``subs``
        sched=subs[0].sched, result=subs[0].result,
        pool_bounds=tuple(bounds), subs=subs)


FLEET_SCENARIOS: Dict[str, Callable[..., Scenario]] = {
    "fleet": fleet,
}


# ---------------------------------------------------------------------------
# Serving profiles (DESIGN.md §15): a hole trace + the request demand
# that co-occurs with it.  Demand is sized against the trace's mean
# idle-pool capacity (eq_nodes × per-node request rate), so the profiles
# stay servable — and scale-invariant in character — at any ``scale``.
# ---------------------------------------------------------------------------

#: per-node request capacity (requests/s) of the serving curves below
_SERVE_THR1 = 2.0


def serve_diurnal(scale: float = 1.0, seed: int = 0) -> Scenario:
    """Capacity-cluster holes serving diurnal user traffic: a midday-
    peaked chat-style service plus a steady background API, sized to
    ~35% of the mean hole capacity."""
    from repro.serving.workload import RequestSpec
    sc = capacity(scale=scale, seed=seed)
    sc.name, sc.description = "serve_diurnal", \
        "capacity holes + diurnal chat service + steady API"
    cap = sc.stats.eq_nodes * _SERVE_THR1        # mean hole capacity, req/s
    sc.requests = [
        RequestSpec(profile="diurnal", base_rate=0.25 * cap, slo=4.0,
                    thr1=_SERVE_THR1, max_batch=4, max_queue=64,
                    queue_timeout=8.0),
        RequestSpec(profile="steady", base_rate=0.10 * cap, slo=4.0,
                    thr1=_SERVE_THR1, max_batch=4, max_queue=64,
                    queue_timeout=8.0),
    ]
    return sc


def serve_bursty(scale: float = 1.0, seed: int = 0) -> Scenario:
    """Bursty submission-storm holes serving flash-crowd traffic: the
    hardest pairing — demand spikes 10x while the hole supply itself is
    churning."""
    from repro.serving.workload import RequestSpec
    sc = bursty(scale=scale, seed=seed)
    sc.name, sc.description = "serve_bursty", \
        "bursty holes + flash-crowd service + bursty background"
    cap = sc.stats.eq_nodes * _SERVE_THR1
    # flash peaks hit 10x base, so demand is sized well below the mean
    # hole capacity — the spikes, not the averages, are the stressor
    sc.requests = [
        RequestSpec(profile="flash", base_rate=0.08 * cap, slo=4.0,
                    thr1=_SERVE_THR1, max_batch=4, max_queue=64,
                    queue_timeout=6.0),
        RequestSpec(profile="bursty", base_rate=0.06 * cap, slo=4.0,
                    thr1=_SERVE_THR1, max_batch=4, max_queue=64,
                    queue_timeout=6.0),
    ]
    return sc


SERVING_SCENARIOS: Dict[str, Callable[..., Scenario]] = {
    "serve_diurnal": serve_diurnal,
    "serve_bursty": serve_bursty,
}


def build_scenario(name: str, scale: float = 1.0, seed: int = 0) -> Scenario:
    try:
        builder = (SCENARIOS.get(name) or CHAOS_SCENARIOS.get(name)
                   or FLEET_SCENARIOS.get(name) or SERVING_SCENARIOS[name])
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; available: "
                       f"{sorted(SCENARIOS) + sorted(CHAOS_SCENARIOS) + sorted(FLEET_SCENARIOS) + sorted(SERVING_SCENARIOS)}"
                       ) from None
    return builder(scale=scale, seed=seed)


def all_scenarios(scale: float = 1.0, seed: int = 0) -> Iterator[Scenario]:
    for name in SCENARIOS:
        yield build_scenario(name, scale=scale, seed=seed)


def run_scenario(scenario, trainers, *, allocator=None, run_live: bool = False,
                 t_fwd=120.0, pj_max: int = 10, coalesce_window: float = 0.0,
                 horizon: float = None, scale: float = 1.0, seed: int = 0,
                 time_scale: float = 1.0, max_steps_per_interval: int = 4,
                 steps_per_second: float = 1.0, objective=None):
    """Run a scenario's unfillable-hole trace through the shared
    ``ControlLoop`` — simulated or live, same policy (DESIGN.md §9).

    ``scenario`` is a ``Scenario`` or a name from ``SCENARIOS`` (built at
    ``scale``/``seed``).  With ``run_live=False`` (default), ``trainers``
    is a list of ``TrainerJob``s and the trace replays through the
    ``Simulator`` (AnalyticBackend), returning a ``SimReport``.  With
    ``run_live=True``, ``trainers`` is a list of ``ManagedTrainer``s
    wrapping real ``ElasticTrainer``s; the same decisions drive actual
    rescales and train steps (LiveBackend, trace time compressed by
    ``time_scale``), returning a ``RuntimeReport``.

    ``objective`` selects the allocation policy (an
    ``repro.core.objectives.Objective``, a registry name such as
    ``"maxmin"``, or ``None`` for the paper's throughput objective) —
    e.g. ``run_scenario("bursty", jobs, objective=MaxMinFairness())``
    replays any scenario under any policy, simulated or live
    (DESIGN.md §10).
    """
    from repro.core import AllocationEngine
    from repro.core.events import fragments_to_events

    if isinstance(scenario, str):
        scenario = build_scenario(scenario, scale=scale, seed=seed)
    events = fragments_to_events(scenario.fragments)
    if horizon is None:
        horizon = scenario.duration
    if allocator is None:
        allocator = AllocationEngine()
    if run_live:
        from repro.elastic import BFTrainerRuntime
        rt = BFTrainerRuntime(trainers, allocator, t_fwd=t_fwd,
                              pj_max=pj_max, coalesce_window=coalesce_window,
                              steps_per_second=steps_per_second,
                              objective=objective)
        return rt.run(events, time_scale=time_scale,
                      max_steps_per_interval=max_steps_per_interval,
                      horizon=horizon)
    from repro.core import Simulator
    return Simulator(events, trainers, allocator, t_fwd=t_fwd, pj_max=pj_max,
                     horizon=horizon, coalesce_window=coalesce_window,
                     objective=objective).run()
