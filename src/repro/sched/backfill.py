"""Event-driven FCFS + EASY-backfill batch-scheduler simulation.

This is the layer that *produces* the idle-node traces BFTrainer
consumes.  A hole in the cluster is **backfillable** when some queued job
fits it (enough free nodes now, and — per EASY — it either finishes by
the head job's reservation or only uses nodes the reservation doesn't
need).  The simulator places those jobs, so they never surface as idle
time.  Everything that remains — holes too small or too short for every
queued job, and low-load idle with an empty queue — is **unfillable** by
the batch scheduler and is emitted as per-node ``Fragment``s (paper §2:
the resource BFTrainer harvests).

Scheduling semantics (classic EASY, Lifka '95):

* jobs start in FCFS order while the queue head fits the free nodes;
* when the head doesn't fit, it gets a *reservation* at the shadow time
  (earliest time enough nodes free, computed from running jobs'
  **requested** walltimes — the scheduler never knows actual runtimes);
* later jobs may backfill now iff they fit the free nodes and either
  (a) their requested walltime ends by the shadow time, or (b) they use
  no more than the ``extra`` nodes the reservation leaves over;
* nodes actually free up at the **actual** runtime, which is how
  walltime overestimation manufactures holes.

Maintenance drains (``drains=[(start, end), ...]``) reserve the whole
machine: no job may overlap a drain window, so the ramp-down ahead of a
drain produces the paper's large sawtooth holes.  Drain node-time itself
is *excluded* from the emitted fragments (the nodes are down, not idle).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.events import Fragment, merge_fragments, validate_fragments
from repro.sched.swf import BatchJob

BLOCKED = "blocked"       # queue non-empty: hole unfillable for every queued job
LOW_LOAD = "low-load"     # queue empty: nothing submitted to fill the hole


@dataclass(frozen=True)
class Hole:
    """One contiguous unfillable idle interval on one node."""

    fragment: Fragment
    blocked_frac: float     # share of the interval with a non-empty queue

    @property
    def kind(self) -> str:
        return BLOCKED if self.blocked_frac >= 0.5 else LOW_LOAD


@dataclass
class JobRecord:
    job: BatchJob
    start: float
    end: float                  # start + actual runtime
    nodes: Tuple[int, ...]
    backfilled: bool

    @property
    def wait(self) -> float:
        return self.start - self.job.submit


@dataclass
class SchedStats:
    n_nodes: int
    duration: float
    n_jobs: int
    n_started: int
    n_backfilled: int
    n_rejected: int
    n_unstarted: int
    utilization: float          # busy node-time / (total - drain) node-time
    idle_fraction: float        # unfillable node-time / total node-time
    blocked_share: float        # of unfillable node-time, share queue-blocked
    drain_nodetime: float
    mean_wait: float
    max_wait: float


@dataclass
class SchedResult:
    n_nodes: int
    t_end: float
    records: List[JobRecord]
    rejected: List[BatchJob]
    unstarted: List[BatchJob]
    holes: List[Hole]
    stats: SchedStats

    def fragments(self, *, min_length: float = 0.0,
                  kinds: Sequence[str] = (BLOCKED, LOW_LOAD)
                  ) -> List[Fragment]:
        """The unfillable-hole trace, ready for ``fragments_to_events``."""
        out = [h.fragment for h in self.holes
               if h.kind in kinds and h.fragment.length >= min_length]
        out.sort(key=lambda f: (f.start, f.node))
        return out


def _overlap(a0: float, a1: float, b0: float, b1: float) -> float:
    return max(0.0, min(a1, b1) - max(a0, b0))


def simulate_schedule(jobs: Sequence[BatchJob], n_nodes: int, *,
                      horizon: Optional[float] = None,
                      drains: Sequence[Tuple[float, float]] = (),
                      min_fragment: float = 0.0) -> SchedResult:
    """Run FCFS+EASY over ``jobs`` on ``n_nodes`` and emit the holes.

    ``horizon`` clips the simulation (jobs submitted after it are ignored,
    trailing idle runs to it); without it the simulation ends at the last
    job completion.  ``min_fragment`` drops emitted holes shorter than the
    given seconds (holes BFTrainer could never amortize).
    """
    drains = sorted((float(s), float(e)) for s, e in drains if e > s)
    for (s0, e0), (s1, e1) in zip(drains, drains[1:]):
        if s1 < e0:
            raise ValueError("drain windows overlap")
    jobs = sorted(jobs, key=lambda j: (j.submit, j.id))
    if horizon is not None:
        jobs = [j for j in jobs if j.submit < horizon]

    free = set(range(n_nodes))
    free_since = {n: 0.0 for n in range(n_nodes)}
    raw_holes: List[Fragment] = []
    queue: List[BatchJob] = []
    running: List[JobRecord] = []
    records: List[JobRecord] = []
    rejected: List[BatchJob] = []

    # event heap: (time, seq, kind, payload); kinds: 0 completion frees
    # nodes, 1 arrival enqueues, 2 bare scheduling tick (drain ends)
    seq = 0
    heap: List[Tuple[float, int, int, object]] = []
    for j in jobs:
        heapq.heappush(heap, (j.submit, seq, 1, j)); seq += 1
    for _, e in drains:
        if horizon is None or e < horizon:
            heapq.heappush(heap, (e, seq, 2, None)); seq += 1

    blocked_segs: List[Tuple[float, float]] = []
    blocked_since: Optional[float] = None

    def _fits_drains(t: float, wall: float) -> bool:
        return all(not (t < de and t + wall > ds) for ds, de in drains)

    def _start(job: BatchJob, t: float, backfilled: bool) -> None:
        nonlocal seq
        chosen = tuple(sorted(free)[:job.nodes])
        for n in chosen:
            free.discard(n)
            if t > free_since[n]:
                raw_holes.append(Fragment(node=n, start=free_since[n], end=t))
        rec = JobRecord(job=job, start=t, end=t + job.runtime,
                        nodes=chosen, backfilled=backfilled)
        running.append(rec)
        records.append(rec)
        heapq.heappush(heap, (rec.end, seq, 0, rec)); seq += 1

    def _schedule(t: float) -> None:
        # FCFS: start queue heads while they fit
        while queue:
            head = queue[0]
            if head.nodes > n_nodes:
                rejected.append(queue.pop(0))
                continue
            if head.nodes <= len(free) and _fits_drains(t, head.walltime):
                _start(queue.pop(0), t, backfilled=False)
            else:
                break
        if not queue:
            return
        head = queue[0]
        # head's reservation (shadow time): earliest node availability per
        # running jobs' *requested* end times, then pushed past any drain
        # the head cannot straddle
        if head.nodes <= len(free):
            shadow, extra = t, len(free) - head.nodes   # drain-blocked only
        else:
            avail = len(free)
            shadow, extra = math.inf, 0
            for req_end, cnt in sorted((r.start + r.job.walltime,
                                        len(r.nodes)) for r in running):
                avail += cnt
                if avail >= head.nodes:
                    shadow, extra = req_end, avail - head.nodes
                    break
        moved = True
        while moved and math.isfinite(shadow):
            moved = False
            for ds, de in drains:
                if shadow < de and shadow + head.walltime > ds:
                    shadow, moved = de, True
        # EASY backfill pass over the rest of the queue, FCFS order
        for job in list(queue[1:]):
            if not free:
                break
            if job.nodes > len(free) or not _fits_drains(t, job.walltime):
                continue
            fits_window = t + job.walltime <= shadow
            if fits_window or job.nodes <= extra:
                if not fits_window:
                    extra -= job.nodes
                queue.remove(job)
                _start(job, t, backfilled=True)

    # ------------------------------------------------------------------
    # main loop
    # ------------------------------------------------------------------
    t_last = 0.0
    while heap:
        t = heap[0][0]
        if horizon is not None and t >= horizon:
            break
        while heap and heap[0][0] == t:
            _, _, kind, payload = heapq.heappop(heap)
            if kind == 0:
                rec = payload
                running.remove(rec)
                for n in rec.nodes:
                    free.add(n)
                    free_since[n] = t
            elif kind == 1:
                queue.append(payload)
        _schedule(t)
        now_blocked = bool(queue)
        if now_blocked and blocked_since is None:
            blocked_since = t
        elif not now_blocked and blocked_since is not None:
            blocked_segs.append((blocked_since, t))
            blocked_since = None
        t_last = t

    t_end = horizon if horizon is not None else t_last
    if blocked_since is not None:
        blocked_segs.append((blocked_since, t_end))
    for n in free:
        if t_end > free_since[n]:
            raw_holes.append(Fragment(node=n, start=free_since[n], end=t_end))
    unstarted = list(queue)

    # subtract drain windows, classify by queue-blocked overlap — all
    # vectorized so month-scale traces (10⁵⁺ holes) classify in numpy
    # time (DESIGN.md §11)
    merged = merge_fragments(raw_holes)
    nd = np.fromiter((f.node for f in merged), dtype=np.int64,
                     count=len(merged))
    hs = np.maximum(np.fromiter((f.start for f in merged), dtype=float,
                                count=len(merged)), 0.0)
    he = np.minimum(np.fromiter((f.end for f in merged), dtype=float,
                                count=len(merged)), t_end)
    for ds, de in drains:            # few windows; each pass is vectorized
        clear = (he <= ds) | (hs >= de)
        cut = ~clear
        pre = cut & (hs < ds)        # piece before the drain
        post = cut & (he > de)       # piece after the drain
        nd = np.concatenate([nd[clear], nd[pre], nd[post]])
        new_hs = np.concatenate([hs[clear], hs[pre],
                                 np.full(int(post.sum()), de)])
        new_he = np.concatenate([he[clear],
                                 np.minimum(he[pre], ds), he[post]])
        hs, he = new_hs, new_he
    keep = (he - hs > 0.0) & (he - hs >= min_fragment)
    nd, hs, he = nd[keep], hs[keep], he[keep]
    # blocked node-time per hole via prefix sums over the (disjoint,
    # sorted) blocked segments: F(t) = blocked time in (-inf, t]
    if blocked_segs and len(hs):
        bs = np.array([b0 for b0, _ in blocked_segs])
        be = np.array([b1 for _, b1 in blocked_segs])
        cum = np.concatenate(([0.0], np.cumsum(be - bs)))

        def cum_blocked(t: np.ndarray) -> np.ndarray:
            i = np.searchsorted(bs, t, side="right")
            over = np.where(i > 0,
                            np.maximum(0.0, be[np.maximum(i - 1, 0)] - t),
                            0.0)
            return cum[i] - over

        blocked = cum_blocked(he) - cum_blocked(hs)
    else:
        blocked = np.zeros(len(hs))
    order = np.lexsort((nd, hs))
    holes = [Hole(fragment=Fragment(node=int(nd[i]), start=float(hs[i]),
                                    end=float(he[i])),
                  blocked_frac=float(blocked[i] / (he[i] - hs[i])))
             for i in order]
    validate_fragments([h.fragment for h in holes])

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------
    busy = sum(len(r.nodes) * max(0.0, min(r.end, t_end) - r.start)
               for r in records)
    drain_nt = n_nodes * sum(_overlap(s, e, 0.0, t_end) for s, e in drains)
    idle = sum(h.fragment.length for h in holes)
    blocked_nt = sum(h.fragment.length * h.blocked_frac for h in holes)
    total_nt = n_nodes * t_end if t_end > 0 else 0.0
    waits = [r.wait for r in records]
    stats = SchedStats(
        n_nodes=n_nodes, duration=t_end,
        n_jobs=len(jobs), n_started=len(records),
        n_backfilled=sum(1 for r in records if r.backfilled),
        n_rejected=len(rejected), n_unstarted=len(unstarted),
        utilization=busy / (total_nt - drain_nt) if total_nt > drain_nt else 0.0,
        idle_fraction=idle / total_nt if total_nt else 0.0,
        blocked_share=blocked_nt / idle if idle else 0.0,
        drain_nodetime=drain_nt,
        mean_wait=float(sum(waits) / len(waits)) if waits else 0.0,
        max_wait=float(max(waits)) if waits else 0.0,
    )
    return SchedResult(n_nodes=n_nodes, t_end=t_end, records=records,
                       rejected=rejected, unstarted=unstarted,
                       holes=holes, stats=stats)
