# Workload subsystem: turns batch-job logs (real SWF or synthetic) into
# the unfillable-hole traces BFTrainer consumes, via an FCFS+EASY
# backfill scheduler simulation, plus a library of named scenarios.
from repro.sched.backfill import (
    BLOCKED,
    LOW_LOAD,
    Hole,
    JobRecord,
    SchedResult,
    SchedStats,
    simulate_schedule,
)
from repro.sched.scenarios import (
    CHAOS_SCENARIOS,
    SCENARIOS,
    Scenario,
    all_scenarios,
    build_scenario,
    run_scenario,
)
from repro.sched.swf import (
    BatchJob,
    dump_swf,
    mean_size,
    offered_load,
    parse_swf,
    synthetic_workload,
)

__all__ = [
    "BLOCKED", "LOW_LOAD", "Hole", "JobRecord", "SchedResult", "SchedStats",
    "simulate_schedule",
    "CHAOS_SCENARIOS", "SCENARIOS", "Scenario", "all_scenarios",
    "build_scenario", "run_scenario",
    "BatchJob", "dump_swf", "mean_size", "offered_load", "parse_swf",
    "synthetic_workload",
]
