"""Synthetic token data pipeline with elastic per-node batching.

BFTrainer semantics (paper §4.2): the per-node minibatch is FIXED; the
global batch is ``n_nodes * per_node_batch`` and changes when the Trainer
rescales (weak scaling).  The pipeline is seeded + step-indexed so a
rescaled Trainer resumes deterministically without data loss or repeats:
sample ids are assigned round-robin over a virtual epoch permutation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    per_node_batch: int = 8
    seed: int = 0
    n_virtual_samples: int = 1 << 20   # virtual epoch size


class TokenPipeline:
    """Deterministic synthetic LM batches (markov-ish token streams)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._consumed = 0   # global sample cursor (survives rescale)

    @property
    def samples_consumed(self) -> int:
        return self._consumed

    def state(self) -> Dict:
        return {"consumed": self._consumed}

    def restore(self, state: Dict) -> None:
        self._consumed = int(state["consumed"])

    def _gen_sample(self, idx: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 32) ^ idx)
        # cheap structured stream: random walk over the vocab so models can
        # actually reduce loss below uniform
        steps = rng.integers(-32, 33, size=cfg.seq_len)
        toks = np.cumsum(steps) + rng.integers(0, cfg.vocab_size)
        return np.mod(toks, cfg.vocab_size).astype(np.int32)

    def next_batch(self, n_nodes: int) -> Dict[str, np.ndarray]:
        """Global batch for the current step at the given scale."""
        cfg = self.cfg
        bsz = n_nodes * cfg.per_node_batch
        idx = (self._consumed + np.arange(bsz)) % cfg.n_virtual_samples
        toks = np.stack([self._gen_sample(int(i)) for i in idx])
        self._consumed += bsz
        return {"tokens": toks, "labels": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch(1)
