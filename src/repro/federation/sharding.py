"""Fleet sharding: node → pool ownership (DESIGN.md §14).

A federated fleet is K *pools*, each an independent allocation domain:
one ``AllocationEngine`` (or any ``Allocator``) per pool, one event
queue per pool, no shared solver state.  ``PoolMap`` is the static
ownership function — every node id belongs to exactly one pool for the
lifetime of the run, so a pool's sub-problems never overlap and the
per-pool solves are embarrassingly parallel.

Three ownership layouts cover the real deployments:

* ``stride``     — ``node % K``: id-agnostic, balances any id domain;
* ``contiguous`` — ``node // block``: rack/row-aligned blocks, the
  natural layout when node ids encode physical placement;
* ``bounds``     — explicit sub-cluster boundaries, for heterogeneous
  fleets composed of differently sized machines (the ``fleet``
  scenario profile in ``repro.sched.scenarios``).
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.events import PoolEvent, split_events_by_pool


@dataclass(frozen=True)
class PoolMap:
    """Static node → pool ownership function.

    Construct via :meth:`stride`, :meth:`contiguous` or
    :meth:`from_bounds`; call it (or :meth:`pool_of`) with a node id.
    """

    n_pools: int
    #: contiguous block width (``node // block``); ``None`` = stride
    block: Optional[int] = None
    #: explicit ascending pool-start offsets (overrides ``block``)
    bounds: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.n_pools < 1:
            raise ValueError(f"n_pools must be >= 1, got {self.n_pools}")
        if self.bounds and len(self.bounds) != self.n_pools:
            raise ValueError(
                f"bounds ({len(self.bounds)}) must have one entry per pool "
                f"({self.n_pools})")

    @classmethod
    def stride(cls, n_pools: int) -> "PoolMap":
        """``node % n_pools`` — id-agnostic round-robin ownership."""
        return cls(n_pools=n_pools)

    @classmethod
    def contiguous(cls, n_nodes: int, n_pools: int) -> "PoolMap":
        """Equal contiguous blocks over ``[0, n_nodes)`` (last pool takes
        the remainder; ids beyond ``n_nodes`` clamp to the last pool)."""
        block = max(1, -(-n_nodes // n_pools))
        return cls(n_pools=n_pools, block=block)

    @classmethod
    def from_bounds(cls, bounds: Sequence[int]) -> "PoolMap":
        """Explicit sub-cluster start offsets (ascending, first must be
        the fleet's lowest id); pool k owns ``[bounds[k], bounds[k+1])``."""
        b = tuple(int(x) for x in bounds)
        if list(b) != sorted(b):
            raise ValueError(f"bounds must be ascending, got {b}")
        return cls(n_pools=len(b), bounds=b)

    def pool_of(self, node: int) -> int:
        if self.bounds:
            return max(0, bisect.bisect_right(self.bounds, node) - 1)
        if self.block is not None:
            return min(self.n_pools - 1, node // self.block)
        return node % self.n_pools

    __call__ = pool_of

    def split(self, events: Sequence[PoolEvent]
              ) -> Dict[int, List[PoolEvent]]:
        """Per-pool, pool-tagged substreams (``split_events_by_pool``)."""
        return split_events_by_pool(events, self.pool_of)


def assign_jobs(jobs: Sequence, weights: Sequence[float]) -> List[int]:
    """Initial job → pool placement: capacity-weighted round-robin.

    Jobs are placed in FCFS order (the same ``(arrival, id)`` order the
    loop admits them in); each goes to the pool with the largest
    remaining capacity-per-job ratio, ties to the lowest pool id —
    deterministic, and proportional to pool size in the steady state.
    The cross-pool rebalancer corrects any drift at run time.
    """
    w = [max(float(x), 1e-9) for x in weights]
    counts = [0] * len(w)
    out = []
    for _ in sorted(jobs, key=lambda j: (j.arrival, j.id)):
        k = max(range(len(w)), key=lambda i: (w[i] / (counts[i] + 1), -i))
        counts[k] += 1
        out.append(k)
    order = sorted(range(len(jobs)),
                   key=lambda i: (jobs[i].arrival, jobs[i].id))
    by_pos = {order[p]: out[p] for p in range(len(order))}
    return [by_pos[i] for i in range(len(jobs))]
