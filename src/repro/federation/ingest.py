"""Async event ingestion: per-pool FIFO queues (DESIGN.md §14).

The federated control plane never hands a pool the fleet's merged
timeline.  Incoming ``PoolEvent``s are routed to the owning pool's
queue as they arrive (``EventRouter.ingest`` / ``push``) and each pool
drains *its own* queue once per decision epoch — an event in pool 3
wakes pool 3's engine and nobody else's.  Queues are plain FIFOs over
an already time-sorted stream, so draining up to an epoch boundary is a
pointer bump, not a sort.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core.events import PoolEvent, merge_events
from repro.federation.sharding import PoolMap


class EventRouter:
    """Routes a fleet event stream into K per-pool FIFO queues.

    ``drain(k, upto)`` pops pool k's events strictly before ``upto`` —
    epoch windows are half-open ``[t0, t1)``, so an event at exactly the
    boundary belongs to the *next* epoch (matching ``ControlLoop``'s
    ``t_start`` filter, which is inclusive).

    Drained prefixes are compacted away once a queue's head pointer
    crosses ``compact_threshold`` entries: week-scale federated replays
    previously retained every event of the stream per pool (the head
    only ever advanced), which is O(stream) resident memory; compaction
    makes it O(pending).  ``pending`` / ``next_time`` semantics are
    unchanged (regression-tested in tests/test_resilience.py).
    """

    def __init__(self, pool_map: PoolMap, *, compact_threshold: int = 1024):
        if compact_threshold < 1:
            raise ValueError("compact_threshold must be >= 1")
        self.pool_map = pool_map
        self.compact_threshold = compact_threshold
        self.compactions = 0
        self._queues: Dict[int, List[PoolEvent]] = {
            k: [] for k in range(pool_map.n_pools)}
        self._heads: Dict[int, int] = {k: 0 for k in self._queues}

    def push(self, event: PoolEvent) -> None:
        """Enqueue one already pool-tagged event (``event.pool`` set)."""
        if event.pool is None:
            raise ValueError("push() requires a pool-tagged event; "
                             "use ingest() for raw fleet events")
        self._queues[event.pool].append(event)

    def ingest(self, events: Sequence[PoolEvent]) -> None:
        """Split a raw fleet stream by ownership and enqueue per pool."""
        for k, evs in self.pool_map.split(merge_events(events)).items():
            self._queues[k].extend(evs)

    def drain(self, pool: int, upto: Optional[float] = None
              ) -> List[PoolEvent]:
        """Pop pool's queued events with ``time < upto`` (all if None)."""
        q = self._queues[pool]
        head = self._heads[pool]
        if upto is None:
            tail = len(q)
        else:
            tail = head
            while tail < len(q) and q[tail].time < upto:
                tail += 1
        out = q[head:tail]
        self._heads[pool] = tail
        if tail >= self.compact_threshold:
            # drop the drained prefix; pending events (and their order)
            # are untouched, so pending()/next_time() see no difference
            del q[:tail]
            self._heads[pool] = 0
            self.compactions += 1
        return out

    def pending(self, pool: int) -> int:
        return len(self._queues[pool]) - self._heads[pool]

    def next_time(self, pool: int) -> Optional[float]:
        """Timestamp of the pool's oldest undrained event, or None."""
        q = self._queues[pool]
        head = self._heads[pool]
        return q[head].time if head < len(q) else None

    def pools_with_pending(self, upto: Optional[float] = None) -> List[int]:
        """Pools holding at least one undrained event (before ``upto``)."""
        out = []
        for k in self._queues:
            t = self.next_time(k)
            if t is not None and (upto is None or t < upto):
                out.append(k)
        return out
