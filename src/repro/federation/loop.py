"""FederatedLoop: K pool-local control loops + slow cross-pool
rebalancing (DESIGN.md §14).

Architecture
------------
The fleet is sharded by ``PoolMap`` into K pools.  Each pool owns an
independent ``ControlLoop`` + ``Allocator`` pair and reacts *only* to
its own events, drained from a per-pool FIFO (``EventRouter``) once per
decision epoch — churn in pool 3 never triggers a re-solve in pool 0.
Execution proceeds in epoch windows ``[a, b)``: every pool with queued
events or unfinished jobs replays its window through a windowed
``ControlLoop`` (``t_start=a``, ``initial_pool`` = the pool's live
set), job state carrying across windows on the shared ``TrainerJob``
objects.  Pool windows are disjoint in state, so they run concurrently
(``parallel=True``) with deterministic results.

At epoch boundaries (every ``rebalance_every``-th), the ``Rebalancer``
compares per-pool ``Objective.upper_bound`` deficits and migrates whole
jobs from persistently starved pools to pools with spare capacity,
charging the teardown + transfer stall explicitly.

Degenerate modes keep the semantics honest:

* ``n_pools=1`` with default cadence runs ONE full-horizon
  ``ControlLoop`` — bit-identical to the single-pool simulator (the
  K=1 parity sweep in tests/test_federation.py);
* rebalancing off (``rebalance=False``) runs each pool's full horizon
  in one un-windowed shot — maximal asynchrony, zero epoch overhead.
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Union

from repro.core.allocator import Allocator
from repro.core.backend import AnalyticBackend
from repro.core.engine import AllocationEngine, EngineStats
from repro.core.events import PoolEvent, apply_events, merge_events
from repro.core.loop import ControlLoop, LoopStats, TrainerJob
from repro.federation.engine import FederatedEngine
from repro.federation.ingest import EventRouter
from repro.federation.rebalance import Migration, PoolView, Rebalancer
from repro.federation.sharding import PoolMap, assign_jobs
from repro.obs.telemetry import NULL_TELEMETRY, Histogram, Telemetry
from repro.resilience.watchdog import PoolWatchdog


@dataclass
class PoolStats:
    """Per-pool slice of a federated run."""
    pool: int
    n_jobs: int = 0                 # jobs owned at end of run
    events_processed: int = 0       # solves this pool's loop performed
    total_samples: float = 0.0
    solver_wall: float = 0.0
    supply_node_s: float = 0.0      # ∫ |live set| dt over the run
    allocated_node_s: float = 0.0   # Σ job node-second deltas while owned
    migrations_in: int = 0
    migrations_out: int = 0
    decision_walls: List[float] = field(default_factory=list)
    engine: Optional[EngineStats] = None
    # watchdog bookkeeping (DESIGN.md §16); all zero when no watchdog
    failures: int = 0               # epochs whose solve raised
    timeouts: int = 0               # epochs whose max decision wall blew
    quarantined_epochs: int = 0     # epochs skipped while quarantined
    state: str = "healthy"          # watchdog state at end of run

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["engine"] = self.engine.as_dict() if self.engine else None
        return d


def _percentile(walls: Sequence[float], q: float) -> float:
    if not walls:
        return 0.0
    s = sorted(walls)
    k = max(0, min(len(s) - 1, int(round(q / 100.0 * len(s))) - 1))
    return s[k]


@dataclass
class FederatedStats:
    """Fleet-level report: LoopStats-shaped totals + federation extras.

    Job-derived totals (rescale/preempt/failure costs, runtimes) are
    computed from the ``TrainerJob`` objects at end of run, so they
    include migration charges; flow totals (samples, solves, solver
    wall) are summed over per-pool epoch runs."""

    total_samples: float
    makespan: float
    events_processed: int
    allocator: str
    per_trainer_runtime: Dict[int, float]
    rescale_cost_samples: float
    rescale_cost_s: float
    preempt_cost_s: float
    solver_wall_total: float
    unfinished: int = 0
    n_failures: int = 0
    lost_progress: float = 0.0
    restart_cost_s: float = 0.0
    # -- federation extras --
    n_pools: int = 1
    epochs: int = 0
    migrations: List[Migration] = field(default_factory=list)
    migration_stall_s: float = 0.0
    pools: List[PoolStats] = field(default_factory=list)
    # -- watchdog extras (DESIGN.md §16; zero without a watchdog) --
    pool_failures: int = 0
    quarantines: int = 0
    readmissions: int = 0
    evacuations: int = 0

    def decision_walls(self) -> List[float]:
        """Fleet-wide per-solve wall times (seconds), pool order."""
        out: List[float] = []
        for p in self.pools:
            out.extend(p.decision_walls)
        return out

    def decision_ms(self, q: float) -> float:
        """Fleet decision-latency percentile in milliseconds."""
        return _percentile(self.decision_walls(), q) * 1e3

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["pools"] = [p.as_dict() for p in self.pools]
        d["migrations"] = [dataclasses.asdict(m) for m in self.migrations]
        return d


def _supply_integral(size0: int, events: Sequence[PoolEvent],
                     a: float, b: float) -> float:
    """∫|live| dt over [a, b) given the window's (sorted) events."""
    t, size, total = a, size0, 0.0
    for e in events:
        total += size * (e.time - t)
        size += len(e.joined) - len(e.left) - len(e.failed)
        t = e.time
    return total + size * (b - t)


class FederatedLoop:
    """K pool-local control loops behind one run() (parameters mirror
    ``ControlLoop``; federation knobs documented below).

    Parameters
    ----------
    pool_map : PoolMap, optional
        Node ownership; default ``PoolMap.stride(n_pools)``.
    n_pools : int
        Pool count when ``pool_map`` is not given (default 1).
    allocator_factory : Callable[[int], Allocator], optional
        Builds pool k's allocator (default: one ``AllocationEngine``
        per pool, wired to that pool's telemetry hub).
    backend_factory : Callable[[int], backend], optional
        Builds pool k's execution backend (default ``AnalyticBackend``).
    epoch_s : float, optional
        Decision-epoch width (trace seconds).  Default: 1/16 of the
        trace span when rebalancing, whole-horizon otherwise.  Also
        forces the epoch path for ``n_pools=1`` when set explicitly
        (used by the windowed-equivalence tests).
    rebalance : bool
        Enable the cross-pool rebalancer (default True; moot at K=1).
    rebalance_every : int
        Rebalance once per this many epochs (default 1).
    rebalancer : Rebalancer, optional
        Custom policy instance (overrides ``migration_cost_s``).
    migration_cost_s : float
        State-transfer stall charged per migrated job (seconds).
    parallel : bool
        Solve pool windows concurrently (default True).  Pool state is
        disjoint, so results are identical either way.
    decision_deadline_s : float, optional
        Hard per-solve deadline threaded into the default per-pool
        engines (DESIGN.md §16 degradation ladder).  None (default)
        disables it — results are then bit-identical to pre-§16 runs.
    watchdog : PoolWatchdog, optional
        Per-pool health tracker enabling quarantine + probation on the
        epoch path: a pool whose epoch raises (or blows
        ``watchdog.timeout_s`` of per-decision wall) repeatedly is
        frozen, its queued jobs evacuated to healthy pools.  None
        (default) keeps the historical fail-loudly behaviour.
    """

    def __init__(self, events: Sequence[PoolEvent],
                 jobs: Sequence[TrainerJob], *,
                 pool_map: Optional[PoolMap] = None, n_pools: int = 1,
                 allocator_factory: Optional[
                     Callable[[int], Allocator]] = None,
                 backend_factory: Optional[Callable[[int], object]] = None,
                 t_fwd: Union[float, str] = 120.0, pj_max: int = 10,
                 horizon: Optional[float] = None, sos2_points: int = 8,
                 coalesce_window: float = 0.0, objective=None,
                 telemetry: Optional[Telemetry] = None,
                 epoch_s: Optional[float] = None, rebalance: bool = True,
                 rebalance_every: int = 1,
                 rebalancer: Optional[Rebalancer] = None,
                 migration_cost_s: float = 0.0, parallel: bool = True,
                 max_workers: Optional[int] = None,
                 decision_deadline_s: Optional[float] = None,
                 watchdog: Optional["PoolWatchdog"] = None):
        self.pool_map = pool_map or PoolMap.stride(n_pools)
        K = self.pool_map.n_pools
        self.events = list(events)
        self.jobs = list(jobs)
        self.t_fwd = t_fwd
        self.pj_max = pj_max
        self.horizon = horizon
        self.sos2_points = sos2_points
        self.coalesce_window = coalesce_window
        self.objective = objective
        self.telemetry = telemetry or NULL_TELEMETRY
        self.epoch_s = epoch_s
        self.rebalance = rebalance and K > 1
        self.rebalance_every = max(1, rebalance_every)
        self.migration_cost_s = migration_cost_s
        self.rebalancer = rebalancer or Rebalancer(
            migration_cost_s=migration_cost_s, sos2_points=sos2_points)
        self.parallel = parallel
        self.max_workers = max_workers
        # self-healing knobs (DESIGN.md §16).  decision_deadline_s is
        # threaded into the default per-pool engines (ladder-backed hard
        # deadline per solve); the watchdog quarantines pools whose
        # epochs raise or blow their timeout.  Both default off — the
        # loop is then byte-identical to the pre-§16 behaviour.
        self.decision_deadline_s = decision_deadline_s
        self.watchdog = watchdog
        # nominal forward window for rebalance projections ("adaptive"
        # resolves per-pool inside each ControlLoop; the rebalancer uses
        # the paper's default constant)
        self._t_fwd_nominal = (float(t_fwd)
                               if not isinstance(t_fwd, str) else 120.0)

        # per-pool telemetry hubs (only when observing: the federated
        # path keeps the zero-overhead-when-disabled property)
        if self.telemetry:
            self._pool_tel: Dict[int, Telemetry] = {
                k: Telemetry(exact_cap=self.telemetry.exact_cap)
                for k in range(K)}
        else:
            self._pool_tel = {k: NULL_TELEMETRY for k in range(K)}

        if allocator_factory is None:
            allocator_factory = (
                lambda k: AllocationEngine(
                    telemetry=self._pool_tel[k],
                    decision_deadline_s=self.decision_deadline_s))
        self.fed_engine = FederatedEngine(self.pool_map, allocator_factory)
        self._backend_factory = backend_factory or (lambda k:
                                                    AnalyticBackend())
        self.backends = {k: self._backend_factory(k) for k in range(K)}

    # ------------------------------------------------------------------

    def run(self) -> FederatedStats:
        K = self.pool_map.n_pools
        events = merge_events(self.events)
        jobs = sorted(self.jobs, key=lambda j: (j.arrival, j.id))

        if not events and not jobs:
            return FederatedStats(0.0, 0.0, 0, self.fed_engine.name, {},
                                  0.0, 0.0, 0.0, 0.0, n_pools=K)

        times = [e.time for e in events] + [j.arrival for j in jobs]
        t0 = min(times)
        t_end = self.horizon if self.horizon is not None else max(times)

        # single-pool, default cadence: ONE full-horizon ControlLoop —
        # the federation layer adds nothing, so it must cost nothing
        # (and the K=1 parity tests hold by construction)
        if K == 1 and self.epoch_s is None:
            return self._run_single(events, jobs, t0, t_end)
        # rebalancing off: maximal asynchrony — every pool replays its
        # full horizon in one un-windowed shot
        if not self.rebalance and self.epoch_s is None:
            return self._run_async(events, jobs, t0, t_end)
        return self._run_epochs(events, jobs, t0, t_end)

    # -- degenerate modes ----------------------------------------------

    def _pool_loop(self, k: int, events: Sequence[PoolEvent],
                   jobs: Sequence[TrainerJob], *,
                   t_start: Optional[float] = None,
                   initial_pool: Sequence[int] = (),
                   horizon: Optional[float] = None) -> ControlLoop:
        return ControlLoop(
            events, jobs, self.fed_engine.engine(k), self.backends[k],
            t_fwd=self.t_fwd, pj_max=self.pj_max, horizon=horizon,
            sos2_points=self.sos2_points,
            coalesce_window=self.coalesce_window, objective=self.objective,
            telemetry=self._pool_tel[k], t_start=t_start,
            initial_pool=initial_pool)

    def _run_single(self, events, jobs, t0, t_end) -> FederatedStats:
        loop = self._pool_loop(0, events, jobs, horizon=self.horizon)
        s = loop.run()
        ps = PoolStats(
            pool=0, n_jobs=len(jobs),
            events_processed=s.events_processed,
            total_samples=s.total_samples, solver_wall=s.solver_wall_total,
            supply_node_s=_supply_integral(0, events, t0, t_end),
            allocated_node_s=sum(j.node_seconds for j in jobs),
            decision_walls=[r.solver_wall for r in s.event_records
                            if r.solver_wall > 0.0])
        stats = self._fleet_stats([s.total_samples], [ps], jobs,
                                  makespan=s.makespan, epochs=1)
        self._finish_telemetry(stats)
        return stats

    def _run_async(self, events, jobs, t0, t_end) -> FederatedStats:
        router = EventRouter(self.pool_map)
        router.ingest(events)
        owned = self._assign(jobs)

        def one(k: int):
            evs = router.drain(k)
            if not evs and not owned[k]:
                return None, evs
            loop = self._pool_loop(k, evs, owned[k], horizon=self.horizon)
            return loop.run(), evs

        results = self._map_pools(one)
        pools, samples = [], []
        for k, (s, evs) in enumerate(results):
            ps = PoolStats(pool=k, n_jobs=len(owned[k]))
            if s is not None:
                ps.events_processed = s.events_processed
                ps.total_samples = s.total_samples
                ps.solver_wall = s.solver_wall_total
                ps.decision_walls = [r.solver_wall for r in s.event_records
                                     if r.solver_wall > 0.0]
                samples.append(s.total_samples)
            start = min([e.time for e in evs]
                        + [j.arrival for j in owned[k]], default=t_end)
            ps.supply_node_s = _supply_integral(0, evs, start, t_end)
            ps.allocated_node_s = sum(j.node_seconds for j in owned[k])
            pools.append(ps)
        stats = self._fleet_stats(samples, pools, jobs,
                                  makespan=self._makespan(jobs, t0, t_end),
                                  epochs=1)
        self._finish_telemetry(stats)
        return stats

    # -- the epoch-windowed federated path -----------------------------

    def _run_epochs(self, events, jobs, t0, t_end) -> FederatedStats:
        K = self.pool_map.n_pools
        router = EventRouter(self.pool_map)
        router.ingest(events)
        owned = self._assign(jobs)
        live: Dict[int, set] = {k: set() for k in range(K)}
        pools = [PoolStats(pool=k) for k in range(K)]
        migrations: List[Migration] = []
        migration_stall = 0.0
        span = max(t_end - t0, 0.0)
        epoch_s = self.epoch_s if self.epoch_s is not None \
            else max(span / 16.0, 1e-9)

        wd = self.watchdog
        evacuations = 0

        def one(k: int, a: float, b: float, evs: List[PoolEvent]):
            if wd is not None and wd.is_quarantined(k):
                # frozen map: events still drain (membership stays
                # honest via the apply_events fold below) but no solve
                return "quarantined"
            unfinished = [j for j in owned[k] if not j.finished]
            if not evs and not unfinished:
                return None
            ns_before = sum(j.node_seconds for j in owned[k])
            try:
                loop = self._pool_loop(k, evs, owned[k], t_start=a,
                                       initial_pool=live[k], horizon=b)
                s = loop.run()
            except Exception as exc:
                if wd is None:
                    raise           # no watchdog: fail loudly, as before
                return ("failed", exc)
            return s, sum(j.node_seconds for j in owned[k]) - ns_before

        a = t0
        epoch = 0
        samples: List[float] = []
        while a < t_end or epoch == 0:
            b = min(a + epoch_s, t_end) if a < t_end else t_end
            epoch += 1
            drained = {k: router.drain(k, b if b < t_end else None)
                       for k in range(K)}
            results = self._map_pools(
                lambda k: one(k, a, b, drained[k]))
            for k, res in enumerate(results):
                ps = pools[k]
                ps.supply_node_s += _supply_integral(len(live[k]),
                                                     drained[k], a, b)
                live[k] = apply_events(live[k], drained[k])
                if res == "quarantined":
                    ps.quarantined_epochs += 1
                    continue
                if res is None:
                    if wd is not None:
                        wd.record(k)            # clean (idle) epoch
                    continue
                failed = timed_out = False
                if isinstance(res, tuple) and res[0] == "failed":
                    failed = True
                    ps.failures += 1
                    if self.telemetry:
                        self.telemetry.instant(
                            "federation", "pool-failure", b, pool=k,
                            error=repr(res[1]))
                else:
                    s, ns_delta = res
                    ps.events_processed += s.events_processed
                    ps.total_samples += s.total_samples
                    ps.solver_wall += s.solver_wall_total
                    ps.allocated_node_s += ns_delta
                    walls = [r.solver_wall for r in s.event_records
                             if r.solver_wall > 0.0]
                    ps.decision_walls.extend(walls)
                    samples.append(s.total_samples)
                    if wd is not None and walls and \
                            wd.over_timeout(max(walls)):
                        timed_out = True
                        ps.timeouts += 1
                if wd is not None:
                    wd.record(k, failed=failed, timed_out=timed_out)

            # quarantine housekeeping: evacuate queued jobs out of sick
            # pools, then advance every pool's state clock
            if wd is not None:
                sick = wd.quarantined_pools()
                if sick:
                    views = [PoolView(k, len(live[k]),
                                      [j for j in owned[k]
                                       if not j.finished])
                             for k in range(K)]
                    for m in self.rebalancer.evacuate(views, sick, b):
                        migration_stall += self._apply_migration(m, owned,
                                                                 b)
                        pools[m.src].migrations_out += 1
                        pools[m.dst].migrations_in += 1
                        migrations.append(m)
                        evacuations += 1
                        if self.telemetry:
                            self.telemetry.instant(
                                "federation", "evacuate", b, job=m.job_id,
                                src=m.src, dst=m.dst)
                for k in range(K):
                    wd.tick(k)

            # degraded decisions upgrade off the hot path, once per epoch
            if self.decision_deadline_s is not None:
                for k in range(K):
                    alloc = self.fed_engine.engine(k)
                    eng = getattr(alloc, "engine", alloc)
                    up = getattr(eng, "upgrade", None)
                    if up is not None:
                        up(max_items=8)

            # cross-pool rebalance on the slow clock
            if self.rebalance and epoch % self.rebalance_every == 0 \
                    and b < t_end:
                sick = set(wd.quarantined_pools()) if wd is not None \
                    else set()
                views = [PoolView(k, len(live[k]),
                                  [j for j in owned[k] if not j.finished])
                         for k in range(K) if k not in sick]
                for m in self.rebalancer.propose(self.objective, views,
                                                 self._t_fwd_nominal, b):
                    migration_stall += self._apply_migration(m, owned, b)
                    pools[m.src].migrations_out += 1
                    pools[m.dst].migrations_in += 1
                    migrations.append(m)
                    if self.telemetry:
                        self.telemetry.instant(
                            "federation", "migrate", b, job=m.job_id,
                            src=m.src, dst=m.dst, gain=m.gain, loss=m.loss)

            if b >= t_end:
                break
            a = b
            if all(j.finished for j in jobs) and \
                    not router.pools_with_pending():
                break

        for k in range(K):
            pools[k].n_jobs = len(owned[k])
            if wd is not None:
                pools[k].state = wd.state(k)
        stats = self._fleet_stats(
            samples, pools, jobs,
            makespan=self._makespan(jobs, t0, t_end), epochs=epoch,
            migrations=migrations, migration_stall_s=migration_stall)
        if wd is not None:
            stats.pool_failures = wd.stats.failures
            stats.quarantines = wd.stats.quarantines
            stats.readmissions = wd.stats.readmissions
        stats.evacuations = evacuations
        self._finish_telemetry(stats)
        return stats

    def _apply_migration(self, m: Migration, owned, now: float) -> float:
        """Move the job between ownership lists and charge the stall:
        teardown ``r_dw`` if it held nodes, plus the transfer cost.
        Returns the stall seconds charged."""
        job = next(j for j in owned[m.src] if j.id == m.job_id)
        owned[m.src].remove(job)
        owned[m.dst].append(job)
        stall = self.migration_cost_s
        if job.nodes:
            old = len(job.nodes)
            job.rescale_cost_s += job.r_dw
            job.rescale_cost_samples += job.curve(old) * job.r_dw
            job.n_rescales += 1
            job.nodes = []
            stall += job.r_dw
        if stall > 0.0:
            job.busy_until = max(job.busy_until, now) + stall
        return stall

    # -- shared plumbing -----------------------------------------------

    def _assign(self, jobs) -> Dict[int, List[TrainerJob]]:
        """Initial job→pool placement, weighted by each pool's distinct
        node count over the whole trace (capacity proxy)."""
        K = self.pool_map.n_pools
        seen: Dict[int, set] = {k: set() for k in range(K)}
        for e in self.events:
            for n in e.joined:
                seen[self.pool_map(n)].add(n)
        weights = [len(seen[k]) for k in range(K)]
        if not any(weights):
            weights = [1.0] * K
        placement = assign_jobs(jobs, weights)
        owned: Dict[int, List[TrainerJob]] = {k: [] for k in range(K)}
        for j, k in zip(jobs, placement):
            owned[k].append(j)
        return owned

    def _map_pools(self, fn):
        K = self.pool_map.n_pools
        if self.parallel and K > 1:
            workers = self.max_workers or min(K, 8)
            with ThreadPoolExecutor(max_workers=workers) as ex:
                return list(ex.map(fn, range(K)))
        return [fn(k) for k in range(K)]

    def _makespan(self, jobs, t0, t_end) -> float:
        ends = [j.finished_at for j in jobs if j.finished_at is not None]
        if any(not j.finished for j in jobs):
            return t_end - t0
        return (max(ends) - t0) if ends else 0.0

    def _fleet_stats(self, samples, pools, jobs, *, makespan, epochs,
                     migrations=(), migration_stall_s=0.0
                     ) -> FederatedStats:
        for ps in pools:
            ps.engine = self.fed_engine.pool_stats().get(ps.pool)
        per_rt = {j.id: (j.finished_at - j.arrival) for j in jobs
                  if j.finished_at is not None}
        return FederatedStats(
            total_samples=sum(samples),
            makespan=makespan,
            events_processed=sum(p.events_processed for p in pools),
            allocator=self.fed_engine.name,
            per_trainer_runtime=per_rt,
            rescale_cost_samples=sum(j.rescale_cost_samples for j in jobs),
            rescale_cost_s=sum(j.rescale_cost_s for j in jobs),
            preempt_cost_s=sum(j.preempt_cost_s for j in jobs),
            solver_wall_total=sum(p.solver_wall for p in pools),
            unfinished=sum(1 for j in jobs if not j.finished),
            n_failures=sum(j.n_failures for j in jobs),
            lost_progress=sum(j.lost_progress for j in jobs),
            restart_cost_s=sum(j.restart_cost_s for j in jobs),
            n_pools=self.pool_map.n_pools,
            epochs=epochs,
            migrations=list(migrations),
            migration_stall_s=migration_stall_s,
            pools=pools,
        )

    def _finish_telemetry(self, stats: FederatedStats) -> None:
        """Fold per-pool hubs into the fleet hub: namespaced per-pool
        metrics + merged fleet decision-latency histograms + federation
        gauges.  Pool order, so fleet traces are deterministic."""
        tel = self.telemetry
        if not tel:
            return
        for k in range(self.pool_map.n_pools):
            sub = self._pool_tel[k]
            tel.merge_from(sub, prefix=f"pool{k}.")
            for src, dst in (("loop.decision_ms", "fleet.decision_ms"),
                             ("engine.decision_ms",
                              "fleet.engine.decision_ms")):
                h = sub.histograms.get(src)
                if h is not None:
                    mine = tel.histograms.get(dst)
                    if mine is None:
                        mine = tel.histograms[dst] = Histogram(tel.exact_cap)
                    mine.merge(h)
        tel.gauge("fleet.n_pools", self.pool_map.n_pools)
        tel.gauge("fleet.epochs", stats.epochs)
        tel.gauge("fleet.migrations", len(stats.migrations))
        tel.gauge("fleet.migration_stall_s", stats.migration_stall_s)
        tel.gauge("fleet.total_samples", stats.total_samples)
