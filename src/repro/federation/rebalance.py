"""Cross-pool rebalancer: slow-cadence job migration (DESIGN.md §14).

Pools solve independently at event cadence; imbalance between them —
one pool starved of nodes while another has spare capacity — is
corrected on a much slower clock by migrating whole *jobs* (never
nodes: node ownership is static, see ``sharding.PoolMap``).

Detection uses the policy's own cheap relaxation,
``Objective.upper_bound``: a pool's *deficit* is the bound evaluated at
unconstrained capacity minus the bound at its actual node count — how
much objective the pool's demand leaves on the table because the pool
is too small.  A pool must stay starved for ``patience`` consecutive
rebalance rounds before it sheds load (transient churn heals itself at
event cadence; migration must not chase it).

A migration is proposed only when the projected gain at the destination
exceeds the projected loss at the source plus the amortized migration
cost — moves pay ``r_dw`` (source teardown) + ``migration_cost_s``
(state transfer) in real stall, so marginal wins are not worth taking.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.objectives import resolve_objective


@dataclass(frozen=True)
class Migration:
    """One accepted job move, with the projections that justified it."""
    job_id: int
    src: int
    dst: int
    time: float
    gain: float       # projected objective gain at dst (bound units)
    loss: float       # projected objective loss at src (bound units)


@dataclass
class PoolView:
    """What the rebalancer sees of one pool: live node count + the
    unfinished jobs it owns (active and queued — queued jobs are demand
    too, and the cheapest to migrate)."""
    pool: int
    n_nodes: int
    jobs: List = field(default_factory=list)


class Rebalancer:
    """Upper-bound-driven migration policy.

    Parameters
    ----------
    patience : int
        Consecutive starved rounds before a pool may shed a job.
    starve_rel : float
        Relative deficit (deficit / unconstrained bound) above which a
        pool counts as starved.
    max_moves : int
        Migration cap per rebalance round (bounds cascade churn).
    migration_cost_s : float
        State-transfer stall (seconds) charged to a migrated job on top
        of its ``r_dw`` teardown; also amortized into the accept test.
    min_net_gain_rel : float
        Minimum net gain, relative to the fleet bound, for a move to be
        worth its churn.
    """

    def __init__(self, *, patience: int = 2, starve_rel: float = 0.05,
                 max_moves: int = 2, migration_cost_s: float = 0.0,
                 min_net_gain_rel: float = 1e-6, sos2_points: int = 8):
        self.patience = patience
        self.starve_rel = starve_rel
        self.max_moves = max_moves
        self.migration_cost_s = migration_cost_s
        self.min_net_gain_rel = min_net_gain_rel
        self.sos2_points = sos2_points
        self.rounds = 0
        self._streak: Dict[int, int] = {}

    # ------------------------------------------------------------------

    def evacuate(self, views: Sequence[PoolView], sick: Sequence[int],
                 now: float) -> List[Migration]:
        """Emergency reassignment out of quarantined pools (DESIGN.md
        §16).  Unlike :meth:`propose` there is no patience or net-gain
        test — a quarantined pool cannot solve at all, so any healthy
        placement beats staying queued behind a frozen map.  Moves every
        *queued* job (nodeless, unfinished — running jobs keep their
        frozen allocation) into the healthy pool with the most spare
        headroom, updating headroom as it goes.  Deterministic: ties
        break toward the lowest pool id."""
        sick_set = set(sick)
        healthy = [v for v in views if v.pool not in sick_set]
        if not healthy:
            return []
        spare = {v.pool: v.n_nodes - sum(j.n_min for j in v.jobs)
                 for v in healthy}
        moves: List[Migration] = []
        for v in views:
            if v.pool not in sick_set:
                continue
            for job in list(v.jobs):
                if job.nodes or getattr(job, "finished", False):
                    continue
                dst = max(spare, key=lambda k: (spare[k], -k))
                moves.append(Migration(job_id=job.id, src=v.pool, dst=dst,
                                       time=now, gain=0.0, loss=0.0))
                spare[dst] -= job.n_min
                v.jobs.remove(job)
                next(w for w in healthy if w.pool == dst).jobs.append(job)
        return moves

    def _bound(self, obj, specs, counts, n_nodes, t_fwd) -> Optional[float]:
        if not specs:
            return 0.0
        return obj.upper_bound(specs, counts, n_nodes, t_fwd)

    def propose(self, objective, views: Sequence[PoolView], t_fwd: float,
                now: float) -> List[Migration]:
        """One rebalance round: update starvation streaks, and for every
        persistently starved pool propose the best net-gain migration.
        Accepted moves update the working views, so multiple moves in
        one round are mutually consistent.  Pure — applying the returned
        migrations (ownership change + stall charge) is the caller's
        job (``FederatedLoop``)."""
        self.rounds += 1
        obj = resolve_objective(objective)
        specs = {v.pool: [j.spec(self.sos2_points, now=now) for j in v.jobs]
                 for v in views}
        counts = {v.pool: [len(j.nodes) for j in v.jobs] for v in views}
        by_pool = {v.pool: v for v in views}

        def cap_bound(k: int) -> Optional[float]:
            return self._bound(obj, specs[k], counts[k],
                               by_pool[k].n_nodes, t_fwd)

        def demand_bound(k: int) -> Optional[float]:
            demand = sum(t.n_max for t in specs[k])
            return self._bound(obj, specs[k], counts[k], demand, t_fwd)

        # -- starvation detection (with patience) ----------------------
        deficits: Dict[int, float] = {}
        fleet_scale = 0.0
        bounded = True
        for v in views:
            cb, db = cap_bound(v.pool), demand_bound(v.pool)
            if cb is None or db is None:
                bounded = False
                break
            fleet_scale = max(fleet_scale, abs(db))
            deficits[v.pool] = max(0.0, db - cb)
        if not bounded:
            # policy without a cheap bound: fall back to pure node
            # arithmetic — starved means demand floor exceeds supply
            fleet_scale = 1.0
            deficits = {
                v.pool: float(max(0, sum(j.n_min for j in v.jobs)
                                  - v.n_nodes))
                for v in views}
        for v in views:
            starved = deficits[v.pool] > self.starve_rel * max(fleet_scale,
                                                               1e-12)
            self._streak[v.pool] = (self._streak.get(v.pool, 0) + 1
                                    if starved else 0)

        ready = sorted((k for k, s in self._streak.items()
                        if s >= self.patience and k in by_pool),
                       key=lambda k: -deficits.get(k, 0.0))
        if not ready:
            return []

        # -- candidate moves -------------------------------------------
        moves: List[Migration] = []
        for src in ready:
            if len(moves) >= self.max_moves:
                break
            v = by_pool[src]
            if not v.jobs:
                continue
            src_cb = cap_bound(src)
            best = None
            for ji, job in enumerate(v.jobs):
                # loss at src: bound with the job removed
                s_wo = specs[src][:ji] + specs[src][ji + 1:]
                c_wo = counts[src][:ji] + counts[src][ji + 1:]
                src_wo = self._bound(obj, s_wo, c_wo, v.n_nodes, t_fwd)
                if src_cb is None or src_wo is None:
                    loss = 0.0 if not job.nodes else float("inf")
                else:
                    loss = src_cb - src_wo
                # amortized churn: teardown + transfer stall expressed in
                # bound units over one forward window
                stall = (job.r_dw if job.nodes else 0.0) \
                    + self.migration_cost_s
                churn = (stall / max(t_fwd, 1e-9)) * specs[src][ji].values[-1]
                for dst in by_pool:
                    if dst == src:
                        continue
                    dst_cb = cap_bound(dst)
                    s_w = specs[dst] + [specs[src][ji]]
                    c_w = counts[dst] + [0]
                    dst_w = self._bound(obj, s_w, c_w,
                                        by_pool[dst].n_nodes, t_fwd)
                    if dst_cb is None or dst_w is None:
                        # unbounded policy: accept only free moves into
                        # pools with uncommitted headroom
                        spare = by_pool[dst].n_nodes \
                            - sum(j.n_min for j in by_pool[dst].jobs)
                        gain = 1.0 if spare >= job.n_min else 0.0
                    else:
                        gain = dst_w - dst_cb
                    net = gain - loss - churn
                    if net > self.min_net_gain_rel * max(fleet_scale, 1e-12) \
                            and (best is None or net > best[0]):
                        best = (net, ji, dst, gain, loss)
            if best is None:
                continue
            net, ji, dst, gain, loss = best
            job = v.jobs[ji]
            moves.append(Migration(job_id=job.id, src=src, dst=dst,
                                   time=now, gain=gain, loss=loss))
            # keep the working views consistent for further moves
            specs[dst].append(specs[src][ji])
            counts[dst].append(0)
            del specs[src][ji], counts[src][ji], v.jobs[ji]
            by_pool[dst].jobs.append(job)
            self._streak[src] = 0
        return moves
