"""FederatedEngine: K independent per-pool allocators (DESIGN.md §14).

One ``AllocationEngine`` (or any ``Allocator``, e.g. a chaos-wrapped
``RestartingAllocator``) per pool, built lazily from a factory.  The
federated engine never merges sub-problems — pool k's problems go to
pool k's engine, full stop — so caches, warm-start state and stats stay
pool-local, and the fleet view is pure composition:

* ``stats()``       — ``EngineStats.sum_of`` over the pools;
* ``snapshot()``    — versioned fleet snapshot embedding one engine
  snapshot per pool (warm-state recovery for the whole federation in
  one artifact);
* ``restore()``     — per-pool warm restore, tolerant of pool-count
  mismatch only in the strict sense: it refuses, because silently
  rekeying pools would corrupt warm-start state.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.core.allocator import Allocator
from repro.core.engine import AllocationEngine, EngineStats
from repro.core.milp import AllocationProblem, AllocationResult
from repro.federation.sharding import PoolMap

# Versioned schema tag for fleet-wide warm-state snapshots; the per-pool
# payloads carry their own engine-level schema tag.
FEDERATION_SNAPSHOT_SCHEMA = "bftrainer-federation-snapshot/1"


class FederatedEngine:
    """K per-pool allocators behind one façade.

    Parameters
    ----------
    pool_map : PoolMap
        Static node → pool ownership.
    factory : Callable[[int], Allocator]
        Builds pool k's allocator; defaults to a fresh
        ``AllocationEngine()`` per pool.
    """

    def __init__(self, pool_map: PoolMap,
                 factory: Optional[Callable[[int], Allocator]] = None):
        self.pool_map = pool_map
        self._factory = factory or (lambda k: AllocationEngine())
        self.engines: Dict[int, Allocator] = {
            k: self._factory(k) for k in range(pool_map.n_pools)}
        self.name = f"federated(x{pool_map.n_pools})"

    @property
    def n_pools(self) -> int:
        return self.pool_map.n_pools

    def engine(self, pool: int) -> Allocator:
        return self.engines[pool]

    def allocate(self, pool: int, prob: AllocationProblem
                 ) -> AllocationResult:
        """Solve one pool-local problem with that pool's engine."""
        return self.engines[pool].allocate(prob)

    # -- fleet composition ---------------------------------------------

    def stats(self) -> EngineStats:
        """Fleet totals: sum of per-pool ``EngineStats`` (pools whose
        allocator keeps no stats contribute zeros)."""
        per_pool = []
        for eng in self.engines.values():
            s = self._engine_of(eng)
            if s is not None:
                per_pool.append(s.stats)
        return EngineStats.sum_of(per_pool)

    def pool_stats(self) -> Dict[int, EngineStats]:
        out = {}
        for k, eng in self.engines.items():
            s = self._engine_of(eng)
            if s is not None:
                out[k] = s.stats
        return out

    @staticmethod
    def _engine_of(alloc: Allocator) -> Optional[AllocationEngine]:
        """Unwrap to the underlying ``AllocationEngine`` if there is one
        (``RestartingAllocator`` exposes it as ``.engine``)."""
        if isinstance(alloc, AllocationEngine):
            return alloc
        inner = getattr(alloc, "engine", None)
        return inner if isinstance(inner, AllocationEngine) else None

    # -- fleet warm-state snapshot / recovery (DESIGN.md §12, §14) -----

    def snapshot(self) -> Dict:
        """One artifact holding every pool's engine snapshot.  Pools
        whose allocator exposes no snapshotable engine store ``None``
        (they restart cold on restore)."""
        pools = {}
        for k, alloc in self.engines.items():
            eng = self._engine_of(alloc)
            pools[str(k)] = eng.snapshot() if eng is not None else None
        return {
            "schema": FEDERATION_SNAPSHOT_SCHEMA,
            "n_pools": self.n_pools,
            "pools": pools,
        }

    def restore(self, snap: Dict) -> int:
        """Warm-restore every pool from a fleet snapshot.  Returns the
        total number of cache entries recovered across pools."""
        if snap.get("schema") != FEDERATION_SNAPSHOT_SCHEMA:
            raise ValueError(
                f"unknown federation-snapshot schema {snap.get('schema')!r} "
                f"(expected {FEDERATION_SNAPSHOT_SCHEMA!r})")
        if snap.get("n_pools") != self.n_pools:
            raise ValueError(
                f"snapshot has {snap.get('n_pools')} pools, "
                f"this federation has {self.n_pools}")
        recovered = 0
        for k, alloc in self.engines.items():
            sub = snap["pools"].get(str(k))
            eng = self._engine_of(alloc)
            if sub is not None and eng is not None:
                recovered += eng.restore(sub)
        return recovered

    @classmethod
    def from_snapshot(cls, snap: Dict, pool_map: PoolMap,
                      factory: Optional[Callable[[int], Allocator]] = None
                      ) -> "FederatedEngine":
        """Build a fresh federation warmed from a fleet snapshot."""
        fed = cls(pool_map, factory)
        fed.restore(snap)
        return fed
