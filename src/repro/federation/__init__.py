"""Federated multi-pool allocation (DESIGN.md §14).

Shards the fleet into K pools — one independent allocation engine and
event queue per pool, parallel per-pool solves, and a slow-cadence
cross-pool rebalancer — so fleet-wide decision latency stays at
single-pool scale while the node count grows by the pool count.
"""
from repro.federation.engine import (
    FEDERATION_SNAPSHOT_SCHEMA,
    FederatedEngine,
)
from repro.federation.ingest import EventRouter
from repro.federation.loop import FederatedLoop, FederatedStats, PoolStats
from repro.federation.rebalance import Migration, PoolView, Rebalancer
from repro.federation.sharding import PoolMap, assign_jobs

__all__ = [
    "FEDERATION_SNAPSHOT_SCHEMA",
    "EventRouter",
    "FederatedEngine",
    "FederatedLoop",
    "FederatedStats",
    "Migration",
    "PoolMap",
    "PoolStats",
    "PoolView",
    "Rebalancer",
    "assign_jobs",
]
