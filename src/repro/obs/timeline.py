"""Per-job lifecycle timelines from the control-plane trace
(DESIGN.md §13).

``build_timelines`` folds the hub's event stream into one
``JobTimeline`` per Trainer: when it waited for admission, which node
counts it ran at (coalescing back-to-back equal-size run segments),
where rescale/preemption/restart stalls sat, and what each kill rolled
back.  This is the per-job accounting view that multi-tenant SLO
policies (Synergy, PAPERS.md) need and that ``repro.obs.report``
renders.

The builder only *reads* events with ``cat == "job"`` — the emission
contract is:

========  =========  ==================================================
name      kind       args
========  =========  ==================================================
admit     instant    arrival, wait
run       span       n (node count over the span)
stall     span       why ∈ {grow, shrink, preempt, restart}, cost_s
rescale   instant    old, new, cost_s
preempt   instant    taken (node count preempted away)
fail      instant    lost (progress units rolled back), penalty_s
finish    instant    —
========  =========  ==================================================
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple, Union

from repro.obs.spans import KIND_INSTANT, KIND_SPAN, SpanEvent
from repro.obs.telemetry import Telemetry


@dataclass
class JobTimeline:
    """Lifecycle of one Trainer, folded from the trace stream."""

    job: int
    arrival: Optional[float] = None
    admitted_at: Optional[float] = None
    started_at: Optional[float] = None      # first interval holding nodes
    finished_at: Optional[float] = None
    #: (t0, t1, n_nodes) run segments, consecutive equal-n merged
    segments: List[Tuple[float, float, int]] = field(default_factory=list)
    #: (t0, t1, why) stall windows: grow/shrink/preempt/restart
    stalls: List[Tuple[float, float, str]] = field(default_factory=list)
    #: (t, old_n, new_n) allocation size changes
    rescales: List[Tuple[float, int, int]] = field(default_factory=list)
    n_preemptions: int = 0
    n_failures: int = 0
    lost_progress: float = 0.0

    @property
    def admission_wait(self) -> Optional[float]:
        if self.admitted_at is None or self.arrival is None:
            return None
        return self.admitted_at - self.arrival

    @property
    def node_seconds(self) -> float:
        return sum(n * (t1 - t0) for t0, t1, n in self.segments)

    @property
    def run_time(self) -> float:
        return sum(t1 - t0 for t0, t1, _ in self.segments)

    @property
    def stall_time(self) -> float:
        return sum(t1 - t0 for t0, t1, _ in self.stalls)

    def summary(self) -> Dict:
        grows = sum(1 for _, old, new in self.rescales if new > old)
        return {
            "job": self.job,
            "arrival": self.arrival,
            "admitted_at": self.admitted_at,
            "admission_wait_s": self.admission_wait,
            "started_at": self.started_at,
            "finished_at": self.finished_at,
            "node_seconds": self.node_seconds,
            "run_time_s": self.run_time,
            "stall_time_s": self.stall_time,
            "n_segments": len(self.segments),
            "n_rescales": len(self.rescales),
            "n_grows": grows,
            "n_shrinks": len(self.rescales) - grows,
            "n_preemptions": self.n_preemptions,
            "n_failures": self.n_failures,
            "lost_progress": self.lost_progress,
        }


def build_timelines(source: Union[Telemetry, Iterable[SpanEvent]]
                    ) -> Dict[int, JobTimeline]:
    """Fold a telemetry hub (or raw event list) into per-job timelines."""
    events = source.events if isinstance(source, Telemetry) else source
    out: Dict[int, JobTimeline] = {}

    def tl(job: int) -> JobTimeline:
        t = out.get(job)
        if t is None:
            t = out[job] = JobTimeline(job=job)
        return t

    for ev in events:
        if ev.cat != "job" or ev.job is None:
            continue
        t = tl(ev.job)
        if ev.kind == KIND_SPAN and ev.name == "run":
            n = int(ev.args.get("n", 0))
            if t.started_at is None:
                t.started_at = ev.t0
            if t.segments and t.segments[-1][1] == ev.t0 \
                    and t.segments[-1][2] == n:
                t0, _, _ = t.segments[-1]
                t.segments[-1] = (t0, ev.t1, n)
            else:
                t.segments.append((ev.t0, ev.t1, n))
        elif ev.kind == KIND_SPAN and ev.name == "stall":
            t.stalls.append((ev.t0, ev.t1, str(ev.args.get("why", ""))))
        elif ev.kind == KIND_INSTANT:
            if ev.name == "admit":
                t.admitted_at = ev.t0
                if "arrival" in ev.args:
                    t.arrival = float(ev.args["arrival"])
            elif ev.name == "rescale":
                t.rescales.append((ev.t0, int(ev.args.get("old", 0)),
                                   int(ev.args.get("new", 0))))
            elif ev.name == "preempt":
                t.n_preemptions += 1
            elif ev.name == "fail":
                t.n_failures += 1
                t.lost_progress += float(ev.args.get("lost", 0.0))
            elif ev.name == "finish":
                t.finished_at = ev.t0
    return out
