"""Run-summary CLI for control-plane telemetry (DESIGN.md §13).

Two modes:

* replay a scenario with telemetry enabled and summarize it::

    python -m repro.obs.report --scenario bursty --scale 0.1 \\
        --trace trace.json          # Chrome trace JSON → Perfetto
    python -m repro.obs.report --scenario bursty --json   # JSON summary

* summarize an existing deterministic trace stream
  (``Telemetry.write_jsonl``)::

    python -m repro.obs.report trace.jsonl

The text report covers the decision-latency histograms (p50/p95/p99 per
solver arm), the hub counters, and one line per Trainer from the
per-job lifecycle timelines (admission wait, run/stall split, rescales,
rollbacks).  ``--trace`` writes Chrome trace-event JSON loadable at
https://ui.perfetto.dev.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence

from repro.obs.spans import read_jsonl
from repro.obs.telemetry import Telemetry
from repro.obs.timeline import build_timelines


def run_summary(tel: Telemetry, stats=None) -> Dict:
    """One JSON-ready dict for a telemetry hub (+ optional LoopStats)."""
    out = tel.summary()
    out["timelines"] = {job: t.summary()
                        for job, t in sorted(build_timelines(tel).items())}
    if stats is not None:
        out["loop_stats"] = stats.as_dict()
    return out


def _fmt(v, width: int = 10) -> str:
    if v is None:
        return "-".rjust(width)
    if isinstance(v, float):
        return f"{v:{width}.3f}" if abs(v) < 1e5 else f"{v:{width}.3e}"
    return f"{v:{width}d}" if isinstance(v, int) else str(v).rjust(width)


def render_text(summary: Dict) -> str:
    lines: List[str] = []
    hists = summary.get("histograms", {})
    if hists:
        lines.append("== histograms (ms unless noted) ==")
        lines.append(f"{'name':<40} {'count':>8} {'p50':>10} {'p95':>10} "
                     f"{'p99':>10} {'max':>10}")
        for name, h in hists.items():
            lines.append(f"{name:<40} {h['count']:>8} {_fmt(h['p50'])} "
                         f"{_fmt(h['p95'])} {_fmt(h['p99'])} {_fmt(h['max'])}")
    counters = summary.get("counters", {})
    if counters:
        lines.append("")
        lines.append("== counters ==")
        for name, v in counters.items():
            lines.append(f"{name:<48} {v:>12g}")
    gauges = summary.get("gauges", {})
    if gauges:
        lines.append("")
        lines.append("== gauges ==")
        for name, v in gauges.items():
            lines.append(f"{name:<48} {v:>12g}")
    timelines = summary.get("timelines", {})
    if timelines:
        lines.append("")
        lines.append("== per-job timelines ==")
        lines.append(f"{'job':>4} {'wait_s':>9} {'run_s':>10} {'stall_s':>9} "
                     f"{'node_s':>12} {'rescales':>8} {'preempt':>7} "
                     f"{'fails':>5} {'lost':>10} {'finished':>10}")
        for job, t in timelines.items():
            fin = (f"{t['finished_at']:.0f}"
                   if t["finished_at"] is not None else "-")
            wait = (f"{t['admission_wait_s']:.1f}"
                    if t["admission_wait_s"] is not None else "-")
            lines.append(
                f"{job:>4} {wait:>9} {t['run_time_s']:>10.0f} "
                f"{t['stall_time_s']:>9.0f} {t['node_seconds']:>12.0f} "
                f"{t['n_rescales']:>8} {t['n_preemptions']:>7} "
                f"{t['n_failures']:>5} {t['lost_progress']:>10.3g} "
                f"{fin:>10}")
    lines.append("")
    lines.append(f"trace events: {summary.get('n_events', 0)}")
    return "\n".join(lines)


def _demo_jobs(n: int, duration: float, eq_nodes: float, seed: int):
    """Contended finite-work Trainers cycled from Tab 2 (the same shape
    the benchmarks use), so a scenario replay exercises every span."""
    import numpy as np

    from repro.core import TrainerJob, tab2_curve
    from repro.core.scaling import TAB2
    rng = np.random.default_rng(seed)
    names = list(TAB2)
    share = max(eq_nodes / max(n, 1), 1.0)
    jobs, t = [], 0.0
    for i in range(n):
        curve = tab2_curve(names[i % len(names)])
        t += float(rng.exponential(duration / (4.0 * max(n, 1))))
        jobs.append(TrainerJob(id=i, curve=curve,
                               work=1.2 * duration * curve(share),
                               n_min=1, n_max=24, r_up=20.0, r_dw=5.0,
                               arrival=t))
    return jobs


def run_scenario_with_telemetry(name: str, *, scale: float = 0.1,
                                seed: int = 7, objective=None,
                                t_fwd: float = 120.0):
    """Replay scenario ``name`` with an enabled hub; returns
    ``(telemetry, stats)``."""
    from repro.core import AllocationEngine, Simulator, fragments_to_events
    from repro.sched import build_scenario

    sc = build_scenario(name, scale=scale, seed=seed)
    events = fragments_to_events(sc.fragments)
    tel = Telemetry()
    n_jobs = max(4, int(round(sc.stats.eq_nodes / 3)))
    jobs = _demo_jobs(n_jobs, sc.duration, sc.stats.eq_nodes, seed)
    engine = AllocationEngine(telemetry=tel)
    stats = Simulator(events, jobs, engine, t_fwd=t_fwd,
                      horizon=sc.duration, objective=objective,
                      telemetry=tel).run()
    return tel, stats


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("jsonl", nargs="?", default=None,
                    help="existing trace JSONL to summarize")
    ap.add_argument("--scenario", default=None,
                    help="replay this scenario (repro.sched name) with "
                         "telemetry enabled")
    ap.add_argument("--scale", type=float, default=0.1,
                    help="scenario scale factor (default 0.1)")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--policy", default=None,
                    help="objective policy name (repro.core.objectives)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write Chrome trace-event JSON (Perfetto)")
    ap.add_argument("--jsonl-out", default=None, metavar="PATH",
                    help="write the deterministic trace JSONL")
    ap.add_argument("--json", action="store_true",
                    help="print the summary as JSON instead of text")
    args = ap.parse_args(argv)

    if (args.jsonl is None) == (args.scenario is None):
        ap.error("pass exactly one of: a trace JSONL path, or --scenario")

    if args.scenario is not None:
        tel, stats = run_scenario_with_telemetry(
            args.scenario, scale=args.scale, seed=args.seed,
            objective=args.policy)
        summary = run_summary(tel, stats)
        if args.trace:
            tel.write_chrome_trace(args.trace)
            print(f"wrote Perfetto trace: {args.trace}", file=sys.stderr)
        if args.jsonl_out:
            tel.write_jsonl(args.jsonl_out)
            print(f"wrote trace JSONL: {args.jsonl_out}", file=sys.stderr)
    else:
        with open(args.jsonl, encoding="utf-8") as f:
            events = read_jsonl(f)
        summary = {"n_events": len(events),
                   "timelines": {job: t.summary() for job, t in
                                 sorted(build_timelines(events).items())}}
        if args.trace:
            from repro.obs.spans import chrome_trace
            with open(args.trace, "w", encoding="utf-8") as f:
                json.dump(chrome_trace(events), f)
            print(f"wrote Perfetto trace: {args.trace}", file=sys.stderr)

    if args.json:
        print(json.dumps(summary, indent=2, sort_keys=True))
    else:
        print(render_text(summary))
    return 0


if __name__ == "__main__":
    sys.exit(main())
