"""Unified telemetry for the BFTrainer control plane (DESIGN.md §13).

One hub observes everything the control plane does — allocation
decisions per solver arm, loop events, rescale durations, fault
injections, checkpoint restores — as counters, gauges, streaming
histograms (p50/p95/p99) and dual-clock spans (trace clock + wall
clock).  The default is ``NULL_TELEMETRY``, a falsy no-op sink, so
instrumented code paths are bit-identical to uninstrumented ones when
telemetry is off (tests/test_obs.py pins this down).

Entry points:

* ``Telemetry()`` — the live hub; pass it as ``telemetry=`` to
  ``AllocationEngine`` / ``ControlLoop`` / ``Simulator`` /
  ``run_scenario`` / ``run_chaos``.
* ``telemetry.write_chrome_trace(path)`` — Chrome trace-event JSON,
  loadable in Perfetto (https://ui.perfetto.dev).
* ``telemetry.write_jsonl(path)`` — deterministic span/event stream
  (wall-clock fields excluded by default).
* ``build_timelines(telemetry)`` — per-job lifecycle timelines.
* ``python -m repro.obs.report`` — text/JSON run summary CLI.
"""
from repro.obs.spans import (
    TRACE_EVENT_KEYS,
    TRACE_SCHEMA,
    SpanEvent,
    chrome_trace,
    read_jsonl,
    to_jsonl,
)
from repro.obs.telemetry import (
    NULL_TELEMETRY,
    Histogram,
    NullTelemetry,
    Telemetry,
)
from repro.obs.timeline import JobTimeline, build_timelines

__all__ = [
    "Telemetry", "NullTelemetry", "NULL_TELEMETRY", "Histogram",
    "SpanEvent", "chrome_trace", "to_jsonl", "read_jsonl",
    "TRACE_SCHEMA", "TRACE_EVENT_KEYS",
    "JobTimeline", "build_timelines",
]
