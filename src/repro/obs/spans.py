"""Span/event records and trace export (DESIGN.md §13).

Every control-plane observation is a ``SpanEvent`` with **two clocks**:

* ``t0``/``t1`` — *trace clock*: simulated seconds on the replayed
  timeline (``ControlLoop``'s ``now``).  Deterministic: a same-seed
  replay emits identical values, which is what the trace-determinism
  test compares.
* ``wall_s`` — *wall clock*: physical seconds the observed operation
  took (solver wall, rescale wall), or ``None`` for instants and pure
  trace-clock spans.  Physical time varies run-to-run, so it is
  excluded from the deterministic JSONL by default.

Two serializations:

* ``to_jsonl`` / ``read_jsonl`` — one JSON object per line, schema
  ``bftrainer-trace/1`` (header line), stable key set
  ``TRACE_EVENT_KEYS``.  ``scripts/check_docs.py`` cross-validates the
  documented schema fence against these constants.
* ``chrome_trace`` — Chrome trace-event JSON (the ``traceEvents``
  format), loadable in Perfetto.  The timeline axis is the *trace
  clock* (µs); decision spans additionally render their *wall*
  duration on the dedicated allocator track, so both "where did the
  node-seconds go" and "where did the solver milliseconds go" are
  visible in one trace.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, IO, Iterable, List, Optional, Union

#: versioned schema tag for the JSONL trace stream; bump on any
#: incompatible change to the per-line layout
TRACE_SCHEMA = "bftrainer-trace/1"

#: the stable per-event key set (every JSONL line carries all of them;
#: unused ones are null) — documented in EXPERIMENTS.md §Telemetry and
#: cross-validated by scripts/check_docs.py
TRACE_EVENT_KEYS = ["kind", "cat", "name", "t0", "t1", "job", "value",
                    "wall_s", "args"]

#: event kinds: a complete trace-clock span, an instantaneous marker,
#: and a sampled counter value (rendered as a Perfetto counter track)
KIND_SPAN = "span"
KIND_INSTANT = "instant"
KIND_COUNTER = "counter"


@dataclass
class SpanEvent:
    """One observation.  ``kind`` is span/instant/counter; ``cat`` is the
    subsystem (``solver``, ``job``, ``loop``, ``chaos``, ``checkpoint``);
    ``job`` ties the event to a Trainer id where applicable."""

    kind: str
    cat: str
    name: str
    t0: float
    t1: float
    job: Optional[int] = None
    value: Optional[float] = None       # counter sample value
    wall_s: Optional[float] = None      # physical duration (second clock)
    args: Dict = field(default_factory=dict)

    def as_dict(self, include_wall: bool = True) -> Dict:
        d = {k: getattr(self, k) for k in TRACE_EVENT_KEYS}
        if not include_wall:
            d["wall_s"] = None
        return d


def to_jsonl(events: Iterable[SpanEvent], *,
             include_wall: bool = False) -> str:
    """Serialize events as JSONL: a schema header line followed by one
    event per line.  ``include_wall=False`` (default) nulls the
    wall-clock field so same-seed replays serialize bit-identically."""
    lines = [json.dumps({"schema": TRACE_SCHEMA})]
    for ev in events:
        lines.append(json.dumps(ev.as_dict(include_wall=include_wall),
                                sort_keys=True))
    return "\n".join(lines) + "\n"


def read_jsonl(text_or_file: Union[str, IO]) -> List[SpanEvent]:
    """Parse a :func:`to_jsonl` stream back into ``SpanEvent``s.  Raises
    ``ValueError`` on a missing/unknown schema header."""
    if hasattr(text_or_file, "read"):
        text = text_or_file.read()
    else:
        text = text_or_file
    lines = [ln for ln in text.splitlines() if ln.strip()]
    if not lines:
        return []
    header = json.loads(lines[0])
    if header.get("schema") != TRACE_SCHEMA:
        raise ValueError(
            f"unknown trace schema {header.get('schema')!r} "
            f"(expected {TRACE_SCHEMA!r})")
    out = []
    for ln in lines[1:]:
        d = json.loads(ln)
        out.append(SpanEvent(**{k: d.get(k) for k in TRACE_EVENT_KEYS}))
    # default-restore args for old/edited lines carrying null
    for ev in out:
        if ev.args is None:
            ev.args = {}
    return out


# ---------------------------------------------------------------------------
# Chrome trace-event export (Perfetto)
# ---------------------------------------------------------------------------

#: process ids for the three tracks of the control-plane trace
PID_POOL = 1          # counter tracks: pool size, allocated nodes
PID_ALLOCATOR = 2     # decision spans (wall-clock durations) + restarts
PID_JOBS = 3          # per-job lifecycle: run segments, stalls, faults

_US = 1e6             # trace seconds → trace-event microseconds


def _meta(pid: int, tid: int, what: str, name: str) -> Dict:
    return {"ph": "M", "pid": pid, "tid": tid, "name": what,
            "args": {"name": name}}


def chrome_trace(events: Iterable[SpanEvent]) -> Dict:
    """Render events as a Chrome trace-event JSON object
    (``{"traceEvents": [...]}``), loadable in Perfetto.

    Track layout:

    * ``pool`` (pid 1) — counter tracks (``ph:"C"``) for sampled values
      such as pool size and allocated nodes;
    * ``allocator`` (pid 2) — one span per allocation decision; the span
      *duration shown is the solver's wall time* (µs) while its position
      is the trace-clock instant the decision happened at (args carry
      both clocks);
    * ``jobs`` (pid 3) — two threads per Trainer: run segments
      (``job <id>``) and rescale/restart stalls (``job <id> stalls``),
      plus instant markers for admissions, kills and rollbacks.
    """
    out: List[Dict] = [
        _meta(PID_POOL, 0, "process_name", "pool"),
        _meta(PID_ALLOCATOR, 0, "process_name", "allocator"),
        _meta(PID_ALLOCATOR, 0, "thread_name", "decisions (wall)"),
        _meta(PID_JOBS, 0, "process_name", "jobs"),
    ]
    seen_jobs = set()
    for ev in events:
        ts = ev.t0 * _US
        if ev.kind == KIND_COUNTER:
            out.append({"ph": "C", "pid": PID_POOL, "tid": 0,
                        "name": ev.name, "ts": ts,
                        "args": {ev.name: ev.value}})
            continue
        args = dict(ev.args)
        if ev.wall_s is not None:
            args["wall_ms"] = ev.wall_s * 1e3
        args["t_trace"] = ev.t0
        if ev.cat == "solver":
            dur = (ev.wall_s or 0.0) * _US
            out.append({"ph": "X", "pid": PID_ALLOCATOR, "tid": 0,
                        "name": ev.name, "cat": ev.cat, "ts": ts,
                        "dur": dur, "args": args})
            continue
        if ev.job is not None and ev.job not in seen_jobs:
            seen_jobs.add(ev.job)
            out.append(_meta(PID_JOBS, 2 * ev.job + 1, "thread_name",
                             f"job {ev.job}"))
            out.append(_meta(PID_JOBS, 2 * ev.job + 2, "thread_name",
                             f"job {ev.job} stalls"))
        if ev.job is not None:
            stall = ev.name in ("stall", "restart-stall")
            pid, tid = PID_JOBS, 2 * ev.job + (2 if stall else 1)
        else:
            pid, tid = PID_ALLOCATOR, 0
        if ev.kind == KIND_SPAN and ev.t1 > ev.t0:
            out.append({"ph": "X", "pid": pid, "tid": tid, "name": ev.name,
                        "cat": ev.cat, "ts": ts,
                        "dur": (ev.t1 - ev.t0) * _US, "args": args})
        else:
            out.append({"ph": "i", "pid": pid, "tid": tid, "name": ev.name,
                        "cat": ev.cat, "ts": ts, "s": "t", "args": args})
    return {"traceEvents": out, "displayTimeUnit": "ms"}
