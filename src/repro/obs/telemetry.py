"""The telemetry hub: counters, gauges, streaming histograms, spans
(DESIGN.md §13).

``Telemetry`` is a passive sink — instrumented code calls ``count`` /
``gauge`` / ``observe`` / ``span`` / ``instant`` / ``sample`` and the
hub accumulates.  It never feeds back into decisions, so enabling it
cannot change any allocation (the enabled-vs-disabled parity test in
tests/test_obs.py).

``NullTelemetry`` is the default everywhere: every method is a no-op
and the instance is *falsy*, so hot paths guard with ``if tel:`` and
skip even argument construction — the zero-overhead-when-disabled
argument (DESIGN.md §13).

``Histogram`` is a streaming log-bucketed histogram: exact samples are
kept up to ``exact_cap`` (percentiles are exact at benchmark scales),
after which only ~7%-resolution geometric buckets accumulate (bounded
memory on month-scale replays).  Everything is deterministic — no
randomness, no wall-clock reads — so same-seed replays produce
bit-identical histogram state.
"""
from __future__ import annotations

import bisect
import json
import math
from typing import Dict, List, Optional

from repro.obs.spans import (
    KIND_COUNTER,
    KIND_INSTANT,
    KIND_SPAN,
    SpanEvent,
    chrome_trace,
    to_jsonl,
)

#: geometric bucket growth: ~7% relative resolution on percentiles once
#: a histogram overflows its exact-sample cap
_GROWTH = 1.07
_LOG_GROWTH = math.log(_GROWTH)


class Histogram:
    """Streaming histogram with p50/p95/p99 (and any other quantile).

    Exact up to ``exact_cap`` samples; log-bucketed (~7% relative error)
    beyond.  Non-positive values land in a dedicated underflow bucket
    reported at 0.0.
    """

    __slots__ = ("exact_cap", "count", "total", "min", "max",
                 "_exact", "_buckets", "_zero")

    def __init__(self, exact_cap: int = 4096):
        self.exact_cap = exact_cap
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._exact: Optional[List[float]] = []
        self._buckets: Dict[int, int] = {}
        self._zero = 0                      # values <= 0

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.total += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value
        if self._exact is not None:
            bisect.insort(self._exact, value)
            if len(self._exact) > self.exact_cap:
                for v in self._exact:       # degrade to buckets once
                    self._bucket(v)
                self._exact = None
            return
        self._bucket(value)

    def _bucket(self, value: float) -> None:
        if value <= 0.0:
            self._zero += 1
            return
        idx = int(math.floor(math.log(value) / _LOG_GROWTH))
        self._buckets[idx] = self._buckets.get(idx, 0) + 1

    def percentile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 100]; 0.0 on an empty histogram."""
        if self.count == 0:
            return 0.0
        if self._exact is not None:
            # nearest-rank on the sorted exact samples
            k = max(0, min(len(self._exact) - 1,
                           int(math.ceil(q / 100.0 * len(self._exact))) - 1))
            return self._exact[k]
        rank = max(1, int(math.ceil(q / 100.0 * self.count)))
        if rank <= self._zero:
            return 0.0
        seen = self._zero
        for idx in sorted(self._buckets):
            seen += self._buckets[idx]
            if seen >= rank:
                # geometric midpoint of the bucket [G^idx, G^(idx+1))
                return math.exp((idx + 0.5) * _LOG_GROWTH)
        return self.max

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def merge(self, other: "Histogram") -> None:
        """Fold ``other``'s samples into this histogram (federated
        per-pool → fleet composition, DESIGN.md §14).  Exact+exact stays
        exact until the cap; any bucketed operand degrades the result to
        buckets (the percentile error stays the ~7% bucket resolution)."""
        if other.count == 0:
            return
        self.count += other.count
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        if self._exact is not None and other._exact is not None \
                and len(self._exact) + len(other._exact) <= self.exact_cap:
            for v in other._exact:
                bisect.insort(self._exact, v)
            return
        if self._exact is not None:
            for v in self._exact:
                self._bucket(v)
            self._exact = None
        if other._exact is not None:
            for v in other._exact:
                self._bucket(v)
        else:
            self._zero += other._zero
            for idx, n in other._buckets.items():
                self._buckets[idx] = self._buckets.get(idx, 0) + n

    def summary(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "sum": self.total,
            "mean": self.mean,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


class Telemetry:
    """The hub.  All mutation goes through the six verbs below; exports
    (`summary` / `write_jsonl` / `write_chrome_trace`) are read-only."""

    enabled = True

    def __init__(self, *, exact_cap: int = 4096):
        self.exact_cap = exact_cap
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, Histogram] = {}
        self.events: List[SpanEvent] = []

    def __bool__(self) -> bool:
        return True

    # -- the six verbs -------------------------------------------------

    def count(self, name: str, delta: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + delta

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        """Add ``value`` to the streaming histogram ``name``."""
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(self.exact_cap)
        h.observe(value)

    def span(self, cat: str, name: str, t0: float, t1: float, *,
             job: Optional[int] = None, wall_s: Optional[float] = None,
             **args) -> None:
        """A completed span: ``[t0, t1]`` on the trace clock, optionally
        carrying the operation's physical duration ``wall_s``."""
        self.events.append(SpanEvent(KIND_SPAN, cat, name, float(t0),
                                     float(t1), job=job, wall_s=wall_s,
                                     args=args))

    def instant(self, cat: str, name: str, t: float, *,
                job: Optional[int] = None,
                wall_s: Optional[float] = None, **args) -> None:
        self.events.append(SpanEvent(KIND_INSTANT, cat, name, float(t),
                                     float(t), job=job, wall_s=wall_s,
                                     args=args))

    def sample(self, name: str, t: float, value: float) -> None:
        """Sample a counter track (e.g. pool size over trace time)."""
        self.events.append(SpanEvent(KIND_COUNTER, "counter", name,
                                     float(t), float(t),
                                     value=float(value)))

    def merge_from(self, other: "Telemetry", *, prefix: str = "") -> None:
        """Fold another hub into this one, optionally namespacing every
        metric with ``prefix`` (e.g. ``"pool3."``).  Counters and gauges
        add/overwrite, histograms merge sample-exactly where possible,
        and span events append in order — the federated layer calls this
        once per pool, in pool order, so fleet traces stay deterministic
        (DESIGN.md §14)."""
        for name, v in other.counters.items():
            key = prefix + name
            self.counters[key] = self.counters.get(key, 0.0) + v
        for name, v in other.gauges.items():
            self.gauges[prefix + name] = v
        for name, h in other.histograms.items():
            mine = self.histograms.get(prefix + name)
            if mine is None:
                mine = self.histograms[prefix + name] = \
                    Histogram(self.exact_cap)
            mine.merge(h)
        self.events.extend(other.events)

    # -- exports -------------------------------------------------------

    def hist_summary(self) -> Dict[str, Dict[str, float]]:
        return {name: h.summary()
                for name, h in sorted(self.histograms.items())}

    def summary(self) -> Dict:
        return {
            "counters": dict(sorted(self.counters.items())),
            "gauges": dict(sorted(self.gauges.items())),
            "histograms": self.hist_summary(),
            "n_events": len(self.events),
        }

    def to_jsonl(self, *, include_wall: bool = False) -> str:
        return to_jsonl(self.events, include_wall=include_wall)

    def write_jsonl(self, path: str, *, include_wall: bool = False) -> None:
        with open(path, "w", encoding="utf-8") as f:
            f.write(self.to_jsonl(include_wall=include_wall))

    def chrome_trace(self) -> Dict:
        return chrome_trace(self.events)

    def write_chrome_trace(self, path: str) -> None:
        """Write a Chrome trace-event JSON loadable in Perfetto
        (https://ui.perfetto.dev → *Open trace file*)."""
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.chrome_trace(), f)


class NullTelemetry(Telemetry):
    """The default sink: falsy, and every verb is a no-op — instrumented
    code is bit-identical to uninstrumented code (and hot paths guarded
    with ``if tel:`` skip argument construction entirely)."""

    enabled = False

    def __bool__(self) -> bool:
        return False

    def count(self, name, delta=1.0):
        pass

    def gauge(self, name, value):
        pass

    def observe(self, name, value):
        pass

    def span(self, cat, name, t0, t1, **kw):
        pass

    def instant(self, cat, name, t, **kw):
        pass

    def sample(self, name, t, value):
        pass

    def merge_from(self, other, *, prefix=""):
        pass


#: the shared default sink.  Stateless (all verbs drop), so one module
#: singleton can back every uninstrumented engine/loop at once.
NULL_TELEMETRY = NullTelemetry()
