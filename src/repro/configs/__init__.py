from repro.configs.base import (
    ArchConfig,
    EncoderConfig,
    InputShape,
    LayerSpec,
    MLAConfig,
    MoEConfig,
    SHAPES,
    SSMConfig,
)
from repro.configs.registry import ARCHS, applicable_shapes, get_arch, get_shape

__all__ = [
    "ArchConfig",
    "EncoderConfig",
    "InputShape",
    "LayerSpec",
    "MLAConfig",
    "MoEConfig",
    "SHAPES",
    "SSMConfig",
    "ARCHS",
    "applicable_shapes",
    "get_arch",
    "get_shape",
]
