"""Mamba2-2.7B — attention-free SSM with SSD (state-space duality).
[arXiv:2405.21060]
"""
from repro.configs.base import ArchConfig, LayerSpec, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    source="arXiv:2405.21060 (Transformers are SSMs — Mamba-2)",
    n_layers=64,
    d_model=2560,
    n_heads=80,                 # d_inner / ssm head_dim = 5120/64
    n_kv_heads=0,               # attention-free
    head_dim=64,
    d_ff=0,                     # no separate MLP; mamba block is the layer
    vocab_size=50280,
    layer_pattern=(LayerSpec(mixer="mamba", mlp="none"),),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
    tie_embeddings=True,
    supports_long_context=True,  # O(1) recurrent state
)
