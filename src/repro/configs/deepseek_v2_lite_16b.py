"""DeepSeek-V2-Lite 16B — MoE with multi-head latent attention (MLA).
[arXiv:2405.04434]

MLA with kv_lora_rank=512; 2 shared + 64 routed experts, top-6
(d_expert=1408). First layer uses a dense MLP (as in the released model);
remaining 26 layers are MoE.
"""
from repro.configs.base import ArchConfig, LayerSpec, MLAConfig, MoEConfig

_PATTERN = (LayerSpec(mixer="attn", mlp="dense"),) + tuple(
    LayerSpec(mixer="attn", mlp="moe") for _ in range(26)
)

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    source="arXiv:2405.04434 (DeepSeek-V2)",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,              # MLA: all heads share one latent KV
    head_dim=192,               # qk_nope (128) + qk_rope (64)
    d_ff=1408,                  # routed-expert hidden size (assignment)
    dense_d_ff=10944,           # dense first-layer MLP hidden size
    vocab_size=102400,
    layer_pattern=_PATTERN,
    mlp_activation="swiglu",
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
    mla=MLAConfig(
        kv_lora_rank=512, q_lora_rank=0,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    ),
    supports_long_context=False,  # MLA is still full-context attention
)
