"""Gemma-2B — dense decoder, GeGLU, head_dim=256, MQA (1 KV head).
[arXiv:2403.08295]
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    source="arXiv:2403.08295 (Gemma: Open Models Based on Gemini)",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,               # MQA
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    layer_pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    mlp_activation="geglu",
    tie_embeddings=True,
    scale_embeddings=True,
    supports_long_context=False,
)
