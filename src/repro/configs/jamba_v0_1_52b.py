"""Jamba-v0.1 52B — hybrid Mamba+attention 1:7 interleave with MoE.
[arXiv:2403.19887]

Structure: 8-layer repeating block; one attention layer per block (1:7
attention:mamba ratio), MoE MLP on every second layer (16 experts, top-2).
"""
from repro.configs.base import ArchConfig, LayerSpec, MoEConfig, SSMConfig

# 8-layer block: attention at in-block index 4 (as in the released model),
# MoE on odd in-block indices -> 16 of 32 layers are MoE.
_PATTERN = tuple(
    LayerSpec(
        mixer="attn" if i == 4 else "mamba",
        mlp="moe" if i % 2 == 1 else "dense",
    )
    for i in range(8)
)

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    source="arXiv:2403.19887 (Jamba: A Hybrid Transformer-Mamba Language Model)",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    layer_pattern=_PATTERN,
    mlp_activation="swiglu",
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
    # Hybrid: mamba layers are O(1)-state; the 4 attention layers use a
    # bounded sliding-window KV in long-context serving mode (DESIGN.md).
    sliding_window=4096,
    supports_long_context=True,
)
