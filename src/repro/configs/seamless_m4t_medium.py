"""SeamlessM4T-medium — encoder-decoder multimodal (speech/text) backbone.
[arXiv:2308.11596]

Per the assignment the modality frontend (mel-spectrogram + conv feature
extractor) is a STUB: ``input_specs()`` provides precomputed frame
embeddings; we implement the transformer encoder-decoder that consumes them.
"""
from repro.configs.base import ArchConfig, EncoderConfig, LayerSpec

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    source="arXiv:2308.11596 (SeamlessM4T)",
    n_layers=12,                 # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    layer_pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    mlp_activation="swiglu",
    encoder=EncoderConfig(
        n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
        head_dim=64,
    ),
    frontend="audio",
    supports_long_context=False,  # full enc-dec attention
)
