"""Architecture / shape configuration system.

Every assigned architecture is expressed as one frozen ``ArchConfig``.  A
single unified schema covers dense / GQA / MQA attention, MoE (with shared
experts), DeepSeek-style MLA, Mamba2 (SSD) blocks, hybrid interleave
patterns (Jamba), encoder-decoder (Seamless) and modality-frontend stubs
(VLM / audio).

``layer_pattern`` is the repeating block of per-layer ``LayerSpec``s; the
full stack is ``n_layers // len(layer_pattern)`` repetitions, which is also
the unit the model scans over (see ``models/transformer.py``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    router_aux_coef: float = 0.01
    # capacity factor for the dispatch formulation (tokens per expert slot)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 => no LoRA on Q (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD, state-space duality) block."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (Seamless backbone)."""

    n_layers: int = 12
    d_model: int = 1024
    n_heads: int = 16
    n_kv_heads: int = 16
    d_ff: int = 4096
    head_dim: int = 64


@dataclass(frozen=True)
class LayerSpec:
    """One layer of the repeating block."""

    mixer: str = "attn"     # attn | swa | mamba
    mlp: str = "dense"      # dense | moe | none


# ---------------------------------------------------------------------------
# Main config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    source: str = ""            # citation for the assignment

    # layer structure
    layer_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    # dense-MLP hidden size when it differs from d_ff (DeepSeek first layer)
    dense_d_ff: int = 0

    # activation / norm
    mlp_activation: str = "swiglu"   # swiglu | geglu
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # attention knobs
    rope_theta: float = 10000.0
    attn_logit_softcap: float = 0.0      # 0 => disabled (gemma2: 50)
    final_logit_softcap: float = 0.0     # 0 => disabled (gemma2: 30)
    sliding_window: int = 0              # 0 => full attention for 'swa' none
    qk_norm: bool = False
    query_scale: float = 0.0             # 0 => 1/sqrt(head_dim)
    scale_embeddings: bool = False       # gemma: embeds *= sqrt(d_model)
    post_norms: bool = False             # gemma2 sandwich norms

    # optional sub-modules
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None

    # modality frontend stub: none | vision | audio
    frontend: str = "none"
    # number of frontend embedding positions prepended to the text sequence
    n_frontend_tokens: int = 0

    # long-context serving honesty flag: True iff serve at 500k+ is
    # sub-quadratic/bounded-state for this architecture (see DESIGN.md).
    supports_long_context: bool = False

    # ------------------------------------------------------------------
    @property
    def n_blocks(self) -> int:
        assert self.n_layers % len(self.layer_pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern length {len(self.layer_pattern)}"
        )
        return self.n_layers // len(self.layer_pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        return self.layer_pattern * self.n_blocks

    def reduced(self, **overrides) -> "ArchConfig":
        """A smoke-test-sized variant of the same family.

        Keeps the structural pattern (mixers, MoE, MLA, SSM, enc-dec,
        frontend) while shrinking widths so one forward/train step runs on a
        single CPU device in well under a second.
        """
        # very long patterns (deepseek: 27 = 1 dense + 26 moe) shrink to the
        # first two positions, preserving the structural mix
        pattern = (self.layer_pattern if len(self.layer_pattern) <= 8
                   else self.layer_pattern[:2])
        small: dict = dict(
            layer_pattern=pattern,
            n_layers=len(pattern) * 2,
            d_model=min(self.d_model, 128),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=min(self.head_dim, 32),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            dense_d_ff=min(self.dense_d_ff, 256) if self.dense_d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            n_frontend_tokens=min(self.n_frontend_tokens, 16) if self.n_frontend_tokens else 0,
        )
        if self.n_kv_heads == self.n_heads:
            small["n_kv_heads"] = small["n_heads"]
        if self.moe is not None:
            small["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_expert=min(self.moe.d_expert, 128),
                n_shared=min(self.moe.n_shared, 1),
            )
        if self.mla is not None:
            small["mla"] = dataclasses.replace(
                self.mla,
                kv_lora_rank=64,
                qk_nope_head_dim=32,
                qk_rope_head_dim=16,
                v_head_dim=32,
            )
            small["head_dim"] = 48  # nope+rope
        if self.ssm is not None:
            small["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk_size=32
            )
        if self.encoder is not None:
            small["encoder"] = dataclasses.replace(
                self.encoder,
                n_layers=2,
                d_model=small["d_model"],
                n_heads=small["n_heads"],
                n_kv_heads=small["n_heads"],
                d_ff=small["d_ff"],
                head_dim=small["head_dim"],
            )
        small.update(overrides)
        return dataclasses.replace(self, name=self.name + "-smoke", **small)


# ---------------------------------------------------------------------------
# Input shapes (assignment block)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
