"""InternVL2-76B — VLM; InternViT vision encoder + Llama-3-70B language
backbone. [arXiv:2404.16821]

Per the assignment the vision frontend (InternViT + MLP projector) is a
STUB: ``input_specs()`` provides precomputed patch embeddings; we implement
the 80-layer language decoder that consumes them.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    source="arXiv:2404.16821 (InternVL 1.5/2); LLM backbone Llama-3-70B",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    layer_pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    mlp_activation="swiglu",
    rope_theta=500000.0,
    frontend="vision",
    n_frontend_tokens=256,      # one image tile -> 256 patch embeddings
    supports_long_context=False,
)
