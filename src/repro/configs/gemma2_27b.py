"""Gemma-2 27B — dense decoder, alternating local(SWA)/global attention,
logit soft-capping. [arXiv:2408.00118]
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    source="arXiv:2408.00118 (Gemma 2)",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    layer_pattern=(
        LayerSpec(mixer="swa", mlp="dense"),
        LayerSpec(mixer="attn", mlp="dense"),
    ),
    mlp_activation="geglu",
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sliding_window=4096,
    tie_embeddings=True,
    scale_embeddings=True,
    post_norms=True,
    query_scale=(4608 / 32) ** -0.5,   # query_pre_attn_scalar = d_model/n_heads
    # long-context serving mode caps global-layer KV to the window
    # (documented deviation, DESIGN.md long_500k table).
    supports_long_context=True,
)
