"""Minitron-8B — pruned Nemotron-4 dense decoder. [arXiv:2407.14679]"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    source="arXiv:2407.14679 (Compact Language Models via Pruning and "
           "Knowledge Distillation)",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    layer_pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    mlp_activation="swiglu",  # squared-relu in nemotron; swiglu used per zoo
    supports_long_context=False,
)
