"""Granite-3.0 MoE 3B (800M active) — 40 experts, top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base family card]
"""
from repro.configs.base import ArchConfig, LayerSpec, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-3b-a800m-base (assignment cites the "
           "1b-a400m card; 3b-a800m settings per assignment row)",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,                   # expert hidden size
    vocab_size=49155,
    layer_pattern=(LayerSpec(mixer="attn", mlp="moe"),),
    mlp_activation="swiglu",
    moe=MoEConfig(n_experts=40, top_k=8, d_expert=512),
    supports_long_context=False,
)
