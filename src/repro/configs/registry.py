"""Registry of assigned architectures (``--arch <id>``) and input shapes."""
from __future__ import annotations

from repro.configs.base import ArchConfig, InputShape, SHAPES
from repro.configs.yi_6b import CONFIG as _yi_6b
from repro.configs.jamba_v0_1_52b import CONFIG as _jamba
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.deepseek_v2_lite_16b import CONFIG as _deepseek
from repro.configs.minitron_8b import CONFIG as _minitron
from repro.configs.gemma2_27b import CONFIG as _gemma2_27b
from repro.configs.internvl2_76b import CONFIG as _internvl2
from repro.configs.granite_moe_3b_a800m import CONFIG as _granite
from repro.configs.mamba2_2_7b import CONFIG as _mamba2
from repro.configs.gemma_2b import CONFIG as _gemma_2b

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        _yi_6b,
        _jamba,
        _seamless,
        _deepseek,
        _minitron,
        _gemma2_27b,
        _internvl2,
        _granite,
        _mamba2,
        _gemma_2b,
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return get_arch(name[: -len("-smoke")]).reduced()
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> InputShape:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def applicable_shapes(arch: ArchConfig) -> list[str]:
    """Shapes exercised for this arch (long_500k only if honest — DESIGN.md)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch.supports_long_context:
        out.append("long_500k")
    return out
