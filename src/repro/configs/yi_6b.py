"""Yi-6B — llama-architecture dense decoder with GQA. [arXiv:2403.04652]"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    source="arXiv:2403.04652 (Yi: Open Foundation Models by 01.AI)",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    layer_pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    mlp_activation="swiglu",
    rope_theta=5_000_000.0,
    supports_long_context=False,  # pure full attention -> long_500k skipped
)
