"""Training launcher: train any assigned architecture on local devices.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b-smoke \
        --steps 100 --per-node-batch 4 --seq 256 [--nodes 1] [--elastic]

``--elastic`` replays a Summit-calibrated idle-node trace and lets the
MILP allocator rescale the Trainer live (the full BFTrainer loop);
otherwise it is a plain fixed-size run.  Full-size architectures are for
the dry-run (``repro.launch.dryrun``); this entry point expects ``-smoke``
variants (or small customs) that fit local devices.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_arch
from repro.elastic import ElasticTrainer
from repro.models import build_model
from repro.optim import AdamW


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b-smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--per-node-batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--nodes", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--elastic", action="store_true",
                    help="drive node count from a replayed idle-node trace "
                         "via the MILP allocator")
    ap.add_argument("--checkpoint", default="",
                    help="path to save the final params/opt state")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if cfg.n_layers > 16 and not args.arch.endswith("-smoke"):
        print(f"note: {args.arch} is a full-size config; consider "
              f"{args.arch}-smoke for local training")
    model = build_model(cfg, remat=False)
    trainer = ElasticTrainer(model, optimizer=AdamW(lr=args.lr),
                             per_node_batch=args.per_node_batch,
                             seed=args.seed, total_steps=args.steps)
    trainer.pipeline.cfg.seq_len = args.seq
    print(f"arch={cfg.name} params={model.n_params():,} "
          f"devices={len(jax.devices())}")

    if args.elastic:
        from repro.core import MILPAllocator, amdahl_curve, \
            fragments_to_events, generate_summit_like
        from repro.elastic import BFTrainerRuntime, ManagedTrainer
        frags = generate_summit_like(n_nodes=max(4, args.nodes * 4),
                                     duration=48 * 3600.0, seed=args.seed)
        managed = [ManagedTrainer(
            id=0, trainer=trainer, curve=amdahl_curve(cfg.name, 100.0, 0.2),
            n_min=1, n_max=args.nodes, target_steps=args.steps)]
        rep = BFTrainerRuntime(managed, MILPAllocator("fast")).run(
            fragments_to_events(frags), max_steps_per_interval=8)
        losses = rep.losses[0]
        print(f"elastic run: {rep.steps[0]} steps over {rep.events} "
              f"allocation events, {rep.rescales[0]} rescales, "
              f"loss {losses[0]:.3f} -> {losses[-1]:.3f}"
              if losses else "no steps ran (trace had no usable fragments)")
    else:
        trainer.rescale(args.nodes)
        t0 = time.perf_counter()
        for i in range(args.steps):
            m = trainer.train_step()
            if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
                print(f"step {m.step:4d} nodes={m.n_nodes} "
                      f"loss={m.loss:.4f} ({m.step_time_s*1e3:.0f} ms)")
        dt = time.perf_counter() - t0
        print(f"{args.steps} steps in {dt:.1f}s "
              f"({args.steps * args.per_node_batch * args.nodes / dt:.1f} "
              f"samples/s)")

    if args.checkpoint:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(args.checkpoint, trainer.params,
                        meta={"step": trainer.step_count, "arch": cfg.name})
        print("saved", args.checkpoint)


if __name__ == "__main__":
    main()
