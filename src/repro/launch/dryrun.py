"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) combination on placeholder devices, and extract roofline terms.

Run:
    PYTHONPATH=src python -m repro.launch.dryrun [--arch all] [--shape all]
        [--mesh both] [--moe dense|capacity] [--out experiments/dryrun]

This file — and ONLY this file — forces 512 host platform devices; smoke
tests and benchmarks see the real device count.
"""
# The XLA_FLAGS assignment MUST precede every other import (jax locks the
# device count on first initialization).
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, applicable_shapes, get_arch, get_shape
from repro.distributed import (
    batch_spec,
    opt_state_specs,
    param_specs,
    sanitize_tree,
    to_named,
)
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import build_model
from repro.models.layers import abstract as abstract_params_of
from repro.optim import AdamW
from repro.roofline import Roofline, model_flops_estimate, parse_collectives


def build_train_step(model, optimizer, microbatch: int = 1):
    """Train step, optionally with gradient accumulation over ``microbatch``
    slices of the global batch (sequential ``lax.scan`` — the deployment
    answer to the §Dry-run finding that batch-256×4k training exceeds one
    v5e's HBM for the larger architectures)."""

    def train_step(params, opt_state, batch):
        if microbatch <= 1:
            loss, grads = jax.value_and_grad(
                lambda p: model.loss(p, batch))(params)
        else:
            def slice_mb(i, x):
                mb = x.shape[0] // microbatch
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, 0)

            def acc_step(carry, i):
                loss_acc, grad_acc = carry
                mb_batch = {k: slice_mb(i, v) for k, v in batch.items()}
                loss, grads = jax.value_and_grad(
                    lambda p: model.loss(p, mb_batch))(params)
                grad_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatch,
                    grad_acc, grads)
                return (loss_acc + loss / microbatch, grad_acc), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), zero),
                jnp.arange(microbatch))
            grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads,
                                 params)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, loss

    return train_step


def _block_abstract(defs_blocks, mesh):
    """Abstract single-block params (strip the stacked n_blocks axis)."""
    import dataclasses
    from repro.models.layers import ParamDef

    def strip(d: ParamDef) -> ParamDef:
        return dataclasses.replace(d, shape=d.shape[1:], spec=P(*list(d.spec)[1:]))

    defs1 = jax.tree.map(strip, defs_blocks,
                         is_leaf=lambda x: isinstance(x, ParamDef))
    return (abstract_params_of(defs1), to_named(param_specs(defs1, mesh), mesh))


def _analyze(compiled, n_dev):
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        # older jax returns one properties dict per executable program;
        # newer jax returns the dict directly.  Sum the numeric entries.
        merged = {}
        for c in cost:
            for k, v in (c or {}).items():
                if isinstance(v, (int, float)):
                    merged[k] = merged.get(k, 0.0) + v
        cost = merged
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(compiled.as_text())
    return flops, bytes_accessed, coll


def _block_cost(model, mesh, dp, x_shape, *, kind, memory_shape=None,
                cache_block=None, cache_specs_block=None):
    """Lower+compile one pattern-block (and its VJP for training) so that
    scan-body costs can be scaled by the trip count — XLA cost_analysis
    counts a while-loop body exactly once regardless of iterations."""
    from repro.models import transformer as T
    cfg = model.cfg
    defs_blocks = model.defs["blocks"]
    abs_p, sh_p = _block_abstract(defs_blocks, mesh)
    x = jax.ShapeDtypeStruct(x_shape, jnp.bfloat16)
    x_sh = NamedSharding(mesh, batch_spec(x_shape, mesh, dp))
    mem_args, mem_sh = (), ()
    if memory_shape is not None:
        mem_args = (jax.ShapeDtypeStruct(memory_shape, jnp.bfloat16),)
        mem_sh = (NamedSharding(mesh, batch_spec(memory_shape, mesh, dp)),)

    if kind == "decode":
        def fn(p_blocks, xx, cache, pos):
            new_c = []
            for i, spec in enumerate(cfg.layer_pattern):
                xx, nc = T.apply_block_decode(
                    cfg, spec, p_blocks[i], xx, cache[i], pos,
                    long_serving=model.long_serving)
                new_c.append(nc)
            return xx, tuple(new_c)

        pos = jax.ShapeDtypeStruct((), jnp.int32)
        jitted = jax.jit(fn, in_shardings=(
            sh_p, x_sh, cache_specs_block, NamedSharding(mesh, P())))
        lowered = jitted.lower(abs_p, x, cache_block, pos)
        return lowered.compile()

    per_layer_ck = len(cfg.layer_pattern) > 4   # mirror Model.forward

    def fwd(p_blocks, xx, *mem):
        memory = mem[0] if mem else None
        aux = jnp.zeros((), jnp.float32)
        for i, spec in enumerate(cfg.layer_pattern):
            def f(p, x, spec=spec):
                return T.apply_block(cfg, spec, p, x, memory=memory,
                                     moe_strategy=model.moe_strategy,
                                     long_serving=model.long_serving)
            if per_layer_ck:
                f = jax.checkpoint(f)
            xx, a = f(p_blocks[i], xx)
            aux = aux + a
        return xx, aux

    if kind == "train":
        ck = fwd if per_layer_ck else jax.checkpoint(fwd)

        def fn(p_blocks, xx, ybar, *mem):
            (y, aux), vjp = jax.vjp(lambda pp, xi: ck(pp, xi, *mem),
                                    p_blocks, xx)
            return vjp((ybar, jnp.ones((), jnp.float32)))

        jitted = jax.jit(fn, in_shardings=(sh_p, x_sh, x_sh) + mem_sh)
        lowered = jitted.lower(abs_p, x, x, *mem_args)
    else:  # prefill forward only
        jitted = jax.jit(fwd, in_shardings=(sh_p, x_sh) + mem_sh)
        lowered = jitted.lower(abs_p, x, *mem_args)
    return lowered.compile()


def _encoder_cost(model, mesh, dp, frames_shape, *, kind):
    """Single encoder layer cost (enc-dec models), same methodology."""
    from repro.models import layers as Lmod
    from repro.models.layers import rms_norm as _rms
    from repro.models import attention as attn_mod
    cfg, enc = model.cfg, model.cfg.encoder
    abs_p, sh_p = _block_abstract(model.defs["encoder"]["layers"], mesh)
    x = jax.ShapeDtypeStruct(frames_shape, jnp.bfloat16)
    x_sh = NamedSharding(mesh, batch_spec(frames_shape, mesh, dp))

    def fwd(p, xx):
        h = _rms(xx, p["attn_norm"], cfg.norm_eps)
        xx = xx + attn_mod.attn_apply(p["attn"], h, cfg=cfg, causal=False,
                                      window=0, n_heads=enc.n_heads,
                                      n_kv=enc.n_kv_heads,
                                      head_dim=enc.head_dim)
        h = _rms(xx, p["mlp_norm"], cfg.norm_eps)
        return xx + Lmod.mlp_apply(p["mlp"], h, cfg.mlp_activation)

    if kind == "train":
        ck = jax.checkpoint(fwd)

        def fn(p, xx, ybar):
            y, vjp = jax.vjp(ck, p, xx)
            return vjp(ybar)

        jitted = jax.jit(fn, in_shardings=(sh_p, x_sh, x_sh))
        lowered = jitted.lower(abs_p, x, x)
    else:
        jitted = jax.jit(fwd, in_shardings=(sh_p, x_sh))
        lowered = jitted.lower(abs_p, x)
    return lowered.compile()


def dryrun_one(arch_name: str, shape_name: str, mesh: Mesh, mesh_name: str,
               *, moe_strategy: str = "dense", zero1: bool = True,
               sharding: str = "tp", norm_mult_fp32: bool = True,
               force_blockwise: bool = False, ce_upcast: bool = True,
               microbatch: int = 1, tag: str = "",
               out_dir: Optional[str] = None, model_kwargs: Optional[dict] = None,
               verbose: bool = True) -> Roofline:
    import dataclasses as _dc
    from repro.models import attention as _attn_mod
    from repro.models import layers as _layers_mod
    from repro.models.layers import ParamDef as _PD
    _layers_mod.NORM_MULT_FP32 = norm_mult_fp32
    _attn_mod.FORCE_BLOCKWISE = force_blockwise
    from repro.models import model_zoo as _mz_mod
    _mz_mod.CE_UPCAST = ce_upcast

    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    model_shards = mesh.shape["model"] if sharding == "tp" else 1
    dp = dp_axes(mesh) if sharding == "tp" else tuple(mesh.axis_names)
    n_dev = int(np.prod(list(mesh.shape.values())))

    kw = dict(scan_unroll=1)
    kw.update(model_kwargs or {})
    model = build_model(
        cfg, model_shards=model_shards, dtype=jnp.bfloat16,
        moe_strategy=moe_strategy,
        long_serving=(shape_name == "long_500k"),
        **kw)
    defs = model.defs
    if sharding == "dp":
        # pure data parallelism (paper-faithful Horovod-style): params
        # replicated; the whole mesh is one big data axis; opt state ZeRO-1
        # sharded over it.
        defs = jax.tree.map(lambda d: _dc.replace(d, spec=P()), defs,
                            is_leaf=lambda x: isinstance(x, _PD))
        model.__dict__["defs"] = defs
    abstract_params = abstract_params_of(defs)
    p_specs = param_specs(defs, mesh)
    p_sh = to_named(p_specs, mesh)

    batch = model.input_specs(shape)
    t0 = time.time()

    if shape.kind == "train":
        optimizer = AdamW()
        abstract_opt = jax.eval_shape(optimizer.init, abstract_params)
        o_specs = opt_state_specs(defs, mesh, dp, zero1=zero1)
        o_sh = type(abstract_opt)(
            step=NamedSharding(mesh, P()),
            mu=to_named(o_specs, mesh), nu=to_named(o_specs, mesh))
        b_sh = {k: NamedSharding(mesh, batch_spec(v.shape, mesh, dp))
                for k, v in batch.items()}
        fn = build_train_step(model, optimizer, microbatch=microbatch)
        jitted = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, NamedSharding(mesh, P())))
        lowered = jitted.lower(abstract_params, abstract_opt, batch)
        tokens = shape.global_batch * shape.seq_len
        kind = "train"
    elif shape.kind == "prefill":
        b_sh = {k: NamedSharding(mesh, batch_spec(v.shape, mesh, dp))
                for k, v in batch.items()}
        fn = lambda params, b: model.prefill(params, b)
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
        lowered = jitted.lower(abstract_params, batch)
        tokens = shape.global_batch * shape.seq_len
        kind = "prefill"
    else:  # decode
        cache = batch["cache"]
        c_specs = model.cache_specs(dp if len(dp) > 1 else dp[0], "model")
        c_specs = sanitize_tree(cache, c_specs, mesh)
        c_sh = to_named(c_specs, mesh)
        tok_sh = NamedSharding(mesh, batch_spec(batch["tokens"].shape, mesh, dp))
        fn = lambda params, cache, toks, pos: model.decode_step(
            params, cache, toks, pos)
        jitted = jax.jit(
            fn, in_shardings=(p_sh, c_sh, tok_sh, NamedSharding(mesh, P())),
            out_shardings=(None, c_sh))
        lowered = jitted.lower(abstract_params, cache, batch["tokens"],
                               batch["pos"])
        tokens = shape.global_batch
        kind = "decode"

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    flops, bytes_accessed, coll = _analyze(compiled, n_dev)
    try:
        mem = compiled.memory_analysis()
        bytes_per_device = getattr(mem, "temp_size_in_bytes", None)
        if bytes_per_device is not None:
            bytes_per_device += getattr(mem, "argument_size_in_bytes", 0)
    except Exception:
        bytes_per_device = None

    # ---- scan-body cost correction (see _block_cost docstring) ----
    n_extra = cfg.n_blocks - 1
    if n_extra > 0:
        d_model = cfg.d_model
        if shape.kind == "train":
            bsz = shape.global_batch
            seq = shape.seq_len if cfg.frontend != "vision" else shape.seq_len
            x_shape = (bsz, seq, d_model)
            mem_shape = ((bsz, shape.seq_len // 4, cfg.encoder.d_model)
                         if cfg.is_encdec else None)
            blk = _block_cost(model, mesh, dp, x_shape, kind="train",
                              memory_shape=mem_shape)
        elif shape.kind == "prefill":
            x_shape = (shape.global_batch, shape.seq_len, d_model)
            mem_shape = ((shape.global_batch, shape.seq_len // 4,
                          cfg.encoder.d_model) if cfg.is_encdec else None)
            blk = _block_cost(model, mesh, dp, x_shape, kind="prefill",
                              memory_shape=mem_shape)
        else:
            import dataclasses as _dc
            cache_block = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), cache)
            cs_block = jax.tree.map(
                lambda sh: NamedSharding(mesh, P(*list(sh.spec)[1:])), c_sh,
                is_leaf=lambda x: isinstance(x, NamedSharding))
            blk = _block_cost(model, mesh, dp,
                              (shape.global_batch, 1, d_model),
                              kind="decode", cache_block=cache_block,
                              cache_specs_block=cs_block)
        bf, bb, bc = _analyze(blk, n_dev)
        flops += n_extra * bf
        bytes_accessed += n_extra * bb
        coll.link_bytes += n_extra * bc.link_bytes
        for k2, v2 in bc.counts.items():
            coll.counts[k2] = coll.counts.get(k2, 0) + n_extra * v2
        for k2, v2 in bc.bytes_by_kind.items():
            coll.bytes_by_kind[k2] = (coll.bytes_by_kind.get(k2, 0)
                                      + n_extra * v2)
    if cfg.is_encdec and shape.kind != "decode" and cfg.encoder.n_layers > 1:
        enc_extra = cfg.encoder.n_layers - 1
        frames_shape = (shape.global_batch, shape.seq_len // 4,
                        cfg.encoder.d_model)
        eb = _encoder_cost(model, mesh, dp, frames_shape,
                           kind=shape.kind)
        ef, ebts, ec = _analyze(eb, n_dev)
        flops += enc_extra * ef
        bytes_accessed += enc_extra * ebts
        coll.link_bytes += enc_extra * ec.link_bytes

    # On the host backend cost_analysis reports per-program totals of the
    # partitioned module (per-device); scale to the full job.
    n_params = model.n_params()
    n_active = model.n_active_params()
    r = Roofline(
        arch=arch_name, shape=shape_name, mesh=mesh_name, n_devices=n_dev,
        hlo_flops=flops * n_dev, hlo_bytes=bytes_accessed * n_dev,
        collective_link_bytes=coll.link_bytes,
        model_flops=model_flops_estimate(n_params, n_active, tokens, kind),
        n_params=n_params, n_active_params=n_active,
        bytes_per_device=bytes_per_device,
        collective_counts=coll.counts, collective_bytes=coll.bytes_by_kind)

    if verbose:
        print(f"[dryrun] {arch_name:24s} {shape_name:12s} {mesh_name:6s} "
              f"lower {t_lower:6.1f}s compile {t_compile:6.1f}s  "
              f"flops/dev {flops:.3e}  coll {coll.link_bytes/1e6:8.1f}MB  "
              f"bottleneck={r.bottleneck}", flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch_name}__{shape_name}__{mesh_name}"
        if tag:
            fname += f"__{tag}"
        extra = dict(t_lower_s=t_lower, t_compile_s=t_compile,
                     moe_strategy=moe_strategy, zero1=zero1,
                     sharding=sharding, norm_mult_fp32=norm_mult_fp32,
                     force_blockwise=force_blockwise, ce_upcast=ce_upcast,
                     tag=tag)
        with open(os.path.join(out_dir, fname + ".json"), "w") as f:
            json.dump({**r.to_json(), **extra}, f, indent=1)
    return r


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--moe", default="dense", choices=["dense", "capacity"])
    ap.add_argument("--no-zero1", action="store_true",
                    help="paper-faithful plain DP (opt state replicated "
                         "over data axes)")
    ap.add_argument("--sharding", default="tp", choices=["tp", "dp"])
    ap.add_argument("--norm-bf16", action="store_true",
                    help="norm multiplies in bf16 (fp32 stats only)")
    ap.add_argument("--flash", action="store_true",
                    help="force blockwise (flash) attention at all lengths")
    ap.add_argument("--ce-bf16", action="store_true",
                    help="mixed-precision CE loss (no fp32 logits copy)")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="gradient-accumulation slices of the global batch")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("1pod-16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("2pod-2x16x16", make_production_mesh(multi_pod=True)))

    results, failures = [], []
    for arch_name in archs:
        cfg = get_arch(arch_name)
        shapes = (applicable_shapes(cfg) if args.shape == "all"
                  else args.shape.split(","))
        for shape_name in shapes:
            if shape_name not in applicable_shapes(cfg):
                print(f"[skip] {arch_name} x {shape_name}: see DESIGN.md "
                      f"long-context table")
                continue
            for mesh_name, mesh in meshes:
                try:
                    results.append(dryrun_one(
                        arch_name, shape_name, mesh, mesh_name,
                        moe_strategy=args.moe, zero1=not args.no_zero1,
                        sharding=args.sharding,
                        norm_mult_fp32=not args.norm_bf16,
                        force_blockwise=args.flash,
                        ce_upcast=not args.ce_bf16,
                        microbatch=args.microbatch, tag=args.tag,
                        out_dir=args.out))
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((arch_name, shape_name, mesh_name,
                                     repr(e)))
    print(f"\n{len(results)} combination(s) compiled, "
          f"{len(failures)} failure(s)")
    for f in failures:
        print("FAIL:", *f)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
