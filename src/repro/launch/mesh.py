"""Production mesh factory.

Functions, not module-level constants, so importing this module never
touches jax device state (jax locks the device count on first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: one pod-slice of 256 chips (16x16
    data x model), or two pods (2 x 16 x 16) for the multi-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_shards: int = 1):
    """Small mesh over whatever local devices exist (tests/examples)."""
    n = len(jax.devices())
    assert n % model_shards == 0
    return jax.make_mesh((n // model_shards, model_shards),
                         ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")
