"""Serving launcher: batched prefill + greedy decode for any architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b-smoke \
        --batch 4 --prompt-len 32 --new-tokens 32
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import build_model
from repro.serving import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default="",
                    help="load params from a train.py checkpoint")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(args.seed))
    if args.checkpoint:
        from repro.checkpoint import load_checkpoint
        params, meta = load_checkpoint(args.checkpoint, params)
        print("loaded", args.checkpoint, meta)

    eng = ServeEngine(model, params,
                      max_len=args.prompt_len + args.new_tokens + 8)
    rng = np.random.RandomState(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.randn(args.batch, args.prompt_len // 4,
                      cfg.encoder.d_model) * 0.02, jnp.float32)
    elif cfg.frontend == "vision":
        nt = min(cfg.n_frontend_tokens, args.prompt_len // 2)
        batch["frontend_embeds"] = jnp.asarray(
            rng.randn(args.batch, cfg.n_frontend_tokens, cfg.d_model) * 0.02,
            jnp.float32)

    res = eng.generate(batch, args.new_tokens)
    print(f"arch={cfg.name} batch={args.batch} "
          f"prefill={res.prefill_time_s*1e3:.1f}ms "
          f"decode={res.decode_time_s*1e3:.1f}ms "
          f"throughput={res.tokens_per_s:.1f} tok/s")
    print("sample output ids:", res.tokens[0][:16].tolist())


if __name__ == "__main__":
    main()
