"""Seedable fault schedules over idle-pool event streams (DESIGN.md §12).

A ``ChaosSpec`` is a frozen description of the fault environment; feeding
it plus an event stream to :func:`generate_fault_schedule` yields a
``FaultSchedule`` that is a pure function of ``(events, spec)`` — same
seed, same trace ⇒ bit-identical schedule.  :func:`inject_faults` then
merges the schedule back into the stream, *consuming* each victim's
original trace departure so pool node-time accounting stays exact: a
node killed at ``t`` whose fragment would have ended at ``T`` contributes
``t − start`` node-seconds, never double-counts the departure, and the
``T − t`` tail is genuinely lost capacity.

Fault kinds
-----------
``kill``      hard node failure: the node vanishes mid-interval without
              drain grace; the holding Trainer rolls back to its last
              checkpoint and pays ``restart_penalty`` (core/loop.py).
``drain``     graceful removal: same capacity loss, but handled as an
              ordinary leave (preemption cost only, no rollback).
``blackout``  correlated mass kill: a fraction of the live pool fails at
              one instant (rack/power-domain events).
``straggler`` a time window during which rescale costs are multiplied —
              modeling slow nodes dragging collective restarts
              (``ChaosBackend`` applies the multiplier via ``refresh``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.events import PoolEvent, merge_events

_HOUR = 3600.0


@dataclass(frozen=True)
class ChaosSpec:
    """Declarative fault environment.  All rates are per trace clock.

    ``mtbf`` is the per-node mean time between failures (seconds): over
    an interval where ``p`` nodes are live, failures arrive Poisson with
    rate ``p·dt/mtbf``.  ``None`` disables node failures entirely — the
    generated schedule is empty and injection is the identity, which is
    the zero-fault-parity guarantee the tests pin down.
    """

    seed: int = 0
    # --- node failures ---
    mtbf: Optional[float] = None        # per-node MTBF (s); None = no faults
    drain_frac: float = 0.0             # fraction of failures that drain
    corrupt_prob: float = 0.0           # P(latest checkpoint unusable | kill)
    # --- straggler episodes (rescale-cost multipliers) ---
    straggler_rate: float = 0.0         # episodes per hour
    straggler_factor: float = 4.0       # r_up/r_dw multiplier while active
    straggler_duration: float = 900.0   # episode length (s)
    # --- correlated blackouts ---
    blackout_every: Optional[float] = None  # period (s); None = never
    blackout_frac: float = 0.5          # fraction of live pool killed
    # --- allocator crash/restart ---
    crash_every: Optional[float] = None  # allocator crash period (s)
    warm_restart: bool = True           # restore engine snapshot on restart
    snapshot_every: float = 600.0       # engine snapshot cadence (trace s)
    # --- trainer-side fault handling (applied to jobs by the harness) ---
    ckpt_every: Optional[float] = None  # checkpoint lattice (progress units)
    restart_penalty: float = 0.0        # extra stall per kill (s)
    # --- control-plane stream corruption (DESIGN.md §16) ---
    # These attack the *event feed*, not the nodes: the stream the
    # control plane sees is duplicated/reordered/late/lossy while the
    # physical pool follows the clean stream.  corrupt_stream() applies
    # them; the resilience layer (hygiene + reconciler) repairs them.
    duplicate_prob: float = 0.0         # P(event delivered twice)
    reorder_window: float = 0.0         # arrival jitter bound (s)
    drop_prob: float = 0.0              # P(event never delivered)
    late_prob: float = 0.0              # P(arrival beyond reorder_window)
    late_by: float = 3600.0             # how far beyond the window (s)
    reconcile_period_s: float = 300.0   # anti-entropy cadence (s)

    @property
    def fault_free(self) -> bool:
        return (self.mtbf is None and self.straggler_rate <= 0.0
                and self.blackout_every is None)

    @property
    def stream_clean(self) -> bool:
        """True when no stream-corruption knob is active —
        :func:`corrupt_stream` is then the identity."""
        return (self.duplicate_prob <= 0.0 and self.reorder_window <= 0.0
                and self.drop_prob <= 0.0 and self.late_prob <= 0.0)


@dataclass(frozen=True)
class FaultEvent:
    time: float
    kind: str                   # "kill" | "drain" | "blackout" | "straggler"
    node: int = -1              # victim (kill/drain/blackout); -1 otherwise
    duration: float = 0.0       # straggler episode length
    factor: float = 1.0         # straggler rescale-cost multiplier
    corrupt: bool = False       # kill whose latest checkpoint is unusable


@dataclass(frozen=True)
class FaultSchedule:
    """Time-sorted, immutable fault timeline (+ cheap lookup views)."""

    events: Tuple[FaultEvent, ...] = ()

    def _kind(self, *kinds: str) -> Tuple[FaultEvent, ...]:
        return tuple(f for f in self.events if f.kind in kinds)

    @property
    def kills(self) -> Tuple[FaultEvent, ...]:
        return self._kind("kill", "blackout")

    @property
    def drains(self) -> Tuple[FaultEvent, ...]:
        return self._kind("drain")

    @property
    def stragglers(self) -> Tuple[FaultEvent, ...]:
        return self._kind("straggler")

    @property
    def blackouts(self) -> Tuple[FaultEvent, ...]:
        return self._kind("blackout")

    def is_corrupt(self, time: float, node: int) -> bool:
        """Was the kill of ``node`` at exactly ``time`` a corrupt-restore
        kill?  Times compare exactly — both sides come from the same
        schedule floats, so no tolerance is needed."""
        return (time, node) in self._corrupt_set()

    def _corrupt_set(self) -> Set[Tuple[float, int]]:
        cached = getattr(self, "_corrupt_cache", None)
        if cached is None:
            cached = {(f.time, f.node) for f in self.events if f.corrupt}
            object.__setattr__(self, "_corrupt_cache", cached)
        return cached

    def straggler_multiplier(self, now: float) -> float:
        """Product of the factors of straggler episodes active at ``now``
        (overlapping episodes compound — two slow racks are worse than
        one); 1.0 outside every episode."""
        m = 1.0
        for f in self.events:
            if f.kind != "straggler":
                continue
            if f.time > now:
                break               # events are time-sorted
            if now < f.time + f.duration:
                m *= f.factor
        return m


def generate_fault_schedule(events: Sequence[PoolEvent],
                            spec: ChaosSpec) -> FaultSchedule:
    """Replay the pool occupancy through ``events`` and draw faults.

    Deterministic: one ``np.random.default_rng(spec.seed)`` stream,
    consumed in a fixed order (blackouts, kills, stragglers per
    inter-event interval).  Victims are sampled from the *live* pool —
    nodes present and not already killed — so a schedule never kills a
    node twice within one fragment, and a node that rejoins (next
    fragment) becomes a valid victim again.
    """
    rng = np.random.default_rng(spec.seed)
    evs = merge_events(events)
    if not evs or spec.fault_free:
        return FaultSchedule()
    faults: List[FaultEvent] = []
    pool: Set[int] = set()
    killed: Set[int] = set()
    next_blackout = (evs[0].time + spec.blackout_every
                     if spec.blackout_every else None)
    for k, e in enumerate(evs):
        for n in e.joined:
            pool.add(n)
            killed.discard(n)       # rejoined: eligible again
        for n in e.left:
            pool.discard(n)
        for n in e.failed:
            pool.discard(n)
        t0 = e.time
        t1 = evs[k + 1].time if k + 1 < len(evs) else e.time
        dt = t1 - t0
        if dt <= 0:
            continue
        live = sorted(pool - killed)
        # correlated blackouts on their fixed grid
        if next_blackout is not None:
            while next_blackout < t1:
                if next_blackout >= t0 and live:
                    n_vict = min(len(live),
                                 max(1, int(round(spec.blackout_frac
                                                  * len(live)))))
                    idx = rng.choice(len(live), size=n_vict, replace=False)
                    for i in sorted(int(x) for x in idx):
                        faults.append(FaultEvent(time=float(next_blackout),
                                                 kind="blackout",
                                                 node=live[i]))
                        killed.add(live[i])
                    live = sorted(pool - killed)
                next_blackout += spec.blackout_every
        # independent per-node failures: Poisson(p·dt/mtbf) over the
        # interval, uniform times, victims without replacement
        if spec.mtbf is not None and live:
            n_fail = min(int(rng.poisson(len(live) * dt / spec.mtbf)),
                         len(live))
            if n_fail:
                ts = np.sort(rng.uniform(t0, t1, size=n_fail))
                idx = rng.choice(len(live), size=n_fail, replace=False)
                for t, i in zip(ts, idx):
                    node = live[int(i)]
                    if rng.random() < spec.drain_frac:
                        faults.append(FaultEvent(time=float(t), kind="drain",
                                                 node=node))
                    else:
                        corrupt = bool(rng.random() < spec.corrupt_prob)
                        faults.append(FaultEvent(time=float(t), kind="kill",
                                                 node=node, corrupt=corrupt))
                    killed.add(node)
        # straggler episodes (global, node-agnostic)
        if spec.straggler_rate > 0.0:
            for _ in range(int(rng.poisson(dt / _HOUR * spec.straggler_rate))):
                faults.append(FaultEvent(
                    time=float(rng.uniform(t0, t1)), kind="straggler",
                    duration=spec.straggler_duration,
                    factor=spec.straggler_factor))
    faults.sort(key=lambda f: (f.time, f.node, f.kind))
    return FaultSchedule(tuple(faults))


def inject_faults(events: Sequence[PoolEvent],
                  schedule: FaultSchedule) -> List[PoolEvent]:
    """Merge a fault schedule into an event stream.

    Each kill/blackout becomes a ``PoolEvent(failed=(node,))`` and each
    drain a ``PoolEvent(left=(node,))`` at the fault time — and the
    victim's *next original departure* after the fault is consumed
    (dropped), because the node already left the pool.  Without that
    consumption the node would be subtracted twice from the pool size
    and conservation of node-seconds would break.

    With an empty schedule this returns ``list(events)`` unchanged — the
    zero-fault-parity fast path.
    """
    removals = [f for f in schedule.events
                if f.kind in ("kill", "drain", "blackout")]
    if not removals:
        return list(events)
    evs = merge_events(events)
    # per-node time-ordered indices of original departures
    left_at: Dict[int, List[int]] = {}
    for i, e in enumerate(evs):
        for n in e.left:
            left_at.setdefault(n, []).append(i)
    consumed: Dict[int, Set[int]] = {}      # event index -> nodes to drop
    ptr: Dict[int, int] = {}
    for f in sorted(removals, key=lambda f: f.time):
        occ = left_at.get(f.node, [])
        p = ptr.get(f.node, 0)
        while p < len(occ) and evs[occ[p]].time <= f.time:
            p += 1
        if p < len(occ):
            consumed.setdefault(occ[p], set()).add(f.node)
            p += 1
        ptr[f.node] = p
    out: List[PoolEvent] = []
    for i, e in enumerate(evs):
        drop = consumed.get(i)
        if drop:
            e = PoolEvent(time=e.time, joined=e.joined,
                          left=tuple(n for n in e.left if n not in drop),
                          failed=e.failed)
        out.append(e)
    for f in removals:
        if f.kind == "drain":
            out.append(PoolEvent(time=f.time, left=(f.node,)))
        else:
            out.append(PoolEvent(time=f.time, failed=(f.node,)))
    return merge_events(out)


def corrupt_stream(events: Sequence[PoolEvent],
                   spec: ChaosSpec) -> List[PoolEvent]:
    """Corrupt the *delivery* of an event stream (DESIGN.md §16).

    Models a lossy monitor feed: each event is independently dropped
    (``drop_prob``), duplicated (``duplicate_prob``, the copy arriving
    later), jittered in arrival time within ``reorder_window`` seconds,
    or delivered late beyond the window (``late_prob``, by ``late_by``
    seconds — hygiene must drop it and the reconciler repair it).  Every
    delivered copy keeps the event's original ``time`` stamp and gains a
    monotone ``seq`` reflecting the monitor's emission order; the
    returned list is in **arrival order** (sorted by arrival, stably),
    which is the order ``EventHygiene.push`` must consume.

    Deterministic in ``(events, spec)``: one rng seeded from
    ``spec.seed``.  With every corruption knob at zero this returns the
    seq-stamped stream in its original order — the identity fast path
    the zero-corruption parity tests pin down.
    """
    evs = merge_events(events)
    stamped = [PoolEvent(time=e.time, joined=e.joined, left=e.left,
                         failed=e.failed, pool=e.pool, seq=i)
               for i, e in enumerate(evs)]
    if spec.stream_clean:
        return stamped
    rng = np.random.default_rng(spec.seed + 0x5EED)
    arrivals: List[Tuple[float, int, PoolEvent]] = []
    for e in stamped:
        if rng.random() < spec.drop_prob:
            continue
        jitter = (rng.uniform(0.0, spec.reorder_window)
                  if spec.reorder_window > 0 else 0.0)
        arr = e.time + jitter
        if spec.late_prob > 0 and rng.random() < spec.late_prob:
            arr = e.time + spec.reorder_window + spec.late_by
        arrivals.append((arr, e.seq, e))
        if rng.random() < spec.duplicate_prob:
            dup_arr = arr + (rng.uniform(0.0, spec.reorder_window)
                             if spec.reorder_window > 0 else 0.0)
            arrivals.append((dup_arr, e.seq, e))
    arrivals.sort(key=lambda it: (it[0], it[1]))
    return [e for _, _, e in arrivals]
