"""RestartingAllocator: allocator crash/restart with warm-state recovery.

BFTrainer's allocator is a single point of failure on the login/service
node; DESIGN.md §12 requires that losing it costs re-convergence time,
not correctness.  ``RestartingAllocator`` wraps an ``AllocationEngine``
factory and a schedule of crash times (trace clock, read from each
problem's ``now``): when a crash time passes, the engine object is
thrown away and rebuilt from the factory — cold, or warm-restored from
the last periodic ``AllocationEngine.snapshot()`` (JSON-round-tripped,
exactly as a real deployment would persist it).

A warm restart makes every previously solved problem a cache hit again;
a cold restart re-converges through the engine's own warm-start repair
path (the current map survives inside the problems themselves).  Either
way the decisions stay *deterministic* for deterministic engines — the
recovery-invariant tests compare restarted vs uninterrupted runs.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

from repro.core.allocator import Allocator
from repro.core.engine import (
    AllocationEngine,
    dumps_snapshot,
    loads_snapshot,
)
from repro.core.milp import AllocationProblem, AllocationResult
from repro.obs.telemetry import NULL_TELEMETRY


class RestartingAllocator(Allocator):
    def __init__(self, factory: Callable[[], AllocationEngine] = None, *,
                 crash_times: Sequence[float] = (),
                 snapshot_every: float = 600.0,
                 warm_restart: bool = True,
                 telemetry=None):
        self.factory = factory or AllocationEngine
        self.telemetry = telemetry or NULL_TELEMETRY
        self.engine = self._build_engine()
        self.name = f"restarting({self.engine.name})"
        self.crash_times = sorted(crash_times)
        self.snapshot_every = snapshot_every
        self.warm_restart = warm_restart
        self._snapshot_text: Optional[str] = None   # last durable snapshot
        self._last_snapshot_t: Optional[float] = None
        self.restarts = 0
        self.recovered_entries = 0

    def _build_engine(self) -> AllocationEngine:
        engine = self.factory()
        # share the hub so decision-latency histograms survive restarts
        # (factory engines default to the null hub; a factory that wires
        # its own telemetry wins)
        if self.telemetry and getattr(engine, "telemetry", None) in (
                None, NULL_TELEMETRY):
            engine.telemetry = self.telemetry
        return engine

    def allocate(self, prob: AllocationProblem) -> AllocationResult:
        now = prob.now
        while self.crash_times and self.crash_times[0] <= now:
            self.crash_times.pop(0)
            self._restart(now)
        res = self.engine.allocate(prob)
        if self.snapshot_every > 0 and (
                self._last_snapshot_t is None
                or now - self._last_snapshot_t >= self.snapshot_every):
            # persist warm state the way a deployment would: through the
            # JSON wire format, so the round trip itself stays exercised
            snap = self.engine.snapshot()
            self._snapshot_text = dumps_snapshot(snap)
            self._last_snapshot_t = now
            tel = self.telemetry
            if tel:
                tel.count("allocator.snapshots")
                tel.instant("allocator", "snapshot", now,
                            entries=len(snap.get("cache", ())))
        return res

    def _restart(self, now: float = 0.0) -> None:
        self.restarts += 1
        self.engine = self._build_engine()
        recovered = 0
        warm = self.warm_restart and self._snapshot_text is not None
        if warm:
            recovered = self.engine.restore(
                loads_snapshot(self._snapshot_text))
            self.recovered_entries += recovered
        tel = self.telemetry
        if tel:
            tel.count("allocator.restarts")
            tel.instant("allocator", "restart", now, warm=warm,
                        recovered=recovered)
