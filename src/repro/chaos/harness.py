"""One-call chaos replay: trace + Trainers + ChaosSpec → ChaosReport.

``run_chaos`` wires the whole fault stack together: generate the
deterministic schedule, inject it into the event stream, wrap the
backend in ``ChaosBackend``, wrap the allocator in
``RestartingAllocator``, run the ordinary ``ControlLoop``, and report
``LoopStats`` plus the fault/recovery bookkeeping the tests and the
chaos benchmark read.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

from repro.chaos.allocator import RestartingAllocator
from repro.chaos.backend import ChaosBackend
from repro.chaos.faults import (
    ChaosSpec,
    FaultSchedule,
    corrupt_stream,
    generate_fault_schedule,
    inject_faults,
)
from repro.core.backend import AnalyticBackend, ExecutionBackend
from repro.core.engine import AllocationEngine
from repro.core.events import PoolEvent, pool_sizes
from repro.core.loop import ControlLoop, LoopStats, TrainerJob


def pool_node_seconds(events: Sequence[PoolEvent],
                      horizon: float) -> float:
    """∫|N(t)|dt over [first event, horizon] — the supply side of the
    conservation invariant (allocated node-seconds can never exceed it)."""
    steps = pool_sizes(list(events))
    if not steps:
        return 0.0
    total = 0.0
    for (t, size), nxt in zip(steps, [t for t, _ in steps[1:]] + [horizon]):
        if nxt > t:
            total += size * (min(nxt, horizon) - t)
        if t >= horizon:
            break
    return total


@dataclass
class ChaosReport:
    stats: LoopStats
    spec: ChaosSpec
    schedule: FaultSchedule
    events: List[PoolEvent]             # the injected stream actually run
    jobs: List[TrainerJob]              # post-run job state
    pool_node_seconds: float
    allocator_restarts: int = 0
    recovered_cache_entries: int = 0
    corrupt_restores: int = 0
    # stream-corruption repair bookkeeping (DESIGN.md §16); None when
    # the spec's corruption knobs are all zero
    hygiene: Optional[object] = None        # resilience.HygieneStats
    reconcile: Optional[object] = None      # resilience.ReconcileStats
    divergence: Optional[dict] = None       # membership_divergence()
    # supply integral of the *true* (uncorrupted) stream; equals
    # pool_node_seconds on a clean feed
    true_pool_node_seconds: float = 0.0

    @property
    def n_kills(self) -> int:
        return len(self.schedule.kills)

    @property
    def allocated_node_seconds(self) -> float:
        return sum(j.node_seconds for j in self.jobs)


def run_chaos(events: Sequence[PoolEvent], jobs: Sequence[TrainerJob],
              spec: ChaosSpec, *,
              backend: Optional[ExecutionBackend] = None,
              engine_factory: Callable[[], AllocationEngine] = None,
              t_fwd=120.0, pj_max: int = 10,
              horizon: Optional[float] = None,
              coalesce_window: float = 0.0,
              objective=None, telemetry=None) -> ChaosReport:
    """Replay ``events`` under the fault environment ``spec``.

    ``jobs`` are mutated in place (standard ``ControlLoop`` contract —
    pass fresh jobs per run): when the spec sets ``ckpt_every`` /
    ``restart_penalty``, they are stamped onto every job first, so one
    spec fully describes the fault discipline.
    """
    jobs = list(jobs)
    for j in jobs:
        if spec.ckpt_every is not None:
            j.ckpt_every = spec.ckpt_every
        if spec.restart_penalty:
            j.restart_penalty = spec.restart_penalty
    schedule = generate_fault_schedule(events, spec)
    chaos_events = inject_faults(events, schedule)
    if horizon is None:
        horizon = max((e.time for e in chaos_events), default=0.0)
    # control-plane stream corruption (DESIGN.md §16): the physical
    # fleet follows chaos_events (truth); the loop sees what survives
    # delivery + hygiene + anti-entropy repair
    run_events = chaos_events
    hygiene_stats = reconcile_stats = divergence = None
    if not spec.stream_clean:
        from repro.resilience import (
            membership_divergence,
            membership_oracle,
            sanitize_stream,
        )
        corrupted = corrupt_stream(chaos_events, spec)
        run_events, hygiene_stats, reconcile_stats = sanitize_stream(
            corrupted, reorder_window=spec.reorder_window,
            oracle=membership_oracle(chaos_events),
            reconcile_period_s=spec.reconcile_period_s)
        divergence = membership_divergence(chaos_events, run_events,
                                           t_end=horizon)
    crash_times: List[float] = []
    if spec.crash_every and chaos_events:
        t = chaos_events[0].time + spec.crash_every
        while t < horizon:
            crash_times.append(t)
            t += spec.crash_every
    allocator = RestartingAllocator(
        engine_factory, crash_times=crash_times,
        snapshot_every=spec.snapshot_every, warm_restart=spec.warm_restart,
        telemetry=telemetry)
    chaos_backend = ChaosBackend(backend or AnalyticBackend(), schedule)
    if telemetry:
        # record the injected fault environment itself so a trace is
        # self-describing: every scheduled fault becomes an instant
        for ev in schedule.kills:
            telemetry.count("chaos.kills")
            telemetry.instant("chaos", "kill", ev.time, node=ev.node,
                              corrupt=ev.corrupt)
        for ev in schedule.drains:
            telemetry.count("chaos.drains")
            telemetry.instant("chaos", "drain", ev.time, node=ev.node,
                              duration=ev.duration)
        for ev in schedule.stragglers:
            telemetry.count("chaos.stragglers")
            telemetry.instant("chaos", "straggler-episode", ev.time,
                              duration=ev.duration, factor=ev.factor)
    stats = ControlLoop(run_events, jobs, allocator, chaos_backend,
                        t_fwd=t_fwd, pj_max=pj_max, horizon=horizon,
                        coalesce_window=coalesce_window,
                        objective=objective, telemetry=telemetry).run()
    return ChaosReport(
        stats=stats, spec=spec, schedule=schedule,
        events=run_events, jobs=jobs,
        pool_node_seconds=pool_node_seconds(run_events, horizon),
        allocator_restarts=allocator.restarts,
        recovered_cache_entries=allocator.recovered_entries,
        corrupt_restores=chaos_backend.corrupt_restores,
        hygiene=hygiene_stats, reconcile=reconcile_stats,
        divergence=divergence,
        true_pool_node_seconds=pool_node_seconds(chaos_events, horizon))


@dataclass
class FederatedChaosReport:
    """Fleet-level chaos report: ``FederatedStats`` plus the fault and
    recovery bookkeeping summed over the per-pool allocators/backends."""
    stats: object                       # repro.federation.FederatedStats
    spec: ChaosSpec
    schedule: FaultSchedule
    events: List[PoolEvent]
    jobs: List[TrainerJob]
    pool_node_seconds: float
    allocator_restarts: int = 0
    recovered_cache_entries: int = 0
    corrupt_restores: int = 0

    @property
    def n_kills(self) -> int:
        return len(self.schedule.kills)

    @property
    def allocated_node_seconds(self) -> float:
        return sum(j.node_seconds for j in self.jobs)


def run_federated_chaos(events: Sequence[PoolEvent],
                        jobs: Sequence[TrainerJob], spec: ChaosSpec, *,
                        n_pools: int = 4, pool_map=None,
                        engine_factory: Callable[[],
                                                 AllocationEngine] = None,
                        t_fwd=120.0, pj_max: int = 10,
                        horizon: Optional[float] = None,
                        coalesce_window: float = 0.0, objective=None,
                        telemetry=None, epoch_s: Optional[float] = None,
                        migration_cost_s: float = 0.0,
                        parallel: bool = True) -> FederatedChaosReport:
    """Federated counterpart of :func:`run_chaos` (DESIGN.md §14).

    Faults are generated and injected into the *fleet* stream — per-pool
    failures emerge from node → pool ownership when the router splits it
    — and every pool gets its own ``RestartingAllocator`` (same crash
    schedule: a control-plane crash takes all pools down together, the
    correlated-failure worst case) over a shared fault schedule, with
    per-pool ``ChaosBackend`` wrappers.  Warm-state recovery is
    therefore exercised pool-by-pool, including across migrations.
    """
    from repro.federation import FederatedLoop

    jobs = list(jobs)
    for j in jobs:
        if spec.ckpt_every is not None:
            j.ckpt_every = spec.ckpt_every
        if spec.restart_penalty:
            j.restart_penalty = spec.restart_penalty
    schedule = generate_fault_schedule(events, spec)
    chaos_events = inject_faults(events, schedule)
    if horizon is None:
        horizon = max((e.time for e in chaos_events), default=0.0)
    crash_times: List[float] = []
    if spec.crash_every and chaos_events:
        t = chaos_events[0].time + spec.crash_every
        while t < horizon:
            crash_times.append(t)
            t += spec.crash_every

    allocators: List[RestartingAllocator] = []
    backends: List[ChaosBackend] = []

    def make_allocator(k: int) -> RestartingAllocator:
        alloc = RestartingAllocator(
            engine_factory, crash_times=list(crash_times),
            snapshot_every=spec.snapshot_every,
            warm_restart=spec.warm_restart, telemetry=telemetry)
        allocators.append(alloc)
        return alloc

    def make_backend(k: int) -> ChaosBackend:
        b = ChaosBackend(AnalyticBackend(), schedule)
        backends.append(b)
        return b

    fed = FederatedLoop(
        chaos_events, jobs, pool_map=pool_map, n_pools=n_pools,
        allocator_factory=make_allocator, backend_factory=make_backend,
        t_fwd=t_fwd, pj_max=pj_max, horizon=horizon,
        coalesce_window=coalesce_window, objective=objective,
        telemetry=telemetry, epoch_s=epoch_s,
        migration_cost_s=migration_cost_s, parallel=parallel)
    stats = fed.run()
    return FederatedChaosReport(
        stats=stats, spec=spec, schedule=schedule,
        events=chaos_events, jobs=jobs,
        pool_node_seconds=pool_node_seconds(chaos_events, horizon),
        allocator_restarts=sum(a.restarts for a in allocators),
        recovered_cache_entries=sum(a.recovered_entries
                                    for a in allocators),
        corrupt_restores=sum(b.corrupt_restores for b in backends))
