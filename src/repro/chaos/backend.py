"""ChaosBackend: fault-wrapping for any ExecutionBackend (DESIGN.md §12).

Wraps an inner backend (Analytic or Live) and a ``FaultSchedule``; every
hook delegates to the inner backend, with two fault behaviors layered on
top:

* **straggler rescale costs** — during an active straggler episode,
  ``refresh`` multiplies the job's ``r_up``/``r_dw`` by the episode
  factor, so the allocator sees (and the loop charges) slowed rescales.
* **corrupt checkpoint restores** — when a kill is flagged corrupt in
  the schedule, ``on_fail`` rejects the latest checkpoint and falls back
  one ``ckpt_every`` interval further (the last *good* checkpoint),
  mirroring what ``repro.checkpoint.CheckpointManager`` does on a real
  checksum mismatch.

With an empty schedule every hook is pure delegation, so a chaos-wrapped
replay is bit-identical to the bare backend — the parity invariant
``tests/test_chaos.py`` pins down.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from repro.chaos.faults import FaultSchedule
from repro.core.backend import ExecutionBackend
from repro.core.loop import TrainerJob
from repro.obs.telemetry import NULL_TELEMETRY


class ChaosBackend(ExecutionBackend):
    """Decorator backend: ``inner`` executes, chaos perturbs."""

    def __init__(self, inner: ExecutionBackend, schedule: FaultSchedule):
        self.inner = inner
        self.schedule = schedule
        self.name = f"chaos({inner.name})"
        self.corrupt_restores = 0
        # straggler bookkeeping: the multiplied costs we last wrote per
        # job, and the clean base they were derived from.  On refresh, if
        # the job still carries exactly what we wrote, restore the clean
        # base first — otherwise multipliers would compound across
        # refreshes on backends whose own refresh is a no-op (Analytic).
        self._written: Dict[int, Tuple[float, float]] = {}
        self._clean: Dict[int, Tuple[float, float]] = {}
        # last straggler multiplier observed, so episode edges emit one
        # instant each instead of one per refresh
        self._last_mult = 1.0

    # -- pure delegation ------------------------------------------------

    def bind(self, jobs) -> None:
        # the loop hands *this* wrapper its telemetry hub; share it with
        # the inner substrate so live rescale spans land in the same trace
        if self.telemetry and getattr(self.inner, "telemetry", None) in (
                None, NULL_TELEMETRY):
            self.inner.telemetry = self.telemetry
        self.inner.bind(jobs)

    def apply_allocation(self, job: TrainerJob, old_n: int,
                         now: float) -> None:
        self.inner.apply_allocation(job, old_n, now)

    def on_preempt(self, job: TrainerJob, taken: List[int],
                   now: float) -> None:
        self.inner.on_preempt(job, taken, now)

    def eta(self, job: TrainerJob, now: float,
            horizon: float) -> Optional[float]:
        return self.inner.eta(job, now, horizon)

    def advance(self, job: TrainerJob, start: float, end: float) -> float:
        return self.inner.advance(job, start, end)

    def on_finish(self, job: TrainerJob, now: float) -> None:
        self.inner.on_finish(job, now)

    # -- fault behaviors ------------------------------------------------

    def refresh(self, job: TrainerJob, now: float) -> None:
        if self._written.get(job.id) == (job.r_up, job.r_dw):
            # our multiplied values are still in place: restore the clean
            # base before the inner backend refreshes (live backends may
            # overwrite with fresh measurements, which then win)
            job.r_up, job.r_dw = self._clean[job.id]
        self.inner.refresh(job, now)
        self._clean[job.id] = (job.r_up, job.r_dw)
        m = self.schedule.straggler_multiplier(now)
        if m != self._last_mult:
            tel = self.telemetry
            if tel:
                tel.instant("chaos", "straggler", now,
                            old=self._last_mult, new=m)
                tel.sample("chaos.straggler_mult", now, m)
            self._last_mult = m
        if m != 1.0:
            job.r_up *= m
            job.r_dw *= m
            self._written[job.id] = (job.r_up, job.r_dw)
        else:
            self._written.pop(job.id, None)

    def on_fail(self, job: TrainerJob, failed: List[int],
                now: float) -> Optional[float]:
        restored = self.inner.on_fail(job, failed, now)
        if not any(self.schedule.is_corrupt(now, n) for n in failed):
            return restored
        # latest checkpoint unusable: fall back one lattice interval to
        # the last good one (only meaningful on a finite lattice —
        # continuous checkpointing has no discrete "previous" snapshot)
        if math.isfinite(job.ckpt_every) and job.ckpt_every > 0:
            base = job.last_checkpoint() if restored is None else restored
            restored = max(0.0, base - job.ckpt_every)
            self.corrupt_restores += 1
            tel = self.telemetry
            if tel:
                tel.count("chaos.corrupt_restores")
                tel.instant("chaos", "corrupt-restore", now, job=job.id,
                            rejected=base, restored=restored)
        return restored
