"""Deterministic fault injection and warm-state recovery (DESIGN.md §12).

Everything in this package is *replayable*: a ``ChaosSpec`` seed fully
determines the fault schedule, so any chaos run can be reproduced
bit-for-bit — which is what turns fault handling into a testable
invariant rather than a flaky integration concern.

Layers (each independently usable):

* ``faults``   — ``ChaosSpec`` → ``FaultSchedule`` (kills, drains,
  straggler episodes, blackouts) + ``inject_faults`` merging the
  schedule into a ``PoolEvent`` stream with exact node-time accounting.
* ``backend``  — ``ChaosBackend`` wrapping any ``ExecutionBackend``:
  straggler rescale-cost multipliers and corrupt-checkpoint restores.
* ``allocator``— ``RestartingAllocator`` wrapping any allocator factory:
  scheduled crash/restart with engine warm-state snapshot recovery.
* ``harness``  — ``run_chaos`` wiring all of the above into one
  ``ControlLoop`` replay, returning a ``ChaosReport``.
"""
from repro.chaos.allocator import RestartingAllocator
from repro.chaos.backend import ChaosBackend
from repro.chaos.faults import (
    ChaosSpec,
    FaultEvent,
    FaultSchedule,
    corrupt_stream,
    generate_fault_schedule,
    inject_faults,
)
from repro.chaos.harness import (
    ChaosReport,
    FederatedChaosReport,
    run_chaos,
    run_federated_chaos,
)

__all__ = [
    "ChaosSpec",
    "FaultEvent",
    "FaultSchedule",
    "corrupt_stream",
    "generate_fault_schedule",
    "inject_faults",
    "ChaosBackend",
    "RestartingAllocator",
    "ChaosReport",
    "FederatedChaosReport",
    "run_chaos",
    "run_federated_chaos",
]
