"""Paper Fig. 16: efficiency vs artificially inflated rescale costs
(2-10x) — expected sub-linear degradation."""
from __future__ import annotations

from benchmarks.common import FULL, efficiency, emit, hpo_jobs, trace
from repro.core import MILPAllocator


def main() -> None:
    hours = 24.0 if FULL else 12.0
    ev = trace(n_nodes=160, hours=hours, seed=77)
    horizon = hours * 3600.0
    scales = [1, 2, 4, 10] if FULL else [1, 4, 10]
    for s in scales:
        rep, u = efficiency(ev, lambda s=s: hpo_jobs(8, r_scale=float(s)),
                            horizon, MILPAllocator("fast"))
        emit(f"rescale_cost/{s}x/efficiency_u", f"{u:.3f}",
             "fig16: sublinear degradation")


if __name__ == "__main__":
    main()
