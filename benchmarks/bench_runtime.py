"""Live-path benchmark: the shared ControlLoop driving real
ElasticTrainers (LiveBackend) over a replayed idle-node trace.

Reports end-to-end steps/s, measured rescale wall time, and
policy-side solver wall — the numbers that tell you what the live path
costs beyond pure simulation (DESIGN.md §9).

``--smoke`` (or ``BENCH_SMOKE=1``) runs a toy scenario sized for CI:
tiny reduced architectures on a small summit-like trace.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from benchmarks.common import emit
from repro.configs import get_arch
from repro.core import (
    AllocationEngine,
    amdahl_curve,
    fragments_to_events,
    generate_summit_like,
)
from repro.elastic import BFTrainerRuntime, ElasticTrainer, ManagedTrainer
from repro.models import build_model
from repro.optim import AdamW


def make_trainer(arch: str, seed: int, seq: int = 48) -> ElasticTrainer:
    cfg = get_arch(arch).reduced()
    model = build_model(cfg, remat=False)
    tr = ElasticTrainer(model, per_node_batch=2, seed=seed,
                        optimizer=AdamW(lr=1e-3), warmup_steps=5)
    tr.pipeline.cfg.seq_len = seq
    return tr


def run(smoke: bool) -> None:
    hours = 12.0 if smoke else 48.0
    target = 4 if smoke else 60
    frags = generate_summit_like(n_nodes=6, duration=hours * 3600.0, seed=13)
    events = fragments_to_events(frags)
    emit("runtime/trace_events", len(events))

    managed = [
        ManagedTrainer(id=0, trainer=make_trainer("gemma-2b", 1),
                       curve=amdahl_curve("gemma-2b", 100.0, 0.2),
                       n_min=1, n_max=1, target_steps=target),
        ManagedTrainer(id=1, trainer=make_trainer("mamba2-2.7b", 2),
                       curve=amdahl_curve("mamba2", 120.0, 0.15),
                       n_min=1, n_max=1, target_steps=target),
    ]
    rt = BFTrainerRuntime(managed, AllocationEngine(), t_fwd=120.0,
                          coalesce_window=30.0)
    t0 = time.perf_counter()
    rep = rt.run(events, time_scale=1.0,
                 max_steps_per_interval=2 if smoke else 8)
    wall = time.perf_counter() - t0

    steps = sum(rep.steps.values())
    emit("runtime/steps", steps)
    emit("runtime/steps_per_s", f"{steps / max(wall, 1e-9):.2f}",
         "end-to-end incl. solver+rescale")
    emit("runtime/wall_s", f"{wall:.2f}")
    emit("runtime/solver_wall_s", f"{rep.solver_wall_s:.3f}")
    emit("runtime/alloc_events", rep.events)
    rescale_ts = [dt for m in managed
                  for (_, _, dt) in m.trainer.rescale_history]
    emit("runtime/rescales", len(rescale_ts))
    if rescale_ts:
        emit("runtime/rescale_wall_mean_ms",
             f"{1e3 * float(np.mean(rescale_ts)):.1f}",
             "measured R_up/R_dw source")
        emit("runtime/rescale_wall_total_s",
             f"{float(np.sum(rescale_ts)):.2f}")
    st = rep.stats
    emit("runtime/policy_rescale_cost_s", f"{st.rescale_cost_s:.2f}",
         "trace-time stall accounting (shared loop)")
    emit("runtime/policy_preempt_cost_s", f"{st.preempt_cost_s:.2f}")
    for m in managed:
        ls = rep.losses[m.id]
        if ls:
            emit(f"runtime/trainer{m.id}/steps", rep.steps[m.id])
            emit(f"runtime/trainer{m.id}/loss_first_last",
                 f"{ls[0]:.3f}->{ls[-1]:.3f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized toy run")
    args, _ = ap.parse_known_args()
    smoke = args.smoke or bool(int(os.environ.get("BENCH_SMOKE", "0")))
    run(smoke)


if __name__ == "__main__":
    main()
