"""Benchmark dispatcher: one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [section ...] [--json [DIR]]``
prints ``name,value,derived`` CSV rows.  Set BENCH_FULL=1 for the
paper-scale variants, BENCH_SMOKE=1 (or ``--smoke``) for CI-scale runs.

``--json [DIR]`` additionally persists the perf-trajectory payloads
(``BENCH_week.json`` from the ``week`` section, ``BENCH_allocator.json``
from ``scale``, ``BENCH_chaos.json`` from ``chaos``,
``BENCH_objectives.json`` from ``objectives``,
``BENCH_scalability.json`` from ``scalability``,
``BENCH_serving.json`` from ``serving``,
``BENCH_resilience.json`` from ``resilience``) into DIR (default:
the current directory), validated
against ``benchmarks.schema`` — the artifacts CI uploads per commit
and ``scripts/bench_compare.py`` diffs against the committed baselines
in ``benchmarks/baselines/``.
"""
from __future__ import annotations

import os
import sys
import time

SECTIONS = [
    ("milp", "Fig 5: MILP solve time", "benchmarks.bench_milp"),
    ("engine", "Allocation engine portfolio vs per-event MILP (week trace)",
     "benchmarks.bench_engine"),
    ("scale", "Scale sweep: incremental engine vs fresh solve, to 4096 nodes",
     "benchmarks.bench_scale"),
    ("tfwd", "Figs 7-9: forward-looking time", "benchmarks.bench_tfwd"),
    ("week", "Figs 10-11: weekly efficiency engine/MILP vs heuristic",
     "benchmarks.bench_week"),
    ("workloads", "Scenario library: engine efficiency per workload profile",
     "benchmarks.bench_workloads"),
    ("objectives", "Figs 12-13 + Tabs 3-4 + policy portfolio: "
     "throughput-vs-fairness across scenarios",
     "benchmarks.bench_objectives"),
    ("runtime", "Live ControlLoop: real elastic trainers on a replayed trace",
     "benchmarks.bench_runtime"),
    ("chaos", "Chaos resilience: efficiency retention under injected faults",
     "benchmarks.bench_chaos"),
    ("serving", "Elastic serving: SLO attainment on harvested holes vs "
     "dedicated nodes", "benchmarks.bench_serving"),
    ("resilience", "Self-healing control plane: stream corruption repair + "
     "decision-deadline ladder", "benchmarks.bench_resilience"),
    ("pjmax", "Fig 14: max parallel Trainers", "benchmarks.bench_pjmax"),
    ("scalability", "Fig 15: per-DNN scalability", "benchmarks.bench_scalability"),
    ("rescale_cost", "Fig 16: rescale-cost sweep", "benchmarks.bench_rescale_cost"),
    ("throughput", "Tab 2 analog: model-zoo throughput", "benchmarks.bench_throughput"),
    ("kernels", "Pallas kernel micro-bench", "benchmarks.bench_kernels"),
]


def _parse_args(argv):
    want, i = set(), 0
    while i < len(argv):
        a = argv[i]
        if a == "--json":
            nxt = argv[i + 1] if i + 1 < len(argv) else None
            if nxt is not None and not nxt.startswith("-") and \
                    nxt not in {k for k, _, _ in SECTIONS}:
                os.environ["BENCH_JSON_DIR"] = nxt
                i += 1
            else:
                os.environ.setdefault("BENCH_JSON_DIR", ".")
        elif a == "--smoke":
            os.environ["BENCH_SMOKE"] = "1"
        else:
            want.add(a)
        i += 1
    return want


def main() -> None:
    want = _parse_args(sys.argv[1:])
    t_start = time.time()
    for key, desc, mod_name in SECTIONS:
        if want and key not in want:
            continue
        print(f"# === {key}: {desc} ===", flush=True)
        t0 = time.time()
        mod = __import__(mod_name, fromlist=["main"])
        mod.main()
        print(f"# {key} done in {time.time()-t0:.1f}s", flush=True)
    print(f"# all benchmarks done in {time.time()-t_start:.1f}s")


if __name__ == "__main__":
    main()
