"""Benchmark dispatcher: one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [section ...]``
prints ``name,value,derived`` CSV rows.  Set BENCH_FULL=1 for the
paper-scale variants.
"""
from __future__ import annotations

import sys
import time

SECTIONS = [
    ("milp", "Fig 5: MILP solve time", "benchmarks.bench_milp"),
    ("engine", "Allocation engine portfolio vs per-event MILP (week trace)",
     "benchmarks.bench_engine"),
    ("tfwd", "Figs 7-9: forward-looking time", "benchmarks.bench_tfwd"),
    ("week", "Figs 10-11: weekly efficiency MILP vs heuristic",
     "benchmarks.bench_week"),
    ("objective", "Figs 12-13 + Tabs 3-4: objective metrics",
     "benchmarks.bench_objective"),
    ("workloads", "Scenario library: engine efficiency per workload profile",
     "benchmarks.bench_workloads"),
    ("objectives", "Policy portfolio: throughput-vs-fairness across scenarios",
     "benchmarks.bench_objectives"),
    ("runtime", "Live ControlLoop: real elastic trainers on a replayed trace",
     "benchmarks.bench_runtime"),
    ("pjmax", "Fig 14: max parallel Trainers", "benchmarks.bench_pjmax"),
    ("scalability", "Fig 15: per-DNN scalability", "benchmarks.bench_scalability"),
    ("rescale_cost", "Fig 16: rescale-cost sweep", "benchmarks.bench_rescale_cost"),
    ("throughput", "Tab 2 analog: model-zoo throughput", "benchmarks.bench_throughput"),
    ("kernels", "Pallas kernel micro-bench", "benchmarks.bench_kernels"),
]


def main() -> None:
    want = set(sys.argv[1:])
    t_start = time.time()
    for key, desc, mod_name in SECTIONS:
        if want and key not in want:
            continue
        print(f"# === {key}: {desc} ===", flush=True)
        t0 = time.time()
        mod = __import__(mod_name, fromlist=["main"])
        mod.main()
        print(f"# {key} done in {time.time()-t0:.1f}s", flush=True)
    print(f"# all benchmarks done in {time.time()-t_start:.1f}s")


if __name__ == "__main__":
    main()
