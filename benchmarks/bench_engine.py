"""Allocation-engine portfolio benchmark (EXPERIMENTS.md §Perf-Engine).

Replays a week-scale Summit-calibrated trace and compares four allocation
policies on total solver wall-time and delivered samples:

* ``node``      — per-event paper-faithful node-level MILP (baseline)
* ``fast``      — per-event aggregate MILP (DESIGN.md §2)
* ``engine``    — AllocationEngine (cache → greedy → fast MILP, DESIGN.md §3)
* ``engine+co`` — AllocationEngine plus 60 s event coalescing in the
                  simulator (DESIGN.md §3.4)

Acceptance target (ISSUE 1): engine solver wall-time ≥5× below per-event
node-MILP with delivered samples within 2%.
"""
from __future__ import annotations

from benchmarks.common import FULL, emit
from repro.core import (
    AllocationEngine,
    MILPAllocator,
    Simulator,
    TrainerJob,
    fragments_to_events,
    generate_summit_like,
    tab2_curve,
)
from repro.core.scaling import TAB2

DAYS = 7.0
COALESCE_S = 60.0


def week_trace(n_nodes: int, seed: int = 7):
    frags = generate_summit_like(n_nodes=n_nodes,
                                 duration=DAYS * 86400.0, seed=seed)
    return fragments_to_events(frags)


def jobs(n: int = 6, n_max: int = 16):
    names = list(TAB2)
    return [TrainerJob(id=i, curve=tab2_curve(names[i % len(names)]),
                       work=1e12, n_min=1, n_max=n_max, r_up=20.0, r_dw=5.0)
            for i in range(n)]


def main() -> None:
    n_nodes = 64 if FULL else 32
    events = week_trace(n_nodes)
    horizon = DAYS * 86400.0
    emit("engine/trace/events", len(events), f"{DAYS:.0f}d N={n_nodes}")

    runs = [
        ("node", MILPAllocator("node"), 0.0),
        ("fast", MILPAllocator("fast"), 0.0),
        ("engine", AllocationEngine(), 0.0),
        ("engine+co", AllocationEngine(), COALESCE_S),
    ]
    results = {}
    for name, alloc, window in runs:
        rep = Simulator(events, jobs(), alloc, t_fwd=120.0,
                        horizon=horizon, coalesce_window=window).run()
        results[name] = rep
        emit(f"engine/{name}/solver_wall_s", f"{rep.solver_wall_total:.3f}")
        emit(f"engine/{name}/samples", f"{rep.total_samples:.4e}")
        emit(f"engine/{name}/allocations", rep.events_processed)
        emit(f"engine/{name}/solver_ms_per_event",
             f"{rep.solver_wall_total / max(1, rep.events_processed) * 1e3:.2f}")
        if isinstance(alloc, AllocationEngine):
            st = alloc.stats
            emit(f"engine/{name}/cache_hit_rate",
                 f"{st.cache_hits / max(1, st.events):.3f}",
                 f"greedy={st.greedy_solves} fast={st.fast_milp_solves} "
                 f"fallback={st.fallbacks}")

    node, eng = results["node"], results["engine"]
    emit("engine/speedup_vs_node",
         f"{node.solver_wall_total / max(1e-9, eng.solver_wall_total):.1f}",
         "target >= 5")
    emit("engine/samples_vs_node",
         f"{eng.total_samples / max(1e-9, node.total_samples):.4f}",
         "target within 2% of 1.0")
    fast = results["fast"]
    emit("engine/speedup_vs_fast",
         f"{fast.solver_wall_total / max(1e-9, eng.solver_wall_total):.1f}")
    co = results["engine+co"]
    emit("engine/coalesce_speedup_vs_node",
         f"{node.solver_wall_total / max(1e-9, co.solver_wall_total):.1f}")
    emit("engine/coalesce_samples_vs_node",
         f"{co.total_samples / max(1e-9, node.total_samples):.4f}")


if __name__ == "__main__":
    main()
