"""Supercomputer-scale allocation sweep (ISSUE 5 / EXPERIMENTS.md §Scale).

Measures per-event solver wall on synthetic event-delta sequences at
Theta-class scales (up to 4,096 nodes × 64 Trainers), comparing

* **baseline** — the pre-PR-5 per-event solve: a fresh scalar-greedy
  solve (``solve_greedy(vectorize=False)``) plus the aggregate MILP
  whenever the engine's cost predictor admits it into the 50 ms budget
  (it does at the small tiers, and rules it out at 1024+ nodes) — no
  memoization, no repair: exactly what the PR-4 engine did per cache
  miss;
* **engine**   — ``AllocationEngine`` with the incremental warm-start
  repair and the vectorized value-table greedy (DESIGN.md §11).

Each sequence starts from a mid-size pool and applies random small
join/leave deltas, feeding every solver's own allocation back in as the
next event's current map — the steady-state replay access pattern.
Solution parity (relative objective gap between the two arms) is
reported alongside the speedup.

On top of the monolithic sweep, the **federated tier** (DESIGN.md §14)
shards the fleet into pools of 4,096 nodes × 64 Trainers — one
``AllocationEngine`` per pool behind a ``FederatedEngine`` — and
replays interleaved per-pool event streams at 16,384 (4 pools) and
65,536 (16 pools) fleet nodes.  Per-event decision latency is the
single-pool solve wall (pools are independent, so fleet size never
enters the per-event critical path); the comparison column is the
monolithic single-engine cost on the equivalent fleet-sized problem,
measured directly up to 16,384 × 256 and extrapolated O(N·J) from the
largest measured tier beyond that (a 65,536 × 1,024 value table alone
is ~0.5 GB — the point of federation is that nobody should build it).

``--smoke`` runs the two small tiers plus the 16k federated point
(CI); the full sweep includes the 4,096 × 64 monolithic tier and the
65k federated point.  With ``--json`` / ``benchmarks.run --json`` the
sweep persists ``BENCH_allocator.json`` (schema
``bftrainer-bench-allocator/3``).
"""
from __future__ import annotations

import sys
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import SMOKE, emit, maybe_write_json
from benchmarks.schema import ALLOCATOR_SCHEMA, bench_payload
from repro.core import AllocationEngine
from repro.core.engine import _est_fast_milp
from repro.core.greedy import solve_greedy
from repro.core.milp import AllocationProblem, TrainerSpec
from repro.core.milp_fast import solve_fast_milp
from repro.core.scaling import amdahl_curve
from repro.federation import FederatedEngine, PoolMap

SWEEP = [(256, 16), (1024, 32), (4096, 64)]
SWEEP_SMOKE = [(128, 8), (256, 16)]

#: federated tier: (fleet nodes, pools) at a fixed 4,096-node /
#: 64-Trainer pool shape — the per-pool problem stays constant while
#: the fleet grows with the pool count.
JOBS_PER_POOL = 64
FED_SWEEP = [(16384, 4), (65536, 16)]
FED_SWEEP_SMOKE = [(16384, 4)]
#: largest monolithic fleet-sized problem we measure directly; beyond
#: it the monolithic column is extrapolated O(N·J) from this tier.
MONO_CAP = 16384 * 256


def _trainers(n_nodes: int, n_jobs: int, rng) -> List[TrainerSpec]:
    out = []
    for j in range(n_jobs):
        curve = amdahl_curve(f"m{j}", 1000.0 * rng.uniform(0.5, 2.0),
                             rng.uniform(0.1, 0.4), max_nodes=256)
        n_min = int(rng.randint(1, 4))
        n_max = int(rng.randint(16, max(17, min(256, n_nodes // 4))))
        pts, vals = curve.breakpoints(n_min, n_max)
        out.append(TrainerSpec(id=j, n_min=n_min, n_max=n_max,
                               r_up=float(rng.uniform(5, 40)),
                               r_dw=float(rng.uniform(1, 10)),
                               points=tuple(pts), values=tuple(vals)))
    return out


def _event_sequence(n_nodes: int, n_jobs: int, n_events: int, seed: int):
    """Yield (nodes, trainers) per event: a pool starting at ~0.75·|N|
    with small random join/leave deltas — the unfillable-hole churn."""
    rng = np.random.RandomState(seed)
    trainers = _trainers(n_nodes, n_jobs, rng)
    pool = set(range(int(0.75 * n_nodes)))
    seqs = []
    for _ in range(n_events):
        joins = int(rng.randint(0, max(2, n_nodes // 64)))
        leaves = int(rng.randint(0, max(2, len(pool) // 64)))
        for nid in rng.choice(sorted(set(range(n_nodes)) - pool),
                              size=min(joins, n_nodes - len(pool)),
                              replace=False):
            pool.add(int(nid))
        for nid in rng.choice(sorted(pool), size=min(leaves, len(pool)),
                              replace=False):
            pool.discard(int(nid))
        seqs.append(sorted(pool))
    return trainers, seqs


def _run_arm(trainers, seqs, solve, currents=None) -> Dict:
    """Replay the sequence; returns per-event walls + objectives.

    Without ``currents`` each allocation feeds back as the next event's
    current map (self-consistent trajectory) and the maps used are
    recorded; with ``currents`` the recorded maps are replayed instead,
    so a second arm solves the *identical* problem instances and the
    objective gap is true per-event solution parity.
    """
    current: Dict[int, List[int]] = {}
    walls, objs, used = [], [], []
    for i, nodes in enumerate(seqs):
        if currents is not None:
            current = currents[i]
        used.append({j: list(ns) for j, ns in current.items()})
        prob = AllocationProblem(nodes=list(nodes), trainers=trainers,
                                 current=current, t_fwd=120.0)
        t0 = time.perf_counter()
        res = solve(prob)
        walls.append(time.perf_counter() - t0)
        objs.append(res.objective)
        current = {j: list(ns) for j, ns in res.allocation.items()}
    return dict(walls=np.array(walls) * 1e3, objs=objs, currents=used)


def _monolithic_p99(n_nodes: int, n_jobs: int, n_events: int) -> float:
    """Measured per-event engine p99 (ms) on one fleet-sized monolithic
    problem — the federated tier's comparison column."""
    trainers, seqs = _event_sequence(n_nodes, n_jobs, n_events, seed=7)
    engine = AllocationEngine()
    res = _run_arm(trainers, seqs, engine.allocate)
    return float(np.percentile(res["walls"], 99))


def _federated_tier(n_fleet: int, n_pools: int, n_events: int) -> Dict:
    """Replay ``n_events`` interleaved join/leave deltas per pool
    through a ``FederatedEngine``; every pool owns a disjoint
    4,096-node slice with its own Trainer population and feedback
    trajectory, and the recorded wall per event is the one-pool solve
    the fleet actually waits on."""
    per_pool = n_fleet // n_pools
    fed = FederatedEngine(PoolMap.contiguous(n_fleet, n_pools))
    pools = []
    for k in range(n_pools):
        trainers, seqs = _event_sequence(per_pool, JOBS_PER_POOL,
                                         n_events, seed=7 + k)
        off = k * per_pool
        seqs = [[nid + off for nid in s] for s in seqs]
        pools.append(dict(trainers=trainers, seqs=seqs, current={}))
    walls = []
    for i in range(n_events):
        for k, p in enumerate(pools):
            prob = AllocationProblem(nodes=list(p["seqs"][i]),
                                     trainers=p["trainers"],
                                     current=p["current"], t_fwd=120.0)
            t0 = time.perf_counter()
            res = fed.allocate(k, prob)
            walls.append(time.perf_counter() - t0)
            p["current"] = {j: list(ns)
                            for j, ns in res.allocation.items()}
    stats = fed.stats()
    return dict(walls=np.array(walls) * 1e3,
                cache_hit_rate=stats.cache_hits / max(stats.events, 1),
                repair_rate=stats.repairs / max(stats.events, 1))


def main() -> None:
    smoke = SMOKE or "--smoke" in sys.argv[1:]
    tiers = SWEEP_SMOKE if smoke else SWEEP
    payload = bench_payload(ALLOCATOR_SCHEMA)
    payload["sweep"] = []
    for n_nodes, n_jobs in tiers:
        # enough events to exercise cache/repair, few enough that the
        # scalar baseline stays affordable at the 4,096 tier
        n_events = 12 if smoke else (20 if n_nodes >= 4096 else 40)
        trainers, seqs = _event_sequence(n_nodes, n_jobs, n_events, seed=7)

        def pr4_solve(p):
            """PR-4 per-cache-miss portfolio: scalar greedy, then the
            aggregate MILP when the cost predictor fits the budget."""
            r = solve_greedy(p, vectorize=False)
            if _est_fast_milp(len(p.nodes), len(p.trainers)) <= 0.050:
                rm = solve_fast_milp(p, time_limit=2.0)
                if rm.objective is not None and (
                        r.objective is None or rm.objective > r.objective):
                    r = rm
            return r

        base = _run_arm(trainers, seqs, pr4_solve)
        engine = AllocationEngine()
        eng = _run_arm(trainers, seqs, engine.allocate,
                       currents=base["currents"])

        # parity: relative objective gap wherever both arms scored
        gaps = [abs(a - b) / max(1.0, abs(b))
                for a, b in zip(eng["objs"], base["objs"])
                if a is not None and b is not None]
        row = dict(
            nodes=n_nodes, jobs=n_jobs, policy="throughput",
            events=n_events,
            baseline_per_event_ms_p50=float(np.percentile(base["walls"], 50)),
            baseline_per_event_ms_p95=float(np.percentile(base["walls"], 95)),
            baseline_per_event_ms_p99=float(np.percentile(base["walls"], 99)),
            engine_per_event_ms_p50=float(np.percentile(eng["walls"], 50)),
            engine_per_event_ms_p95=float(np.percentile(eng["walls"], 95)),
            engine_per_event_ms_p99=float(np.percentile(eng["walls"], 99)),
            speedup_p50=float(np.percentile(base["walls"], 50)
                              / max(np.percentile(eng["walls"], 50), 1e-6)),
            cache_hit_rate=engine.stats.cache_hits
            / max(engine.stats.events, 1),
            repair_rate=engine.stats.repairs / max(engine.stats.events, 1),
            parity_max_rel_gap=float(max(gaps)) if gaps else 0.0,
        )
        payload["sweep"].append(row)
        emit(f"scale/{n_nodes}x{n_jobs}/baseline_ms_p50",
             f"{row['baseline_per_event_ms_p50']:.2f}", "scalar fresh solve")
        emit(f"scale/{n_nodes}x{n_jobs}/engine_ms_p50",
             f"{row['engine_per_event_ms_p50']:.2f}", "incremental engine")
        emit(f"scale/{n_nodes}x{n_jobs}/speedup_p50",
             f"{row['speedup_p50']:.1f}", "target >= 10x at 4096")
        emit(f"scale/{n_nodes}x{n_jobs}/parity_max_rel_gap",
             f"{row['parity_max_rel_gap']:.2e}", "")
        emit(f"scale/{n_nodes}x{n_jobs}/repair_rate",
             f"{row['repair_rate']:.2f}", "")

    # --- federated tier: sharded engines at 16k/65k fleet nodes ------
    payload["federated"] = []
    fed_tiers = FED_SWEEP_SMOKE if smoke else FED_SWEEP
    # one measured monolithic anchor at the largest affordable
    # fleet-sized problem; larger tiers extrapolate O(N·J) from it
    anchor_nodes, anchor_jobs = 16384, 256
    anchor_events = 6 if smoke else 8
    anchor_p99 = _monolithic_p99(anchor_nodes, anchor_jobs, anchor_events)
    for n_fleet, n_pools in fed_tiers:
        n_events = 6 if smoke else (8 if n_fleet >= 65536 else 10)
        fed = _federated_tier(n_fleet, n_pools, n_events)
        n_jobs_fleet = n_pools * JOBS_PER_POOL
        extrapolated = n_fleet * n_jobs_fleet > MONO_CAP
        if extrapolated:
            mono_p99 = anchor_p99 * (n_fleet * n_jobs_fleet
                                     / (anchor_nodes * anchor_jobs))
        elif (n_fleet, n_jobs_fleet) == (anchor_nodes, anchor_jobs):
            mono_p99 = anchor_p99
        else:
            mono_p99 = _monolithic_p99(n_fleet, n_jobs_fleet,
                                       anchor_events)
        fed_p99 = float(np.percentile(fed["walls"], 99))
        row = dict(
            nodes=n_fleet, jobs=n_jobs_fleet, pools=n_pools,
            events=n_events * n_pools,
            decision_ms_p50=float(np.percentile(fed["walls"], 50)),
            decision_ms_p95=float(np.percentile(fed["walls"], 95)),
            decision_ms_p99=fed_p99,
            monolithic_ms_p99=float(mono_p99),
            monolithic_extrapolated=extrapolated,
            speedup_p99_vs_monolithic=float(mono_p99 / max(fed_p99, 1e-6)),
            cache_hit_rate=float(fed["cache_hit_rate"]),
            repair_rate=float(fed["repair_rate"]),
        )
        payload["federated"].append(row)
        tag = f"scale/fed/{n_fleet}x{n_pools}p"
        emit(f"{tag}/decision_ms_p50", f"{row['decision_ms_p50']:.2f}",
             "per-pool solve wall")
        emit(f"{tag}/decision_ms_p99", f"{row['decision_ms_p99']:.2f}", "")
        emit(f"{tag}/monolithic_ms_p99", f"{row['monolithic_ms_p99']:.1f}",
             "extrapolated O(N*J)" if extrapolated else "measured")
        emit(f"{tag}/speedup_p99_vs_monolithic",
             f"{row['speedup_p99_vs_monolithic']:.1f}",
             "target >= 5x at 65536")
    maybe_write_json("BENCH_allocator.json", payload)


if __name__ == "__main__":
    if "--json" in sys.argv[1:]:
        import os
        os.environ.setdefault("BENCH_JSON_DIR", ".")
    main()
