"""Stable schemas for the persistent perf-trajectory JSONs.

``benchmarks.run --json`` (and the individual benchmarks) write
``BENCH_week.json`` / ``BENCH_allocator.json`` with the keys declared
here; CI uploads them as artifacts so per-commit perf trajectories are
comparable across PRs.  EXPERIMENTS.md §Scale documents the same keys,
and ``scripts/check_docs.py`` cross-validates docs ↔ this module ↔ any
JSON present on disk — a key can only be added or renamed by touching
all three, which is what keeps the trajectory machine-readable over
time.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

WEEK_SCHEMA = "bftrainer-bench-week/2"
ALLOCATOR_SCHEMA = "bftrainer-bench-allocator/3"
CHAOS_SCHEMA = "bftrainer-bench-chaos/2"
OBJECTIVES_SCHEMA = "bftrainer-bench-objectives/1"
SCALABILITY_SCHEMA = "bftrainer-bench-scalability/1"
SERVING_SCHEMA = "bftrainer-bench-serving/1"
RESILIENCE_SCHEMA = "bftrainer-bench-resilience/1"

#: BENCH_week.json — one week-trace replay, engine vs the PR-4 baseline
#: (per-event aggregate MILP), both measured in the same run.
WEEK_KEYS = ["schema", "generated_unix", "trace", "arms",
             "speedup_end_to_end", "speedup_solver_wall"]
WEEK_TRACE_KEYS = ["n_nodes", "hours", "seed", "n_events"]
WEEK_ARM_KEYS = ["allocator", "wall_s", "solver_wall_s",
                 "solver_wall_p50_ms", "solver_wall_p95_ms",
                 "solver_wall_p99_ms",
                 "efficiency_u", "samples", "events_processed"]

#: BENCH_allocator.json — the nodes × jobs scale sweep: per-event solve
#: wall of the incremental/vectorized engine vs the pre-PR-5 scalar
#: fresh-solve baseline, plus hit rates and solution parity.  Since /3
#: the payload also carries the ``federated`` tier (DESIGN.md §14):
#: sharded per-pool engines replaying interleaved pool-event streams at
#: 16k/65k fleet nodes, compared against the monolithic single-engine
#: per-event cost (measured up to 16,384 × 256, extrapolated O(N·J)
#: beyond — ``monolithic_extrapolated`` flags which).
ALLOCATOR_KEYS = ["schema", "generated_unix", "sweep", "federated"]
ALLOCATOR_ROW_KEYS = ["nodes", "jobs", "policy", "events",
                      "baseline_per_event_ms_p50",
                      "baseline_per_event_ms_p95",
                      "baseline_per_event_ms_p99",
                      "engine_per_event_ms_p50", "engine_per_event_ms_p95",
                      "engine_per_event_ms_p99",
                      "speedup_p50", "cache_hit_rate", "repair_rate",
                      "parity_max_rel_gap"]
FEDERATED_ROW_KEYS = ["nodes", "jobs", "pools", "events",
                      "decision_ms_p50", "decision_ms_p95",
                      "decision_ms_p99", "monolithic_ms_p99",
                      "monolithic_extrapolated",
                      "speedup_p99_vs_monolithic",
                      "cache_hit_rate", "repair_rate"]

#: BENCH_chaos.json — the fault-injection MTBF sweep on the ``flaky``
#: chaos scenario: efficiency retention under node kills, drains,
#: corrupt checkpoint restores and allocator crash/restart.
CHAOS_KEYS = ["schema", "generated_unix", "scenario", "scale", "seed",
              "u_clean", "sweep"]
CHAOS_ROW_KEYS = ["mtbf_h", "u_chaos", "u_raw", "kills", "drains",
                  "corrupt_restores", "allocator_restarts",
                  "recovered_cache_entries", "lost_progress_frac",
                  "events", "decision_ms_p50", "decision_ms_p95",
                  "decision_ms_p99"]

#: BENCH_objectives.json — the policy portfolio sweep (Figs 12-13 +
#: Tabs 3-4): per scenario × policy efficiency/fairness/deadline rows,
#: plus the throughput-vs-efficiency metric arms on the diverse-DNN
#: trace (the legacy ``bench_objective`` fig-12/13 measurement, folded
#: in here when it moved onto the JSON path).
OBJECTIVES_KEYS = ["schema", "generated_unix", "scale", "policies",
                   "metrics"]
OBJECTIVES_POLICY_ROW_KEYS = ["scenario", "policy", "efficiency_u",
                              "jain_fairness", "min_norm_progress",
                              "deadline_miss_rate", "solver_wall_s",
                              "cache_hit_rate"]
OBJECTIVES_METRIC_ROW_KEYS = ["metric", "total_samples",
                              "rescale_cost_samples", "runtime_spread"]

#: BENCH_scalability.json — paper Fig 15: HPO efficiency U per Tab-2
#: DNN scalability class on the same unfillable-hole trace.
SCALABILITY_KEYS = ["schema", "generated_unix", "trace", "rows"]
SCALABILITY_TRACE_KEYS = ["n_nodes", "hours", "seed"]
SCALABILITY_ROW_KEYS = ["dnn", "efficiency_u"]

#: BENCH_serving.json — the elastic serving tier (DESIGN.md §15): each
#: serving scenario replayed on harvested holes under the latency_slo
#: policy vs the same demand on a static, peak-provisioned dedicated
#: pool.  ``attainment_vs_dedicated`` (elastic SLO attainment /
#: dedicated SLO attainment) is the headline; the CI floor is >= 0.9.
SERVING_KEYS = ["schema", "generated_unix", "scale", "seed", "scenarios"]
SERVING_ROW_KEYS = ["scenario", "n_nodes", "hours", "services",
                    "requests", "requests_per_sec", "served_frac",
                    "dropped_frac", "latency_ms_p50", "latency_ms_p95",
                    "latency_ms_p99", "slo_attainment",
                    "dedicated_nodes", "dedicated_slo_attainment",
                    "attainment_vs_dedicated", "events",
                    "decision_ms_p50", "decision_ms_p95",
                    "decision_ms_p99"]


#: BENCH_resilience.json — the self-healing control-plane sweeps
#: (DESIGN.md §16): efficiency retention under event-stream corruption
#: repaired by hygiene + anti-entropy (CI floor: ``u_frac_of_clean`` >=
#: 0.85 at 1% corruption), and the hard-deadline degradation ladder
#: (CI asserts ``within_deadline_frac`` == 1.0 on every row).
RESILIENCE_KEYS = ["schema", "generated_unix", "scenario", "scale",
                   "seed", "u_clean", "corruption", "deadline"]
RESILIENCE_CORRUPTION_ROW_KEYS = ["corrupt_prob", "u", "u_frac_of_clean",
                                  "divergence_frac", "max_lag_s",
                                  "defects", "duplicates_dropped",
                                  "late_dropped", "phantom_joins",
                                  "orphan_leaves", "repair_events",
                                  "reconciles", "events"]
RESILIENCE_DEADLINE_ROW_KEYS = ["deadline_ms", "u", "u_frac_of_ref",
                                "within_deadline_frac", "deadline_hits",
                                "rung_cache", "rung_repair",
                                "rung_greedy", "rung_milp",
                                "rung_project", "rung_equal", "upgrades",
                                "events", "decision_ms_p99"]


def bench_payload(schema: str) -> Dict:
    return {"schema": schema, "generated_unix": time.time()}


def write_bench_json(path: str, payload: Dict) -> None:
    errors = validate_bench_payload(payload)
    if errors:
        raise ValueError(f"refusing to write non-conforming {path}: {errors}")
    with open(path, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def validate_bench_payload(payload: Dict) -> List[str]:
    """Schema check for a bench JSON payload; returns human-readable
    failures (empty list = conforming)."""
    errors: List[str] = []

    def need(obj: Dict, keys: List[str], where: str) -> None:
        for k in keys:
            if k not in obj:
                errors.append(f"{where}: missing key {k!r}")

    schema = payload.get("schema")
    if schema == WEEK_SCHEMA:
        need(payload, WEEK_KEYS, "week")
        need(payload.get("trace", {}), WEEK_TRACE_KEYS, "week.trace")
        arms = payload.get("arms", {})
        if not isinstance(arms, dict) or not arms:
            errors.append("week.arms: expected a non-empty mapping")
        else:
            for name, arm in arms.items():
                need(arm, WEEK_ARM_KEYS, f"week.arms[{name}]")
    elif schema == ALLOCATOR_SCHEMA:
        need(payload, ALLOCATOR_KEYS, "allocator")
        rows = payload.get("sweep", [])
        if not isinstance(rows, list) or not rows:
            errors.append("allocator.sweep: expected a non-empty list")
        else:
            for i, row in enumerate(rows):
                need(row, ALLOCATOR_ROW_KEYS, f"allocator.sweep[{i}]")
        fed = payload.get("federated", [])
        if not isinstance(fed, list) or not fed:
            errors.append("allocator.federated: expected a non-empty list")
        else:
            for i, row in enumerate(fed):
                need(row, FEDERATED_ROW_KEYS, f"allocator.federated[{i}]")
    elif schema == OBJECTIVES_SCHEMA:
        need(payload, OBJECTIVES_KEYS, "objectives")
        rows = payload.get("policies", [])
        if not isinstance(rows, list) or not rows:
            errors.append("objectives.policies: expected a non-empty list")
        else:
            for i, row in enumerate(rows):
                need(row, OBJECTIVES_POLICY_ROW_KEYS,
                     f"objectives.policies[{i}]")
        rows = payload.get("metrics", [])
        if not isinstance(rows, list) or not rows:
            errors.append("objectives.metrics: expected a non-empty list")
        else:
            for i, row in enumerate(rows):
                need(row, OBJECTIVES_METRIC_ROW_KEYS,
                     f"objectives.metrics[{i}]")
    elif schema == SCALABILITY_SCHEMA:
        need(payload, SCALABILITY_KEYS, "scalability")
        need(payload.get("trace", {}), SCALABILITY_TRACE_KEYS,
             "scalability.trace")
        rows = payload.get("rows", [])
        if not isinstance(rows, list) or not rows:
            errors.append("scalability.rows: expected a non-empty list")
        else:
            for i, row in enumerate(rows):
                need(row, SCALABILITY_ROW_KEYS, f"scalability.rows[{i}]")
    elif schema == CHAOS_SCHEMA:
        need(payload, CHAOS_KEYS, "chaos")
        rows = payload.get("sweep", [])
        if not isinstance(rows, list) or not rows:
            errors.append("chaos.sweep: expected a non-empty list")
        else:
            for i, row in enumerate(rows):
                need(row, CHAOS_ROW_KEYS, f"chaos.sweep[{i}]")
    elif schema == SERVING_SCHEMA:
        need(payload, SERVING_KEYS, "serving")
        rows = payload.get("scenarios", [])
        if not isinstance(rows, list) or not rows:
            errors.append("serving.scenarios: expected a non-empty list")
        else:
            for i, row in enumerate(rows):
                need(row, SERVING_ROW_KEYS, f"serving.scenarios[{i}]")
    elif schema == RESILIENCE_SCHEMA:
        need(payload, RESILIENCE_KEYS, "resilience")
        rows = payload.get("corruption", [])
        if not isinstance(rows, list) or not rows:
            errors.append("resilience.corruption: expected a non-empty list")
        else:
            for i, row in enumerate(rows):
                need(row, RESILIENCE_CORRUPTION_ROW_KEYS,
                     f"resilience.corruption[{i}]")
        rows = payload.get("deadline", [])
        if not isinstance(rows, list) or not rows:
            errors.append("resilience.deadline: expected a non-empty list")
        else:
            for i, row in enumerate(rows):
                need(row, RESILIENCE_DEADLINE_ROW_KEYS,
                     f"resilience.deadline[{i}]")
    else:
        errors.append(f"unknown schema {schema!r} (expected {WEEK_SCHEMA!r}, "
                      f"{ALLOCATOR_SCHEMA!r}, {CHAOS_SCHEMA!r}, "
                      f"{OBJECTIVES_SCHEMA!r}, {SCALABILITY_SCHEMA!r}, "
                      f"{SERVING_SCHEMA!r} or {RESILIENCE_SCHEMA!r})")
    return errors


def validate_bench_file(path: str) -> List[str]:
    try:
        with open(path, encoding="utf-8") as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as exc:
        return [f"{path}: unreadable: {exc}"]
    return [f"{path}: {e}" for e in validate_bench_payload(payload)]
