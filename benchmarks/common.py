"""Shared benchmark utilities.

Every benchmark prints ``name,value,derived`` CSV rows (one per measured
quantity) so ``benchmarks.run`` output is machine-parsable.  Scales are
reduced vs the paper's week-long replays (CPU container); set
``BENCH_FULL=1`` for the larger variants.
"""
from __future__ import annotations

import os
import time
from functools import lru_cache
from typing import List

from repro.core import (
    MILPAllocator,
    Simulator,
    TrainerJob,
    eq_nodes,
    fragments_to_events,
    generate_summit_like,
    static_outcome,
    tab2_curve,
)
from repro.core.scaling import TAB2

FULL = bool(int(os.environ.get("BENCH_FULL", "0")))
# BENCH_SMOKE=1 (or --smoke on the individual benchmarks): CI-scale runs
SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))


def emit(name: str, value, derived: str = "") -> None:
    print(f"{name},{value},{derived}", flush=True)


def json_dir():
    """Directory for persistent BENCH_*.json payloads, or ``None``.

    Set by ``benchmarks.run --json [dir]`` (via BENCH_JSON_DIR) — when
    unset the benchmarks print CSV only and persist nothing.
    """
    return os.environ.get("BENCH_JSON_DIR") or None


def maybe_write_json(filename: str, payload) -> None:
    """Write a schema-validated bench JSON into ``json_dir()`` (no-op
    when JSON output is not requested)."""
    d = json_dir()
    if d is None:
        return
    from benchmarks.schema import write_bench_json
    path = os.path.join(d, filename)
    write_bench_json(path, payload)
    emit(f"json/{filename}", path, "persistent perf trajectory")


@lru_cache(maxsize=8)
def trace(n_nodes: int = 160, hours: float = 24.0, seed: int = 21):
    frags = generate_summit_like(n_nodes=n_nodes, duration=hours * 3600.0,
                                 seed=seed)
    return tuple(fragments_to_events(frags))


def hpo_jobs(n: int = 8, dnn: str = "ShuffleNet", work: float = 1e12,
             n_max: int = 24, metric: str = "throughput",
             r_scale: float = 1.0) -> List[TrainerJob]:
    curve = tab2_curve(dnn)
    return [TrainerJob(id=i, curve=curve, work=work, n_min=1, n_max=n_max,
                       r_up=20.0 * r_scale, r_dw=5.0 * r_scale,
                       metric=metric) for i in range(n)]


def diverse_jobs(n: int = 21, work: float = 2e8, metric: str = "throughput",
                 arrival_rate: float = 1 / 1800.0, seed: int = 0
                 ) -> List[TrainerJob]:
    """Paper §5.2: Trainer DNNs cycled from Tab 2, Poisson arrivals."""
    import numpy as np
    rng = np.random.default_rng(seed)
    names = list(TAB2)
    jobs, t = [], 0.0
    for i in range(n):
        name = names[i % len(names)]
        t += float(rng.exponential(1.0 / arrival_rate))
        jobs.append(TrainerJob(id=i, curve=tab2_curve(name), work=work,
                               n_min=1, n_max=24, r_up=20.0, r_dw=5.0,
                               arrival=t, metric=metric))
    return jobs


def efficiency(events, jobs_fn, horizon: float, allocator=None,
               t_fwd: float = 120.0, pj_max: int = 10):
    rep, u, _ = efficiency_timed(events, jobs_fn, horizon, allocator,
                                 t_fwd=t_fwd, pj_max=pj_max)
    return rep, u


def efficiency_timed(events, jobs_fn, horizon: float, allocator=None,
                     t_fwd: float = 120.0, pj_max: int = 10):
    """Like :func:`efficiency` but also returns the *replay* wall time
    (the elastic Simulator run only — the static-baseline denominator is
    excluded so arm timings compare allocators, not the shared A_s)."""
    allocator = allocator or MILPAllocator("fast")
    t0 = time.perf_counter()
    rep = Simulator(list(events), jobs_fn(), allocator, t_fwd=t_fwd,
                    pj_max=pj_max, horizon=horizon).run()
    wall = time.perf_counter() - t0
    n_eq = max(1, round(eq_nodes(list(events), 0.0, horizon)))
    a_s = static_outcome(jobs_fn(), n_eq, horizon, MILPAllocator("fast"),
                         pj_max=pj_max)
    return rep, (rep.total_samples / a_s if a_s > 0 else 0.0), wall
