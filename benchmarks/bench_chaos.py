"""Chaos resilience sweep: efficiency retention under injected faults.

Replays the ``flaky`` chaos scenario (capacity trace + node kills,
drains, corrupt checkpoint restores and allocator crash/restart from
DESIGN.md §12) across a node-MTBF sweep and reports two efficiencies:

- ``u_chaos`` — A_e against the *achievable* static baseline, i.e.
  eq-nodes computed on the fault-reduced trace.  This measures
  allocation quality on the capacity that actually survived; the
  allocator is not billed for node-time destroyed by hardware.
- ``u_raw`` — the same A_e against the clean trace's baseline, so the
  gap ``u_clean - u_raw`` is the total cost of the faults (destroyed
  capacity + rollbacks + restart penalties).

The headline acceptance bar is ``u_chaos >= 0.80`` at MTBF = 4 h.

``--smoke`` (or ``BENCH_SMOKE=1``) shrinks the trace for CI.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
from typing import Sequence

import numpy as np

from benchmarks.common import FULL, diverse_jobs, emit, maybe_write_json
from benchmarks.schema import CHAOS_SCHEMA, bench_payload
from repro.chaos import run_chaos
from repro.core import (
    AllocationEngine,
    MILPAllocator,
    Simulator,
    eq_nodes,
    fragments_to_events,
    static_outcome,
)
from repro.sched import build_scenario

MTBF_HOURS = (1.0, 2.0, 4.0, 8.0)
#: checkpoint lattice used for the sweep — coarse enough that rollbacks
#: cost real progress, fine enough that a kill never erases a whole run
CKPT_EVERY = 5e6


def _decision_ms(stats):
    """(p50, p95, p99) decision latency in ms from the replay's records."""
    walls = np.array([r.solver_wall for r in stats.event_records
                      if r.solver_wall > 0.0]) * 1e3
    if not len(walls):
        return 0.0, 0.0, 0.0
    return tuple(float(np.percentile(walls, q)) for q in (50, 95, 99))


def _static_baseline(events, jobs_fn, horizon: float) -> float:
    n_eq = max(1, round(eq_nodes(list(events), 0.0, horizon)))
    return static_outcome(jobs_fn(), n_eq, horizon, MILPAllocator("fast"),
                          pj_max=10)


def run_sweep(scale: float, seed: int = 7, scenario: str = "flaky") -> None:
    sc = build_scenario(scenario, scale=scale, seed=seed)
    events = fragments_to_events(sc.fragments)
    n_jobs = max(4, int(round(sc.stats.eq_nodes / 3)))
    jobs_fn = lambda: diverse_jobs(n=n_jobs, work=1e12, seed=seed)

    a_s = _static_baseline(events, jobs_fn, sc.duration)
    clean = Simulator(list(events), jobs_fn(), AllocationEngine(),
                      t_fwd=120.0, pj_max=10, horizon=sc.duration).run()
    u_clean = clean.total_samples / a_s if a_s > 0 else 0.0
    emit(f"chaos/{scenario}/n_nodes", sc.n_nodes)
    emit(f"chaos/{scenario}/hours", f"{sc.duration / 3600.0:.1f}")
    emit(f"chaos/{scenario}/u_clean", f"{u_clean:.3f}",
         "fault-free replay vs dedicated eq-nodes")

    payload = bench_payload(CHAOS_SCHEMA)
    payload.update(scenario=scenario, scale=scale, seed=seed,
                   u_clean=u_clean, sweep=[])
    for mtbf_h in MTBF_HOURS:
        spec = dataclasses.replace(sc.chaos, mtbf=mtbf_h * 3600.0,
                                   ckpt_every=CKPT_EVERY)
        rep = run_chaos(list(events), jobs_fn(), spec, horizon=sc.duration)
        a_s_chaos = _static_baseline(rep.events, jobs_fn, sc.duration)
        samples = rep.stats.total_samples
        u_chaos = samples / a_s_chaos if a_s_chaos > 0 else 0.0
        u_raw = samples / a_s if a_s > 0 else 0.0
        lost_frac = rep.stats.lost_progress / samples if samples > 0 else 0.0
        p50, p95, p99 = _decision_ms(rep.stats)
        row = {
            "mtbf_h": mtbf_h,
            "u_chaos": u_chaos,
            "u_raw": u_raw,
            "kills": rep.n_kills,
            "drains": len(rep.schedule.drains),
            "corrupt_restores": rep.corrupt_restores,
            "allocator_restarts": rep.allocator_restarts,
            "recovered_cache_entries": rep.recovered_cache_entries,
            "lost_progress_frac": lost_frac,
            "events": rep.stats.events_processed,
            "decision_ms_p50": p50,
            "decision_ms_p95": p95,
            "decision_ms_p99": p99,
        }
        payload["sweep"].append(row)
        tag = f"chaos/{scenario}/mtbf_{mtbf_h:g}h"
        emit(f"{tag}/u_chaos", f"{u_chaos:.3f}",
             "vs achievable (fault-reduced) baseline")
        emit(f"{tag}/u_raw", f"{u_raw:.3f}", "vs clean-trace baseline")
        emit(f"{tag}/kills", rep.n_kills)
        emit(f"{tag}/corrupt_restores", rep.corrupt_restores)
        emit(f"{tag}/allocator_restarts", rep.allocator_restarts)
        emit(f"{tag}/recovered_cache_entries", rep.recovered_cache_entries)
        emit(f"{tag}/lost_progress_frac", f"{lost_frac:.4f}")
    maybe_write_json("BENCH_chaos.json", payload)


def main(argv: Sequence[str] = ()) -> None:
    # default () — benchmarks.run calls main() with section names still in
    # sys.argv, so only the __main__ guard forwards the real CLI args
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace for CI smoke runs")
    args = ap.parse_args(argv)
    smoke = args.smoke or bool(int(os.environ.get("BENCH_SMOKE", "0")))
    scale = 0.15 if smoke else (1.0 if FULL else 0.5)
    run_sweep(scale)


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
