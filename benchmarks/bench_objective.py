"""Paper Figs. 12-13 + Tabs. 3-4: diverse Trainers under different
objective metrics (throughput vs scaling efficiency) — fairness and U."""
from __future__ import annotations

from collections import defaultdict

import numpy as np

from benchmarks.common import FULL, diverse_jobs, emit, trace
from repro.core import MILPAllocator, Simulator, eq_nodes, static_outcome


def main() -> None:
    hours = 48.0 if FULL else 24.0
    ev = trace(n_nodes=160, hours=hours, seed=44)
    horizon = hours * 3600.0
    n_jobs = 42 if FULL else 21
    for metric in ("throughput", "efficiency"):
        jobs = diverse_jobs(n=n_jobs, metric=metric)
        rep = Simulator(list(ev), jobs, MILPAllocator("fast"), t_fwd=120.0,
                        pj_max=10, horizon=horizon).run()
        runtimes = defaultdict(list)
        for j in jobs:
            if j.finished_at is not None:
                runtimes[j.curve.name].append(
                    (j.finished_at - j.arrival) / 3600.0)
        for dnn, rts in sorted(runtimes.items()):
            emit(f"objective/{metric}/{dnn}/runtime_h",
                 f"{np.mean(rts):.2f}", "fig12")
        if runtimes:
            means = [np.mean(v) for v in runtimes.values()]
            emit(f"objective/{metric}/runtime_spread",
                 f"{max(means)/max(min(means),1e-9):.1f}",
                 "fig12: throughput metric starves compute-heavy DNNs")
        emit(f"objective/{metric}/total_samples",
             f"{rep.total_samples:.3e}", "fig13 proxy")
        emit(f"objective/{metric}/rescale_cost_samples",
             f"{rep.rescale_cost_samples:.3e}", "")


if __name__ == "__main__":
    main()
