"""Scenario sweep: the AllocationEngine portfolio across the workload
library (repro/sched/scenarios.py).

For every scenario the batch-scheduler simulation emits its
unfillable-hole trace; we replay it in the BFTrainer ``Simulator`` under
each allocator and report utilization efficiency U = A_e / A_s against
the dedicated-eq-nodes baseline (paper §4.1.2), plus solver wall time
and engine cache behavior — how per-event allocation cost holds up as
trace churn varies (MalleTrain's sensitivity axis).

``--smoke`` (or ``BENCH_SMOKE=1``) shrinks every scenario for CI.
"""
from __future__ import annotations

import argparse
import os
from typing import Sequence

from benchmarks.common import FULL, diverse_jobs, efficiency, emit
from repro.core import AllocationEngine, EqualShareAllocator, \
    fragments_to_events
from repro.sched import SCENARIOS, build_scenario


def _allocators():
    return (
        ("engine", lambda: AllocationEngine(time_budget=0.050)),
        ("equal-share", lambda: EqualShareAllocator()),
    )


def run_scenario(name: str, scale: float, seed: int = 7,
                 t_fwd: float = 120.0) -> None:
    sc = build_scenario(name, scale=scale, seed=seed)
    st = sc.stats
    emit(f"workloads/{name}/n_nodes", sc.n_nodes)
    emit(f"workloads/{name}/fragments", st.n_fragments)
    emit(f"workloads/{name}/events_per_hour", f"{st.events_per_hour:.1f}")
    emit(f"workloads/{name}/idle_fraction", f"{st.idle_fraction:.3f}")
    emit(f"workloads/{name}/pct_fragments_short",
         f"{st.pct_fragments_short:.2f}")
    emit(f"workloads/{name}/sched_utilization",
         f"{sc.sched.utilization:.3f}")
    emit(f"workloads/{name}/sched_backfilled", sc.sched.n_backfilled)

    events = fragments_to_events(sc.fragments)
    # enough Trainers and work that the idle pool stays saturated — U then
    # measures allocation quality, not early completion
    n_jobs = max(4, int(round(st.eq_nodes / 3)))
    jobs_fn = lambda: diverse_jobs(n=n_jobs, work=1e12, seed=seed)
    for alloc_name, mk in _allocators():
        alloc = mk()
        rep, u = efficiency(events, jobs_fn, sc.duration, alloc,
                            t_fwd=t_fwd)
        emit(f"workloads/{name}/{alloc_name}/efficiency_u", f"{u:.3f}",
             "vs dedicated eq-nodes")
        emit(f"workloads/{name}/{alloc_name}/solver_wall_s",
             f"{rep.solver_wall_total:.3f}")
        emit(f"workloads/{name}/{alloc_name}/events",
             rep.events_processed)
        if isinstance(alloc, AllocationEngine):
            s = alloc.stats
            hit = s.cache_hits / s.events if s.events else 0.0
            emit(f"workloads/{name}/{alloc_name}/cache_hit_rate",
                 f"{hit:.2f}")


def main(argv: Sequence[str] = ()) -> None:
    # default () — benchmarks.run calls main() with section names still in
    # sys.argv, so only the __main__ guard forwards the real CLI args
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scenarios for CI smoke runs")
    ap.add_argument("--scenario", action="append", choices=sorted(SCENARIOS),
                    help="restrict to named scenario(s)")
    args = ap.parse_args(argv)
    smoke = args.smoke or bool(int(os.environ.get("BENCH_SMOKE", "0")))
    scale = 0.15 if smoke else (1.0 if FULL else 0.5)
    names = args.scenario or sorted(SCENARIOS)
    for name in names:
        run_scenario(name, scale=scale)


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
