"""Paper Fig. 14 + Tabs. 3/4: maximum parallel Trainers P_jmax —
resource integral vs per-Trainer runtime trade-off."""
from __future__ import annotations

import numpy as np

from benchmarks.common import FULL, diverse_jobs, emit, trace
from repro.core import MILPAllocator, Simulator


def main() -> None:
    hours = 48.0 if FULL else 24.0
    ev = trace(n_nodes=160, hours=hours, seed=55)
    horizon = hours * 3600.0
    pj_values = [5, 10, 20, 35] if FULL else [5, 10, 20]
    for pj in pj_values:
        jobs = diverse_jobs(n=30 if FULL else 18, work=1.2e8,
                            arrival_rate=1 / 600.0)
        rep = Simulator(list(ev), jobs, MILPAllocator("fast"), t_fwd=120.0,
                        pj_max=pj, horizon=horizon).run()
        finished = [j for j in jobs if j.finished_at is not None]
        if finished:
            rts = [(j.finished_at - j.arrival) / 3600.0 for j in finished]
            # resource integral consumed = node-seconds of actual usage
            emit(f"pjmax/{pj}/avg_runtime_h", f"{np.mean(rts):.2f}",
                 "fig14-center")
        emit(f"pjmax/{pj}/finished", f"{len(finished)}", "")
        emit(f"pjmax/{pj}/total_samples", f"{rep.total_samples:.3e}",
             "fig14-right proxy")
        emit(f"pjmax/{pj}/rescale_cost_samples",
             f"{rep.rescale_cost_samples:.3e}", "")


if __name__ == "__main__":
    main()
