"""Tab. 2 analog for the assigned JAX model zoo: measured single-node
samples/s of each reduced architecture (real train steps on CPU) plus the
synthetic weak-scaling curves fed to the MILP."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FULL, emit
from repro.configs import ARCHS, get_arch
from repro.core.scaling import model_zoo_curves
from repro.models import build_model
from repro.optim import AdamW


def measure_arch(arch: str, steps: int = 3, b: int = 2, s: int = 64) -> float:
    cfg = get_arch(arch).reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    opt = AdamW()
    state = opt.init(params)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    if cfg.frontend == "vision":
        nt = cfg.n_frontend_tokens
        batch = {"tokens": batch["tokens"][:, : s - nt],
                 "labels": batch["labels"][:, : s - nt],
                 "frontend_embeds": jnp.zeros((b, nt, cfg.d_model))}
    elif cfg.is_encdec:
        batch["frames"] = jnp.zeros((b, s // 4, cfg.encoder.d_model))

    @jax.jit
    def step(p, st):
        loss, g = jax.value_and_grad(lambda pp: model.loss(pp, batch))(p)
        p2, st2 = opt.update(g, st, p)
        return p2, st2, loss

    params, state, _ = step(params, state)  # compile
    t0 = time.perf_counter()
    for _ in range(steps):
        params, state, loss = step(params, state)
    jax.block_until_ready(loss)
    dt = (time.perf_counter() - t0) / steps
    return b / dt


def main() -> None:
    for arch in ARCHS:
        thr = measure_arch(arch)
        emit(f"throughput/{arch}-smoke/samples_per_s", f"{thr:.2f}",
             "tab2-analog measured 1-node CPU")
    for name, curve in model_zoo_curves().items():
        vals = ",".join(f"{curve(n)/1000:.1f}" for n in (1, 2, 4, 8, 16, 32))
        emit(f"curve/{name}/kilo_samples_per_s@1-32", f'"{vals}"',
             "synthetic weak-scaling curve (MILP input)")


if __name__ == "__main__":
    main()
