"""Pallas kernel micro-benchmarks (interpret mode on CPU: correctness-
bearing cost proxies; real speed requires TPU) vs their XLA reference."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels.ops import flash_attention, rms_norm, ssd_scan
from repro.kernels.ref import flash_attention_ref, rms_norm_ref, ssd_scan_ref


def timeit(fn, *args, reps=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def main() -> None:
    rng = np.random.RandomState(0)
    b, h, kv, s, d = 1, 4, 2, 256, 64
    q = jnp.asarray(rng.randn(b, h, s, d) * 0.3, jnp.float32)
    k = jnp.asarray(rng.randn(b, kv, s, d) * 0.3, jnp.float32)
    v = jnp.asarray(rng.randn(b, kv, s, d) * 0.3, jnp.float32)
    emit("kernel/flash_attention/interpret",
         f"{timeit(flash_attention, q, k, v, interpret=True):.0f}",
         "us_per_call")
    emit("kernel/flash_attention/xla_ref",
         f"{timeit(jax.jit(flash_attention_ref), q, k, v):.0f}",
         "us_per_call")

    bs, ss, hh, p, n = 1, 256, 2, 32, 16
    x = jnp.asarray(rng.randn(bs, ss, hh, p) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.rand(bs, ss, hh) * 0.5 + 0.01, jnp.float32)
    a = jnp.asarray(-np.exp(rng.randn(hh) * 0.3), jnp.float32)
    bm = jnp.asarray(rng.randn(bs, ss, hh, n) * 0.4, jnp.float32)
    cm = jnp.asarray(rng.randn(bs, ss, hh, n) * 0.4, jnp.float32)
    emit("kernel/ssd_scan/interpret",
         f"{timeit(ssd_scan, x, dt, a, bm, cm, chunk=64, interpret=True):.0f}",
         "us_per_call")
    emit("kernel/ssd_scan/xla_ref",
         f"{timeit(jax.jit(lambda *aa: ssd_scan_ref(*aa)[0]), x, dt, a, bm, cm):.0f}",
         "us_per_call")

    xx = jnp.asarray(rng.randn(8, 512, 1024), jnp.float32)
    sc = jnp.asarray(rng.randn(1024) * 0.1, jnp.float32)
    emit("kernel/rms_norm/interpret",
         f"{timeit(rms_norm, xx, sc, interpret=True):.0f}", "us_per_call")
    emit("kernel/rms_norm/xla_ref",
         f"{timeit(jax.jit(rms_norm_ref), xx, sc):.0f}", "us_per_call")


if __name__ == "__main__":
    main()
