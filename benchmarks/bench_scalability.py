"""Paper Fig. 15: HPO efficiency per DNN scalability class — every Tab-2
DNN gets an HPO run on the same trace.

With ``--json`` / ``BENCH_JSON_DIR`` the sweep persists
``BENCH_scalability.json`` (schema ``bftrainer-bench-scalability/1``);
``--smoke`` (or ``BENCH_SMOKE=1``) shortens the trace for CI.
"""
from __future__ import annotations

import sys

from benchmarks.common import FULL, SMOKE, efficiency, emit, hpo_jobs, \
    maybe_write_json, trace
from benchmarks.schema import SCALABILITY_SCHEMA, bench_payload
from repro.core import MILPAllocator
from repro.core.scaling import TAB2


def main() -> None:
    smoke = SMOKE or "--smoke" in sys.argv[1:]
    hours = 24.0 if FULL else (6.0 if smoke else 12.0)
    seed = 66
    ev = trace(n_nodes=160, hours=hours, seed=seed)
    horizon = hours * 3600.0
    payload = bench_payload(SCALABILITY_SCHEMA)
    payload["trace"] = dict(n_nodes=160, hours=hours, seed=seed)
    payload["rows"] = []
    for dnn in TAB2:
        rep, u = efficiency(ev, lambda d=dnn: hpo_jobs(8, dnn=d), horizon,
                            MILPAllocator("fast"))
        payload["rows"].append(dict(dnn=dnn, efficiency_u=float(u)))
        emit(f"scalability/{dnn}/efficiency_u", f"{u:.3f}",
             "fig15: U grows with DNN scalability")
    maybe_write_json("BENCH_scalability.json", payload)


if __name__ == "__main__":
    if "--json" in sys.argv[1:]:
        import os
        os.environ.setdefault("BENCH_JSON_DIR", ".")
    main()
