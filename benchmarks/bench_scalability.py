"""Paper Fig. 15: HPO efficiency per DNN scalability class — every Tab-2
DNN gets an HPO run on the same trace."""
from __future__ import annotations

from benchmarks.common import FULL, efficiency, emit, hpo_jobs, trace
from repro.core import MILPAllocator
from repro.core.scaling import TAB2


def main() -> None:
    hours = 24.0 if FULL else 12.0
    ev = trace(n_nodes=160, hours=hours, seed=66)
    horizon = hours * 3600.0
    for dnn in TAB2:
        rep, u = efficiency(ev, lambda d=dnn: hpo_jobs(8, dnn=d), horizon,
                            MILPAllocator("fast"))
        emit(f"scalability/{dnn}/efficiency_u", f"{u:.3f}",
             "fig15: U grows with DNN scalability")


if __name__ == "__main__":
    main()
