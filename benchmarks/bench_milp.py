"""Paper Fig. 5: MILP solve time vs number of jobs and nodes.

Benchmarks both the paper-faithful node-level model and the beyond-paper
aggregate reformulation; 10 repetitions with random initial conditions, as
in §3.6.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FULL, emit
from repro.core.milp import AllocationProblem, TrainerSpec, solve_node_milp
from repro.core.milp_fast import solve_fast_milp
from repro.core.scaling import TAB2, tab2_curve


def make_problem(n_nodes: int, n_jobs: int, seed: int) -> AllocationProblem:
    rng = np.random.RandomState(seed)
    names = list(TAB2)
    trainers, current, used = [], {}, set()
    for j in range(n_jobs):
        curve = tab2_curve(names[j % len(names)])
        n_max = int(rng.randint(8, min(64, max(9, n_nodes // 2))))
        pts, vals = curve.breakpoints(1, n_max)
        trainers.append(TrainerSpec(id=j, n_min=1, n_max=n_max, r_up=20.0,
                                    r_dw=5.0, points=tuple(pts),
                                    values=tuple(vals)))
        avail = [x for x in range(n_nodes) if x not in used]
        k = int(rng.randint(0, min(n_max, len(avail)) + 1))
        cur = [int(c) for c in rng.choice(avail, size=k, replace=False)]
        current[j] = cur
        used.update(cur)
    return AllocationProblem(nodes=list(range(n_nodes)), trainers=trainers,
                             current=current, t_fwd=120.0)


def main(reps: int = 10) -> None:
    node_sizes = [50, 100, 200, 400, 800] if FULL else [50, 100, 200]
    job_counts = [5, 10] if not FULL else [5, 10, 20]
    for n in node_sizes:
        for j in job_counts:
            for mode, solve in (("fast", solve_fast_milp),
                                ("node", solve_node_milp)):
                if mode == "node" and n > 100:
                    continue  # paper-scale node model: see EXPERIMENTS.md
                times = []
                for rep in range(reps):
                    prob = make_problem(n, j, seed=rep)
                    r = solve(prob, time_limit=60)
                    times.append(r.wall_time)
                emit(f"milp_solve/{mode}/N{n}/J{j}",
                     f"{np.mean(times)*1e6:.0f}",
                     f"us_per_solve reps={reps}")


if __name__ == "__main__":
    main()
