"""Self-healing control-plane sweep: stream corruption + decision
deadlines (DESIGN.md §16).

Two sweeps over the ``bursty`` capacity scenario:

- **corruption** — the event feed is duplicated/reordered/dropped/late
  at increasing intensity while the physical pool follows the clean
  trace; hygiene + anti-entropy reconciliation repair the stream before
  the control loop sees it.  ``u_frac_of_clean`` (efficiency retained
  vs the uncorrupted replay) is the headline; the CI floor is >= 0.85
  at 1% corruption.  Rows also carry the repair bookkeeping
  (defect counters, reconcile repairs, membership divergence).
- **deadline** — the same replay under hard per-decision deadlines
  enforced by the engine's degradation ladder
  (cache → repair → greedy → MILP → project → equal-share).
  ``within_deadline_frac`` must be 1.0 (CI asserts it); rows carry the
  rung mix and the efficiency retained vs the same engine without a
  deadline.

The smallest deadline is kept >= 25 ms: below that the engine's fixed
per-call bookkeeping (problem signature hashing) dominates on large
problems and wall-clock noise, not ladder policy, decides the outcome.

``--smoke`` (or ``BENCH_SMOKE=1``) shrinks the trace for CI.
"""
from __future__ import annotations

import argparse
import os
from typing import Sequence

import numpy as np

from benchmarks.common import FULL, diverse_jobs, emit, maybe_write_json
from benchmarks.schema import RESILIENCE_SCHEMA, bench_payload
from repro.chaos import ChaosSpec, run_chaos
from repro.core import (
    AllocationEngine,
    MILPAllocator,
    Simulator,
    eq_nodes,
    fragments_to_events,
    static_outcome,
)
from repro.sched import build_scenario

#: corruption intensity p: duplicate_prob = drop_prob = p, late_prob =
#: p/2.  0.01 is the CI-floor point (u_frac_of_clean >= 0.85 there).
CORRUPT_LEVELS = (0.0, 0.01, 0.05, 0.10)
REORDER_WINDOW = 300.0
RECONCILE_PERIOD = 900.0

#: hard decision deadlines (ms) for the ladder sweep
DEADLINE_MS = (25.0, 50.0, 100.0)


def _static_baseline(events, jobs_fn, horizon: float) -> float:
    n_eq = max(1, round(eq_nodes(list(events), 0.0, horizon)))
    return static_outcome(jobs_fn(), n_eq, horizon, MILPAllocator("fast"),
                          pj_max=10)


def _corrupt_spec(p: float, seed: int) -> ChaosSpec:
    if p <= 0.0:
        # fully clean feed (reorder_window alone already jitters
        # arrival order) — the zero-corruption identity row
        return ChaosSpec(seed=seed)
    return ChaosSpec(seed=seed, duplicate_prob=p, drop_prob=p,
                     late_prob=p / 2.0, reorder_window=REORDER_WINDOW,
                     reconcile_period_s=RECONCILE_PERIOD)


def run_sweep(scale: float, seed: int = 7,
              scenario: str = "bursty") -> None:
    sc = build_scenario(scenario, scale=scale, seed=seed)
    events = fragments_to_events(sc.fragments)
    n_jobs = max(4, int(round(sc.stats.eq_nodes / 3)))
    jobs_fn = lambda: diverse_jobs(n=n_jobs, work=1e12, seed=seed)
    a_s = _static_baseline(events, jobs_fn, sc.duration)

    clean = Simulator(list(events), jobs_fn(), AllocationEngine(),
                      t_fwd=120.0, pj_max=10, horizon=sc.duration).run()
    u_clean = clean.total_samples / a_s if a_s > 0 else 0.0
    emit(f"resilience/{scenario}/n_nodes", sc.n_nodes)
    emit(f"resilience/{scenario}/u_clean", f"{u_clean:.3f}",
         "clean-feed replay vs dedicated eq-nodes")

    payload = bench_payload(RESILIENCE_SCHEMA)
    payload.update(scenario=scenario, scale=scale, seed=seed,
                   u_clean=u_clean, corruption=[], deadline=[])

    # -- corruption sweep ----------------------------------------------
    for p in CORRUPT_LEVELS:
        rep = run_chaos(list(events), jobs_fn(), _corrupt_spec(p, seed),
                        horizon=sc.duration)
        samples = rep.stats.total_samples
        # physical capacity follows the clean trace, so the clean
        # baseline is the honest denominator at every corruption level
        u = samples / a_s if a_s > 0 else 0.0
        hyg = rep.hygiene.as_dict() if rep.hygiene is not None else {}
        rec = rep.reconcile.as_dict() if rep.reconcile is not None else {}
        div = rep.divergence or {}
        row = {
            "corrupt_prob": p,
            "u": u,
            "u_frac_of_clean": (u / u_clean) if u_clean > 0 else 0.0,
            "divergence_frac": div.get("divergence_frac", 0.0),
            "max_lag_s": div.get("max_lag_s", 0.0),
            "defects": (rep.hygiene.defects
                        if rep.hygiene is not None else 0),
            "duplicates_dropped": hyg.get("duplicates_dropped", 0),
            "late_dropped": hyg.get("late_dropped", 0),
            "phantom_joins": hyg.get("phantom_joins", 0),
            "orphan_leaves": hyg.get("orphan_leaves", 0),
            "repair_events": rec.get("repair_events", 0),
            "reconciles": rec.get("reconciles", 0),
            "events": rep.stats.events_processed,
        }
        payload["corruption"].append(row)
        tag = f"resilience/{scenario}/corrupt_{p:g}"
        emit(f"{tag}/u_frac_of_clean", f"{row['u_frac_of_clean']:.3f}",
             "efficiency retained vs clean feed")
        emit(f"{tag}/divergence_frac", f"{row['divergence_frac']:.4f}")
        emit(f"{tag}/max_lag_s", f"{row['max_lag_s']:.0f}",
             "worst believed-vs-truth window")
        emit(f"{tag}/defects", row["defects"])
        emit(f"{tag}/repair_events", row["repair_events"])

    # -- deadline ladder sweep -----------------------------------------
    # reference: same greedy-tier engine, no deadline (time_budget=0
    # keeps CBC wall-time jitter out of a wall-clock assertion)
    ref = Simulator(list(events), jobs_fn(),
                    AllocationEngine(time_budget=0.0),
                    t_fwd=120.0, pj_max=10, horizon=sc.duration).run()
    u_ref = ref.total_samples / a_s if a_s > 0 else 0.0
    for ms in DEADLINE_MS:
        eng = AllocationEngine(time_budget=0.0,
                               decision_deadline_s=ms / 1e3)
        rep = Simulator(list(events), jobs_fn(), eng, t_fwd=120.0,
                        pj_max=10, horizon=sc.duration).run()
        u = rep.total_samples / a_s if a_s > 0 else 0.0
        walls = np.array([r.solver_wall for r in rep.event_records
                          if r.solver_wall > 0.0]) * 1e3
        within = (float(np.mean(walls <= ms)) if len(walls) else 1.0)
        p99 = float(np.percentile(walls, 99)) if len(walls) else 0.0
        s = eng.stats
        row = {
            "deadline_ms": ms,
            "u": u,
            "u_frac_of_ref": (u / u_ref) if u_ref > 0 else 0.0,
            "within_deadline_frac": within,
            "deadline_hits": s.deadline_hits,
            "rung_cache": s.rung_cache,
            "rung_repair": s.rung_repair,
            "rung_greedy": s.rung_greedy,
            "rung_milp": s.rung_milp,
            "rung_project": s.rung_project,
            "rung_equal": s.rung_equal,
            "upgrades": s.upgrades,
            "events": rep.events_processed,
            "decision_ms_p99": p99,
        }
        payload["deadline"].append(row)
        tag = f"resilience/{scenario}/deadline_{ms:g}ms"
        emit(f"{tag}/within_deadline_frac", f"{within:.3f}",
             "fraction of decisions inside the hard deadline")
        emit(f"{tag}/u_frac_of_ref", f"{row['u_frac_of_ref']:.3f}")
        emit(f"{tag}/deadline_hits", s.deadline_hits,
             "decisions where the ladder demoted a rung")
        emit(f"{tag}/decision_ms_p99", f"{p99:.2f}")
    maybe_write_json("BENCH_resilience.json", payload)


def main(argv: Sequence[str] = ()) -> None:
    # default () — benchmarks.run calls main() with section names still in
    # sys.argv, so only the __main__ guard forwards the real CLI args
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace for CI smoke runs")
    args = ap.parse_args(argv)
    smoke = args.smoke or bool(int(os.environ.get("BENCH_SMOKE", "0")))
    scale = 0.15 if smoke else (1.0 if FULL else 0.5)
    run_sweep(scale)


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
