"""Elastic serving on harvested holes vs dedicated nodes (DESIGN.md §15).

Replays each serving scenario (a node-hole trace paired with request
demand, ``repro.sched.scenarios.SERVING_SCENARIOS``) through the
ControlLoop under the ``latency_slo`` policy and reports requests/s,
p50/p95/p99 request latency and SLO attainment — then serves the *same*
request traces on a static, peak-provisioned pool
(``repro.serving.dedicated_baseline``) and reports the attainment ratio
``attainment_vs_dedicated``.  The headline acceptance bar, mirroring
the chaos tier's U floor, is ``attainment_vs_dedicated >= 0.9`` on the
smoke configuration: harvested holes must deliver at least 90% of the
SLO attainment an always-on dedicated deployment would.

``--smoke`` (or ``BENCH_SMOKE=1``) shrinks the traces for CI.
"""
from __future__ import annotations

import argparse
import os
from typing import Sequence

import numpy as np

from benchmarks.common import FULL, emit, maybe_write_json
from benchmarks.schema import SERVING_SCHEMA, bench_payload
from repro.core import AllocationEngine, fragments_to_events
from repro.sched.scenarios import SERVING_SCENARIOS, build_scenario
from repro.serving import dedicated_baseline, run_serving


def _decision_ms(stats):
    """(p50, p95, p99) decision latency in ms from the replay's records."""
    walls = np.array([r.solver_wall for r in stats.event_records
                      if r.solver_wall > 0.0]) * 1e3
    if not len(walls):
        return 0.0, 0.0, 0.0
    return tuple(float(np.percentile(walls, q)) for q in (50, 95, 99))


def run_sweep(scale: float, seed: int = 7) -> None:
    payload = bench_payload(SERVING_SCHEMA)
    payload.update(scale=scale, seed=seed, scenarios=[])
    for name in sorted(SERVING_SCENARIOS):
        sc = build_scenario(name, scale=scale, seed=seed)
        rep = run_serving(sc, seed=seed, allocator=AllocationEngine())
        ded = dedicated_baseline(sc, seed=seed)
        ratio = (rep.slo_attainment / ded.slo_attainment
                 if ded.slo_attainment > 0 else 1.0)
        p50, p95, p99 = _decision_ms(rep.stats)
        row = {
            "scenario": name,
            "n_nodes": sc.n_nodes,
            "hours": sc.duration / 3600.0,
            "services": len(sc.requests),
            "requests": rep.requests,
            "requests_per_sec": rep.requests_per_sec,
            "served_frac": rep.served_frac,
            "dropped_frac": rep.dropped_frac,
            "latency_ms_p50": rep.latency_ms_p50,
            "latency_ms_p95": rep.latency_ms_p95,
            "latency_ms_p99": rep.latency_ms_p99,
            "slo_attainment": rep.slo_attainment,
            "dedicated_nodes": ded.summary["dedicated_nodes"],
            "dedicated_slo_attainment": ded.slo_attainment,
            "attainment_vs_dedicated": ratio,
            "events": rep.stats.events_processed,
            "decision_ms_p50": p50,
            "decision_ms_p95": p95,
            "decision_ms_p99": p99,
        }
        payload["scenarios"].append(row)
        tag = f"serving/{name}"
        emit(f"{tag}/n_nodes", sc.n_nodes)
        emit(f"{tag}/hours", f"{sc.duration / 3600.0:.1f}")
        emit(f"{tag}/requests", rep.requests)
        emit(f"{tag}/requests_per_sec", f"{rep.requests_per_sec:.3f}")
        emit(f"{tag}/served_frac", f"{rep.served_frac:.3f}")
        emit(f"{tag}/latency_ms_p50", f"{rep.latency_ms_p50:.0f}")
        emit(f"{tag}/latency_ms_p95", f"{rep.latency_ms_p95:.0f}")
        emit(f"{tag}/latency_ms_p99", f"{rep.latency_ms_p99:.0f}")
        emit(f"{tag}/slo_attainment", f"{rep.slo_attainment:.3f}",
             "on harvested holes under latency_slo")
        emit(f"{tag}/dedicated_nodes", ded.summary["dedicated_nodes"])
        emit(f"{tag}/dedicated_slo_attainment",
             f"{ded.slo_attainment:.3f}", "static peak-provisioned pool")
        emit(f"{tag}/attainment_vs_dedicated", f"{ratio:.3f}",
             "elastic / dedicated; CI floor 0.9")
    maybe_write_json("BENCH_serving.json", payload)


def main(argv: Sequence[str] = ()) -> None:
    # default () — benchmarks.run calls main() with section names still in
    # sys.argv, so only the __main__ guard forwards the real CLI args
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny traces for CI smoke runs")
    args = ap.parse_args(argv)
    smoke = args.smoke or bool(int(os.environ.get("BENCH_SMOKE", "0")))
    scale = 0.15 if smoke else (1.0 if FULL else 0.5)
    run_sweep(scale)


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
