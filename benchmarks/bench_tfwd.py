"""Paper Figs. 7-9: influence of forward-looking time T_fwd on rescale
investment, ROI, and resource utilization efficiency (HPO scenario)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import FULL, efficiency, emit, hpo_jobs, trace
from repro.core import MILPAllocator


def main() -> None:
    hours = 36.0 if FULL else 18.0
    ev = trace(n_nodes=160, hours=hours, seed=21)
    horizon = hours * 3600.0
    tfwds = [10, 30, 60, 120, 300, 600] if FULL else [10, 60, 120, 300]
    for t_fwd in tfwds:
        rep, u = efficiency(ev, lambda: hpo_jobs(8), horizon,
                            MILPAllocator("fast"), t_fwd=float(t_fwd))
        # ROI per event (Fig 8): return until next event / rescale spend
        invests = [r.rescale_cost_samples for r in rep.event_records
                   if r.rescale_cost_samples > 0]
        returns = [r.outcome_until_next for r in rep.event_records
                   if r.rescale_cost_samples > 0]
        roi = (np.sum(returns) / np.sum(invests)) if invests else float("inf")
        emit(f"tfwd/{t_fwd}/rescale_samples_per_event",
             f"{rep.rescale_cost_samples/max(rep.events_processed,1):.3e}",
             "fig7b")
        emit(f"tfwd/{t_fwd}/roi", f"{roi:.2f}", "fig8")
        emit(f"tfwd/{t_fwd}/efficiency_u", f"{u:.3f}", "fig9")


if __name__ == "__main__":
    main()
