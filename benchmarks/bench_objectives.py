"""Policy sweep: the objective portfolio (repro/core/objectives.py)
across the workload scenario library — the throughput-vs-fairness
tradeoff curve (EXPERIMENTS.md §Objectives, DESIGN.md §10).

For every scenario x policy we replay the scenario's unfillable-hole
trace in the ``Simulator`` under the ``AllocationEngine`` and report:

* ``efficiency_u``      — U = A_e / A_s vs the dedicated-eq-nodes static
  baseline (paper §4.1.2; same denominator for every policy);
* ``jain_fairness``     — Jain index over per-job normalized progress
  x_j = min(done_j / work_j, 1)  (1 = perfectly even);
* ``min_norm_progress`` — min_j x_j (what MaxMinFairness maximizes);
* ``deadline_miss_rate``— fraction of jobs whose soft deadline passed
  unfinished (what DeadlineAware minimizes);
* ``solver_wall_s`` / ``cache_hit_rate`` — policy cost in the engine.

Jobs carry finite work (sized so a fair share finishes ~most of it),
staggered soft deadlines, and node-second budgets on half the fleet, so
every policy has something to act on.  ``--smoke`` (or ``BENCH_SMOKE=1``)
shrinks scenarios for CI.

The **metric arms** (paper Figs 12-13 + Tabs 3-4, absorbed from the
legacy ``bench_objective`` module when it moved onto the JSON path)
replay diverse Tab-2 Trainers on one unfillable-hole trace under the
``throughput`` vs ``efficiency`` objective metrics and report total /
rescale-lost samples and the per-DNN runtime spread — the paper's
evidence that the raw-throughput metric starves compute-heavy DNNs.

With ``--json`` / ``BENCH_JSON_DIR`` the sweep persists
``BENCH_objectives.json`` (schema ``bftrainer-bench-objectives/1``).
"""
from __future__ import annotations

import argparse
import os
from collections import defaultdict
from typing import Dict, List, Sequence

from benchmarks.common import FULL, diverse_jobs, emit, maybe_write_json, trace
from benchmarks.schema import OBJECTIVES_SCHEMA, bench_payload
from repro.core import (
    AllocationEngine,
    CostCap,
    DeadlineAware,
    MaxMinFairness,
    MILPAllocator,
    Simulator,
    Throughput,
    WeightedPriority,
    deadline_miss_rate,
    eq_nodes,
    fragments_to_events,
    jain_fairness,
    min_normalized_progress,
    normalized_progress,
    static_outcome,
)
from repro.core.loop import TrainerJob
from repro.core.scaling import TAB2, tab2_curve
from repro.sched import SCENARIOS, build_scenario


def policy_jobs(n: int, duration: float, share: float,
                seed: int = 0) -> List[TrainerJob]:
    """Trainers cycled from Tab 2 with the per-job policy fields set:
    finite work 1.5x what a fair ``share``-node slice delivers over the
    trace (so the pool is contended and progress spreads out) — except
    every third job, which is smaller (0.8x fair share) and carries a
    soft deadline at 75% of the trace (achievable at ~1.1x its fair
    rate, so deadline-aware allocation can actually save it); double
    weight on the first quarter of the fleet, and a node-second budget
    on every other job."""
    import numpy as np
    rng = np.random.default_rng(seed)
    names = list(TAB2)
    jobs, t = [], 0.0
    for i in range(n):
        curve = tab2_curve(names[i % len(names)])
        t += float(rng.exponential(duration / (4.0 * max(n, 1))))
        deadlined = i % 3 == 0
        work = ((0.8 if deadlined else 1.5)
                * duration * curve(max(share, 1.0)))
        jobs.append(TrainerJob(
            id=i, curve=curve, work=work, n_min=1, n_max=24,
            r_up=20.0, r_dw=5.0, arrival=t,
            weight=2.0 if i < max(1, n // 4) else 1.0,
            deadline=(t + 0.75 * duration) if deadlined else None,
            budget=(0.35 * duration * share if i % 2 else None)))
    return jobs


def _policies():
    return (
        ("throughput", lambda: Throughput()),
        ("weighted", lambda: WeightedPriority()),
        ("maxmin", lambda: MaxMinFairness()),
        ("deadline", lambda: DeadlineAware()),
        ("costcap", lambda: CostCap()),
    )


def run_scenario_sweep(name: str, scale: float, seed: int = 7,
                       t_fwd: float = 120.0) -> List[Dict]:
    sc = build_scenario(name, scale=scale, seed=seed)
    events = fragments_to_events(sc.fragments)
    n_eq = max(1, round(eq_nodes(events, 0.0, sc.duration)))
    # capped at the default pj_max so admission never confounds fairness
    n_jobs = min(10, max(4, int(round(sc.stats.eq_nodes / 3))))
    share = sc.stats.eq_nodes / max(n_jobs, 1)

    jobs_fn = lambda: policy_jobs(n_jobs, sc.duration, share, seed=seed)
    # one static baseline per scenario: the U denominator is
    # policy-independent so efficiency stays comparable across policies
    a_s = static_outcome(jobs_fn(), n_eq, sc.duration, MILPAllocator("fast"))
    emit(f"objectives/{name}/n_jobs", n_jobs)
    emit(f"objectives/{name}/eq_nodes", n_eq)

    rows: List[Dict] = []
    for pol_name, mk in _policies():
        eng = AllocationEngine(time_budget=0.050)
        jobs = jobs_fn()
        rep = Simulator(events, jobs, eng, t_fwd=t_fwd,
                        horizon=sc.duration, objective=mk()).run()
        u = rep.total_samples / a_s if a_s > 0 else 0.0
        xs = normalized_progress(jobs)
        s = eng.stats
        row = dict(
            scenario=name, policy=pol_name, efficiency_u=float(u),
            jain_fairness=float(jain_fairness(xs)),
            min_norm_progress=float(min_normalized_progress(jobs)),
            deadline_miss_rate=float(
                deadline_miss_rate(jobs, sc.duration)),
            solver_wall_s=float(rep.solver_wall_total),
            cache_hit_rate=float(
                s.cache_hits / s.events if s.events else 0.0))
        rows.append(row)
        pre = f"objectives/{name}/{pol_name}"
        emit(f"{pre}/efficiency_u", f"{u:.3f}", "vs dedicated eq-nodes")
        emit(f"{pre}/jain_fairness", f"{row['jain_fairness']:.3f}")
        emit(f"{pre}/min_norm_progress",
             f"{row['min_norm_progress']:.3f}")
        emit(f"{pre}/deadline_miss_rate",
             f"{row['deadline_miss_rate']:.2f}")
        emit(f"{pre}/solver_wall_s", f"{row['solver_wall_s']:.3f}")
        emit(f"{pre}/cache_hit_rate", f"{row['cache_hit_rate']:.2f}")
    return rows


def run_metric_arms(smoke: bool) -> List[Dict]:
    """Figs 12-13: diverse Trainers under throughput vs efficiency
    objective metrics — per-DNN runtime spread and sample totals."""
    import numpy as np
    hours = 48.0 if FULL else (6.0 if smoke else 24.0)
    ev = trace(n_nodes=160, hours=hours, seed=44)
    horizon = hours * 3600.0
    n_jobs = 42 if FULL else (10 if smoke else 21)
    rows: List[Dict] = []
    for metric in ("throughput", "efficiency"):
        jobs = diverse_jobs(n=n_jobs, metric=metric)
        rep = Simulator(list(ev), jobs, MILPAllocator("fast"), t_fwd=120.0,
                        pj_max=10, horizon=horizon).run()
        runtimes = defaultdict(list)
        for j in jobs:
            if j.finished_at is not None:
                runtimes[j.curve.name].append(
                    (j.finished_at - j.arrival) / 3600.0)
        for dnn, rts in sorted(runtimes.items()):
            emit(f"objective/{metric}/{dnn}/runtime_h",
                 f"{np.mean(rts):.2f}", "fig12")
        spread = 0.0
        if runtimes:
            means = [float(np.mean(v)) for v in runtimes.values()]
            spread = max(means) / max(min(means), 1e-9)
            emit(f"objective/{metric}/runtime_spread", f"{spread:.1f}",
                 "fig12: throughput metric starves compute-heavy DNNs")
        emit(f"objective/{metric}/total_samples",
             f"{rep.total_samples:.3e}", "fig13 proxy")
        emit(f"objective/{metric}/rescale_cost_samples",
             f"{rep.rescale_cost_samples:.3e}", "")
        rows.append(dict(metric=metric,
                         total_samples=float(rep.total_samples),
                         rescale_cost_samples=float(
                             rep.rescale_cost_samples),
                         runtime_spread=float(spread)))
    return rows


def main(argv: Sequence[str] = ()) -> None:
    # default () — benchmarks.run calls main() with section names still in
    # sys.argv, so only the __main__ guard forwards the real CLI args
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scenarios for CI smoke runs")
    ap.add_argument("--scenario", action="append", choices=sorted(SCENARIOS),
                    help="restrict to named scenario(s)")
    args = ap.parse_args(argv)
    smoke = args.smoke or bool(int(os.environ.get("BENCH_SMOKE", "0")))
    scale = 0.12 if smoke else (1.0 if FULL else 0.5)
    names = args.scenario or (
        ["bursty", "capacity"] if smoke else sorted(SCENARIOS))
    payload = bench_payload(OBJECTIVES_SCHEMA)
    payload["scale"] = scale
    payload["policies"] = []
    for name in names:
        payload["policies"].extend(run_scenario_sweep(name, scale=scale))
    payload["metrics"] = run_metric_arms(smoke)
    maybe_write_json("BENCH_objectives.json", payload)


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
