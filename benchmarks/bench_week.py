"""Paper Figs. 10-11: efficiency over time windows, plus their rescale /
preemption cost split — and the PR-5 perf trajectory.

Three arms replay the same trace in one run:

* ``engine``   — the production ``AllocationEngine`` (memoization +
  incremental warm-start repair + vectorized greedy, DESIGN.md §11);
* ``milp``     — the PR-4 baseline: a fresh aggregate MILP per event
  (``MILPAllocator("fast")``), the paper's allocator;
* ``heuristic`` — the equal-share comparison scheme (paper §5.1).

With ``--json`` / ``benchmarks.run --json`` the run persists
``BENCH_week.json`` (schema ``bftrainer-bench-week/2``) carrying both
the baseline and engine walls measured in the same process — the
CI-tracked end-to-end speedup (EXPERIMENTS.md §Scale).
"""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import (
    FULL,
    SMOKE,
    efficiency_timed,
    emit,
    hpo_jobs,
    maybe_write_json,
    trace,
)
from benchmarks.schema import WEEK_SCHEMA, bench_payload
from repro.core import AllocationEngine, EqualShareAllocator, MILPAllocator
from repro.obs import Telemetry


def _solver_wall_ms(rep):
    """(p50, p95, p99) decision latency in ms over the replay's events."""
    walls = np.array([r.solver_wall for r in rep.event_records
                      if r.solver_wall > 0.0]) * 1e3
    if not len(walls):
        return 0.0, 0.0, 0.0
    return tuple(float(np.percentile(walls, q)) for q in (50, 95, 99))


def main() -> None:
    smoke = SMOKE or "--smoke" in sys.argv[1:]
    hours = 48.0 if FULL else (6.0 if smoke else 24.0)
    seed, n_nodes = 33, 160
    ev = trace(n_nodes=n_nodes, hours=hours, seed=seed)
    horizon = hours * 3600.0

    # the engine arm carries a live hub so the payload can break decision
    # latency down per solver arm (cache/repair/greedy/milp/fallback)
    engine = AllocationEngine(telemetry=Telemetry())
    arms = (("engine", engine),
            ("milp", MILPAllocator("fast")),
            ("heuristic", EqualShareAllocator()))
    results = {}
    for name, alloc in arms:
        rep, u, wall = efficiency_timed(ev, lambda: hpo_jobs(8), horizon,
                                        alloc)
        results[name] = (rep, u, wall)
        emit(f"week/{name}/efficiency_u", f"{u:.3f}", "fig10")
        emit(f"week/{name}/wall_s", f"{wall:.2f}", "replay wall")
        emit(f"week/{name}/solver_wall_s", f"{rep.solver_wall_total:.2f}", "")
        emit(f"week/{name}/rescale_cost_samples",
             f"{rep.rescale_cost_samples:.3e}", "fig11b")
        emit(f"week/{name}/preempt_cost_s", f"{rep.preempt_cost_s:.0f}",
             "fig11a")
        # six-hour windows (Fig 10)
        window = 6 * 3600.0
        recs = rep.event_records
        k = 0
        while k * window < horizon:
            lo, hi = k * window, (k + 1) * window
            out = sum(r.outcome_until_next for r in recs
                      if lo <= r.time < hi)
            emit(f"week/{name}/window{k}/samples", f"{out:.3e}", "fig10")
            k += 1
    m, h = results["milp"], results["heuristic"]
    e = results["engine"]
    emit("week/milp_over_heuristic_u", f"{m[1]/max(h[1],1e-9):.3f}",
         "paper: up to 1.32x")
    emit("week/heuristic_over_milp_rescale_cost",
         f"{h[0].rescale_cost_samples/max(m[0].rescale_cost_samples,1e-9):.1f}",
         "paper: ~76x at tfwd=10")
    speedup = m[2] / max(e[2], 1e-9)
    solver_speedup = (m[0].solver_wall_total
                      / max(e[0].solver_wall_total, 1e-9))
    emit("week/engine_over_milp_speedup", f"{speedup:.1f}",
         "end-to-end, target >= 3x")
    emit("week/engine_cache_hit_rate",
         f"{engine.stats.cache_hits/max(engine.stats.events,1):.3f}", "")
    emit("week/engine_repair_rate",
         f"{engine.stats.repairs/max(engine.stats.events,1):.3f}", "")

    payload = bench_payload(WEEK_SCHEMA)
    payload["trace"] = dict(n_nodes=n_nodes, hours=hours, seed=seed,
                            n_events=len(ev))
    payload["arms"] = {}
    for name, alloc in arms:
        rep, u, wall = results[name]
        p50, p95, p99 = _solver_wall_ms(rep)
        payload["arms"][name] = dict(
            allocator=alloc.name, wall_s=wall,
            solver_wall_s=rep.solver_wall_total,
            solver_wall_p50_ms=p50, solver_wall_p95_ms=p95,
            solver_wall_p99_ms=p99,
            efficiency_u=u, samples=rep.total_samples,
            events_processed=rep.events_processed)
    payload["arms"]["engine"]["engine_stats"] = engine.stats.as_dict()
    # per-arm decision-latency split from the engine's own telemetry hub
    # (cache/repair/greedy/fallback/milp), when the caller enabled one
    if engine.telemetry:
        payload["arms"]["engine"]["decision_ms_by_arm"] = {
            k.split(".")[-1]: v
            for k, v in engine.telemetry.hist_summary().items()
            if k.startswith("engine.decision_ms.")}
    payload["speedup_end_to_end"] = speedup
    payload["speedup_solver_wall"] = solver_speedup
    maybe_write_json("BENCH_week.json", payload)


if __name__ == "__main__":
    if "--json" in sys.argv[1:]:
        import os
        os.environ.setdefault("BENCH_JSON_DIR", ".")
    main()
