"""Paper Figs. 10-11: efficiency over time windows, MILP vs equal-share
heuristic, plus their rescale / preemption cost split."""
from __future__ import annotations

from benchmarks.common import FULL, efficiency, emit, hpo_jobs, trace
from repro.core import EqualShareAllocator, MILPAllocator, Simulator, \
    eq_nodes, static_outcome


def main() -> None:
    hours = 48.0 if FULL else 24.0
    ev = trace(n_nodes=160, hours=hours, seed=33)
    horizon = hours * 3600.0
    results = {}
    for name, alloc in (("milp", MILPAllocator("fast")),
                        ("heuristic", EqualShareAllocator())):
        rep, u = efficiency(ev, lambda: hpo_jobs(8), horizon, alloc)
        results[name] = (rep, u)
        emit(f"week/{name}/efficiency_u", f"{u:.3f}", "fig10")
        emit(f"week/{name}/rescale_cost_samples",
             f"{rep.rescale_cost_samples:.3e}", "fig11b")
        emit(f"week/{name}/preempt_cost_s", f"{rep.preempt_cost_s:.0f}",
             "fig11a")
        # six-hour windows (Fig 10)
        window = 6 * 3600.0
        recs = rep.event_records
        k = 0
        while k * window < horizon:
            lo, hi = k * window, (k + 1) * window
            out = sum(r.outcome_until_next for r in recs
                      if lo <= r.time < hi)
            emit(f"week/{name}/window{k}/samples", f"{out:.3e}", "fig10")
            k += 1
    m, h = results["milp"], results["heuristic"]
    emit("week/milp_over_heuristic_u", f"{m[1]/max(h[1],1e-9):.3f}",
         "paper: up to 1.32x")
    emit("week/heuristic_over_milp_rescale_cost",
         f"{h[0].rescale_cost_samples/max(m[0].rescale_cost_samples,1e-9):.1f}",
         "paper: ~76x at tfwd=10")


if __name__ == "__main__":
    main()
