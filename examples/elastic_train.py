"""End-to-end driver: REAL elastic JAX training under BFTrainer control.

Two Trainers (reduced gemma-2b and mamba2 architectures) are trained with
genuine train steps while the AllocationEngine (memoized greedy/MILP
portfolio, DESIGN.md §3) rescales them across a replayed idle-node trace.
The runtime is the same ControlLoop the simulator uses (DESIGN.md §9), so
the live path is policy-complete.  Demonstrates:
  * state carry across rescale (no restart, no durable checkpoint),
  * per-node fixed minibatch => global batch tracks the allocation,
  * measured (not assumed) R_up / R_dw fed back into the MILP,
  * FCFS admission under pj_max, event coalescing, and rescale/preemption
    stall accounting — live, not just simulated.

Run:  PYTHONPATH=src python examples/elastic_train.py [--steps 200]
"""
import argparse

import numpy as np

from repro.configs import get_arch
from repro.core import AllocationEngine, amdahl_curve, fragments_to_events, \
    generate_summit_like
from repro.elastic import BFTrainerRuntime, ElasticTrainer, ManagedTrainer
from repro.models import build_model
from repro.optim import AdamW


def make_trainer(arch: str, seed: int, seq: int = 128) -> ElasticTrainer:
    cfg = get_arch(arch).reduced()
    model = build_model(cfg, remat=False)
    tr = ElasticTrainer(model, per_node_batch=4, seed=seed,
                        optimizer=AdamW(lr=1e-3), warmup_steps=10)
    tr.pipeline.cfg.seq_len = seq
    return tr


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60,
                    help="target train steps per Trainer")
    ap.add_argument("--hours", type=float, default=48.0)
    args = ap.parse_args()

    frags = generate_summit_like(n_nodes=6, duration=args.hours * 3600,
                                 seed=13)
    events = fragments_to_events(frags)
    print(f"trace: {len(events)} events over {args.hours:.0f}h")

    managed = [
        ManagedTrainer(id=0, trainer=make_trainer("gemma-2b", 1),
                       curve=amdahl_curve("gemma-2b", 100.0, 0.2),
                       n_min=1, n_max=1, target_steps=args.steps),
        ManagedTrainer(id=1, trainer=make_trainer("mamba2-2.7b", 2),
                       curve=amdahl_curve("mamba2", 120.0, 0.15),
                       n_min=1, n_max=1, target_steps=args.steps),
    ]
    engine = AllocationEngine()
    rt = BFTrainerRuntime(managed, engine, t_fwd=120.0, pj_max=2,
                          coalesce_window=30.0)
    rep = rt.run(events, time_scale=1.0, max_steps_per_interval=8)

    st = engine.stats
    print(f"\nallocation events: {rep.events} "
          f"(solver {rep.solver_wall_s:.2f}s), wall {rep.wall_time_s:.1f}s")
    ls = rep.stats
    print(f"policy (shared ControlLoop): rescale stalls {ls.rescale_cost_s:.1f}s, "
          f"preemption {ls.preempt_cost_s:.1f}s of trace time, "
          f"{ls.unfinished} unfinished")
    print(f"engine: {st.cache_hits}/{st.events} cache hits, "
          f"{st.greedy_solves} greedy + {st.fast_milp_solves} fast-MILP "
          f"solves, {st.fallbacks} fallbacks")
    for m in managed:
        losses = rep.losses[m.id]
        r_up, r_dw = m.trainer.measured_rescale_costs()
        first = np.mean(losses[:5]) if len(losses) >= 5 else float("nan")
        last = np.mean(losses[-5:]) if len(losses) >= 5 else float("nan")
        print(f"trainer {m.id} ({m.trainer.model.cfg.name}): "
              f"{rep.steps[m.id]} steps, {rep.samples[m.id]} samples, "
              f"{rep.rescales[m.id]} rescales "
              f"(measured r_up={r_up*1e3:.0f}ms r_dw={r_dw*1e3:.0f}ms), "
              f"loss {first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    main()
