"""Workload scenarios: from a batch-job log to BFTrainer efficiency.

1. Synthesize an SWF-style job log (or load a real one via
   ``repro.sched.parse_swf``).
2. Replay it through the FCFS+EASY-backfill scheduler simulation — the
   per-node holes no queued job can use come out as ``Fragment``s.
3. Hand that unfillable-hole trace to the BFTrainer ``Simulator`` with
   the ``AllocationEngine`` and compare against a named scenario from
   the library.

Every scenario also runs *live* with the same policy: pass
``run_live=True`` (and ``ManagedTrainer``s) to ``repro.sched.run_scenario``
and the identical ControlLoop decisions drive real elastic JAX trainers
(DESIGN.md §9).

Run:  PYTHONPATH=src python examples/workload_scenarios.py
"""
from repro.core import (
    AllocationEngine,
    MILPAllocator,
    Simulator,
    TrainerJob,
    eq_nodes,
    fragments_to_events,
    static_outcome,
    tab2_curve,
)
from repro.sched import (
    build_scenario,
    offered_load,
    run_scenario,
    simulate_schedule,
    synthetic_workload,
)

N_NODES = 32
HOURS = 12.0


def trainers(n=6):
    return [TrainerJob(id=i, curve=tab2_curve("ShuffleNet"), work=3e11,
                       n_min=1, n_max=16, r_up=20.0, r_dw=5.0)
            for i in range(n)]


def main() -> None:
    duration = HOURS * 3600.0

    # --- 1. a job log, as a real scheduler would see it -----------------
    jobs = synthetic_workload(duration=duration, seed=3,
                              mean_interarrival=420.0,
                              size_choices=(1, 2, 4, 8),
                              runtime_median=1800.0, overestimate=3.0)
    print(f"workload: {len(jobs)} jobs, offered load "
          f"{offered_load(jobs, N_NODES, duration):.2f}")

    # --- 2. FCFS + EASY backfill → unfillable holes ---------------------
    res = simulate_schedule(jobs, N_NODES, horizon=duration)
    frags = res.fragments()
    print(f"scheduler: utilization {res.stats.utilization:.1%}, "
          f"{res.stats.n_backfilled} backfilled, "
          f"{len(frags)} unfillable fragments "
          f"({res.stats.idle_fraction:.1%} of node-time)")

    # --- 3. BFTrainer harvests the holes --------------------------------
    events = fragments_to_events(frags)
    n_eq = max(1, round(eq_nodes(events, 0, duration)))
    a_s = static_outcome(trainers(), n_eq, duration, MILPAllocator("fast"))
    rep = Simulator(events, trainers(), AllocationEngine(), t_fwd=120.0,
                    horizon=duration).run()
    print(f"BFTrainer: {rep.total_samples:.3e} samples on the holes "
          f"(U={rep.total_samples/a_s:5.1%} of {n_eq} dedicated nodes), "
          f"solver {rep.solver_wall_total:.2f}s")

    # --- same flow, one line, via the scenario library ------------------
    sc = build_scenario("bursty", scale=0.25, seed=3)
    print(f"scenario '{sc.name}': {sc.stats.n_fragments} fragments, "
          f"idle fraction {sc.stats.idle_fraction:.1%} "
          f"({sc.description})")
    rep2 = run_scenario(sc, trainers())    # run_live=True for real trainers
    print(f"scenario replay: {rep2.total_samples:.3e} samples, "
          f"{rep2.events_processed} allocation events")


if __name__ == "__main__":
    main()
