"""HPO scenario (paper §5.1): one user runs many trials of the same DNN;
BFTrainer maximizes aggregate throughput.  Sweeps the forward-looking
time T_fwd and reports the efficiency/ROI trade-off (paper Figs 7-9).

Run:  PYTHONPATH=src python examples/hpo_search.py
"""
import numpy as np

from repro.core import MILPAllocator, Simulator, TrainerJob, eq_nodes, \
    fragments_to_events, generate_summit_like, static_outcome, tab2_curve

HOURS = 18.0


def trials(n=10):
    curve = tab2_curve("ShuffleNet")
    return [TrainerJob(id=i, curve=curve, work=5e8, n_min=1, n_max=16,
                       r_up=20.0, r_dw=5.0) for i in range(n)]


def main() -> None:
    frags = generate_summit_like(n_nodes=192, duration=HOURS * 3600, seed=9)
    events = fragments_to_events(frags)
    n_eq = round(eq_nodes(events, 0, HOURS * 3600))
    a_s = static_outcome(trials(), n_eq, HOURS * 3600, MILPAllocator("fast"))

    print(f"{'T_fwd':>6} {'U':>7} {'rescale(samples/ev)':>20} {'ROI':>8} "
          f"{'trials done':>12}")
    for t_fwd in (10, 30, 60, 120, 300, 600):
        jobs = trials()
        rep = Simulator(events, jobs, MILPAllocator("fast"),
                        t_fwd=float(t_fwd), horizon=HOURS * 3600).run()
        inv = [r.rescale_cost_samples for r in rep.event_records
               if r.rescale_cost_samples > 0]
        ret = [r.outcome_until_next for r in rep.event_records
               if r.rescale_cost_samples > 0]
        roi = np.sum(ret) / np.sum(inv) if inv else float("inf")
        done = sum(1 for j in jobs if j.finished_at is not None)
        print(f"{t_fwd:>6} {rep.total_samples/a_s:>7.1%} "
              f"{rep.rescale_cost_samples/max(rep.events_processed,1):>20.2e} "
              f"{roi:>8.1f} {done:>12}")


if __name__ == "__main__":
    main()
