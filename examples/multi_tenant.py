"""Multi-user scenario (paper §5.2-5.3): diverse DNNs submitted to one
BFTrainer instance; compares the two objective metrics (raw throughput vs
scaling efficiency) and their fairness implications.

Run:  PYTHONPATH=src python examples/multi_tenant.py
"""
from collections import defaultdict

import numpy as np

from repro.core import MILPAllocator, Simulator, TrainerJob, \
    fragments_to_events, generate_summit_like, tab2_curve
from repro.core.scaling import TAB2

HOURS = 24.0


def submissions(metric: str, n=21, seed=1):
    rng = np.random.default_rng(seed)
    names = list(TAB2)
    jobs, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(1800.0))
        jobs.append(TrainerJob(id=i, curve=tab2_curve(names[i % len(names)]),
                               work=2e8, n_min=1, n_max=24, r_up=20.0,
                               r_dw=5.0, arrival=t, metric=metric))
    return jobs


def main() -> None:
    frags = generate_summit_like(n_nodes=96, duration=HOURS * 3600, seed=17)
    events = fragments_to_events(frags)
    for metric in ("throughput", "efficiency"):
        jobs = submissions(metric)
        rep = Simulator(events, jobs, MILPAllocator("fast"), t_fwd=120.0,
                        pj_max=10, horizon=HOURS * 3600).run()
        runtimes = defaultdict(list)
        for j in jobs:
            if j.finished_at is not None:
                runtimes[j.curve.name].append((j.finished_at - j.arrival) / 3600)
        print(f"\nobjective metric = {metric!r} "
              f"(total {rep.total_samples:.2e} samples)")
        for name in TAB2:
            if runtimes[name]:
                print(f"  {name:12s} avg runtime {np.mean(runtimes[name]):6.2f} h")
        means = [np.mean(v) for v in runtimes.values() if v]
        if means:
            print(f"  spread (max/min): {max(means)/min(means):.1f}x  "
                  f"<- paper: throughput metric starves compute-heavy DNNs")


if __name__ == "__main__":
    main()
