"""Quickstart: the BFTrainer loop in ~40 lines.

1. Generate a Summit-calibrated idle-node trace.
2. Submit four DNN Trainers (paper Tab-2 scaling curves).
3. Let the MILP allocator re-fit them to the changing pool; report
   utilization efficiency vs the static-equivalent baseline.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (
    EqualShareAllocator,
    MILPAllocator,
    Simulator,
    TrainerJob,
    eq_nodes,
    fragments_to_events,
    generate_summit_like,
    static_outcome,
    tab2_curve,
)

HOURS = 24.0


def jobs():
    return [TrainerJob(id=i, curve=tab2_curve("ShuffleNet"), work=1e12,
                       n_min=1, n_max=24, r_up=20.0, r_dw=5.0)
            for i in range(8)]


def main() -> None:
    fragments = generate_summit_like(n_nodes=96, duration=HOURS * 3600, seed=0)
    events = fragments_to_events(fragments)
    print(f"trace: {len(fragments)} fragments, {len(events)} events, "
          f"eq-nodes={eq_nodes(events, 0, HOURS*3600):.1f}")

    a_s = static_outcome(jobs(), round(eq_nodes(events, 0, HOURS * 3600)),
                         HOURS * 3600, MILPAllocator("fast"))
    for alloc in (MILPAllocator("fast"), EqualShareAllocator()):
        rep = Simulator(events, jobs(), alloc, t_fwd=120.0,
                        horizon=HOURS * 3600).run()
        print(f"{alloc.name:12s}: processed {rep.total_samples:.3e} samples "
              f"(U={rep.total_samples/a_s:5.1%}), "
              f"rescale cost {rep.rescale_cost_samples:.2e} samples, "
              f"{rep.events_processed} allocations, "
              f"solver {rep.solver_wall_total:.2f}s total")


if __name__ == "__main__":
    main()
