"""Batched serving example: prefill + greedy decode for several assigned
architectures (dense GQA, SSM, MLA, hybrid) via the ServeEngine.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import build_model
from repro.serving import ServeEngine

ARCHS = ["gemma-2b", "mamba2-2.7b", "deepseek-v2-lite-16b", "jamba-v0.1-52b"]


def main() -> None:
    rng = np.random.RandomState(0)
    for arch in ARCHS:
        cfg = get_arch(arch).reduced()
        model = build_model(cfg, remat=False)
        params = model.init(jax.random.key(0))
        eng = ServeEngine(model, params, max_len=96)
        prompts = jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 16)),
                              jnp.int32)
        res = eng.generate({"tokens": prompts}, n_new=16)
        print(f"{arch:24s} prefill {res.prefill_time_s*1e3:7.1f}ms  "
              f"decode {res.decode_time_s*1e3:7.1f}ms  "
              f"{res.tokens_per_s:7.1f} tok/s  out={res.tokens.shape}")


if __name__ == "__main__":
    main()
