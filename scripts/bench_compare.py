#!/usr/bin/env python
"""Diff fresh BENCH_*.json perf artifacts against committed baselines.

``benchmarks/baselines/BENCH_<name>.baseline.json`` holds smoke-mode
artifacts committed with the repo; CI regenerates the same artifacts
per commit and runs this script so a perf or quality regression fails
the build instead of silently drifting.  Rows are matched by their
natural keys (nodes × jobs for the allocator sweep, scenario × policy
for the objectives sweep, …) and every shared numeric field is
classified by name into a tolerance class:

* **time-like** (``*_ms*``, ``*wall*``, ``*_s`` suffixes) — flagged
  only when the fresh value exceeds baseline × ``--time-tol`` (default
  4.0: CI runners are noisy, so only order-of-magnitude regressions
  should fail; improvements never do);
* **parity/gap** — solution-parity fields; fresh must stay ≤
  max(baseline × 10, 2e-3).  The absolute floor is 2× the engine's
  ``repair_gap`` acceptance bound (1e-3): a run may legitimately land
  anywhere in [0, repair_gap] depending on which events the wall-clock
  budget lets escalate, so only gaps past the contract are
  regressions;
* **quality** (efficiency ``u``, fairness, hit/miss rates) — bounded
  drift: |fresh − baseline| ≤ ``--quality-tol`` (default 0.25);
* everything else (counts, flags, schema strings) — exact for strings
  and booleans, informational for numbers.

Rows present in the baseline but missing fresh are failures (a tier
was dropped); new fresh rows are reported but pass (a tier was added).

Usage:
    python scripts/bench_compare.py [--baseline-dir benchmarks/baselines]
                                    [--fresh-dir .] [names...]

``names`` restricts the comparison (e.g. ``allocator objectives``);
default is every baseline present.  Exits non-zero on any violation.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

#: baseline-row keys used to match a fresh row, per artifact kind and
#: row-list key.  Artifact kind = the <name> in BENCH_<name>.json.
ROW_KEYS = {
    ("allocator", "sweep"): ("nodes", "jobs"),
    ("allocator", "federated"): ("nodes", "pools"),
    ("chaos", "sweep"): ("mtbf_h",),
    ("objectives", "policies"): ("scenario", "policy"),
    ("objectives", "metrics"): ("metric",),
    ("scalability", "rows"): ("dnn",),
    ("serving", "scenarios"): ("scenario",),
    ("resilience", "corruption"): ("corrupt_prob",),
    ("resilience", "deadline"): ("deadline_ms",),
}

#: top-level keys that are never compared numerically
SKIP_FIELDS = {"schema", "generated_unix"}


def _classify(field: str) -> str:
    f = field.lower()
    if "parity" in f or "gap" in f:
        return "parity"
    if "ms" in f or "wall" in f or f.endswith("_s") or f == "speedup" \
            or "speedup" in f:
        return "time"
    if ("rate" in f or "fairness" in f or "progress" in f
            or f.startswith("u_") or f.endswith("_u") or f == "u"
            or "spread" in f or "frac" in f or "attainment" in f):
        return "quality"
    return "info"


class Comparison:
    def __init__(self, time_tol: float, quality_tol: float):
        self.time_tol = time_tol
        self.quality_tol = quality_tol
        self.failures: list = []
        self.notes: list = []

    def field(self, where: str, name: str, base, fresh) -> None:
        if name in SKIP_FIELDS:
            return
        if isinstance(base, str) or isinstance(base, bool):
            if base != fresh:
                # schema strings must match exactly; flags (e.g.
                # monolithic_extrapolated) flipping is a real change
                self.failures.append(
                    f"{where}.{name}: {base!r} -> {fresh!r}")
            return
        if not isinstance(base, (int, float)) or \
                not isinstance(fresh, (int, float)):
            return
        cls = _classify(name)
        if cls == "time":
            # speedups regress downward, walls regress upward
            if "speedup" in name.lower():
                if fresh < base / self.time_tol:
                    self.failures.append(
                        f"{where}.{name}: speedup {base:.2f} -> "
                        f"{fresh:.2f} (< 1/{self.time_tol:g} of baseline)")
            elif fresh > base * self.time_tol and fresh > 1.0:
                self.failures.append(
                    f"{where}.{name}: {base:.3g} -> {fresh:.3g} "
                    f"(> {self.time_tol:g}x baseline)")
        elif cls == "parity":
            # floor = 2x the engine's repair_gap acceptance bound:
            # parity varies in [0, repair_gap] run-to-run (wall-clock
            # budget gating), so only contract violations fail
            ceiling = max(base * 10.0, 2e-3)
            if fresh > ceiling:
                self.failures.append(
                    f"{where}.{name}: parity {base:.3g} -> {fresh:.3g} "
                    f"(> {ceiling:.3g})")
        elif cls == "quality":
            if abs(fresh - base) > self.quality_tol:
                self.failures.append(
                    f"{where}.{name}: {base:.3f} -> {fresh:.3f} "
                    f"(drift > {self.quality_tol:g})")
        else:
            if fresh != base:
                self.notes.append(
                    f"{where}.{name}: {base!r} -> {fresh!r} (info)")


def compare_payloads(kind: str, base: dict, fresh: dict,
                     cmp: Comparison) -> None:
    if base.get("schema") != fresh.get("schema"):
        cmp.failures.append(
            f"{kind}: schema {base.get('schema')!r} != "
            f"{fresh.get('schema')!r} — regenerate the baseline")
        return
    for key, value in base.items():
        if key in SKIP_FIELDS:
            continue
        where = f"{kind}.{key}"
        if isinstance(value, list) and (kind, key) in ROW_KEYS:
            match_on = ROW_KEYS[(kind, key)]
            fresh_rows = {
                tuple(r.get(k) for k in match_on): r
                for r in fresh.get(key, []) if isinstance(r, dict)}
            for row in value:
                rid = tuple(row.get(k) for k in match_on)
                label = f"{where}[{'/'.join(str(x) for x in rid)}]"
                if rid not in fresh_rows:
                    cmp.failures.append(f"{label}: row missing from "
                                        f"fresh artifact")
                    continue
                for fname, fval in row.items():
                    if fname in fresh_rows[rid]:
                        cmp.field(label, fname, fval,
                                  fresh_rows[rid][fname])
            extra = set(fresh_rows) - {
                tuple(r.get(k) for k in match_on) for r in value}
            for rid in sorted(extra, key=str):
                cmp.notes.append(f"{where}: new row "
                                 f"{'/'.join(str(x) for x in rid)}")
        elif isinstance(value, dict):
            # e.g. week.arms / week.trace: recurse one level by name
            for sub, subrow in value.items():
                if isinstance(subrow, dict):
                    if sub not in fresh.get(key, {}):
                        cmp.failures.append(f"{where}[{sub}]: missing")
                        continue
                    for fname, fval in subrow.items():
                        if fname in fresh[key][sub]:
                            cmp.field(f"{where}[{sub}]", fname, fval,
                                      fresh[key][sub][fname])
                else:
                    if key in fresh and sub in fresh[key]:
                        cmp.field(where, sub, subrow, fresh[key][sub])
        elif not isinstance(value, list):
            if key in fresh:
                cmp.field(kind, key, value, fresh[key])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("names", nargs="*",
                    help="artifact kinds to compare (default: all "
                         "baselines present)")
    ap.add_argument("--baseline-dir", default="benchmarks/baselines")
    ap.add_argument("--fresh-dir", default=".")
    ap.add_argument("--time-tol", type=float, default=4.0,
                    help="wall-time regression factor (default 4x)")
    ap.add_argument("--quality-tol", type=float, default=0.25,
                    help="absolute quality-metric drift (default 0.25)")
    args = ap.parse_args(argv)

    base_dir = Path(args.baseline_dir)
    fresh_dir = Path(args.fresh_dir)
    baselines = sorted(base_dir.glob("BENCH_*.baseline.json"))
    if args.names:
        baselines = [p for p in baselines
                     if p.name.replace("BENCH_", "").replace(
                         ".baseline.json", "") in set(args.names)]
    if not baselines:
        print(f"bench-compare: no baselines found in {base_dir}")
        return 1

    cmp = Comparison(args.time_tol, args.quality_tol)
    compared = 0
    for bpath in baselines:
        kind = bpath.name.replace("BENCH_", "").replace(
            ".baseline.json", "")
        fpath = fresh_dir / f"BENCH_{kind}.json"
        if not fpath.exists():
            print(f"bench-compare: {fpath} not present, skipping {kind}")
            continue
        with open(bpath, encoding="utf-8") as f:
            base = json.load(f)
        with open(fpath, encoding="utf-8") as f:
            fresh = json.load(f)
        compared += 1
        compare_payloads(kind, base, fresh, cmp)

    for note in cmp.notes:
        print(f"  note: {note}")
    if cmp.failures:
        print(f"bench-compare: {len(cmp.failures)} regression(s) vs "
              f"baseline:")
        for fail in cmp.failures:
            print(f"  FAIL: {fail}")
        return 1
    if compared == 0:
        print("bench-compare: nothing compared (no fresh artifacts)")
        return 0
    print(f"bench-compare: OK ({compared} artifact(s) within tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
