#!/usr/bin/env python
"""Docs-consistency check: every ``repro`` import shown in a Markdown
python code fence must actually work against ``src/``, the bench
JSON schema documented in EXPERIMENTS.md must match
``benchmarks/schema.py`` (and any BENCH_*.json present on disk), and
the documented trace-JSONL schema must match ``repro.obs.spans``.

Scans the given Markdown files (default: README.md DESIGN.md
EXPERIMENTS.md), extracts fenced ```python blocks, parses each with
``ast`` (fences that are pseudo-code and do not parse are skipped), and
for every ``import repro...`` / ``from repro... import name`` statement
verifies the module imports and the names exist.  Exits non-zero with a
per-failure report — wired into CI so documented examples cannot rot
when the API moves (as happened after the PR-3 facade refactor).

The bench-schema pass parses ```json fences whose top-level keys name
the perf-trajectory artifacts (``BENCH_week.json`` /
``BENCH_allocator.json`` / ``BENCH_chaos.json``) and requires the
documented key lists to
equal the declared schema constants — so a key cannot be added, renamed
or dropped without updating docs, schema, and emitters together
(EXPERIMENTS.md §Scale).

Usage:  PYTHONPATH=src python scripts/check_docs.py [files...]
"""
from __future__ import annotations

import ast
import importlib
import json
import re
import sys
from pathlib import Path

FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)
JSON_FENCE = re.compile(r"```json\n(.*?)```", re.DOTALL)


def iter_repro_imports(code: str):
    """Yield (lineno, module, names) for repro imports in parseable code."""
    try:
        tree = ast.parse(code)
    except SyntaxError:
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.split(".")[0] == "repro":
                    yield node.lineno, alias.name, []
        elif isinstance(node, ast.ImportFrom):
            if node.module and node.module.split(".")[0] == "repro":
                yield (node.lineno, node.module,
                       [a.name for a in node.names])


def check_file(path: Path) -> list:
    failures = []
    text = path.read_text(encoding="utf-8")
    for m in FENCE.finditer(text):
        code = m.group(1)
        for lineno, module, names in iter_repro_imports(code):
            try:
                mod = importlib.import_module(module)
            except Exception as exc:
                failures.append(f"{path}: import {module}: {exc!r}")
                continue
            for name in names:
                if name == "*":
                    continue
                if not hasattr(mod, name):
                    failures.append(
                        f"{path}: from {module} import {name}: "
                        f"name does not exist")
    return failures


def check_bench_schema(root: Path) -> list:
    """EXPERIMENTS.md's documented bench-JSON keys must equal
    ``benchmarks.schema``'s declared constants; on-disk BENCH_*.json
    artifacts (if any — CI emits them first) must validate too."""
    sys.path.insert(0, str(root))
    try:
        from benchmarks import schema
    except Exception as exc:
        return [f"benchmarks.schema unimportable: {exc!r}"]
    declared = {
        "BENCH_week.json": schema.WEEK_KEYS,
        "BENCH_week.json arms.*": schema.WEEK_ARM_KEYS,
        "BENCH_allocator.json": schema.ALLOCATOR_KEYS,
        "BENCH_allocator.json sweep[]": schema.ALLOCATOR_ROW_KEYS,
        "BENCH_allocator.json federated[]": schema.FEDERATED_ROW_KEYS,
        "BENCH_chaos.json": schema.CHAOS_KEYS,
        "BENCH_chaos.json sweep[]": schema.CHAOS_ROW_KEYS,
        "BENCH_objectives.json": schema.OBJECTIVES_KEYS,
        "BENCH_objectives.json policies[]":
            schema.OBJECTIVES_POLICY_ROW_KEYS,
        "BENCH_objectives.json metrics[]":
            schema.OBJECTIVES_METRIC_ROW_KEYS,
        "BENCH_scalability.json": schema.SCALABILITY_KEYS,
        "BENCH_scalability.json rows[]": schema.SCALABILITY_ROW_KEYS,
        "BENCH_serving.json": schema.SERVING_KEYS,
        "BENCH_serving.json scenarios[]": schema.SERVING_ROW_KEYS,
        "BENCH_resilience.json": schema.RESILIENCE_KEYS,
        "BENCH_resilience.json corruption[]":
            schema.RESILIENCE_CORRUPTION_ROW_KEYS,
        "BENCH_resilience.json deadline[]":
            schema.RESILIENCE_DEADLINE_ROW_KEYS,
    }
    failures = []
    exp = root / "EXPERIMENTS.md"
    text = exp.read_text(encoding="utf-8")
    documented = {}
    for m in JSON_FENCE.finditer(text):
        try:
            obj = json.loads(m.group(1))
        except json.JSONDecodeError:
            continue
        if isinstance(obj, dict):
            for k, v in obj.items():
                if k in declared and isinstance(v, list):
                    documented[k] = v
    for name, keys in declared.items():
        if name not in documented:
            failures.append(
                f"{exp}: bench schema for {name!r} not documented "
                f"(EXPERIMENTS.md §Scale json fence)")
        elif sorted(documented[name]) != sorted(keys):
            failures.append(
                f"{exp}: {name!r} keys {sorted(documented[name])} != "
                f"benchmarks.schema {sorted(keys)}")
    for artifact in ("BENCH_week.json", "BENCH_allocator.json",
                     "BENCH_chaos.json", "BENCH_objectives.json",
                     "BENCH_scalability.json", "BENCH_serving.json",
                     "BENCH_resilience.json"):
        p = root / artifact
        if p.exists():
            failures.extend(schema.validate_bench_file(str(p)))
    # committed baselines must conform to the same schemas — they are
    # what scripts/bench_compare.py diffs CI's fresh artifacts against
    for p in sorted((root / "benchmarks" / "baselines").glob(
            "BENCH_*.baseline.json")):
        failures.extend(schema.validate_bench_file(str(p)))
    return failures


def check_trace_schema(root: Path) -> list:
    """EXPERIMENTS.md §Telemetry's documented trace-JSONL schema must
    equal ``repro.obs.spans``'s declared constants (tag + key set)."""
    try:
        from repro.obs import spans
    except Exception as exc:
        return [f"repro.obs.spans unimportable: {exc!r}"]
    exp = root / "EXPERIMENTS.md"
    text = exp.read_text(encoding="utf-8")
    documented_tag = None
    documented_keys = None
    for m in JSON_FENCE.finditer(text):
        try:
            obj = json.loads(m.group(1))
        except json.JSONDecodeError:
            continue
        if isinstance(obj, dict) and "trace.jsonl" in obj:
            documented_tag = obj.get("trace.jsonl")
            documented_keys = obj.get("trace.jsonl events[]")
    failures = []
    if documented_tag is None:
        failures.append(
            f"{exp}: trace schema not documented "
            f"(EXPERIMENTS.md §Telemetry json fence)")
        return failures
    if documented_tag != spans.TRACE_SCHEMA:
        failures.append(
            f"{exp}: documented trace schema {documented_tag!r} != "
            f"repro.obs.spans.TRACE_SCHEMA {spans.TRACE_SCHEMA!r}")
    if documented_keys != spans.TRACE_EVENT_KEYS:
        failures.append(
            f"{exp}: documented trace event keys {documented_keys} != "
            f"repro.obs.spans.TRACE_EVENT_KEYS {spans.TRACE_EVENT_KEYS}")
    return failures


def main(argv) -> int:
    root = Path(__file__).resolve().parent.parent
    files = ([Path(a) for a in argv] if argv else
             [root / n for n in ("README.md", "DESIGN.md", "EXPERIMENTS.md")])
    failures, checked = [], 0
    for f in files:
        if not f.exists():
            failures.append(f"{f}: file not found")
            continue
        checked += 1
        failures.extend(check_file(f))
    failures.extend(check_bench_schema(root))
    failures.extend(check_trace_schema(root))
    if failures:
        print(f"docs-consistency: {len(failures)} failure(s):")
        for fail in failures:
            print(f"  {fail}")
        return 1
    print(f"docs-consistency: OK ({checked} file(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
