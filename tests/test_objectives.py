"""Pluggable objective/policy subsystem tests (DESIGN.md §10).

Covers: registry/resolution, Throughput regression parity (manual Eqn 16
+ default-vs-explicit scenario replay), weighted dominance, max-min
anti-starvation, deadline-penalty monotonicity, CostCap budget caps,
greedy-vs-MILP parity per policy, node-vs-fast MILP agreement per
policy, engine cache keying per (signature, policy), budget accounting
in the ControlLoop, and the fairness >= equal-share hypothesis property.
"""
import math

import numpy as np
import pytest

from repro.core import (
    AllocationEngine,
    AllocationProblem,
    CostCap,
    DeadlineAware,
    EqualShareAllocator,
    MaxMinFairness,
    Objective,
    OBJECTIVES,
    Throughput,
    TrainerSpec,
    WeightedPriority,
    resolve_objective,
    solve_fast_milp,
    solve_greedy,
    solve_node_milp,
)
from repro.core.engine import problem_signature
from repro.core.events import PoolEvent
from repro.core.loop import TrainerJob as LoopTrainerJob
from repro.core.scaling import TAB2, tab2_curve
from repro.core.simulator import Simulator, TrainerJob

from tests.test_engine import check_allocation_invariants, manual_objective


def mkspec(i, name="ShuffleNet", n_min=1, n_max=8, r_up=20.0, r_dw=5.0,
           **extra):
    curve = tab2_curve(name)
    pts, vals = curve.breakpoints(n_min, n_max)
    return TrainerSpec(id=i, n_min=n_min, n_max=n_max, r_up=r_up, r_dw=r_dw,
                       points=tuple(pts), values=tuple(vals), **extra)


def random_policy_instance(seed, objective, n_lo=6, n_hi=20, j_lo=2, j_hi=5):
    """Random instance with the per-job policy fields populated."""
    rng = np.random.RandomState(seed)
    n_nodes = rng.randint(n_lo, n_hi)
    nodes = list(range(n_nodes))
    trainers, current, used = [], {}, set()
    for j in range(rng.randint(j_lo, j_hi)):
        name = list(TAB2)[(seed + j) % len(TAB2)]
        n_min = rng.randint(1, 3)
        n_max = rng.randint(n_min + 1, 12)
        work = float(rng.uniform(1e7, 1e9))
        trainers.append(mkspec(
            j, name, n_min=n_min, n_max=n_max,
            r_up=float(rng.uniform(5, 40)), r_dw=float(rng.uniform(1, 10)),
            weight=float(rng.choice([0.5, 1.0, 2.0, 4.0])),
            deadline=float(rng.uniform(100, 5000)),
            budget=float(rng.uniform(50, 5000)),
            work=work, progress=float(rng.uniform(0.0, 0.9))))
        k = rng.randint(0, min(n_max, n_nodes - len(used)) + 1)
        if 0 < k < n_min:
            k = 0
        avail = [x for x in nodes if x not in used]
        cur = [int(c) for c in
               rng.choice(avail, size=min(k, len(avail)), replace=False)]
        current[j] = cur
        used.update(cur)
    t_fwd = float(rng.choice([30.0, 60.0, 120.0, 300.0]))
    return AllocationProblem(nodes=nodes, trainers=trainers, current=current,
                             t_fwd=t_fwd, objective=objective)


def policy_objective_of(prob, counts):
    """Evaluate a count vector under the problem's policy (reference)."""
    obj = resolve_objective(prob.objective)
    node_set = set(prob.nodes)
    vals = []
    for t in prob.trainers:
        cj = len([n for n in prob.current.get(t.id, []) if n in node_set])
        vals.append(obj.job_value(t, counts[t.id], cj, prob.t_fwd))
    return obj.combine(vals, prob.trainers)


# ---------------------------------------------------------------------------
# Registry / resolution
# ---------------------------------------------------------------------------


def test_resolve_objective():
    assert isinstance(resolve_objective(None), Throughput)
    for name, cls in OBJECTIVES.items():
        o = resolve_objective(name)
        assert isinstance(o, cls) and o.name == name
    mm = MaxMinFairness(tiebreak=0.01)
    assert resolve_objective(mm) is mm
    with pytest.raises(KeyError):
        resolve_objective("nope")
    with pytest.raises(TypeError):
        resolve_objective(42)


def test_cache_keys_distinguish_params():
    assert MaxMinFairness().cache_key() != MaxMinFairness(0.05).cache_key()
    assert WeightedPriority().cache_key() != \
        WeightedPriority({0: 2.0}).cache_key()
    assert Throughput().cache_key() != DeadlineAware().cache_key()


# ---------------------------------------------------------------------------
# Throughput: regression parity with the pre-policy allocator
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_throughput_matches_manual_eqn16(seed):
    from tests.test_engine import random_instance
    prob = random_instance(seed)
    for explicit in (None, Throughput(), "throughput"):
        prob.objective = explicit
        r = solve_fast_milp(prob, time_limit=60)
        assert r.objective == pytest.approx(
            manual_objective(prob, r.counts), rel=1e-6)
        g = solve_greedy(prob)
        assert g.objective == pytest.approx(
            manual_objective(prob, g.counts), rel=1e-6)


def _scenario_jobs():
    return [TrainerJob(id=i, curve=tab2_curve(list(TAB2)[i % len(TAB2)]),
                       work=1e12, n_min=1, n_max=16, r_up=20.0, r_dw=5.0)
            for i in range(4)]


@pytest.mark.parametrize("name", ["capability", "capacity", "bursty",
                                  "maintenance", "weekend", "overestimate"])
def test_throughput_scenario_allocations_bit_for_bit(name):
    """Acceptance: the default (objective=None) replay of every scenario is
    bit-for-bit identical to an explicit Throughput() replay — i.e. the
    policy refactor did not change the paper's allocator behavior."""
    from repro.sched import build_scenario
    from repro.core.events import fragments_to_events

    sc = build_scenario(name, scale=0.1, seed=3)
    events = fragments_to_events(sc.fragments)

    def run(objective):
        eng = AllocationEngine(time_budget=0.0)   # deterministic portfolio
        sim = Simulator(events, _scenario_jobs(), eng, t_fwd=120.0,
                        horizon=sc.duration, objective=objective)
        return sim.run()

    base, explicit = run(None), run(Throughput())
    assert base.total_samples == explicit.total_samples
    assert base.events_processed == explicit.events_processed
    assert len(base.event_records) == len(explicit.event_records)
    for a, b in zip(base.event_records, explicit.event_records):
        assert a.time == b.time
        assert a.allocated == b.allocated
        assert a.outcome_until_next == b.outcome_until_next


# ---------------------------------------------------------------------------
# WeightedPriority
# ---------------------------------------------------------------------------


def test_weighted_uniform_reduces_to_throughput():
    from tests.test_engine import random_instance
    for seed in range(5):
        prob = random_instance(seed)
        prob.objective = None
        base = solve_fast_milp(prob, time_limit=60)
        prob.objective = WeightedPriority()
        w = solve_fast_milp(prob, time_limit=60)
        assert w.counts == base.counts
        assert w.objective == pytest.approx(base.objective, rel=1e-6)


def test_weighted_dominance():
    """Raising one job's weight never shrinks its allocation, and a large
    enough weight flips a contended decision its way."""
    # two identical jobs, 6 nodes, each wants up to 6: contention
    t0 = mkspec(0, "ResNet18", n_min=2, n_max=6)
    counts_at = {}
    for w in (1.0, 2.0, 8.0, 64.0):
        t1 = mkspec(1, "ResNet18", n_min=2, n_max=6, weight=w)
        prob = AllocationProblem(nodes=list(range(6)), trainers=[t0, t1],
                                 current={0: [], 1: []}, t_fwd=120.0,
                                 objective=WeightedPriority())
        r = solve_fast_milp(prob, time_limit=60)
        counts_at[w] = r.counts
        check_allocation_invariants(prob, r)
    ws = sorted(counts_at)
    for lo, hi in zip(ws, ws[1:]):
        assert counts_at[hi][1] >= counts_at[lo][1]
    assert counts_at[64.0][1] > counts_at[64.0][0]


def test_weighted_mapping_overrides_spec():
    t0 = mkspec(0, "ResNet18", n_min=2, n_max=6, weight=1.0)
    t1 = mkspec(1, "ResNet18", n_min=2, n_max=6, weight=1.0)
    prob = AllocationProblem(nodes=list(range(6)), trainers=[t0, t1],
                             current={0: [], 1: []}, t_fwd=120.0,
                             objective=WeightedPriority({0: 100.0}))
    r = solve_fast_milp(prob, time_limit=60)
    assert r.counts[0] > r.counts[1]


# ---------------------------------------------------------------------------
# MaxMinFairness
# ---------------------------------------------------------------------------


def test_maxmin_unstarves_job_the_throughput_policy_starves():
    """Only one of two jobs can run (n_min = pool size).  Throughput
    always picks the faster DNN; max-min picks the one that is behind."""
    ahead = mkspec(0, "AlexNet", n_min=4, n_max=4, work=1e9, progress=0.5)
    behind = mkspec(1, "DenseNet", n_min=4, n_max=4, work=1e9, progress=0.0)
    nodes = list(range(4))
    thr = AllocationProblem(nodes=nodes, trainers=[ahead, behind],
                            current={0: [], 1: []}, t_fwd=120.0)
    r_thr = solve_fast_milp(thr, time_limit=60)
    assert r_thr.counts == {0: 4, 1: 0}      # throughput starves DenseNet

    fair = AllocationProblem(nodes=nodes, trainers=[ahead, behind],
                             current={0: [], 1: []}, t_fwd=120.0,
                             objective=MaxMinFairness())
    for solve in (solve_fast_milp, solve_node_milp, solve_greedy):
        r = solve(fair)
        assert r.counts == {0: 0, 1: 4}, solve.__name__


def test_maxmin_equalizes_over_a_trace():
    """Acceptance-criterion shape: replaying a contended trace, max-min
    must raise the minimum normalized progress vs throughput."""
    events = [PoolEvent(time=float(k * 200), joined=(k % 4,))
              if k % 2 == 0 else
              PoolEvent(time=float(k * 200), left=((k - 1) % 4,))
              for k in range(24)]

    def jobs():
        return [TrainerJob(id=i, curve=tab2_curve(n), work=2e7,
                           n_min=1, n_max=4, r_up=2.0, r_dw=1.0)
                for i, n in enumerate(["AlexNet", "VGG-16", "DenseNet"])]

    def min_prog(objective):
        js = jobs()
        Simulator(events, js, AllocationEngine(time_budget=0.0),
                  t_fwd=120.0, horizon=5000.0, objective=objective).run()
        return min(min(j.done / j.work, 1.0) for j in js)

    assert min_prog(MaxMinFairness()) > min_prog(None) + 0.01


def test_maxmin_hypothesis_fairness_vs_equal_share():
    """Property: the fairness objective's min projected normalized
    progress is never below the equal-share heuristic's minus epsilon."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    obj = MaxMinFairness()

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 10_000))
    def prop(seed):
        rng = np.random.RandomState(seed)
        n_nodes = int(rng.randint(4, 12))
        trainers = []
        for j in range(int(rng.randint(2, 4))):
            trainers.append(mkspec(
                j, list(TAB2)[j % len(TAB2)], n_min=1,
                n_max=int(rng.randint(2, 8)),
                work=float(rng.uniform(1e6, 1e8)),
                progress=float(rng.uniform(0, 0.9))))
        prob = AllocationProblem(
            nodes=list(range(n_nodes)), trainers=trainers,
            current={t.id: [] for t in trainers}, t_fwd=120.0, objective=obj)

        def min_p(counts):
            return min(obj.job_value(t, counts[t.id], 0, prob.t_fwd)
                       for t in trainers)

        fair = solve_fast_milp(prob, time_limit=60)
        eq = EqualShareAllocator().allocate(prob)
        assert fair.objective is not None
        # epsilon: the leximin tiebreak may trade up to its own total
        # weight of min-progress for higher-ranked gains
        eps = 2.0 * obj.tiebreak + 1e-9
        assert min_p(fair.counts) >= min_p(eq.counts) - eps

    prop()


# ---------------------------------------------------------------------------
# DeadlineAware
# ---------------------------------------------------------------------------


def test_deadline_penalty_monotone_in_deadline():
    """Looser deadline => lower required rate => value non-decreasing,
    at every node count."""
    obj = DeadlineAware()
    prev = None
    for dl in (50.0, 200.0, 1000.0, 10_000.0):
        t = mkspec(0, "DenseNet", n_max=8, work=1e8, progress=0.2,
                   deadline=dl)
        vals = [obj.job_value(t, n, 0, 120.0) for n in range(9)]
        if prev is not None:
            assert all(v >= p - 1e-9 for v, p in zip(vals, prev))
        prev = vals
    # no deadline == plain throughput
    t_free = mkspec(0, "DenseNet", n_max=8, work=1e8, progress=0.2)
    thr = Throughput()
    for n in range(9):
        assert obj.job_value(t_free, n, 0, 120.0) == \
            pytest.approx(thr.job_value(t_free, n, 0, 120.0))


def test_deadline_flips_a_contended_allocation():
    """An urgent slow job beats a fast job once the penalty weight is
    high enough — and loses without a deadline."""
    slow_urgent = mkspec(0, "DenseNet", n_min=4, n_max=4, work=5e6,
                         progress=0.0, deadline=700.0)
    fast = mkspec(1, "AlexNet", n_min=4, n_max=4)
    nodes = list(range(4))
    base = AllocationProblem(nodes=nodes, trainers=[slow_urgent, fast],
                             current={0: [], 1: []}, t_fwd=120.0)
    assert solve_fast_milp(base, time_limit=60).counts == {0: 0, 1: 4}
    dl = AllocationProblem(nodes=nodes, trainers=[slow_urgent, fast],
                           current={0: [], 1: []}, t_fwd=120.0,
                           objective=DeadlineAware(penalty_weight=50.0))
    for solve in (solve_fast_milp, solve_greedy):
        assert solve(dl).counts == {0: 4, 1: 0}, solve.__name__


# ---------------------------------------------------------------------------
# CostCap
# ---------------------------------------------------------------------------


def test_costcap_caps_counts_all_solvers():
    t = mkspec(0, "AlexNet", n_min=1, n_max=8, budget=360.0)
    prob = AllocationProblem(nodes=list(range(8)), trainers=[t],
                             current={0: []}, t_fwd=120.0,
                             objective=CostCap())
    for solve in (solve_fast_milp, solve_node_milp, solve_greedy):
        r = solve(prob)
        assert r.counts[0] == 3, solve.__name__      # floor(360/120)


def test_costcap_below_nmin_idles_job():
    t = mkspec(0, "AlexNet", n_min=4, n_max=8, budget=360.0)  # cap 3 < n_min
    prob = AllocationProblem(nodes=list(range(8)), trainers=[t],
                             current={0: []}, t_fwd=120.0,
                             objective=CostCap())
    for solve in (solve_fast_milp, solve_greedy):
        assert solve(prob).counts[0] == 0, solve.__name__


def test_costcap_default_budget_and_no_budget():
    t = mkspec(0, "AlexNet", n_min=1, n_max=8)
    uncapped = AllocationProblem(nodes=list(range(8)), trainers=[t],
                                 current={0: []}, t_fwd=120.0,
                                 objective=CostCap())
    assert solve_fast_milp(uncapped, time_limit=60).counts[0] == 8
    defaulted = AllocationProblem(nodes=list(range(8)), trainers=[t],
                                  current={0: []}, t_fwd=120.0,
                                  objective=CostCap(default_budget=240.0))
    assert solve_fast_milp(defaulted, time_limit=60).counts[0] == 2


def test_costcap_budget_accounting_in_loop():
    """The ControlLoop charges node-seconds and the spec projects the
    unspent remainder, so allocations shrink as the budget drains."""
    events = [PoolEvent(time=float(k * 50), joined=(100 + k,))
              for k in range(10)]
    job = LoopTrainerJob(id=0, curve=tab2_curve("AlexNet"), work=1e14,
                         n_min=1, n_max=8, r_up=0.0, r_dw=0.0,
                         budget=900.0)
    sim = Simulator(events, [job], AllocationEngine(time_budget=0.0),
                    t_fwd=100.0, horizon=500.0, objective=CostCap())
    sim.run()
    # 500 s x up to 8 nodes = 4000 node-s unbudgeted; the cap must bite
    assert job.node_seconds < 2000.0
    # decisions happen every 50 s with t_fwd=100: overshoot past the
    # budget is bounded by one window's spend (cap * inter-event gap)
    assert job.node_seconds <= 900.0 + 8 * 50.0


def test_maxmin_greedy_does_not_strand_free_nodes():
    """When one job pins the epigraph minimum (n_min > pool), the
    rank-decayed tiebreak gains are tiny but must still place every
    usable node on the remaining jobs."""
    trainers = [mkspec(j, list(TAB2)[j % len(TAB2)], n_min=1, n_max=8,
                       work=1e8, progress=0.0) for j in range(7)]
    trainers.append(mkspec(7, "AlexNet", n_min=64, n_max=64,
                           work=1e8, progress=0.0))   # pins the min
    prob = AllocationProblem(nodes=list(range(20)), trainers=trainers,
                             current={t.id: [] for t in trainers},
                             t_fwd=120.0, objective=MaxMinFairness())
    rg = solve_greedy(prob)
    assert sum(rg.counts.values()) == 20      # all placeable nodes used
    assert rg.counts[7] == 0


def test_maxmin_greedy_fills_deep_ranked_jobs():
    """Leximin weights underflow float64 past rank ~8; exact-delta move
    gains must still allocate to every deep-ranked job instead of
    rounding their tiebreak gains to zero."""
    trainers = [mkspec(j, "ResNet18", n_min=1, n_max=4, work=1e8,
                       progress=0.0) for j in range(12)]
    prob = AllocationProblem(nodes=list(range(60)), trainers=trainers,
                             current={t.id: [] for t in trainers},
                             t_fwd=120.0, objective=MaxMinFairness())
    r = solve_greedy(prob)
    assert all(r.counts[t.id] == 4 for t in trainers)   # 48 of 60 nodes


def test_weighted_zero_weight_job_gets_nothing_every_solver():
    """Weight 0 must pin the job to zero nodes in the MILPs too — an
    all-zero objective column alone leaves the solver indifferent."""
    t0 = mkspec(0, "ResNet18", n_min=1, n_max=2, weight=1.0)
    t1 = mkspec(1, "ResNet18", n_min=1, n_max=4, weight=0.0)
    prob = AllocationProblem(nodes=list(range(6)), trainers=[t0, t1],
                             current={0: [], 1: []}, t_fwd=120.0,
                             objective=WeightedPriority())
    for solve in (solve_fast_milp, solve_node_milp, solve_greedy):
        r = solve(prob)
        assert r.counts == {0: 2, 1: 0}, solve.__name__


def test_maxmin_combine_requires_trainers():
    with pytest.raises(ValueError):
        MaxMinFairness().combine([0.1, 0.2])


def test_nmin_above_pool_stays_feasible():
    """A Trainer whose n_min exceeds the pool must be forced to 0 nodes,
    not render the MILP infeasible (which would trigger the keep-current
    fallback and block every other job's re-allocation)."""
    big = mkspec(0, "AlexNet", n_min=20, n_max=32)
    small = mkspec(1, "DenseNet", n_min=1, n_max=8)
    prob = AllocationProblem(nodes=list(range(4)), trainers=[big, small],
                             current={0: [], 1: []}, t_fwd=120.0)
    for solve in (solve_fast_milp, solve_node_milp, solve_greedy):
        r = solve(prob)
        assert not r.fell_back, solve.__name__
        assert r.counts == {0: 0, 1: 4}, solve.__name__


# ---------------------------------------------------------------------------
# Greedy vs MILP parity, per policy
# ---------------------------------------------------------------------------


POLICIES = [Throughput(), WeightedPriority(), MaxMinFairness(),
            DeadlineAware(), CostCap()]


@pytest.mark.parametrize("objective", POLICIES, ids=lambda o: o.name)
@pytest.mark.parametrize("seed", range(8))
def test_greedy_vs_milp_parity_per_policy(seed, objective):
    prob = random_policy_instance(seed, objective)
    rg = solve_greedy(prob)
    rm = solve_fast_milp(prob, time_limit=60)
    assert rm.objective is not None
    check_allocation_invariants(prob, rg)
    check_allocation_invariants(prob, rm)
    # both report the objective the policy defines
    assert rg.objective == pytest.approx(
        policy_objective_of(prob, rg.counts), rel=1e-6, abs=1e-9)
    assert rm.objective == pytest.approx(
        policy_objective_of(prob, rm.counts), rel=1e-6, abs=1e-6)
    scale = max(1.0, abs(rm.objective))
    # greedy can never beat the exact optimum...
    assert rg.objective <= rm.objective + 1e-6 * scale
    # ...and stays within 5% of it on these instances
    assert rg.objective >= rm.objective - 0.05 * scale


@pytest.mark.parametrize("objective", POLICIES, ids=lambda o: o.name)
def test_node_vs_fast_milp_agree_per_policy(objective):
    for seed in (1, 4):
        prob = random_policy_instance(seed, objective, n_hi=12, j_hi=4)
        rf = solve_fast_milp(prob, time_limit=60)
        rn = solve_node_milp(prob, time_limit=60)
        assert rf.objective is not None and rn.objective is not None
        scale = max(1.0, abs(rf.objective))
        assert rn.objective == pytest.approx(rf.objective,
                                             abs=1e-5 * scale)
        check_allocation_invariants(prob, rn)


# ---------------------------------------------------------------------------
# Engine memoization per (signature, policy)
# ---------------------------------------------------------------------------


def test_engine_cache_keyed_by_policy():
    from tests.test_engine import random_instance
    base = random_instance(3)

    def with_obj(o):
        return AllocationProblem(nodes=base.nodes, trainers=base.trainers,
                                 current=base.current, t_fwd=base.t_fwd,
                                 objective=o)

    eng = AllocationEngine(time_budget=0.0)
    eng.allocate(with_obj(None))
    eng.allocate(with_obj(Throughput()))       # same policy -> hit
    assert eng.stats.cache_hits == 1
    eng.allocate(with_obj(MaxMinFairness()))   # other policy -> miss
    assert eng.stats.cache_hits == 1
    eng.allocate(with_obj(MaxMinFairness()))   # same params -> hit
    assert eng.stats.cache_hits == 2
    eng.allocate(with_obj(MaxMinFairness(tiebreak=0.05)))  # params differ
    assert eng.stats.cache_hits == 2


def test_maxmin_cache_consistent_under_id_permutation():
    """The engine signature is id-free, so the leximin rank assignment
    must be too: id-permuted but structurally identical problems must
    cache-hit onto the same canonical decision (same DNN wins)."""
    def mk(i, name):
        return mkspec(i, name, n_min=1, n_max=4, work=1e9, progress=0.0)

    eng = AllocationEngine(time_budget=0.0)
    p1 = AllocationProblem(nodes=[0],
                           trainers=[mk(0, "AlexNet"), mk(1, "DenseNet")],
                           current={0: [], 1: []}, t_fwd=120.0,
                           objective=MaxMinFairness())
    r1 = eng.allocate(p1)
    p2 = AllocationProblem(nodes=[0],
                           trainers=[mk(1, "AlexNet"), mk(0, "DenseNet")],
                           current={0: [], 1: []}, t_fwd=120.0,
                           objective=MaxMinFairness())
    r2 = eng.allocate(p2)
    assert eng.stats.cache_hits == 1
    # the same *DNN* wins in both labelings
    assert r1.counts[0] == r2.counts[1]
    assert r1.counts[1] == r2.counts[0]


def test_signature_ignores_fields_policy_does_not_read():
    """Throughput must keep its cache-hit rate while progress drifts."""
    t_a = mkspec(0, "ResNet18", work=1e9, progress=0.1)
    t_b = mkspec(0, "ResNet18", work=1e9, progress=0.7)
    pa = AllocationProblem(nodes=list(range(6)), trainers=[t_a],
                           current={0: []}, t_fwd=120.0)
    pb = AllocationProblem(nodes=list(range(6)), trainers=[t_b],
                           current={0: []}, t_fwd=120.0)
    assert problem_signature(pa)[0] == problem_signature(pb)[0]
    # ...but a progress-aware policy must see the difference
    pa.objective = pb.objective = MaxMinFairness()
    assert problem_signature(pa)[0] != problem_signature(pb)[0]


# ---------------------------------------------------------------------------
# run_scenario integration
# ---------------------------------------------------------------------------


def test_run_scenario_accepts_objective():
    from repro.sched import run_scenario

    jobs = [TrainerJob(id=i, curve=tab2_curve("ShuffleNet"), work=1e8,
                       n_min=1, n_max=8, r_up=5.0, r_dw=2.0)
            for i in range(3)]
    rep = run_scenario("bursty", jobs, scale=0.1, seed=1,
                       objective=MaxMinFairness(),
                       allocator=AllocationEngine(time_budget=0.0))
    assert rep.total_samples > 0
