"""Chaos determinism tier (DESIGN.md §12): a ChaosSpec seed fully
determines the fault schedule and the whole replay — same seed ⇒
bit-identical schedule and LoopStats; different seed ⇒ different faults.

Wall-clock fields (``solver_wall`` / ``solver_wall_total``) are physical
time and excluded from the comparison; everything else — progress,
costs, failures, per-event records — must match exactly."""
import dataclasses
import math

import pytest

from repro.chaos import ChaosSpec, generate_fault_schedule, run_chaos
from repro.core import AllocationEngine, TrainerJob, fragments_to_events, tab2_curve
from repro.sched.scenarios import CHAOS_SCENARIOS, build_scenario


def normalized(stats):
    recs = [dataclasses.replace(r, solver_wall=0.0)
            for r in stats.event_records]
    return dataclasses.replace(stats, solver_wall_total=0.0,
                               allocator="", event_records=recs)


def _det_engine():
    return AllocationEngine(time_budget=0.0)


def _jobs():
    return [TrainerJob(id=i, curve=tab2_curve("ShuffleNet"), work=math.inf,
                       n_min=1, n_max=8, r_up=20.0, r_dw=5.0)
            for i in range(3)]


@pytest.mark.parametrize("name", sorted(CHAOS_SCENARIOS))
def test_same_seed_same_schedule_and_stats(name):
    sc1 = build_scenario(name, scale=0.1, seed=6)
    sc2 = build_scenario(name, scale=0.1, seed=6)
    ev1 = fragments_to_events(sc1.fragments)
    ev2 = fragments_to_events(sc2.fragments)
    assert ev1 == ev2                              # scenario build replays

    s1 = generate_fault_schedule(ev1, sc1.chaos)
    s2 = generate_fault_schedule(ev2, sc2.chaos)
    assert s1 == s2                                # bit-identical schedule

    r1 = run_chaos(ev1, _jobs(), sc1.chaos, engine_factory=_det_engine,
                   horizon=sc1.duration)
    r2 = run_chaos(ev2, _jobs(), sc2.chaos, engine_factory=_det_engine,
                   horizon=sc2.duration)
    assert r1.events == r2.events                  # injected stream
    assert normalized(r1.stats) == normalized(r2.stats)
    assert (r1.allocator_restarts, r1.recovered_cache_entries,
            r1.corrupt_restores) == \
           (r2.allocator_restarts, r2.recovered_cache_entries,
            r2.corrupt_restores)


@pytest.mark.parametrize("name", sorted(CHAOS_SCENARIOS))
def test_different_seed_different_schedule(name):
    sc = build_scenario(name, scale=0.1, seed=6)
    events = fragments_to_events(sc.fragments)
    base = generate_fault_schedule(events, sc.chaos)
    other = generate_fault_schedule(
        events, dataclasses.replace(sc.chaos, seed=sc.chaos.seed + 1))
    # a reseeded spec must not reproduce the same fault timeline (unless
    # the profile draws nothing at this scale — then both are empty)
    if base.events or other.events:
        assert base != other
