"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.events import Fragment, fragments_to_events, pool_sizes
from repro.core.metrics import eq_nodes, resource_integral
from repro.core.milp import AllocationProblem, TrainerSpec
from repro.core.milp_fast import solve_fast_milp
from repro.core.scaling import ScalingCurve


# ---------------------------------------------------------------------------
# Scaling curves
# ---------------------------------------------------------------------------

curve_points = st.lists(
    st.floats(min_value=0.1, max_value=1e4), min_size=2, max_size=6)


@given(curve_points)
def test_curve_interp_within_hull(vals):
    nodes = tuple(2 ** i for i in range(len(vals)))
    c = ScalingCurve(nodes, tuple(vals))
    lo, hi = min(vals), max(vals)
    for n in np.linspace(nodes[0], nodes[-1], 17):
        v = c(float(n))
        assert lo - 1e-9 <= v <= hi + 1e-9
    assert c(0) == 0.0


@given(curve_points, st.integers(1, 4), st.integers(5, 40))
def test_breakpoints_always_bracket(vals, n_min, n_max):
    nodes = tuple(2 ** i for i in range(len(vals)))
    c = ScalingCurve(nodes, tuple(vals))
    pts, out = c.breakpoints(n_min, n_max)
    assert pts[0] == 0 and out[0] == 0.0
    assert n_min in pts and pts[-1] == n_max
    assert len(pts) == len(out)
    assert all(a < b for a, b in zip(pts, pts[1:]))


# ---------------------------------------------------------------------------
# Events / metrics
# ---------------------------------------------------------------------------

fragment_lists = st.lists(
    st.tuples(st.integers(0, 10),
              st.floats(0, 1e4),
              st.floats(1.0, 1e4)),
    min_size=1, max_size=30)


@given(fragment_lists)
@settings(max_examples=50)
def test_pool_size_conservation(raw):
    # ensure per-node fragments don't overlap: offset each by node phase
    frags = []
    per_node_t = {}
    for node, start, dur in raw:
        t0 = max(start, per_node_t.get(node, 0.0) + 1e-3)
        frags.append(Fragment(node=node, start=t0, end=t0 + dur))
        per_node_t[node] = t0 + dur
    events = fragments_to_events(frags)
    sizes = pool_sizes(events)
    assert all(n >= 0 for _, n in sizes)
    assert sizes[-1][1] == 0  # every fragment eventually ends

    t0 = min(f.start for f in frags)
    t1 = max(f.end for f in frags)
    integral = resource_integral(events, t0, t1)
    manual = sum(f.length for f in frags) / 3600.0
    assert abs(integral - manual) < 1e-6 * max(1.0, manual) + 1e-9
    eq = eq_nodes(events, t0, t1)
    assert 0 <= eq <= len({f.node for f in frags}) + 1e-9


# ---------------------------------------------------------------------------
# ControlLoop conservation (shared policy engine, DESIGN.md §9)
# ---------------------------------------------------------------------------


@given(fragment_lists,
       st.lists(st.tuples(st.integers(1, 3),          # n_min
                          st.integers(3, 8),          # n_max - extra
                          st.floats(1e3, 1e9),        # work
                          st.floats(0.0, 2e3)),       # arrival
                min_size=1, max_size=4),
       st.sampled_from([0.0, 30.0]))
@settings(max_examples=40, deadline=None)
def test_control_loop_never_allocates_beyond_pool(raw, raw_jobs, window):
    """Conservation invariant on the shared loop: at every event the nodes
    held by Trainers never exceed the pool, so allocated node-seconds ≤
    pool node-seconds over the whole replay."""
    from repro.core import (AnalyticBackend, ControlLoop,
                            EqualShareAllocator, TrainerJob, amdahl_curve)

    frags, per_node_t = [], {}
    for node, start, dur in raw:
        t0 = max(start, per_node_t.get(node, 0.0) + 1e-3)
        frags.append(Fragment(node=node, start=t0, end=t0 + dur))
        per_node_t[node] = t0 + dur
    events = fragments_to_events(frags)
    jobs = [TrainerJob(id=i, curve=amdahl_curve(f"j{i}", 50.0, 0.3),
                       work=w, n_min=lo, n_max=lo + hi, arrival=arr)
            for i, (lo, hi, w, arr) in enumerate(raw_jobs)]
    stats = ControlLoop(events, jobs, EqualShareAllocator(),
                        AnalyticBackend(), t_fwd=60.0,
                        coalesce_window=window).run()

    recs = stats.event_records
    assert all(r.allocated <= r.pool_size for r in recs)
    t_close = max(r.time for r in recs) if recs else 0.0
    alloc_ns = pool_ns = 0.0
    for a, b in zip(recs, recs[1:] + [None]):
        dt = (b.time if b is not None else t_close) - a.time
        alloc_ns += a.allocated * dt
        pool_ns += a.pool_size * dt
    assert alloc_ns <= pool_ns + 1e-9
    # and progress is only ever non-negative and bounded by requested work
    assert stats.total_samples >= 0.0
    assert all(0.0 <= j.done <= j.work for j in jobs)


# ---------------------------------------------------------------------------
# Scheduler-derived traces (repro.sched)
# ---------------------------------------------------------------------------

batch_jobs = st.lists(
    st.tuples(st.floats(0.0, 500.0),      # submit
              st.integers(1, 6),          # nodes (may exceed the machine)
              st.floats(1.0, 100.0),      # runtime
              st.floats(1.0, 3.0)),       # walltime overestimation factor
    min_size=1, max_size=25)


@given(batch_jobs, st.integers(2, 5),
       st.sampled_from([(), ((40.0, 60.0),), ((40.0, 60.0), (200.0, 230.0))]))
@settings(max_examples=60, deadline=None)
def test_sched_fragments_replay_cleanly(raw, n_nodes, drains):
    """FCFS+EASY output → fragments_to_events → pool replay: sizes never
    negative, per-node fragments never overlap, node-time conserved."""
    from repro.core.events import validate_fragments
    from repro.sched import BatchJob, simulate_schedule

    jobs = [BatchJob(id=i, submit=s, nodes=n, runtime=r,
                     walltime=r * f)
            for i, (s, n, r, f) in enumerate(raw)]
    horizon = 600.0
    res = simulate_schedule(jobs, n_nodes, horizon=horizon, drains=drains)
    frags = res.fragments()
    validate_fragments(frags)              # raises on per-node overlap
    if frags:
        sizes = pool_sizes(fragments_to_events(frags))
        assert all(n >= 0 for _, n in sizes)
        assert sizes[-1][1] == 0
        assert all(0.0 <= f.start < f.end <= res.t_end for f in frags)
    busy = sum(len(r.nodes) * (min(r.end, res.t_end) - r.start)
               for r in res.records)
    idle = sum(h.fragment.length for h in res.holes)
    total = n_nodes * res.t_end
    assert busy + idle + res.stats.drain_nodetime == pytest.approx(total)
    # every accepted job is either running/ran, still queued, or rejected
    assert (len(res.records) + len(res.unstarted) + len(res.rejected)
            == len([j for j in jobs if j.submit < horizon]))


# ---------------------------------------------------------------------------
# MILP invariants under hypothesis-generated instances
# ---------------------------------------------------------------------------


@st.composite
def milp_instances(draw):
    n_nodes = draw(st.integers(3, 16))
    n_jobs = draw(st.integers(1, 4))
    trainers, current, used = [], {}, set()
    for j in range(n_jobs):
        n_min = draw(st.integers(1, 2))
        n_max = draw(st.integers(n_min, 10))
        thr1 = draw(st.floats(0.5, 10.0))
        pts = [0, n_min] if n_min == n_max else [0, n_min, n_max]
        vals = [0.0] + [thr1 * p * (0.9 ** i)
                        for i, p in enumerate(pts[1:])]
        trainers.append(TrainerSpec(
            id=j, n_min=n_min, n_max=n_max,
            r_up=draw(st.floats(0.0, 50.0)), r_dw=draw(st.floats(0.0, 20.0)),
            points=tuple(pts), values=tuple(vals)))
        avail = [x for x in range(n_nodes) if x not in used]
        k = draw(st.integers(0, min(n_max, len(avail))))
        if 0 < k < n_min:
            k = 0
        cur = avail[:k]
        current[j] = cur
        used.update(cur)
    t_fwd = draw(st.floats(1.0, 600.0))
    return AllocationProblem(nodes=list(range(n_nodes)), trainers=trainers,
                             current=current, t_fwd=t_fwd)


# ---------------------------------------------------------------------------
# Incremental warm-start re-solve == fresh solve (DESIGN.md §11)
# ---------------------------------------------------------------------------


@st.composite
def event_delta_sequences(draw):
    """A small allocation problem plus a sequence of pool/job deltas:
    nodes join/leave, a job may arrive or finish, progress drifts —
    the engine's steady-state replay access pattern."""
    n_nodes = draw(st.integers(4, 14))
    n_jobs = draw(st.integers(1, 3))
    specs = []
    for j in range(n_jobs):
        n_min = draw(st.integers(1, 2))
        n_max = draw(st.integers(n_min + 1, 8))
        thr1 = draw(st.floats(0.5, 10.0))
        pts = [0, n_min, n_max] if n_min != n_max else [0, n_min]
        vals = [0.0] + [thr1 * p * (0.9 ** i) for i, p in enumerate(pts[1:])]
        specs.append(dict(
            id=j, n_min=n_min, n_max=n_max,
            r_up=draw(st.floats(0.0, 50.0)), r_dw=draw(st.floats(0.0, 20.0)),
            points=tuple(pts), values=tuple(vals),
            weight=draw(st.floats(0.5, 3.0)),
            deadline=draw(st.one_of(st.none(), st.floats(100.0, 5e4))),
            budget=draw(st.one_of(st.none(), st.floats(1e3, 1e6))),
            work=draw(st.floats(1e4, 1e8))))
    deltas = draw(st.lists(
        st.tuples(st.integers(-3, 3),                 # pool-size delta
                  st.floats(0.0, 0.3),                # progress drift
                  st.integers(0, 2)),                 # 0: keep jobs, 1: drop
                                                      # one, 2: add one back
        min_size=2, max_size=4))
    policy = draw(st.sampled_from(
        ["throughput", "weighted", "maxmin", "deadline", "costcap"]))
    return n_nodes, specs, deltas, policy


@given(event_delta_sequences())
@settings(max_examples=20, deadline=None)
def test_incremental_resolve_equals_fresh_solve(seq):
    """Property (ISSUE 5 satellite): across random event-delta sequences
    and all five policies, the incremental engine's per-event objective
    equals a fresh portfolio solve within tolerance, and every
    conservation invariant holds on the allocation it returns."""
    from repro.core.engine import AllocationEngine

    n_nodes, raw_specs, deltas, policy = seq
    inc = AllocationEngine(incremental=True, time_budget=2.0)
    fresh = AllocationEngine(incremental=False, time_budget=2.0)

    pool = list(range(n_nodes))
    progress = {s["id"]: 0.0 for s in raw_specs}
    active = [s["id"] for s in raw_specs]
    current = {}
    for pool_delta, drift, job_op in deltas:
        n = max(2, len(pool) + pool_delta)
        pool = list(range(n))
        if job_op == 1 and len(active) > 1:
            active = active[1:]
        elif job_op == 2:
            active = [s["id"] for s in raw_specs if s["id"] in active
                      or s["id"] == raw_specs[0]["id"]]
        trainers = []
        for s in raw_specs:
            if s["id"] not in active:
                continue
            progress[s["id"]] = min(1.0, progress[s["id"]] + drift)
            trainers.append(TrainerSpec(progress=progress[s["id"]], **s))
        prob = AllocationProblem(nodes=pool, trainers=trainers,
                                 current=current, t_fwd=120.0,
                                 objective=policy)
        ri = inc.allocate(prob)
        rf = fresh.allocate(prob)
        # conservation invariants on the incremental result
        seen = set()
        for t in trainers:
            alloc = set(ri.allocation[t.id])
            assert not (alloc & seen)                      # exclusivity
            seen |= alloc
            assert alloc <= set(pool)
            assert len(alloc) == 0 or t.n_min <= len(alloc) <= t.n_max
            cur = set(current.get(t.id, [])) & set(pool)
            if len(alloc) >= len(cur):                     # no migration
                assert cur <= alloc
            else:
                assert alloc <= cur
        # objective parity vs the fresh portfolio
        assert ri.fell_back == rf.fell_back
        if ri.objective is not None and rf.objective is not None:
            scale = max(1.0, abs(rf.objective))
            assert abs(ri.objective - rf.objective) <= 1e-6 * scale
        current = {j: list(ns) for j, ns in ri.allocation.items()}


@given(milp_instances())
@settings(max_examples=25, deadline=None)
def test_fast_milp_invariants(prob):
    r = solve_fast_milp(prob, time_limit=30)
    seen = set()
    for t in prob.trainers:
        alloc = r.allocation[t.id]
        assert not (set(alloc) & seen)
        seen |= set(alloc)
        assert len(alloc) == 0 or t.n_min <= len(alloc) <= t.n_max
    assert len(seen) <= len(prob.nodes)
    if r.objective is not None:
        # optimal must be at least as good as "keep current" and "all zero"
        keep = {t.id: len(prob.current.get(t.id, [])) for t in prob.trainers}
        zero_obj = sum(-t.value_at(keep[t.id]) * t.r_dw
                       for t in prob.trainers if keep[t.id] > 0)
        keep_obj = sum(prob.t_fwd * t.value_at(keep[t.id])
                       for t in prob.trainers)
        assert r.objective >= max(keep_obj, zero_obj) - 1e-6


# ---------------------------------------------------------------------------
# Chaos recovery invariants (DESIGN.md §12)
# ---------------------------------------------------------------------------


@given(fragment_lists, st.integers(0, 1000), st.booleans())
@settings(max_examples=40, deadline=None)
def test_chaos_recovery_invariants(raw, chaos_seed, corrupt):
    """Under seeded fault injection (kills, drains, corrupt restores):
    conservation still holds — Trainers never hold more nodes than the
    (fault-reduced) pool, allocated node-seconds <= pool node-seconds —
    and recovery is bounded: progress stays within [0, work] and every
    kill loses at most one checkpoint interval (two when the latest
    checkpoint restores corrupt), i.e. never more than the lattice
    guarantees."""
    from repro.chaos import ChaosSpec, generate_fault_schedule, run_chaos
    from repro.core import TrainerJob, amdahl_curve

    frags, per_node_t = [], {}
    for node, start, dur in raw:
        t0 = max(start, per_node_t.get(node, 0.0) + 1e-3)
        frags.append(Fragment(node=node, start=t0, end=t0 + dur))
        per_node_t[node] = t0 + dur
    events = fragments_to_events(frags)
    ckpt = 200.0
    jobs = [TrainerJob(id=i, curve=amdahl_curve(f"j{i}", 50.0, 0.3),
                       work=1e6, n_min=1, n_max=4)
            for i in range(2)]
    spec = ChaosSpec(seed=chaos_seed, mtbf=1500.0, drain_frac=0.25,
                     corrupt_prob=0.5 if corrupt else 0.0,
                     ckpt_every=ckpt, restart_penalty=10.0)
    rep = run_chaos(events, jobs, spec,
                    horizon=max(f.end for f in frags))
    stats = rep.stats

    # fault schedules are pure functions of (events, spec)
    assert generate_fault_schedule(events, spec) == rep.schedule
    # the injected stream never drives the pool negative (each victim's
    # original departure was consumed by the injection)
    assert all(n >= 0 for _, n in pool_sizes(rep.events))

    recs = stats.event_records
    assert all(r.allocated <= r.pool_size for r in recs)
    t_close = max(r.time for r in recs) if recs else 0.0
    alloc_ns = pool_ns = 0.0
    for a, b in zip(recs, recs[1:] + [None]):
        dt = (b.time if b is not None else t_close) - a.time
        alloc_ns += a.allocated * dt
        pool_ns += a.pool_size * dt
    assert alloc_ns <= pool_ns + 1e-9

    # recovery bounds: progress never negative, never beyond work, and
    # rollback loss bounded by the checkpoint lattice
    assert all(0.0 <= j.done <= j.work for j in jobs)
    assert stats.lost_progress >= 0.0
    per_kill_bound = (2.0 if corrupt else 1.0) * ckpt
    assert stats.lost_progress <= stats.n_failures * per_kill_bound + 1e-9
    assert stats.restart_cost_s == pytest.approx(10.0 * stats.n_failures)
