"""Unit tests for the shared policy-portfolio metrics in
``repro.core.metrics`` (deduplicated out of the objectives benchmark)."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import pytest

from repro.core import (
    deadline_miss_rate,
    jain_fairness,
    min_normalized_progress,
    normalized_progress,
)


@dataclass
class _Job:
    done: float = 0.0
    work: float = 100.0
    deadline: Optional[float] = None
    finished_at: Optional[float] = None


def test_jain_fairness_perfectly_even():
    assert jain_fairness([0.5, 0.5, 0.5]) == pytest.approx(1.0)


def test_jain_fairness_single_winner():
    # one of n jobs gets everything → index 1/n
    assert jain_fairness([1.0, 0.0, 0.0, 0.0]) == pytest.approx(0.25)


def test_jain_fairness_degenerate():
    assert jain_fairness([]) == 0.0
    assert jain_fairness([0.0, 0.0]) == 0.0
    # negative progress is clamped, not allowed to inflate the index
    assert jain_fairness([-1.0, 1.0]) == pytest.approx(0.5)


def test_normalized_progress_clamps_and_handles_infinite_work():
    jobs = [_Job(done=50.0, work=100.0),
            _Job(done=250.0, work=100.0),          # overshoot clamps to 1
            _Job(done=1.0, work=math.inf),         # run-forever: never behind
            _Job(done=0.0, work=0.0)]              # degenerate work
    assert normalized_progress(jobs) == [0.5, 1.0, 1.0, 1.0]


def test_min_normalized_progress():
    assert min_normalized_progress([]) == 0.0
    jobs = [_Job(done=30.0), _Job(done=80.0)]
    assert min_normalized_progress(jobs) == pytest.approx(0.3)


def test_deadline_miss_rate():
    horizon = 1000.0
    jobs = [
        _Job(deadline=500.0, finished_at=400.0),    # made it
        _Job(deadline=500.0, finished_at=600.0),    # late
        _Job(deadline=500.0, finished_at=None),     # never finished
        _Job(deadline=2000.0, finished_at=None),    # deadline past horizon
        _Job(deadline=None),                        # no deadline
    ]
    assert deadline_miss_rate(jobs, horizon) == pytest.approx(2 / 5)
    assert deadline_miss_rate([], horizon) == 0.0
