"""MILP model tests: paper-faithful node-level vs fast aggregate
equivalence, brute-force optimality on small instances, constraint
invariants, and the §3.6 timeout fallback."""
import itertools

import numpy as np
import pytest

from repro.core.milp import AllocationProblem, TrainerSpec, solve_node_milp
from repro.core.milp_fast import reconstruct_map, solve_fast_milp
from repro.core.scaling import TAB2, tab2_curve


def random_instance(seed, n_lo=6, n_hi=24, j_lo=2, j_hi=5):
    rng = np.random.RandomState(seed)
    n_nodes = rng.randint(n_lo, n_hi)
    nodes = list(range(n_nodes))
    trainers, current, used = [], {}, set()
    for j in range(rng.randint(j_lo, j_hi)):
        curve = tab2_curve(list(TAB2)[j % len(TAB2)])
        n_min = rng.randint(1, 3)
        n_max = rng.randint(n_min + 1, 12)
        pts, vals = curve.breakpoints(n_min, n_max)
        trainers.append(TrainerSpec(
            id=j, n_min=n_min, n_max=n_max,
            r_up=float(rng.uniform(5, 40)), r_dw=float(rng.uniform(1, 10)),
            points=tuple(pts), values=tuple(vals)))
        k = rng.randint(0, min(n_max, n_nodes - len(used)) + 1)
        if 0 < k < n_min:
            k = 0
        avail = [x for x in nodes if x not in used]
        cur = [int(c) for c in
               rng.choice(avail, size=min(k, len(avail)), replace=False)]
        current[j] = cur
        used.update(cur)
    t_fwd = float(rng.choice([10.0, 60.0, 120.0, 300.0]))
    return AllocationProblem(nodes=nodes, trainers=trainers,
                             current=current, t_fwd=t_fwd)


def manual_objective(prob, counts):
    obj = 0.0
    for t in prob.trainers:
        cj = len([n for n in prob.current.get(t.id, [])
                  if n in set(prob.nodes)])
        c = counts[t.id]
        obj += prob.t_fwd * t.value_at(c)
        if c > cj:
            obj -= t.value_at(cj) * t.r_up
        elif c < cj:
            obj -= t.value_at(cj) * t.r_dw
    return obj


@pytest.mark.parametrize("seed", range(10))
def test_node_vs_fast_equivalence(seed):
    prob = random_instance(seed)
    r1 = solve_node_milp(prob, time_limit=60)
    r2 = solve_fast_milp(prob, time_limit=60)
    assert r1.objective is not None and r2.objective is not None
    tol = 1e-4 * max(1.0, abs(r1.objective))
    assert abs(r1.objective - r2.objective) < tol


@pytest.mark.parametrize("seed", range(5))
def test_fast_matches_bruteforce(seed):
    prob = random_instance(seed, n_lo=5, n_hi=10, j_hi=4)
    r = solve_fast_milp(prob, time_limit=60)
    ranges = [([0] if t.n_min > len(prob.nodes) else
               [0] + list(range(t.n_min, min(t.n_max, len(prob.nodes)) + 1)))
              for t in prob.trainers]
    best = None
    for counts in itertools.product(*ranges):
        if sum(counts) > len(prob.nodes):
            continue
        obj = manual_objective(
            prob, {t.id: c for t, c in zip(prob.trainers, counts)})
        best = obj if best is None else max(best, obj)
    assert abs(r.objective - best) < 1e-4 * max(1.0, abs(best))


@pytest.mark.parametrize("seed", range(10))
def test_allocation_invariants(seed):
    prob = random_instance(seed)
    for solve in (solve_node_milp, solve_fast_milp):
        r = solve(prob, time_limit=60)
        node_set = set(prob.nodes)
        seen = set()
        for t in prob.trainers:
            alloc = r.allocation[t.id]
            # exclusivity (Eqn 5)
            assert not (set(alloc) & seen)
            seen |= set(alloc)
            assert set(alloc) <= node_set
            # size constraint (Eqn 4)
            assert len(alloc) == 0 or t.n_min <= len(alloc) <= t.n_max
            # no migration (Eqns 6-10): keep-own-nodes
            cur = set(prob.current.get(t.id, [])) & node_set
            if len(alloc) >= len(cur):
                assert cur <= set(alloc)
            else:
                assert set(alloc) <= cur


def test_solver_objective_matches_manual():
    prob = random_instance(42)
    r = solve_fast_milp(prob, time_limit=60)
    assert abs(r.objective - manual_objective(prob, r.counts)) < \
        1e-3 * max(1.0, abs(r.objective))


def test_timeout_fallback_keeps_current_map():
    prob = random_instance(3)
    r = solve_fast_milp(prob, time_limit=1e-9)
    if r.fell_back:    # §3.6 behaviour
        node_set = set(prob.nodes)
        for t in prob.trainers:
            assert set(r.allocation[t.id]) == \
                set(prob.current.get(t.id, [])) & node_set


def test_reconstruct_map_properties():
    rng = np.random.RandomState(0)
    for _ in range(20):
        n = rng.randint(4, 20)
        nodes = list(range(n))
        trainers = [TrainerSpec(id=j, n_min=1, n_max=n, r_up=1, r_dw=1,
                                points=(0, 1, n), values=(0, 1, n))
                    for j in range(3)]
        current = {0: [0, 1], 1: [2], 2: []}
        counts = {0: int(rng.randint(0, n // 2)),
                  1: int(rng.randint(0, n // 3)), 2: int(rng.randint(0, 2))}
        while sum(counts.values()) > n:
            counts[0] = max(0, counts[0] - 1)
        alloc = reconstruct_map(nodes, trainers, current, counts)
        seen = set()
        for t in trainers:
            assert len(alloc[t.id]) == counts[t.id]
            assert not (set(alloc[t.id]) & seen)
            seen |= set(alloc[t.id])
            kept = set(alloc[t.id]) & set(current[t.id])
            # keep-own-first
            assert len(kept) == min(counts[t.id], len(current[t.id]))
