"""Additional coverage: trace CSV loader, MoE capacity path, launchers."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_trace_csv_roundtrip(tmp_path):
    from repro.core import Fragment, generate_summit_like, load_trace_csv
    frags = generate_summit_like(n_nodes=8, duration=86400.0, seed=2)
    path = tmp_path / "trace.csv"
    with open(path, "w") as f:
        f.write("node,start,end\n")
        for fr in frags:
            f.write(f"{fr.node},{fr.start},{fr.end}\n")
    loaded = load_trace_csv(str(path))
    assert loaded == frags


def test_moe_capacity_matches_dense_with_ample_capacity():
    from repro.configs import get_arch
    from repro.models import moe as M
    from repro.models.layers import materialize
    cfg = get_arch("granite-moe-3b-a800m").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    params = materialize(M.moe_defs(cfg), jax.random.key(0))
    x = jnp.asarray(np.random.RandomState(0).randn(2, 40, cfg.d_model) * 0.1,
                    jnp.float32)
    yd, _ = M.moe_apply(params, x, cfg, strategy="dense")
    yc, _ = M.moe_apply(params, x, cfg, strategy="capacity")
    assert float(jnp.max(jnp.abs(yd - yc))) < 1e-4


def test_moe_capacity_drops_overflow_gracefully():
    from repro.configs import get_arch
    from repro.models import moe as M
    from repro.models.layers import materialize
    cfg = get_arch("granite-moe-3b-a800m").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.1))
    params = materialize(M.moe_defs(cfg), jax.random.key(0))
    x = jnp.asarray(np.random.RandomState(0).randn(2, 64, cfg.d_model) * 0.1,
                    jnp.float32)
    yc, aux = M.moe_apply(params, x, cfg, strategy="capacity")
    assert not bool(jnp.any(jnp.isnan(yc)))
    # dropped tokens get (at most) the shared-expert output; the routed
    # contribution must be smaller than the ample-capacity case on average
    assert float(jnp.mean(jnp.abs(yc))) >= 0.0


def _run(mod, args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    return subprocess.run([sys.executable, "-m", mod, *args],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)


@pytest.mark.slow
def test_train_launcher_smoke():
    r = _run("repro.launch.train",
             ["--arch", "gemma-2b-smoke", "--steps", "3", "--seq", "64"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "loss=" in r.stdout


@pytest.mark.slow
def test_serve_launcher_smoke():
    r = _run("repro.launch.serve",
             ["--arch", "yi-6b-smoke", "--batch", "2", "--prompt-len", "8",
              "--new-tokens", "4"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "tok/s" in r.stdout


def test_scaling_efficiency_metric_is_normalized():
    """Paper §5.2: the 'efficiency' objective is throughput normalized by
    the DNN's own single-node rate (fair across DNNs)."""
    from repro.core import tab2_curve
    alex = tab2_curve("AlexNet")
    dense = tab2_curve("DenseNet")
    # raw throughputs differ ~7x; normalized values are comparable
    a = alex._metric_value(16, "efficiency")
    d = dense._metric_value(16, "efficiency")
    assert 0.3 < a / d < 3.0
    assert alex._metric_value(16, "throughput") / \
        dense._metric_value(16, "throughput") > 3.0


def test_adaptive_tfwd_estimator():
    from repro.core import TfwdEstimator
    est = TfwdEstimator()
    assert est.estimate() == est.default
    t = 0.0
    for gap in [30, 60, 90, 120, 150, 180]:
        t += gap
        est.observe(t, nodes_left=1)
    e = est.estimate()
    assert est.t_min <= e <= est.t_max
    assert 30 <= e <= 180
    # join-only events don't perturb the estimate
    before = est.estimate()
    est.observe(t + 5, nodes_left=0)
    assert est.estimate() == before


def test_adaptive_tfwd_matches_tuned_constant():
    """Beyond-paper: the adaptive T_fwd should perform within a few percent
    of the best hand-tuned constant without any tuning."""
    from repro.core import (MILPAllocator, Simulator, TrainerJob,
                            fragments_to_events, generate_summit_like,
                            tab2_curve)
    frags = generate_summit_like(n_nodes=96, duration=12 * 3600, seed=3)
    ev = fragments_to_events(frags)

    def jobs():
        return [TrainerJob(id=i, curve=tab2_curve("ShuffleNet"), work=1e12,
                           n_min=1, n_max=16, r_up=20.0, r_dw=5.0)
                for i in range(6)]

    best = max(
        Simulator(ev, jobs(), MILPAllocator("fast"), t_fwd=tf,
                  horizon=12 * 3600).run().total_samples
        for tf in (10.0, 120.0, 300.0))
    adaptive = Simulator(ev, jobs(), MILPAllocator("fast"), t_fwd="adaptive",
                         horizon=12 * 3600).run().total_samples
    assert adaptive > 0.97 * best


def test_topology_aware_allocation_packs_racks():
    """Paper §7 future work: with the rack-spread penalty, a Trainer that
    fits in one rack is packed there; without it the solver may spread."""
    from repro.core.milp import (AllocationProblem, TrainerSpec,
                                 solve_node_milp)
    # 2 racks x 4 nodes; one trainer needing 3 nodes, currently empty
    nodes = list(range(8))
    racks = {n: n // 4 for n in nodes}
    t = TrainerSpec(id=0, n_min=3, n_max=3, r_up=10.0, r_dw=2.0,
                    points=(0, 3), values=(0.0, 3000.0))
    prob = AllocationProblem(nodes=nodes, trainers=[t], current={0: []},
                             t_fwd=120.0, racks=racks)
    r = solve_node_milp(prob, topo_coef=0.05)
    alloc = r.allocation[0]
    assert len(alloc) == 3
    assert len({racks[n] for n in alloc}) == 1  # packed into one rack

    # keep-own-nodes still wins over rack purity (no forced migration):
    prob2 = AllocationProblem(nodes=nodes, trainers=[t],
                              current={0: [0, 4, 5]}, t_fwd=120.0,
                              racks=racks)
    r2 = solve_node_milp(prob2, topo_coef=0.05)
    assert set(r2.allocation[0]) == {0, 4, 5}  # no-migration constraint


def test_topology_penalty_does_not_change_counts():
    """The rack penalty is a tie-breaker: with a modest coefficient the
    chosen node COUNTS match the topology-free optimum."""
    import numpy as np
    from repro.core.milp import (AllocationProblem, TrainerSpec,
                                 solve_node_milp)
    from repro.core.scaling import tab2_curve
    rng = np.random.RandomState(1)
    nodes = list(range(12))
    racks = {n: n // 4 for n in nodes}
    trainers = []
    for j in range(3):
        pts, vals = tab2_curve("ResNet18").breakpoints(1, 6)
        trainers.append(TrainerSpec(id=j, n_min=1, n_max=6, r_up=20.0,
                                    r_dw=5.0, points=tuple(pts),
                                    values=tuple(vals)))
    prob = AllocationProblem(nodes=nodes, trainers=trainers,
                             current={0: [1], 1: [], 2: [8, 9]},
                             t_fwd=120.0, racks=racks)
    base = solve_node_milp(prob)
    topo = solve_node_milp(prob, topo_coef=0.02)
    # Trainers 0 and 1 can tie (growing from C=0 is penalty-free, so
    # swapping their counts costs nothing) and the rack penalty may break
    # the tie either way: compare the count multiset, not the per-trainer
    # assignment, plus the topology-free objective of the topo solution.
    assert sorted(base.counts.values()) == sorted(topo.counts.values())

    def plain_objective(counts):
        obj = 0.0
        for t in prob.trainers:
            cj = len(prob.current.get(t.id, []))
            c = counts[t.id]
            obj += prob.t_fwd * t.value_at(c)
            if c > cj:
                obj -= t.value_at(cj) * t.r_up
            elif c < cj:
                obj -= t.value_at(cj) * t.r_dw
        return obj

    assert plain_objective(topo.counts) == \
        pytest.approx(plain_objective(base.counts), rel=1e-6)


def test_microbatch_train_step_matches_full_batch():
    """Gradient accumulation (dryrun --microbatch) is numerically
    equivalent to the full-batch step."""
    import numpy as np
    jax.devices()   # lock the real device count BEFORE importing dryrun,
    # whose module-level XLA_FLAGS would otherwise force 512 host devices
    from repro.configs import get_arch
    from repro.launch import dryrun as DR
    from repro.models import build_model
    from repro.optim import AdamW

    cfg = get_arch("yi-6b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    opt = AdamW()
    state = opt.init(params)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 32)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    full = DR.build_train_step(model, opt, microbatch=1)
    accum = DR.build_train_step(model, opt, microbatch=4)
    p1, _, l1 = jax.jit(full)(params, state, batch)
    p4, _, l4 = jax.jit(accum)(params, state, batch)
    assert abs(float(l1) - float(l4)) < 2e-3
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p1, p4)
    assert max(jax.tree.leaves(diffs)) < 2e-2
