# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see the real device count; only launch/dryrun.py
# (and the subprocess tests that exec it) force placeholder devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
