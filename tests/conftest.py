# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see the real device count; only launch/dryrun.py
# (and the subprocess tests that exec it) force placeholder devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The property tiers need hypothesis; environments without it fall back
# to the deterministic compat stub so those tests run instead of skip.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub
    _hypothesis_stub.install()
