"""Workload subsystem: SWF I/O, FCFS+EASY backfill correctness, the
scenario library, and end-to-end consumption by the AllocationEngine."""
import math

import pytest

from repro.core import (
    AllocationEngine,
    Simulator,
    TrainerJob,
    fragments_to_events,
    pool_sizes,
    tab2_curve,
    validate_fragments,
)
from repro.core.trace import trace_stats
from repro.sched import (
    BLOCKED,
    LOW_LOAD,
    BatchJob,
    SCENARIOS,
    build_scenario,
    dump_swf,
    offered_load,
    parse_swf,
    simulate_schedule,
    synthetic_workload,
)


def J(jid, submit, nodes, runtime, walltime=None):
    return BatchJob(id=jid, submit=submit, nodes=nodes, runtime=runtime,
                    walltime=walltime if walltime is not None else runtime)


def rec_of(res, jid):
    return next(r for r in res.records if r.job.id == jid)


# ---------------------------------------------------------------------------
# FCFS + EASY backfill correctness
# ---------------------------------------------------------------------------


def test_fcfs_order_and_trailing_holes():
    res = simulate_schedule(
        [J(0, 0.0, 4, 100.0), J(1, 0.0, 2, 100.0)], 4, horizon=300.0)
    a, b = rec_of(res, 0), rec_of(res, 1)
    assert a.start == 0.0 and not a.backfilled
    assert b.start == 100.0 and not b.backfilled      # waited for A
    # nodes 2,3 sit idle from B's start to the horizon
    frags = res.fragments()
    tail = {f.node: f for f in frags if f.end == 300.0}
    assert set(tail) >= {2, 3}
    assert all(math.isclose(tail[n].start, 100.0) for n in (2, 3))


def test_backfillable_job_is_placed_in_the_hole():
    """EASY: a short job jumps a blocked head into the hole in front of
    the head's reservation."""
    res = simulate_schedule(
        [J(0, 0.0, 2, 100.0),          # A runs on 2 of 4 nodes
         J(1, 1.0, 4, 100.0),          # B = head, needs the whole machine
         J(2, 2.0, 2, 50.0)],          # C fits the hole and ends by shadow
        4, horizon=500.0)
    c = rec_of(res, 2)
    assert c.backfilled and c.start == 2.0
    # B still starts at its shadow time (A's requested end), undelayed
    assert rec_of(res, 1).start == 100.0


def test_backfill_never_delays_the_reservation():
    """A job that would outlive the shadow time and doesn't fit in the
    'extra' nodes must NOT backfill."""
    res = simulate_schedule(
        [J(0, 0.0, 3, 100.0),          # leaves 1 free node
         J(1, 1.0, 4, 100.0),          # head, reserved at t=100
         J(2, 2.0, 1, 200.0)],         # would hold its node past t=100
        4, horizon=1000.0)
    b, c = rec_of(res, 1), rec_of(res, 2)
    assert b.start == 100.0            # reservation honored
    assert not c.backfilled and c.start >= b.end


def test_unfillable_hole_is_emitted_as_fragment():
    """Two free nodes, but the only queued job needs four: the hole is
    unfillable and must surface in the trace, tagged queue-blocked."""
    res = simulate_schedule(
        [J(0, 0.0, 2, 100.0), J(1, 0.0, 4, 300.0)], 4, horizon=400.0)
    blocked = [h for h in res.holes
               if h.kind == BLOCKED and h.fragment.end <= 100.0]
    assert {h.fragment.node for h in blocked} == {2, 3}
    for h in blocked:
        assert h.fragment.start == 0.0
        assert math.isclose(h.fragment.end, 100.0)
        assert h.blocked_frac == 1.0
    # and it is in the BFTrainer-facing trace
    assert {(f.node, f.start) for f in res.fragments()} >= {(2, 0.0), (3, 0.0)}


def test_overestimated_walltime_creates_early_start():
    """Nodes free up at the *actual* runtime even though the reservation
    was computed from the requested walltime."""
    res = simulate_schedule(
        [J(0, 0.0, 2, 10.0, walltime=100.0),   # ends at 10, promised 100
         J(1, 1.0, 4, 100.0),                  # head, shadow = 100
         J(2, 2.0, 2, 60.0)],                  # backfills (ends 62 <= 100)
        4, horizon=500.0)
    assert rec_of(res, 2).backfilled
    assert rec_of(res, 1).start == 62.0        # not 100: freed early


def test_low_load_hole_kind():
    res = simulate_schedule([J(0, 0.0, 1, 10.0)], 2, horizon=100.0)
    assert res.holes and all(h.kind == LOW_LOAD for h in res.holes)


def test_oversized_job_rejected():
    res = simulate_schedule(
        [J(0, 0.0, 8, 100.0), J(1, 1.0, 2, 100.0)], 4, horizon=300.0)
    assert [j.id for j in res.rejected] == [0]
    assert rec_of(res, 1).start == 1.0         # queue not wedged behind it


def test_drain_windows_block_and_are_excluded():
    res = simulate_schedule(
        [J(0, 50.0, 1, 80.0),      # 50+80 crosses the drain: waits for 200
         J(1, 60.0, 1, 30.0)],     # 60+30=90 <= 100: may still run
        2, horizon=400.0, drains=[(100.0, 200.0)])
    assert rec_of(res, 0).start == 200.0
    assert rec_of(res, 1).start == 60.0
    for f in res.fragments():                  # drain node-time is not idle
        assert f.end <= 100.0 or f.start >= 200.0
    assert res.stats.drain_nodetime == 2 * 100.0


def test_min_fragment_filter():
    res = simulate_schedule(
        [J(0, 0.0, 2, 100.0), J(1, 100.5, 2, 100.0)], 2, horizon=300.0,
        min_fragment=10.0)
    assert all(h.fragment.length >= 10.0 for h in res.holes)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_sched_replay_invariants_random(seed):
    """No-hypothesis mirror of the property test in test_property.py
    (hypothesis is optional in some environments): random workloads →
    fragments replay with non-negative pool sizes and no overlap."""
    import numpy as np
    rng = np.random.default_rng(seed)
    jobs = [BatchJob(id=i, submit=float(rng.uniform(0, 500)),
                     nodes=int(rng.integers(1, 7)),
                     runtime=float(rng.uniform(1, 100)),
                     walltime=float(rng.uniform(1, 100)) + 100.0)
            for i in range(20)]
    drains = ((40.0, 60.0),) if seed % 2 else ()
    res = simulate_schedule(jobs, 4, horizon=600.0, drains=drains)
    frags = res.fragments()
    validate_fragments(frags)
    if frags:
        sizes = pool_sizes(fragments_to_events(frags))
        assert all(n >= 0 for _, n in sizes)
        assert sizes[-1][1] == 0
    busy = sum(len(r.nodes) * (min(r.end, res.t_end) - r.start)
               for r in res.records)
    idle = sum(h.fragment.length for h in res.holes)
    assert busy + idle + res.stats.drain_nodetime == \
        pytest.approx(4 * res.t_end)


def test_sched_conservation():
    """busy + unfillable-idle + drain node-time == n_nodes * duration."""
    jobs = synthetic_workload(duration=6 * 3600.0, seed=5,
                              mean_interarrival=120.0,
                              size_choices=(1, 2, 4),
                              runtime_median=1200.0)
    res = simulate_schedule(jobs, 8, horizon=6 * 3600.0,
                            drains=[(7200.0, 9000.0)])
    busy = sum(len(r.nodes) * (min(r.end, res.t_end) - r.start)
               for r in res.records)
    idle = sum(h.fragment.length for h in res.holes)
    total = res.n_nodes * res.t_end
    assert abs(busy + idle + res.stats.drain_nodetime - total) < 1e-6 * total


# ---------------------------------------------------------------------------
# SWF I/O + synthetic generator
# ---------------------------------------------------------------------------


def test_swf_round_trip(tmp_path):
    jobs = synthetic_workload(duration=4 * 3600.0, seed=1,
                              mean_interarrival=300.0)
    for name in ("log.swf", "log.swf.gz"):
        p = str(tmp_path / name)
        dump_swf(jobs, p)
        back = parse_swf(p)
        assert len(back) == len(jobs)
        for a, b in zip(sorted(jobs, key=lambda j: (j.submit, j.id)), back):
            assert (a.id, a.nodes) == (b.id, b.nodes)
            assert abs(a.runtime - b.runtime) <= 1.0
            assert abs(a.walltime - b.walltime) <= 1.0


def test_parse_swf_skips_comments_and_invalid_jobs():
    lines = [
        "; SWF header comment",
        "1 0 5 100 4 -1 -1 4 200 -1 1 1 1 -1 -1 -1 -1 -1",
        "2 10 0 -1 4 -1 -1 4 200 -1 0 1 1 -1 -1 -1 -1 -1",   # runtime -1
        "3 20 0 50 0 -1 -1 0 -1 -1 0 1 1 -1 -1 -1 -1 -1",    # 0 procs
        "4 30 0 50 8 -1 -1 -1 -1 -1 1 1 1 -1 -1 -1 -1 -1",   # alloc fallback
    ]
    jobs = parse_swf(lines, procs_per_node=2)
    assert [j.id for j in jobs] == [1, 4]
    assert jobs[0].nodes == 2                  # 4 procs / 2 per node
    assert jobs[0].walltime == 200.0
    assert jobs[1].nodes == 4                  # allocated-procs fallback
    assert jobs[1].walltime == 50.0            # requested-time fallback


def test_parse_swf_rejects_short_lines():
    with pytest.raises(ValueError, match="fields"):
        parse_swf(["1 0 5 100 4"])


def test_batchjob_validation():
    with pytest.raises(ValueError):
        BatchJob(id=0, submit=0.0, nodes=0, runtime=10.0, walltime=10.0)
    with pytest.raises(ValueError, match="walltime"):
        BatchJob(id=0, submit=0.0, nodes=1, runtime=10.0, walltime=5.0)


def test_synthetic_workload_shapes():
    dur = 24 * 3600.0
    jobs = synthetic_workload(duration=dur, seed=2, mean_interarrival=200.0,
                              size_choices=(1, 2), overestimate=4.0,
                              burst_every=4 * 3600.0, burst_size=10)
    assert jobs and all(0 <= j.submit < dur for j in jobs)
    assert all(j.walltime >= j.runtime for j in jobs)
    # overestimation factor is real: median request well above runtime
    factors = sorted(j.walltime / j.runtime for j in jobs)
    assert factors[len(factors) // 2] > 2.0
    # bursts exist: some submit times repeat
    assert len({j.submit for j in jobs}) < len(jobs)
    assert offered_load(jobs, 16, dur) > 0


# ---------------------------------------------------------------------------
# Scenario library round-trip (acceptance: all scenarios non-empty, stats
# asserted, engine consumes them end-to-end)
# ---------------------------------------------------------------------------


SCALE = 0.15


def test_scenario_registry_complete():
    assert set(SCENARIOS) == {"capability", "capacity", "bursty",
                              "maintenance", "weekend", "overestimate"}
    with pytest.raises(KeyError, match="unknown scenario"):
        build_scenario("nope")


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_produces_consistent_trace(name):
    sc = build_scenario(name, scale=SCALE, seed=7)
    assert sc.fragments, f"{name}: empty unfillable-hole trace"
    validate_fragments(sc.fragments)
    sizes = pool_sizes(fragments_to_events(sc.fragments))
    assert all(n >= 0 for _, n in sizes)
    st = sc.stats
    assert st.n_fragments == len(sc.fragments)
    assert 0.0 < st.idle_fraction < 1.0
    assert st.eq_nodes > 0
    assert 0.0 <= st.pct_fragments_short <= 1.0
    # scheduler side is consistent with the trace side
    assert abs(sc.sched.idle_fraction - st.idle_fraction) < 1e-6
    assert sc.sched.n_started > 0


def test_capacity_scenario_is_short_fragment_heavy():
    sc = build_scenario("capacity", scale=0.25, seed=7)
    assert sc.stats.pct_fragments_short > 0.3
    assert 0.05 < sc.stats.idle_fraction < 0.6
    assert sc.sched.n_backfilled > 0


def test_weekend_scenario_is_low_load_dominated():
    sc = build_scenario("weekend", scale=0.25, seed=7)
    assert sc.sched.blocked_share < 0.5          # idle mostly queue-empty
    assert sc.stats.idle_fraction > 0.3


def test_maintenance_scenario_has_no_drain_idle():
    # full scale: 24h trace with 1h drains starting at 6h, 14h, 22h
    sc = build_scenario("maintenance", scale=1.0, seed=7)
    assert sc.sched.drain_nodetime == sc.n_nodes * 3 * 3600.0
    drains = [(s * 3600.0, (s + 1) * 3600.0) for s in (6.0, 14.0, 22.0)]
    for f in sc.fragments:
        for s, e in drains:
            assert f.end <= s or f.start >= e, (f, s, e)


def test_engine_consumes_scenario_end_to_end():
    sc = build_scenario("capacity", scale=SCALE, seed=7)
    events = fragments_to_events(sc.fragments)
    jobs = [TrainerJob(id=i, curve=tab2_curve("ShuffleNet"), work=1e9,
                       n_min=1, n_max=8, r_up=20.0, r_dw=5.0)
            for i in range(4)]
    eng = AllocationEngine(time_budget=0.050)
    rep = Simulator(events, jobs, eng, t_fwd=120.0,
                    horizon=sc.duration).run()
    assert rep.total_samples > 0
    assert rep.events_processed > 0
    assert eng.stats.events == rep.events_processed
