"""Integration tests: ElasticTrainer rescale semantics, the full
BFTrainerRuntime (scheduler driving real JAX training), and the serving
engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import (
    MILPAllocator,
    amdahl_curve,
    fragments_to_events,
    generate_summit_like,
)
from repro.elastic import BFTrainerRuntime, ElasticTrainer, ManagedTrainer
from repro.models import build_model
from repro.serving import ServeEngine


def small_trainer(arch="gemma-2b", seed=0, seq=48, lr=3e-3):
    from repro.optim import AdamW
    cfg = get_arch(arch).reduced()
    model = build_model(cfg, remat=False)
    tr = ElasticTrainer(model, per_node_batch=2, seed=seed,
                        optimizer=AdamW(lr=lr), warmup_steps=2)
    tr.pipeline.cfg.seq_len = seq
    return tr


def test_elastic_trainer_trains_and_rescales():
    tr = small_trainer()
    tr.rescale(1)
    losses = [tr.train_step().loss for _ in range(6)]
    # rescale preserves state: params identical before/after
    before = jax.tree.leaves(tr.params)[0].copy()
    tr.rescale(0)          # waiting (host snapshot)
    assert tr.n_nodes == 0
    tr.rescale(1)
    after = jax.tree.leaves(tr.params)[0]
    np.testing.assert_array_equal(np.asarray(before), np.asarray(after))
    # continues training from where it left off (step count preserved)
    m = tr.train_step()
    assert m.step == 7
    assert np.isfinite(m.loss)
    # loss should broadly decrease over continued training
    more = [tr.train_step().loss for _ in range(25)]
    assert np.mean(more[-5:]) < np.mean(losses[:3])


def test_elastic_trainer_measures_rescale_costs():
    tr = small_trainer(seed=1)
    tr.rescale(1)
    tr.train_step()
    tr.rescale(0)
    tr.rescale(1)
    r_up, r_dw = tr.measured_rescale_costs()
    assert r_up > 0 and r_dw >= 0
    # 0->1, 1->0, 0->1 (no-op rescale(1)->1 is not recorded)
    assert len(tr.rescale_history) == 3


def test_measured_rescale_costs_exclude_kills():
    """Regression: transitions to/from 0 nodes (kill/park and unpark)
    are host-transfer events, not mesh rescales — they must not
    contaminate the r_dw/r_up estimates fed back into the MILP.  The old
    filter ``0 <= b < a`` averaged kill walls into r_dw."""
    tr = object.__new__(ElasticTrainer)   # only rescale_history is read
    tr.rescale_history = [
        (4, 2, 0.2), (2, 1, 0.4),   # true downscales
        (3, 0, 50.0),               # kill: must be excluded from r_dw
        (1, 2, 0.6), (2, 4, 1.0),   # true upscales
        (0, 2, 40.0),               # unpark: must be excluded from r_up
    ]
    r_up, r_dw = tr.measured_rescale_costs()
    assert r_dw == pytest.approx(0.3)     # mean(0.2, 0.4), no 50.0
    assert r_up == pytest.approx(0.8)     # mean(0.6, 1.0), no 40.0


def test_measured_rescale_costs_defaults_without_history():
    tr = object.__new__(ElasticTrainer)
    tr.rescale_history = [(0, 1, 12.0), (1, 0, 9.0)]   # only park/unpark
    r_up, r_dw = tr.measured_rescale_costs()
    assert (r_up, r_dw) == (0.5, 0.1)     # pre-measurement defaults


def test_elastic_rescale_rejects_oversubscription():
    tr = small_trainer(seed=2)
    with pytest.raises(ValueError):
        tr.rescale(len(jax.devices()) + 1)


def test_bftrainer_runtime_end_to_end():
    """The paper's full loop at miniature scale: MILP allocates single-node
    pools to two real Trainers over a replayed trace."""
    frags = generate_summit_like(n_nodes=6, duration=24 * 3600.0, seed=5)
    events = fragments_to_events(frags)
    managed = [
        ManagedTrainer(id=i, trainer=small_trainer(seed=10 + i),
                       curve=amdahl_curve(f"t{i}", 100.0, 0.2),
                       n_min=1, n_max=1, target_steps=3)
        for i in range(2)
    ]
    rt = BFTrainerRuntime(managed, MILPAllocator("fast"), t_fwd=120.0)
    rep = rt.run(events, time_scale=1.0, max_steps_per_interval=2)
    assert rep.events > 0
    assert sum(rep.steps.values()) > 0
    for mid, ls in rep.losses.items():
        assert all(np.isfinite(v) for v in ls)


def test_serve_engine_greedy_matches_forward_argmax():
    cfg = get_arch("yi-6b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(3))
    eng = ServeEngine(model, params, max_len=64)
    rng = np.random.RandomState(0)
    prompt = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 12)), jnp.int32)
    res = eng.generate({"tokens": prompt}, 5)
    assert res.tokens.shape == (2, 5)

    # replicate greedily with repeated full forwards
    toks = prompt
    for i in range(5):
        logits, _ = jax.jit(model.forward)(params, {"tokens": toks})
        nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        assert np.array_equal(np.asarray(nxt[:, 0]), res.tokens[:, i]), i
        toks = jnp.concatenate([toks, nxt], axis=1)


def test_serve_engine_ssm():
    cfg = get_arch("mamba2-2.7b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(4))
    eng = ServeEngine(model, params, max_len=64)
    prompt = jnp.ones((1, 8), jnp.int32)
    res = eng.generate({"tokens": prompt}, 4)
    assert res.tokens.shape == (1, 4)
