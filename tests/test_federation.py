"""Federated multi-pool allocation tests (DESIGN.md §14).

Tier groups:

* **K=1 parity** — the federated loop with one pool is bit-identical to
  the single-pool ``Simulator`` across the 6-scenario × 5-policy sweep
  (the federation layer must cost nothing when it adds nothing).
* **Conservation** — allocated node-time never exceeds the pool's idle
  supply, per pool and fleet-wide, including under random event streams
  (hypothesis property) and under migrations.
* **Rebalancer accounting** — every migration changes ownership exactly
  once, charges the teardown + transfer stall, and is reflected in
  both pools' counters.
* **Recovery** — fleet snapshot/restore round-trips; federated chaos
  runs restart per-pool allocators warm.
"""
import dataclasses
import json
import math

import pytest

from repro.core import AllocationEngine, Simulator
from repro.core.engine import EngineStats
from repro.core.events import (
    PoolEvent,
    apply_events,
    fragments_to_events,
    merge_events,
    split_events_by_pool,
)
from repro.core.loop import TrainerJob
from repro.core.scaling import TAB2, tab2_curve
from repro.federation import (
    FEDERATION_SNAPSHOT_SCHEMA,
    EventRouter,
    FederatedEngine,
    FederatedLoop,
    PoolMap,
    PoolView,
    Rebalancer,
    assign_jobs,
)
from repro.sched.scenarios import build_scenario

_SWEEP_SCENARIOS = ["capability", "capacity", "bursty", "maintenance",
                    "weekend", "overestimate"]
_SWEEP_POLICIES = ["throughput", "weighted", "maxmin", "deadline", "costcap"]


def _policy_jobs(policy="throughput", n=6):
    names = list(TAB2)
    out = []
    for i in range(n):
        j = TrainerJob(id=i, curve=tab2_curve(names[i % len(names)]),
                       work=2e8, n_min=1, n_max=16, r_up=20.0, r_dw=5.0)
        if policy == "weighted":
            j.weight = 1.0 + (i % 3)
        if policy == "deadline":
            j.deadline = 3600.0 * (4 + i)
        if policy == "costcap":
            j.budget = 3.0e5
        out.append(j)
    return out


def _det_engine(k=None):
    # time_budget=0: greedy+cache only — no MILP, so identical replays
    # are bit-identical regardless of machine load
    return AllocationEngine(time_budget=0.0)


# ---------------------------------------------------------------------------
# sharding / ingestion primitives
# ---------------------------------------------------------------------------


def test_pool_map_layouts():
    assert [PoolMap.stride(3)(n) for n in range(6)] == [0, 1, 2, 0, 1, 2]
    cm = PoolMap.contiguous(10, 3)          # blocks of 4
    assert [cm(n) for n in (0, 3, 4, 7, 8, 9, 99)] == [0, 0, 1, 1, 2, 2, 2]
    bm = PoolMap.from_bounds([0, 16, 40])
    assert [bm(n) for n in (0, 15, 16, 39, 40, 1000)] == [0, 0, 1, 1, 2, 2]
    with pytest.raises(ValueError):
        PoolMap.from_bounds([10, 5])
    with pytest.raises(ValueError):
        PoolMap(n_pools=0)


def test_split_events_by_pool_partitions_and_tags():
    events = [
        PoolEvent(0.0, joined=(0, 1, 2, 3)),
        PoolEvent(5.0, left=(1,), joined=(4,)),
        PoolEvent(9.0, failed=(2, 3)),
    ]
    per = split_events_by_pool(events, PoolMap.stride(2))
    # every node lands in exactly one pool's substream, tagged
    seen = set()
    for k, evs in per.items():
        for e in evs:
            assert e.pool == k
            for n in e.joined + e.left + e.failed:
                assert PoolMap.stride(2)(n) == k
                seen.add((e.time, n))
    total = sum(len(e.joined) + len(e.left) + len(e.failed) for e in events)
    assert len(seen) == total


def test_apply_events_folds_membership():
    live = apply_events(set(), [PoolEvent(0.0, joined=(1, 2, 3)),
                                PoolEvent(1.0, left=(2,)),
                                PoolEvent(2.0, failed=(3,))])
    assert live == {1}


def test_event_router_drains_fifo_per_epoch():
    pm = PoolMap.stride(2)
    r = EventRouter(pm)
    r.ingest([PoolEvent(t, joined=(int(t) % 2,)) for t in (0.0, 1.0, 2.0,
                                                           3.0, 4.0)])
    assert r.pending(0) == 3 and r.pending(1) == 2
    # half-open window [0, 2): event at exactly 2.0 stays queued
    got = r.drain(0, 2.0)
    assert [e.time for e in got] == [0.0]
    assert r.next_time(0) == 2.0
    assert [e.time for e in r.drain(0)] == [2.0, 4.0]
    assert r.pending(0) == 0
    with pytest.raises(ValueError):
        r.push(PoolEvent(9.0, joined=(1,)))     # untagged


def test_assign_jobs_is_capacity_weighted_and_deterministic():
    jobs = _policy_jobs(n=8)
    p1 = assign_jobs(jobs, [3.0, 1.0])
    assert p1 == assign_jobs(jobs, [3.0, 1.0])
    # 3:1 weights → ~6:2 split
    assert p1.count(0) == 6 and p1.count(1) == 2


# ---------------------------------------------------------------------------
# K=1 parity: the federation layer must add nothing at K=1
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", _SWEEP_SCENARIOS)
def test_federated_k1_parity_sweep(scenario):
    """Acceptance sweep (ISSUE 8): K=1 federated replay matches the
    single-pool engine within 1e-12 relative on every scenario × policy
    combination."""
    sc = build_scenario(scenario, scale=0.25)
    events = fragments_to_events(sc.fragments)
    for policy in _SWEEP_POLICIES:
        base = Simulator(events, _policy_jobs(policy), _det_engine(),
                         t_fwd=120.0, pj_max=10, horizon=sc.duration,
                         objective=policy).run()
        fed = FederatedLoop(events, _policy_jobs(policy), n_pools=1,
                            allocator_factory=_det_engine, t_fwd=120.0,
                            pj_max=10, horizon=sc.duration,
                            objective=policy).run()
        ref = max(1.0, abs(base.total_samples))
        gap = abs(base.total_samples - fed.total_samples) / ref
        assert gap <= 1e-12, f"{scenario}/{policy}: parity gap {gap:.2e}"
        assert fed.makespan == base.makespan
        assert fed.events_processed == base.events_processed
        assert fed.rescale_cost_s == base.rescale_cost_s
        assert fed.preempt_cost_s == base.preempt_cost_s
        assert fed.unfinished == base.unfinished


def test_federated_k1_forced_epochs_matches_throughput():
    """Windowed K=1 replay (explicit epoch_s) matches the single-shot
    run within 1e-12 relative under the progress-insensitive throughput
    policy: cached decisions are identical across window boundaries and
    reconstruct_map keeps node sets stable, so chunking the horizon
    changes nothing but float-summation order in the integrator (the
    epoch-boundary heartbeat solves are cache hits, not rescales)."""
    sc = build_scenario("bursty", scale=0.25, seed=1)
    events = fragments_to_events(sc.fragments)
    base = FederatedLoop(events, _policy_jobs(), n_pools=1,
                         allocator_factory=_det_engine,
                         horizon=sc.duration).run()
    chunked = FederatedLoop(events, _policy_jobs(), n_pools=1,
                            allocator_factory=_det_engine,
                            horizon=sc.duration,
                            epoch_s=sc.duration / 7.0).run()
    gap = abs(chunked.total_samples - base.total_samples) \
        / max(1.0, abs(base.total_samples))
    assert gap <= 1e-12
    # windowing must not introduce a single extra rescale
    assert chunked.rescale_cost_s == base.rescale_cost_s
    assert chunked.unfinished == base.unfinished
    assert chunked.epochs == 7


def test_parallel_serial_and_telemetry_runs_identical():
    from repro.obs import Telemetry

    sc = build_scenario("capacity", scale=0.25, seed=3)
    events = fragments_to_events(sc.fragments)

    def run(parallel, tel):
        s = FederatedLoop(events, _policy_jobs(n=8), n_pools=4,
                          allocator_factory=_det_engine,
                          horizon=sc.duration, parallel=parallel,
                          telemetry=tel, migration_cost_s=10.0).run()
        return (s.total_samples, s.events_processed, s.rescale_cost_s,
                s.preempt_cost_s, len(s.migrations), s.unfinished)

    assert run(False, None) == run(True, None) == run(True, Telemetry())


# ---------------------------------------------------------------------------
# conservation: allocated node-time <= idle supply, per pool + fleet
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pools", [2, 4])
def test_node_time_conservation_per_pool_and_fleet(pools):
    sc = build_scenario("fleet", scale=0.25, seed=2)
    events = fragments_to_events(sc.fragments)
    s = FederatedLoop(events, _policy_jobs(n=2 * pools),
                      pool_map=PoolMap.contiguous(sc.n_nodes, pools),
                      allocator_factory=_det_engine, horizon=sc.duration,
                      migration_cost_s=15.0).run()
    assert s.pools, "no per-pool stats"
    for p in s.pools:
        assert p.allocated_node_s <= p.supply_node_s + 1e-6, \
            f"pool {p.pool}: allocated {p.allocated_node_s} > " \
            f"supply {p.supply_node_s}"
    fleet_alloc = sum(p.allocated_node_s for p in s.pools)
    fleet_supply = sum(p.supply_node_s for p in s.pools)
    assert fleet_alloc <= fleet_supply + 1e-6


def test_conservation_property_random_streams():
    """Hypothesis property: on arbitrary join/leave streams, per-pool
    allocated node-time never exceeds the pool's supply integral."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(st.lists(st.tuples(st.floats(0.0, 4000.0),
                              st.integers(0, 15),
                              st.booleans()),
                    min_size=4, max_size=40),
           st.integers(2, 3))
    def prop(raw, pools):
        live = set()
        events = []
        for t, node, join in sorted(raw, key=lambda x: x[0]):
            if join and node not in live:
                live.add(node)
                events.append(PoolEvent(t, joined=(node,)))
            elif not join and node in live:
                live.remove(node)
                events.append(PoolEvent(t, left=(node,)))
        if not events:
            return
        s = FederatedLoop(events, _policy_jobs(n=3),
                          pool_map=PoolMap.stride(pools),
                          allocator_factory=_det_engine,
                          horizon=4000.0, epoch_s=997.0).run()
        for p in s.pools:
            assert p.allocated_node_s <= p.supply_node_s + 1e-6

    prop()


# ---------------------------------------------------------------------------
# rebalancer
# ---------------------------------------------------------------------------


def _starved_views():
    # pool 0: 4 jobs on 2 nodes (starved); pool 1: 12 nodes, no jobs
    jobs = _policy_jobs(n=4)
    return [PoolView(0, 2, list(jobs)), PoolView(1, 12, [])]


def test_rebalancer_respects_patience():
    rb = Rebalancer(patience=3, starve_rel=0.01)
    assert rb.propose(None, _starved_views(), 120.0, 0.0) == []
    assert rb.propose(None, _starved_views(), 120.0, 1.0) == []
    moves = rb.propose(None, _starved_views(), 120.0, 2.0)
    assert moves and all(m.src == 0 and m.dst == 1 for m in moves)


def test_rebalancer_stands_still_when_balanced():
    rb = Rebalancer(patience=1)
    jobs = _policy_jobs(n=2)
    views = [PoolView(0, 32, [jobs[0]]), PoolView(1, 32, [jobs[1]])]
    for t in range(4):
        assert rb.propose(None, views, 120.0, float(t)) == []


def test_migration_accounting_charges_stall_and_moves_ownership():
    sc = build_scenario("fleet", scale=0.25, seed=4)
    events = fragments_to_events(sc.fragments)
    jobs = _policy_jobs(n=8)
    loop = FederatedLoop(events, jobs, pool_map=sc.pool_map(),
                         allocator_factory=_det_engine,
                         horizon=sc.duration, migration_cost_s=25.0,
                         rebalancer=Rebalancer(patience=1, starve_rel=0.01,
                                               max_moves=2,
                                               migration_cost_s=25.0))
    s = loop.run()
    # in/out tallies match the migration list exactly
    assert sum(p.migrations_out for p in s.pools) == len(s.migrations)
    assert sum(p.migrations_in for p in s.pools) == len(s.migrations)
    for m in s.migrations:
        assert m.src != m.dst
        assert s.pools[m.src].migrations_out >= 1
        assert s.pools[m.dst].migrations_in >= 1
    # each migration charged at least the transfer stall
    if s.migrations:
        assert s.migration_stall_s >= 25.0 * len(s.migrations) - 1e-9


def test_migration_of_running_job_pays_teardown():
    from repro.federation.rebalance import Migration

    loop = FederatedLoop([PoolEvent(0.0, joined=(0,))], [], n_pools=2,
                         migration_cost_s=40.0)
    jobs = _policy_jobs(n=2)
    running, queued = jobs
    running.nodes = [0, 1]
    owned = {0: [running, queued], 1: []}

    stall = loop._apply_migration(
        Migration(job_id=running.id, src=0, dst=1, time=100.0,
                  gain=1.0, loss=0.0), owned, 100.0)
    assert running in owned[1] and running not in owned[0]
    assert running.nodes == []                      # torn down at source
    assert stall == 40.0 + running.r_dw             # transfer + teardown
    assert running.rescale_cost_s == running.r_dw
    assert running.n_rescales == 1
    assert running.busy_until == 100.0 + stall

    stall_q = loop._apply_migration(
        Migration(job_id=queued.id, src=0, dst=1, time=100.0,
                  gain=1.0, loss=0.0), owned, 100.0)
    assert stall_q == 40.0                          # no nodes → no teardown
    assert queued.n_rescales == 0


# ---------------------------------------------------------------------------
# fleet snapshot / recovery
# ---------------------------------------------------------------------------


def test_federated_snapshot_roundtrip_json():
    sc = build_scenario("capacity", scale=0.25, seed=5)
    events = fragments_to_events(sc.fragments)
    loop = FederatedLoop(events, _policy_jobs(n=6), n_pools=3,
                         allocator_factory=_det_engine,
                         horizon=sc.duration)
    loop.run()
    snap = loop.fed_engine.snapshot()
    assert snap["schema"] == FEDERATION_SNAPSHOT_SCHEMA
    blob = json.dumps(snap)
    fe2 = FederatedEngine.from_snapshot(json.loads(blob),
                                        PoolMap.stride(3),
                                        lambda k: _det_engine())
    # every pool's cache came back entry-for-entry
    for k, eng in loop.fed_engine.engines.items():
        assert fe2.engines[k]._cache.keys() == eng._cache.keys()
    # schema / shape guards
    with pytest.raises(ValueError):
        FederatedEngine(PoolMap.stride(3)).restore({"schema": "nope"})
    with pytest.raises(ValueError):
        FederatedEngine(PoolMap.stride(2)).restore(json.loads(blob))


def test_federated_engine_stats_compose():
    a, b = EngineStats(), EngineStats()
    a.events, a.cache_hits = 5, 2
    b.events, b.wall_time = 3, 1.5
    tot = EngineStats.sum_of([a, b])
    assert tot.events == 8 and tot.cache_hits == 2 and tot.wall_time == 1.5
    sc = build_scenario("bursty", scale=0.25, seed=6)
    events = fragments_to_events(sc.fragments)
    loop = FederatedLoop(events, _policy_jobs(n=6), n_pools=2,
                         allocator_factory=_det_engine,
                         horizon=sc.duration)
    loop.run()
    fleet = loop.fed_engine.stats()
    per = loop.fed_engine.pool_stats()
    assert fleet.events == sum(s.events for s in per.values()) > 0


def test_federated_chaos_recovers_warm_per_pool():
    from repro.chaos import ChaosSpec, run_federated_chaos

    sc = build_scenario("fleet", scale=0.25, seed=7)
    events = fragments_to_events(sc.fragments)
    spec = ChaosSpec(seed=11, mtbf=4 * 3600.0,
                     crash_every=sc.duration / 3.0, snapshot_every=600.0,
                     restart_penalty=30.0)
    rep = run_federated_chaos(events, _policy_jobs(n=8), spec,
                              pool_map=sc.pool_map(), horizon=sc.duration,
                              engine_factory=_det_engine)
    assert rep.allocator_restarts > 0, "no restarts exercised"
    assert rep.recovered_cache_entries > 0, "restarts never restored warm"
    assert rep.stats.n_failures > 0
    assert rep.allocated_node_seconds <= rep.pool_node_seconds + 1e-6
    for p in rep.stats.pools:
        assert p.allocated_node_s <= p.supply_node_s + 1e-6


# ---------------------------------------------------------------------------
# telemetry composition
# ---------------------------------------------------------------------------


def test_fleet_telemetry_merges_pool_hubs():
    from repro.obs import Telemetry

    sc = build_scenario("capacity", scale=0.25, seed=8)
    events = fragments_to_events(sc.fragments)
    tel = Telemetry()
    s = FederatedLoop(events, _policy_jobs(n=6), n_pools=2,
                      allocator_factory=None, horizon=sc.duration,
                      telemetry=tel).run()
    # fleet decision histogram aggregates exactly the per-pool solves
    h = tel.histograms["fleet.decision_ms"]
    assert h.count == s.events_processed
    # per-pool namespaced counters present and summing to engine totals
    ev = sum(v for k, v in tel.counters.items()
             if k.endswith(".engine.events"))
    assert ev == s.events_processed
    assert tel.gauges["fleet.n_pools"] == 2


def test_histogram_merge_exact_and_bucketed():
    from repro.obs.telemetry import Histogram

    a, b = Histogram(exact_cap=8), Histogram(exact_cap=8)
    for v in (1.0, 2.0, 3.0):
        a.observe(v)
    for v in (4.0, 5.0):
        b.observe(v)
    a.merge(b)
    assert a.count == 5 and a.percentile(50) == 3.0 and a.max == 5.0
    # overflow: merged histogram degrades to buckets but keeps count/sum
    big = Histogram(exact_cap=4)
    for v in range(1, 9):
        big.observe(float(v))
    c = Histogram(exact_cap=4)
    c.observe(10.0)
    c.merge(big)
    assert c.count == 9
    assert c.total == pytest.approx(sum(range(1, 9)) + 10.0)
    assert c.percentile(99) > 5.0
