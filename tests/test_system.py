"""End-to-end behaviour test: the paper's headline claims at reduced scale.

Replays a Summit-calibrated trace with Tab-2 DNN Trainers; asserts the
reproduction-level behaviours: MILP >= heuristic efficiency, rescale-cost
gap, efficiency U in a sane band, T_fwd monotonicity of rescale spend.
"""
import pytest

from repro.core import (
    EqualShareAllocator,
    MILPAllocator,
    Simulator,
    TrainerJob,
    eq_nodes,
    fragments_to_events,
    generate_summit_like,
    static_outcome,
    tab2_curve,
)

HORIZON = 36 * 3600.0


@pytest.fixture(scope="module")
def trace():
    frags = generate_summit_like(n_nodes=128, duration=HORIZON, seed=21)
    return fragments_to_events(frags)


def _hpo_jobs(n=8):
    curve = tab2_curve("ShuffleNet")
    return [TrainerJob(id=i, curve=curve, work=1e12, n_min=1, n_max=24,
                       r_up=20.0, r_dw=5.0) for i in range(n)]


def test_hpo_efficiency_band(trace):
    rep = Simulator(trace, _hpo_jobs(), MILPAllocator("fast"), t_fwd=120.0,
                    horizon=HORIZON).run()
    n_eq = eq_nodes(trace, 0.0, HORIZON)
    a_s = static_outcome(_hpo_jobs(), max(1, round(n_eq)), HORIZON,
                         MILPAllocator("fast"))
    u = rep.total_samples / a_s
    # paper: up to ~93%, average ~80%; superlinear Tab-2 rows and eq-node
    # rounding allow >1 at miniature scale — assert a broad sane band.
    assert 0.5 < u < 1.6, u


def test_milp_vs_heuristic_headline(trace):
    milp = Simulator(trace, _hpo_jobs(), MILPAllocator("fast"), t_fwd=120.0,
                     horizon=HORIZON).run()
    heur = Simulator(trace, _hpo_jobs(), EqualShareAllocator(), t_fwd=120.0,
                     horizon=HORIZON).run()
    assert milp.total_samples >= 0.95 * heur.total_samples
    assert milp.rescale_cost_samples < 0.5 * heur.rescale_cost_samples


def test_tfwd_monotone_rescale_investment(trace):
    """Paper Fig 7b: rescale spend grows with forward-looking time."""
    costs = []
    for t_fwd in (10.0, 600.0):
        rep = Simulator(trace, _hpo_jobs(), MILPAllocator("fast"),
                        t_fwd=t_fwd, horizon=HORIZON).run()
        costs.append(rep.rescale_cost_samples)
    assert costs[0] <= costs[1] * 1.05


def test_preemption_cost_allocator_independent(trace):
    """Paper Fig 11a: preemption cost is outside the allocator's control."""
    milp = Simulator(trace, _hpo_jobs(), MILPAllocator("fast"), t_fwd=120.0,
                     horizon=HORIZON).run()
    heur = Simulator(trace, _hpo_jobs(), EqualShareAllocator(), t_fwd=120.0,
                     horizon=HORIZON).run()
    assert milp.preempt_cost_s <= heur.preempt_cost_s * 2.0 + 1.0
