"""Observability subsystem tests (DESIGN.md §13).

Three invariant families:

* **zero-overhead parity** — replaying every scenario × policy with a
  live ``Telemetry`` hub produces bit-identical ``LoopStats`` /
  ``EngineStats`` to the disabled (``NULL_TELEMETRY``) replay: the hub
  is a passive sink and can never feed back into decisions;
* **trace determinism** — same-seed replays (clean and chaos) emit
  byte-identical JSONL streams (the wall clock is excluded by default);
* unit coverage for the pieces: streaming ``Histogram`` percentiles,
  JSONL round-trip, Chrome-trace export shape, per-job timelines, and
  the dataclass-derived ``as_dict`` serialization.
"""
from __future__ import annotations

import dataclasses
import json
import math

import pytest

from repro.chaos import ChaosSpec, run_chaos
from repro.core import AllocationEngine, Simulator, fragments_to_events
from repro.core.engine import EngineStats
from repro.core.loop import LoopStats, TrainerJob
from repro.core.scaling import tab2_curve
from repro.obs import (
    NULL_TELEMETRY,
    Histogram,
    NullTelemetry,
    SpanEvent,
    Telemetry,
    TRACE_EVENT_KEYS,
    TRACE_SCHEMA,
    build_timelines,
    chrome_trace,
    read_jsonl,
    to_jsonl,
)
from repro.obs.report import _demo_jobs, run_summary
from repro.sched import SCENARIOS, build_scenario

POLICIES = ("throughput", "weighted", "maxmin", "deadline", "costcap")


# ---------------------------------------------------------------------------
# Histogram
# ---------------------------------------------------------------------------


def test_histogram_exact_percentiles():
    h = Histogram()
    for v in range(1, 101):            # 1..100
        h.observe(float(v))
    assert h.count == 100
    assert h.percentile(50) == 50.0
    assert h.percentile(95) == 95.0
    assert h.percentile(99) == 99.0
    assert h.min == 1.0 and h.max == 100.0
    assert h.mean == pytest.approx(50.5)


def test_histogram_empty():
    h = Histogram()
    s = h.summary()
    assert s["count"] == 0
    assert s["p50"] == 0.0 and s["min"] == 0.0 and s["max"] == 0.0


def test_histogram_log_bucket_degradation():
    h = Histogram(exact_cap=64)
    vals = [1.001 ** i for i in range(1000)]   # spread over ~e
    for v in vals:
        h.observe(v)
    assert h._exact is None                     # degraded to buckets
    assert h.count == 1000
    exact = sorted(vals)
    for q in (50, 95, 99):
        approx = h.percentile(q)
        true = exact[max(0, math.ceil(q / 100 * len(exact)) - 1)]
        assert approx == pytest.approx(true, rel=0.08)   # ~7% buckets
    assert h.percentile(100) <= h.max * 1.07


def test_histogram_nonpositive_underflow():
    h = Histogram(exact_cap=2)
    for v in (-1.0, 0.0, 5.0, 7.0):
        h.observe(v)
    assert h.count == 4
    assert h.percentile(25) == 0.0              # underflow bucket
    assert h.percentile(99) == pytest.approx(7.0, rel=0.08)


# ---------------------------------------------------------------------------
# Span serialization + Chrome export
# ---------------------------------------------------------------------------


def _sample_events():
    return [
        SpanEvent("instant", "job", "admit", 5.0, 5.0, job=0,
                  args={"arrival": 1.0, "wait": 4.0}),
        SpanEvent("span", "job", "run", 5.0, 20.0, job=0, args={"n": 4}),
        SpanEvent("span", "job", "stall", 20.0, 25.0, job=0,
                  args={"why": "grow", "cost_s": 5.0}),
        SpanEvent("span", "solver", "greedy", 5.0, 5.0, wall_s=0.002,
                  args={"pool": 8}),
        SpanEvent("counter", "counter", "pool_size", 5.0, 5.0, value=8.0),
    ]


def test_jsonl_round_trip():
    evs = _sample_events()
    text = to_jsonl(evs)
    header = json.loads(text.splitlines()[0])
    assert header == {"schema": TRACE_SCHEMA}
    back = read_jsonl(text)
    assert len(back) == len(evs)
    # wall clock excluded by default: the solver span's wall_s is nulled
    assert back[3].wall_s is None
    assert back[1].args == {"n": 4}
    # include_wall keeps it
    back_w = read_jsonl(to_jsonl(evs, include_wall=True))
    assert back_w[3].wall_s == pytest.approx(0.002)


def test_jsonl_rejects_unknown_schema():
    with pytest.raises(ValueError, match="trace schema"):
        read_jsonl('{"schema": "bftrainer-trace/999"}\n')


def test_span_event_key_set_is_stable():
    d = _sample_events()[0].as_dict()
    assert list(d) == TRACE_EVENT_KEYS


def test_chrome_trace_shape():
    trace = chrome_trace(_sample_events())
    evs = trace["traceEvents"]
    phases = {e["ph"] for e in evs}
    assert {"M", "X", "i", "C"} <= phases
    # every non-metadata event is a complete trace-event record
    for e in evs:
        assert {"ph", "pid", "tid", "name"} <= set(e)
        if e["ph"] in ("X", "i", "C"):
            assert "ts" in e
    # the solver span's rendered duration is its *wall* time in µs
    solver = [e for e in evs if e.get("cat") == "solver"][0]
    assert solver["dur"] == pytest.approx(0.002 * 1e6)
    # stalls render on the job's dedicated stall thread
    stall = [e for e in evs if e["name"] == "stall"][0]
    run = [e for e in evs if e["name"] == "run"][0]
    assert stall["tid"] == run["tid"] + 1


# ---------------------------------------------------------------------------
# Timelines
# ---------------------------------------------------------------------------


def test_build_timelines_folds_lifecycle():
    tel = Telemetry()
    tel.instant("job", "admit", 5.0, job=1, arrival=1.0, wait=4.0)
    tel.span("job", "run", 5.0, 10.0, job=1, n=4)
    tel.span("job", "run", 10.0, 20.0, job=1, n=4)     # merges with prev
    tel.instant("job", "rescale", 20.0, job=1, old=4, new=2, cost_s=5.0)
    tel.span("job", "stall", 20.0, 25.0, job=1, why="shrink", cost_s=5.0)
    tel.span("job", "run", 25.0, 30.0, job=1, n=2)
    tel.instant("job", "preempt", 30.0, job=1, taken=1)
    tel.instant("job", "fail", 31.0, job=1, lost=100.0, penalty_s=60.0)
    tel.instant("job", "finish", 40.0, job=1)
    tel.instant("loop", "pool-event", 5.0)             # ignored: not cat=job
    tls = build_timelines(tel)
    assert set(tls) == {1}
    t = tls[1]
    assert t.arrival == 1.0 and t.admitted_at == 5.0
    assert t.admission_wait == 4.0
    assert t.segments == [(5.0, 20.0, 4), (25.0, 30.0, 2)]
    assert t.node_seconds == pytest.approx(15 * 4 + 5 * 2)
    assert t.stalls == [(20.0, 25.0, "shrink")]
    assert t.rescales == [(20.0, 4, 2)]
    assert t.n_preemptions == 1 and t.n_failures == 1
    assert t.lost_progress == 100.0
    assert t.finished_at == 40.0
    s = t.summary()
    assert s["n_shrinks"] == 1 and s["n_grows"] == 0


# ---------------------------------------------------------------------------
# Null hub
# ---------------------------------------------------------------------------


def test_null_telemetry_is_falsy_noop():
    assert not NULL_TELEMETRY
    assert not NullTelemetry()
    assert Telemetry()
    NULL_TELEMETRY.count("x")
    NULL_TELEMETRY.gauge("x", 1.0)
    NULL_TELEMETRY.observe("x", 1.0)
    NULL_TELEMETRY.span("c", "n", 0.0, 1.0)
    NULL_TELEMETRY.instant("c", "n", 0.0)
    NULL_TELEMETRY.sample("x", 0.0, 1.0)
    assert NULL_TELEMETRY.counters == {}
    assert NULL_TELEMETRY.events == []


# ---------------------------------------------------------------------------
# Dataclass-derived serialization (EngineStats / LoopStats)
# ---------------------------------------------------------------------------


def test_engine_stats_as_dict_matches_fields():
    s = EngineStats()
    assert set(s.as_dict()) == {f.name for f in dataclasses.fields(s)}


def test_loop_stats_as_dict_matches_fields():
    s = LoopStats(total_samples=0.0, makespan=0.0, events_processed=0,
                  allocator="x", per_trainer_runtime={},
                  rescale_cost_samples=0.0, rescale_cost_s=0.0,
                  preempt_cost_s=0.0, solver_wall_total=0.0)
    d = s.as_dict()
    assert set(d) == {f.name for f in dataclasses.fields(s)}
    # and it is JSON-clean for the simple fields
    json.dumps({k: v for k, v in d.items() if k != "event_records"})


# ---------------------------------------------------------------------------
# Zero-overhead parity + trace determinism on real replays
# ---------------------------------------------------------------------------

PARITY_SCALE = 0.04


def _normalized(stats: LoopStats) -> LoopStats:
    recs = [dataclasses.replace(r, solver_wall=0.0)
            for r in stats.event_records]
    return dataclasses.replace(stats, solver_wall_total=0.0,
                               allocator="", event_records=recs)


def _replay(scenario: str, policy, tel):
    sc = build_scenario(scenario, scale=PARITY_SCALE, seed=7)
    events = fragments_to_events(sc.fragments)
    jobs = _demo_jobs(max(4, int(round(sc.stats.eq_nodes / 3))),
                      sc.duration, sc.stats.eq_nodes, seed=7)
    engine = AllocationEngine(time_budget=0.0)   # deterministic portfolio
    if tel is not None:
        engine.telemetry = tel
    stats = Simulator(events, jobs, engine, t_fwd=120.0,
                      horizon=sc.duration, objective=policy,
                      telemetry=tel).run()
    return stats, engine.stats


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
@pytest.mark.parametrize("policy", POLICIES)
def test_enabled_disabled_parity(scenario, policy):
    """Enabling telemetry must not change a single decision or stat."""
    off_stats, off_engine = _replay(scenario, policy, None)
    tel = Telemetry()
    on_stats, on_engine = _replay(scenario, policy, tel)
    assert _normalized(on_stats) == _normalized(off_stats)
    assert dataclasses.replace(on_engine, wall_time=0.0) \
        == dataclasses.replace(off_engine, wall_time=0.0)
    assert tel.events                 # the enabled run really observed


def test_engine_stats_from_telemetry_round_trip():
    tel = Telemetry()
    _, engine_stats = _replay("bursty", None, tel)
    assert EngineStats.from_telemetry(tel) == engine_stats


def test_same_seed_trace_jsonl_is_deterministic():
    tel1 = Telemetry()
    tel2 = Telemetry()
    _replay("bursty", "maxmin", tel1)
    _replay("bursty", "maxmin", tel2)
    assert tel1.to_jsonl() == tel2.to_jsonl()


def _chaos_jobs():
    return [TrainerJob(id=i, curve=tab2_curve("ShuffleNet"), work=1e9,
                       n_min=1, n_max=8, r_up=20.0, r_dw=5.0)
            for i in range(3)]


def _chaos_events():
    from repro.core.events import PoolEvent
    return [PoolEvent(time=0.0, joined=tuple(range(8))),
            PoolEvent(time=3600.0, left=(0, 1)),
            PoolEvent(time=7200.0, joined=(0,))]


def _run_chaos(tel):
    spec = ChaosSpec(mtbf=4 * 3600.0, seed=11, ckpt_every=1e8,
                     crash_every=5000.0, corrupt_prob=1.0)
    return run_chaos(_chaos_events(), _chaos_jobs(), spec,
                     engine_factory=lambda: AllocationEngine(time_budget=0.0),
                     horizon=10800.0, telemetry=tel)


def test_chaos_trace_determinism_and_parity():
    rep_off = _run_chaos(None)
    tel1 = Telemetry()
    tel2 = Telemetry()
    rep_on = _run_chaos(tel1)
    _run_chaos(tel2)
    assert tel1.to_jsonl() == tel2.to_jsonl()
    assert _normalized(rep_on.stats) == _normalized(rep_off.stats)
    # the chaos layers observed into the shared hub
    assert any(k.startswith("chaos.") for k in tel1.counters) \
        or not rep_on.schedule.kills
    if rep_on.allocator_restarts:
        assert tel1.counters.get("allocator.restarts") \
            == rep_on.allocator_restarts
    if rep_on.corrupt_restores:
        assert tel1.counters.get("chaos.corrupt_restores") \
            == rep_on.corrupt_restores


def test_run_summary_is_json_ready():
    tel = Telemetry()
    stats, _ = _replay("bursty", None, tel)
    summary = run_summary(tel, stats)
    # dense trace: histograms, counters, gauges, per-job timelines
    assert summary["histograms"]["engine.decision_ms"]["count"] > 0
    assert summary["counters"]["engine.events"] > 0
    assert summary["gauges"]["loop.events_processed"] \
        == stats.events_processed
    assert summary["timelines"]
    json.dumps({k: v for k, v in summary.items() if k != "loop_stats"})
