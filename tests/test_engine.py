"""AllocationEngine subsystem tests: greedy-vs-MILP objective parity,
vectorized-vs-scalar greedy parity, feasibility invariants,
reconstruct_map properties, memoization behaviour, the incremental
warm-start repair (including the 6-scenario × 5-policy parity sweep),
the §3.6 keep-current fallback, and simulator event coalescing."""
import time

import numpy as np
import pytest

from repro.core.engine import AllocationEngine, problem_signature
from repro.core.events import PoolEvent, fragments_to_events
from repro.core.greedy import PAIR_REPAIR_MAX_TRAINERS, solve_greedy
from repro.core.milp import AllocationProblem, TrainerSpec, project_current
from repro.core.milp_fast import reconstruct_map, solve_fast_milp
from repro.core.scaling import TAB2, amdahl_curve, tab2_curve
from repro.core.simulator import Simulator, TrainerJob


def random_instance(seed, n_lo=6, n_hi=24, j_lo=2, j_hi=5):
    rng = np.random.RandomState(seed)
    n_nodes = rng.randint(n_lo, n_hi)
    nodes = list(range(n_nodes))
    trainers, current, used = [], {}, set()
    for j in range(rng.randint(j_lo, j_hi)):
        curve = tab2_curve(list(TAB2)[j % len(TAB2)])
        n_min = rng.randint(1, 3)
        n_max = rng.randint(n_min + 1, 12)
        pts, vals = curve.breakpoints(n_min, n_max)
        trainers.append(TrainerSpec(
            id=j, n_min=n_min, n_max=n_max,
            r_up=float(rng.uniform(5, 40)), r_dw=float(rng.uniform(1, 10)),
            points=tuple(pts), values=tuple(vals)))
        k = rng.randint(0, min(n_max, n_nodes - len(used)) + 1)
        if 0 < k < n_min:
            k = 0
        avail = [x for x in nodes if x not in used]
        cur = [int(c) for c in
               rng.choice(avail, size=min(k, len(avail)), replace=False)]
        current[j] = cur
        used.update(cur)
    t_fwd = float(rng.choice([10.0, 60.0, 120.0, 300.0]))
    return AllocationProblem(nodes=nodes, trainers=trainers,
                             current=current, t_fwd=t_fwd)


def manual_objective(prob, counts):
    obj = 0.0
    for t in prob.trainers:
        cj = len([n for n in prob.current.get(t.id, [])
                  if n in set(prob.nodes)])
        c = counts[t.id]
        obj += prob.t_fwd * t.value_at(c)
        if c > cj:
            obj -= t.value_at(cj) * t.r_up
        elif c < cj:
            obj -= t.value_at(cj) * t.r_dw
    return obj


def check_allocation_invariants(prob, res):
    node_set = set(prob.nodes)
    seen = set()
    for t in prob.trainers:
        alloc = res.allocation[t.id]
        assert not (set(alloc) & seen)          # node exclusivity (Eqn 5)
        seen |= set(alloc)
        assert set(alloc) <= node_set
        assert len(alloc) == 0 or t.n_min <= len(alloc) <= t.n_max  # Eqn 4
        cur = set(prob.current.get(t.id, [])) & node_set
        if len(alloc) >= len(cur):              # no migration (Eqns 6-10)
            assert cur <= set(alloc)
        else:
            assert set(alloc) <= cur


# ---------------------------------------------------------------------------
# Greedy solver
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(15))
def test_greedy_feasible_and_consistent(seed):
    prob = random_instance(seed)
    r = solve_greedy(prob)
    check_allocation_invariants(prob, r)
    assert sum(r.counts.values()) <= len(prob.nodes)
    assert abs(r.objective - manual_objective(prob, r.counts)) < \
        1e-6 * max(1.0, abs(r.objective))


@pytest.mark.parametrize("seed", range(15))
def test_greedy_vs_milp_objective_parity(seed):
    prob = random_instance(seed)
    rg = solve_greedy(prob)
    rm = solve_fast_milp(prob, time_limit=60)
    assert rm.objective is not None
    scale = max(1.0, abs(rm.objective))
    # greedy can never beat the exact optimum...
    assert rg.objective <= rm.objective + 1e-6 * scale
    # ...and stays within 2% of it on these instances
    assert rg.objective >= rm.objective - 0.02 * scale


def test_greedy_prefers_keep_current_over_churn():
    # one trainer already at its optimum: greedy must not rescale it
    curve = tab2_curve("ResNet18")
    pts, vals = curve.breakpoints(1, 8)
    t = TrainerSpec(id=0, n_min=1, n_max=8, r_up=1e9, r_dw=1e9,
                    points=tuple(pts), values=tuple(vals))
    prob = AllocationProblem(nodes=list(range(8)), trainers=[t],
                             current={0: [0, 1, 2, 3]}, t_fwd=60.0)
    r = solve_greedy(prob)
    assert r.counts[0] == 4          # any rescale costs 1e9x more than it buys
    assert r.allocation[0] == [0, 1, 2, 3]


@pytest.mark.parametrize("seed", range(20))
def test_vectorized_matches_scalar_greedy(seed):
    """The numpy matrix path and the scalar reference path climb the
    same search space; their objectives must agree to float tolerance
    (counts may differ only between exactly-tied optima)."""
    prob = random_instance(seed)
    rv = solve_greedy(prob, vectorize=True)
    rs = solve_greedy(prob, vectorize=False)
    scale = max(1.0, abs(rs.objective))
    assert rv.objective >= rs.objective - 1e-9 * scale
    check_allocation_invariants(prob, rv)


@pytest.mark.parametrize("seed", range(10))
def test_warm_start_greedy_is_feasible(seed):
    """Warm-starting from the (projected) current map — the engine's
    repair move set — keeps every feasibility invariant, including after
    snapping stranded/over-cap counts onto the lattice."""
    prob = random_instance(seed)
    start = {t.id: len(v) for t, v in
             zip(prob.trainers, project_current(prob).values())}
    r = solve_greedy(prob, start_counts=start)
    check_allocation_invariants(prob, r)
    # and never beats the exact optimum
    rm = solve_fast_milp(prob, time_limit=60)
    assert r.objective <= rm.objective + 1e-6 * max(1.0, abs(rm.objective))


@pytest.mark.parametrize("vectorize", [True, False])
def test_warm_start_oversubscribed_pool_is_made_feasible(vectorize):
    """Regression: a stale start vector summing beyond the pool (caller
    skipped projection after a shrink) must be clamped to capacity, not
    returned as an infeasible allocation."""
    t = lambda i: TrainerSpec(id=i, n_min=2, n_max=12, r_up=5, r_dw=1,
                              points=(0, 2, 12), values=(0.0, 100.0, 500.0))
    prob = AllocationProblem(nodes=[0, 1, 2, 3], trainers=[t(0), t(1)],
                             current={0: [0, 1], 1: [2, 3]}, t_fwd=60.0)
    r = solve_greedy(prob, start_counts={0: 12, 1: 12}, vectorize=vectorize)
    assert sum(r.counts.values()) <= len(prob.nodes)
    check_allocation_invariants(prob, r)


def _scale_instance(n_nodes, n_jobs, seed=0):
    rng = np.random.RandomState(seed)
    trainers, current, used = [], {}, 0
    for j in range(n_jobs):
        curve = amdahl_curve(f"m{j}", 1000.0 * rng.uniform(0.5, 2.0),
                             rng.uniform(0.1, 0.4), max_nodes=128)
        n_min = int(rng.randint(1, 4))
        n_max = int(rng.randint(16, 128))
        pts, vals = curve.breakpoints(n_min, n_max)
        trainers.append(TrainerSpec(
            id=j, n_min=n_min, n_max=n_max,
            r_up=float(rng.uniform(5, 40)), r_dw=float(rng.uniform(1, 10)),
            points=tuple(pts), values=tuple(vals)))
        k = int(rng.randint(0, 40))
        current[j] = list(range(used, min(used + k, n_nodes)))
        used = min(used + k, n_nodes)
    return AllocationProblem(nodes=list(range(n_nodes)), trainers=trainers,
                             current=current, t_fwd=120.0)


def test_pair_repair_guard_is_explicit_and_large_instances_terminate():
    """The pairwise shrink-to-grow pass is gated by an explicit module
    constant, and instances far above it (here 40 Trainers × 512 nodes)
    must still finish within the polish budget — i.e. the guard actually
    skips the O(J²·breakpoints²) pass instead of grinding through it."""
    assert PAIR_REPAIR_MAX_TRAINERS == 12
    prob = _scale_instance(512, 40, seed=3)
    assert len(prob.trainers) > PAIR_REPAIR_MAX_TRAINERS
    t0 = time.perf_counter()
    r = solve_greedy(prob)
    wall = time.perf_counter() - t0
    check_allocation_invariants(prob, r)
    assert wall < 2.0, f"greedy at 512x40 took {wall:.2f}s"
    # above the guard the default result is identical to explicitly
    # disabling the pass — i.e. it really did not run
    r2 = solve_greedy(prob, pair_repair_limit=0)
    assert r2.objective == pytest.approx(r.objective, rel=1e-9)


# ---------------------------------------------------------------------------
# reconstruct_map invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(10))
def test_reconstruct_map_randomized_invariants(seed):
    rng = np.random.RandomState(seed)
    n = rng.randint(5, 30)
    nodes = sorted(rng.choice(1000, size=n, replace=False).tolist())
    n_tr = rng.randint(1, 5)
    trainers = [TrainerSpec(id=j, n_min=1, n_max=n, r_up=1, r_dw=1,
                            points=(0, 1, n), values=(0.0, 1.0, float(n)))
                for j in range(n_tr)]
    avail = list(nodes)
    rng.shuffle(avail)
    current, counts = {}, {}
    for t in trainers:
        k = rng.randint(0, max(1, len(avail) // 2))
        current[t.id], avail = avail[:k], avail[k:]
    total = n
    for t in trainers:
        counts[t.id] = int(rng.randint(0, total + 1))
        total -= counts[t.id]
    alloc = reconstruct_map(nodes, trainers, current, counts)
    seen = set()
    for t in trainers:
        got = alloc[t.id]
        assert len(got) == counts[t.id]             # counts honored
        assert not (set(got) & seen)                # no node reuse
        seen |= set(got)
        assert set(got) <= set(nodes)
        kept = set(got) & set(current[t.id])        # keep-own-nodes-first
        assert len(kept) == min(counts[t.id], len(current[t.id]))


# ---------------------------------------------------------------------------
# AllocationEngine
# ---------------------------------------------------------------------------


def test_engine_result_is_feasible_and_near_optimal():
    for seed in range(8):
        prob = random_instance(seed)
        eng = AllocationEngine()
        r = eng.allocate(prob)
        check_allocation_invariants(prob, r)
        rm = solve_fast_milp(prob, time_limit=60)
        scale = max(1.0, abs(rm.objective))
        assert r.objective >= rm.objective - 0.02 * scale


def test_engine_cache_hit_same_problem():
    prob = random_instance(3)
    eng = AllocationEngine()
    r1 = eng.allocate(prob)
    r2 = eng.allocate(prob)
    assert eng.stats.events == 2
    assert eng.stats.cache_hits == 1
    assert r2.solver_status.startswith("cache")
    assert r2.counts == r1.counts
    check_allocation_invariants(prob, r2)


def test_engine_cache_hit_is_node_id_agnostic():
    prob = random_instance(5)
    eng = AllocationEngine()
    r1 = eng.allocate(prob)
    # same structure, node ids shifted by 1000
    shift = 1000
    prob2 = AllocationProblem(
        nodes=[n + shift for n in prob.nodes],
        trainers=prob.trainers,
        current={j: [n + shift for n in ns] for j, ns in prob.current.items()},
        t_fwd=prob.t_fwd)
    r2 = eng.allocate(prob2)
    assert eng.stats.cache_hits == 1
    assert r2.counts == r1.counts
    check_allocation_invariants(prob2, r2)


def test_engine_cache_capacity_is_bounded():
    eng = AllocationEngine(cache_size=4)
    for seed in range(10):
        eng.allocate(random_instance(seed))
    assert len(eng._cache) <= 4


def test_engine_signature_distinguishes_current_counts():
    prob = random_instance(2)
    k1, _ = problem_signature(prob)
    moved = dict(prob.current)
    t0 = prob.trainers[0]
    if moved.get(t0.id):
        moved[t0.id] = moved[t0.id][:-1]   # one fewer current node
        prob2 = AllocationProblem(nodes=prob.nodes, trainers=prob.trainers,
                                  current=moved, t_fwd=prob.t_fwd)
        k2, _ = problem_signature(prob2)
        assert k1 != k2


def test_engine_fallback_keeps_current_map():
    # no solver is allowed to run -> §3.6 keep-current fallback
    prob = random_instance(4)
    eng = AllocationEngine(use_greedy=False, time_budget=0.0)
    r = eng.allocate(prob)
    assert r.fell_back
    assert eng.stats.fallbacks == 1
    node_set = set(prob.nodes)
    for t in prob.trainers:
        assert set(r.allocation[t.id]) == \
            set(prob.current.get(t.id, [])) & node_set
    # fallbacks must not be cached
    assert len(eng._cache) == 0


# ---------------------------------------------------------------------------
# Incremental warm-start repair (DESIGN.md §11)
# ---------------------------------------------------------------------------


class _TwinAllocator:
    """Solves every problem with an incremental and a fresh engine,
    driving the replay with the incremental decision and recording the
    per-event objective gap."""

    name = "twin"

    def __init__(self):
        # generous budget: the MILP always solves to optimality, so the
        # comparison is deterministic (a tight wall-clock limit would
        # make HiGHS results load-dependent)
        self.inc = AllocationEngine(incremental=True, time_budget=2.0)
        self.fresh = AllocationEngine(incremental=False, time_budget=2.0)
        self.gaps = []

    def allocate(self, prob):
        ri = self.inc.allocate(prob)
        rf = self.fresh.allocate(prob)
        assert ri.fell_back == rf.fell_back          # identical feasibility
        if ri.objective is not None and rf.objective is not None:
            self.gaps.append((ri.objective - rf.objective)
                             / max(1.0, abs(rf.objective)))
        return ri


_SWEEP_POLICIES = ["throughput", "weighted", "maxmin", "deadline", "costcap"]


def _policy_jobs(policy, n=6):
    names = list(TAB2)
    out = []
    for i in range(n):
        j = TrainerJob(id=i, curve=tab2_curve(names[i % len(names)]),
                       work=2e8, n_min=1, n_max=16, r_up=20.0, r_dw=5.0)
        if policy == "weighted":
            j.weight = 1.0 + (i % 3)
        if policy == "deadline":
            j.deadline = 3600.0 * (4 + i)
        if policy == "costcap":
            j.budget = 3.0e5
        out.append(j)
    return out


@pytest.mark.parametrize("scenario", ["capability", "capacity", "bursty",
                                      "maintenance", "weekend",
                                      "overestimate"])
def test_incremental_matches_fresh_across_policy_sweep(scenario):
    """Acceptance sweep (ISSUE 5): on every scenario × policy replay the
    incremental engine's objective equals a fresh portfolio solve within
    1e-6 relative, event by event."""
    from repro.sched.scenarios import build_scenario

    sc = build_scenario(scenario, scale=0.25)
    events = fragments_to_events(sc.fragments)
    for policy in _SWEEP_POLICIES:
        twin = _TwinAllocator()
        Simulator(events, _policy_jobs(policy), twin, t_fwd=120.0,
                  pj_max=10, horizon=sc.duration, objective=policy).run()
        assert twin.gaps, f"{scenario}/{policy}: no solved events"
        worst = max(abs(g) for g in twin.gaps)
        assert worst <= 1e-6, f"{scenario}/{policy}: parity gap {worst:.2e}"


def test_incremental_repair_fast_path_engages():
    """On a realistic replay the exact-bound tier must actually fire —
    the incremental layer is pointless if every event escalates."""
    from repro.core.trace import generate_summit_like

    events = fragments_to_events(
        generate_summit_like(n_nodes=64, duration=12 * 3600.0, seed=9))
    eng = AllocationEngine()
    Simulator(events, _policy_jobs("throughput"), eng, t_fwd=120.0,
              pj_max=10, horizon=12 * 3600.0).run()
    assert eng.stats.repairs > 0
    # repairs + escalations never exceed non-cached events
    assert (eng.stats.repairs + eng.stats.repair_escalations
            <= eng.stats.events - eng.stats.cache_hits)


def test_incremental_repair_is_deterministic_with_zero_budget():
    """time_budget=0 + incremental is still fully deterministic: same
    problem sequence, same decisions (the repair tiers use only the
    bound, never wall-clock)."""
    probs = [random_instance(s) for s in range(6)]
    runs = []
    for _ in range(2):
        eng = AllocationEngine(time_budget=0.0)
        runs.append([eng.allocate(p).counts for p in probs])
    assert runs[0] == runs[1]


# ---------------------------------------------------------------------------
# Simulator event coalescing
# ---------------------------------------------------------------------------


def _burst_events(n_bursts=6, burst_size=5, gap_in_burst=2.0,
                  gap_between=900.0):
    events, t, nid = [], 0.0, 0
    for _ in range(n_bursts):
        for _ in range(burst_size):
            events.append(PoolEvent(time=t, joined=(nid,)))
            nid += 1
            t += gap_in_burst
        t += gap_between
    return events


def _jobs():
    return [TrainerJob(id=i, curve=tab2_curve("ShuffleNet"), work=1e12,
                       n_min=1, n_max=16, r_up=20.0, r_dw=5.0)
            for i in range(3)]


def _det_engine():
    # time_budget=0 disables MILP escalation: greedy + cache only, which is
    # fully deterministic (no solver time limits in play)
    return AllocationEngine(time_budget=0.0)


def test_coalescing_reduces_allocations():
    events = _burst_events()
    horizon = 6 * 900.0
    base = Simulator(events, _jobs(), _det_engine(), t_fwd=120.0,
                     horizon=horizon).run()
    co = Simulator(events, _jobs(), _det_engine(), t_fwd=120.0,
                   horizon=horizon, coalesce_window=30.0).run()
    assert co.events_processed < base.events_processed
    assert co.total_samples > 0
    # a 10s-scale deferral on 900s intervals costs ~1% of throughput
    assert co.total_samples >= 0.95 * base.total_samples


def test_coalescing_never_defers_below_n_min():
    # preemption drops the only trainer below n_min while another event is
    # imminent: the re-allocation must fire immediately, not defer
    events = [PoolEvent(time=0.0, joined=(0, 1)),
              PoolEvent(time=50.0, left=(1,)),
              PoolEvent(time=55.0, joined=(2,))]
    def jobs():
        return [TrainerJob(id=0, curve=tab2_curve("ShuffleNet"), work=1e12,
                           n_min=2, n_max=4, r_up=1.0, r_dw=1.0)]
    base = Simulator(events, jobs(), _det_engine(), t_fwd=120.0,
                     horizon=200.0).run()
    co = Simulator(events, jobs(), _det_engine(), t_fwd=120.0,
                   horizon=200.0, coalesce_window=30.0).run()
    # every deferral opportunity is blocked by the feasibility guard, so
    # coalescing must behave exactly like the per-event baseline here
    assert co.events_processed == base.events_processed
    assert co.total_samples == pytest.approx(base.total_samples)


def test_coalescing_disabled_by_default_matches_old_behavior():
    events = _burst_events(n_bursts=2)
    horizon = 2 * 900.0
    r1 = Simulator(events, _jobs(), _det_engine(), t_fwd=120.0,
                   horizon=horizon).run()
    r2 = Simulator(events, _jobs(), _det_engine(), t_fwd=120.0,
                   horizon=horizon, coalesce_window=0.0).run()
    assert r1.events_processed == r2.events_processed
    assert r1.total_samples == pytest.approx(r2.total_samples)


# ---------------------------------------------------------------------------
# Warm-state snapshot / restore (DESIGN.md §12)
# ---------------------------------------------------------------------------


def test_engine_snapshot_restore_round_trip():
    """Snapshot -> JSON -> fresh engine: every previously solved problem
    is a cache hit with the *identical* (bit-for-bit) counts and
    objective, and unseen problems solve exactly as a never-crashed
    engine would (deterministic engines, zero time budget)."""
    from repro.core.engine import dumps_snapshot, loads_snapshot

    eng = AllocationEngine(time_budget=0.0)
    probs = [random_instance(seed) for seed in range(8)]
    before = [eng.allocate(p) for p in probs]

    restored = AllocationEngine.from_snapshot(
        loads_snapshot(dumps_snapshot(eng.snapshot())))
    assert restored.stats.restores == 1
    assert restored.stats.restored_entries == len(eng._cache)

    hits0 = restored.stats.cache_hits
    after = [restored.allocate(p) for p in probs]
    assert restored.stats.cache_hits - hits0 == len(probs)   # all hits
    for b, a in zip(before, after):
        assert a.counts == b.counts                          # exact
        assert a.objective == b.objective                    # bit-identical
        assert a.allocation == b.allocation

    # unseen problem: restored engine == pristine engine, 0.0 gap
    novel = random_instance(99)
    r_restored = restored.allocate(novel)
    r_fresh = AllocationEngine(time_budget=0.0).allocate(novel)
    assert r_restored.counts == r_fresh.counts
    if r_restored.objective is not None and r_fresh.objective is not None:
        assert abs(r_restored.objective - r_fresh.objective) <= 1e-12


def test_engine_snapshot_config_round_trips():
    eng = AllocationEngine(time_budget=0.123, use_greedy=False,
                           use_node_milp=True, cache_size=7,
                           incremental=False, repair_gap=1e-2,
                           repair_exact_gap=1e-8)
    twin = AllocationEngine.from_snapshot(eng.snapshot())
    for attr in ("time_budget", "use_greedy", "use_node_milp", "cache_size",
                 "incremental", "repair_gap", "repair_exact_gap"):
        assert getattr(twin, attr) == getattr(eng, attr)


def test_engine_snapshot_rejects_unknown_schema():
    eng = AllocationEngine()
    snap = eng.snapshot()
    snap["schema"] = "bftrainer-engine-snapshot/999"
    with pytest.raises(ValueError, match="snapshot schema"):
        eng.restore(snap)
    with pytest.raises(ValueError, match="snapshot schema"):
        AllocationEngine.from_snapshot(snap)


def test_engine_restore_respects_cache_capacity():
    """Restoring a big snapshot into a smaller-cache engine keeps only
    the most recent entries (LRU order survives the round trip)."""
    big = AllocationEngine(time_budget=0.0, cache_size=64)
    probs = [random_instance(seed) for seed in range(10)]
    for p in probs:
        big.allocate(p)
    small = AllocationEngine(time_budget=0.0, cache_size=4)
    recovered = small.restore(big.snapshot())
    assert recovered == 4 == len(small._cache)
    # the survivors are the most recently used ones
    hits0 = small.stats.cache_hits
    small.allocate(probs[-1])
    assert small.stats.cache_hits == hits0 + 1
