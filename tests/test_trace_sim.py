"""Trace generator calibration (paper §2.1 statistics) and simulator
behaviour (§4-5 semantics)."""
import numpy as np
import pytest

from repro.core import (
    EqualShareAllocator,
    MILPAllocator,
    Simulator,
    TrainerJob,
    eq_nodes,
    fragments_to_events,
    generate_summit_like,
    static_outcome,
    tab2_curve,
    trace_stats,
)


def test_trace_calibration_matches_paper():
    dur = 3 * 86400.0
    frags = generate_summit_like(n_nodes=256, duration=dur, seed=7)
    st = trace_stats(frags, 256, dur)
    # paper: 58% of fragments < 10 min; ~10% of node x time from them;
    # ~9% idle overall.  Generator is stochastic — assert loose windows.
    assert 0.45 < st.pct_fragments_short < 0.70
    assert st.share_nodetime_short < 0.20
    assert 0.05 < st.idle_fraction < 0.15
    assert st.joins_per_hour > st.leaves_per_hour * 0.5


def test_trace_deterministic():
    a = generate_summit_like(64, 86400.0, seed=3)
    b = generate_summit_like(64, 86400.0, seed=3)
    assert a == b
    c = generate_summit_like(64, 86400.0, seed=4)
    assert a != c


def _jobs(n=6, work=1e9, n_max=16):
    curve = tab2_curve("ShuffleNet")
    return [TrainerJob(id=i, curve=curve, work=work, n_min=1, n_max=n_max,
                       r_up=20.0, r_dw=5.0) for i in range(n)]


@pytest.fixture(scope="module")
def small_trace():
    frags = generate_summit_like(n_nodes=48, duration=24 * 3600.0, seed=11)
    return fragments_to_events(frags)


def test_simulator_conservation(small_trace):
    horizon = 24 * 3600.0
    rep = Simulator(small_trace, _jobs(), MILPAllocator("fast"),
                    t_fwd=120.0, horizon=horizon).run()
    # outcome cannot exceed (idle node-hours) x (best per-node throughput)
    total_nh = eq_nodes(small_trace, 0, horizon) * horizon / 3600.0
    curve = tab2_curve("ShuffleNet")
    best_per_node = max(curve(n) / n for n in [1, 2, 4, 8, 16])
    assert 0 < rep.total_samples <= total_nh * 3600.0 * best_per_node * 1.01


def test_milp_beats_heuristic_on_rescale_cost(small_trace):
    horizon = 24 * 3600.0
    r_milp = Simulator(small_trace, _jobs(), MILPAllocator("fast"),
                       t_fwd=120.0, horizon=horizon).run()
    r_heur = Simulator(small_trace, _jobs(), EqualShareAllocator(),
                       t_fwd=120.0, horizon=horizon).run()
    # paper Fig 11b: MILP rescale cost is far below the heuristic's
    assert r_milp.rescale_cost_samples < r_heur.rescale_cost_samples
    # paper Fig 10: MILP uses resources at least as efficiently (loose)
    assert r_milp.total_samples > 0.85 * r_heur.total_samples


def test_pjmax_limits_parallelism(small_trace):
    horizon = 12 * 3600.0
    jobs = _jobs(n=10, work=1e12)
    sim = Simulator(small_trace, jobs, MILPAllocator("fast"), t_fwd=120.0,
                    pj_max=3, horizon=horizon)
    rep = sim.run()
    started = sum(1 for j in jobs if j.started_at is not None)
    running = sum(1 for j in jobs if j.nodes)
    assert running <= 3
    assert rep.total_samples > 0


def test_jobs_complete_and_fcfs(small_trace):
    jobs = _jobs(n=4, work=2e6, n_max=8)
    rep = Simulator(small_trace, jobs, MILPAllocator("fast"), t_fwd=60.0,
                    horizon=24 * 3600.0).run()
    assert rep.unfinished == 0
    assert all(abs(j.done - j.work) < 1.0 for j in jobs)


def test_static_outcome_has_no_rescale_cost():
    jobs = _jobs(n=2, work=1e12)
    a_s = static_outcome(jobs, 8, 3600.0, MILPAllocator("fast"))
    curve = tab2_curve("ShuffleNet")
    assert a_s > 0
    # upper bound: best split of 8 nodes for an hour
    assert a_s <= curve(8) * 3600.0 * 1.01


# ---------------------------------------------------------------------------
# load_trace_csv hardening (validation + gzip)
# ---------------------------------------------------------------------------


def _write_csv(tmp_path, body, name="trace.csv"):
    p = tmp_path / name
    p.write_text("node,start,end\n" + body)
    return str(p)


def test_load_trace_csv_roundtrip_and_gzip(tmp_path):
    import gzip

    from repro.core import load_trace_csv

    body = "0,0.0,10.0\n1,5.0,20.0\n0,12.0,30.0\n"
    plain = _write_csv(tmp_path, body)
    frags = load_trace_csv(plain)
    assert [(f.node, f.start, f.end) for f in frags] == \
        [(0, 0.0, 10.0), (1, 5.0, 20.0), (0, 12.0, 30.0)]

    gz = str(tmp_path / "trace.csv.gz")
    with gzip.open(gz, "wt") as f:
        f.write("node,start,end\n" + body)
    assert load_trace_csv(gz) == frags


def test_load_trace_csv_rejects_malformed_rows(tmp_path):
    from repro.core import load_trace_csv

    with pytest.raises(ValueError, match="end .* must be > start"):
        load_trace_csv(_write_csv(tmp_path, "0,10.0,10.0\n"))
    with pytest.raises(ValueError, match="negative node id"):
        load_trace_csv(_write_csv(tmp_path, "-2,0.0,10.0\n"))
    with pytest.raises(ValueError, match="trace.csv:3"):   # line number
        load_trace_csv(_write_csv(tmp_path, "0,0.0,10.0\n1,abc,10.0\n"))
    with pytest.raises(ValueError, match="missing column"):
        p = tmp_path / "bad.csv"
        p.write_text("node,begin,end\n0,0.0,10.0\n")
        load_trace_csv(str(p))
    with pytest.raises(ValueError, match="overlap"):
        load_trace_csv(_write_csv(tmp_path, "0,0.0,10.0\n0,5.0,15.0\n"))
    # overlap check can be disabled for raw logs
    from repro.core.trace import load_trace_csv as raw_loader
    assert len(raw_loader(_write_csv(tmp_path, "0,0.0,10.0\n0,5.0,15.0\n"),
                          validate=False)) == 2


def test_validate_and_merge_fragments():
    from repro.core import Fragment, merge_fragments, validate_fragments

    frags = [Fragment(0, 0.0, 10.0), Fragment(0, 10.0, 15.0),
             Fragment(1, 3.0, 4.0), Fragment(0, 20.0, 25.0)]
    validate_fragments(frags)
    merged = merge_fragments(frags)
    assert (0, 0.0, 15.0) in [(f.node, f.start, f.end) for f in merged]
    assert len(merged) == 3
    with pytest.raises(ValueError, match="overlap"):
        validate_fragments([Fragment(2, 0.0, 10.0), Fragment(2, 9.0, 12.0)])
    with pytest.raises(ValueError, match="end <= start"):
        validate_fragments([Fragment(0, 5.0, 5.0)])
    with pytest.raises(ValueError, match="negative node"):
        validate_fragments([Fragment(-1, 0.0, 1.0)])
