"""Per-architecture smoke tests (assignment requirement): reduced variant
of each family runs one forward/train step on CPU, asserts output shapes
and no NaNs; decode paths; prefill/decode consistency; full-config
parameter counts sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import build_model

ALL_ARCHS = sorted(ARCHS)


def make_batch(cfg, b=2, s=64, seed=0):
    rng = np.random.RandomState(seed)
    if cfg.frontend == "vision":
        nt = cfg.n_frontend_tokens
        return {
            "tokens": jnp.asarray(
                rng.randint(0, cfg.vocab_size, (b, s - nt)), jnp.int32),
            "labels": jnp.asarray(
                rng.randint(0, cfg.vocab_size, (b, s - nt)), jnp.int32),
            "frontend_embeds": jnp.asarray(
                rng.randn(b, nt, cfg.d_model) * 0.02, jnp.float32),
        }
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
    }
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.randn(b, s // 4, cfg.encoder.d_model) * 0.02, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_arch(arch).reduced()
    assert cfg.n_layers <= 16 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)

    logits, aux = jax.jit(model.forward)(params, batch)
    b = batch["tokens"].shape[0]
    s_total = (batch["tokens"].shape[1] + cfg.n_frontend_tokens
               if cfg.frontend == "vision" else batch["tokens"].shape[1])
    assert logits.shape == (b, s_total, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))

    # one real train step: loss decreases-or-stays-sane and params update
    from repro.optim import AdamW
    opt = AdamW(lr=1e-3)
    state = opt.init(params)

    @jax.jit
    def step(p, st):
        loss, g = jax.value_and_grad(lambda pp: model.loss(pp, batch))(p)
        p2, st2 = opt.update(g, st, p)
        return p2, st2, loss

    p2, st2, loss = step(params, state)
    assert np.isfinite(float(loss))
    changed = jax.tree.map(
        lambda a, b_: bool(jnp.any(a != b_)), params, p2)
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    b, cache_len = 2, 96
    nf = 16 if cfg.is_encdec else 0
    cache = model.init_cache(b, cache_len, n_frames=nf, dtype=jnp.float32)
    toks = jnp.zeros((b, 1), jnp.int32)
    logits, new_cache = jax.jit(model.decode_step)(
        params, cache, toks, jnp.int32(3))
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


CONSISTENCY_ARCHS = ["yi-6b", "gemma2-27b", "mamba2-2.7b", "jamba-v0.1-52b",
                     "deepseek-v2-lite-16b", "seamless-m4t-medium",
                     "granite-moe-3b-a800m", "internvl2-76b", "gemma-2b",
                     "minitron-8b"]


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    b, s, cl = 2, 48, 64
    rng = np.random.RandomState(0)
    if cfg.frontend == "vision":
        nt = cfg.n_frontend_tokens
        toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s + 1 - nt)),
                           jnp.int32)
        fe = jnp.asarray(rng.randn(b, nt, cfg.d_model) * 0.02, jnp.float32)
        full = {"tokens": toks, "frontend_embeds": fe}
        pre = {"tokens": toks[:, :-1], "frontend_embeds": fe}
        last_tok = toks[:, -1:]
        pos = jnp.int32(s)            # absolute position incl. frontend
    else:
        toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s + 1)),
                           jnp.int32)
        full = {"tokens": toks}
        pre = {"tokens": toks[:, :s]}
        last_tok = toks[:, s:s + 1]
        pos = jnp.int32(s)
        if cfg.is_encdec:
            frames = jnp.asarray(rng.randn(b, 12, cfg.encoder.d_model) * 0.02,
                                 jnp.float32)
            full["frames"] = frames
            pre["frames"] = frames
    want, _ = jax.jit(model.forward)(params, full)
    want = want[:, -1]
    _, cache = jax.jit(lambda p, x: model.prefill(p, x, cache_len=cl))(
        params, pre)
    cache = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        cache)
    got, _ = jax.jit(model.decode_step)(params, cache, last_tok, pos)
    got = got[:, 0]
    scale = float(jnp.max(jnp.abs(want))) + 1e-6
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 0.06 * max(scale, 1.0), (arch, err, scale)


# --- full-config parameter counts vs published sizes -----------------------

EXPECTED_PARAMS = {
    "yi-6b": (5e9, 7.5e9),
    "jamba-v0.1-52b": (45e9, 60e9),
    "deepseek-v2-lite-16b": (13e9, 19e9),
    "minitron-8b": (7e9, 10e9),
    "gemma2-27b": (24e9, 30e9),
    "internvl2-76b": (65e9, 76e9),     # language backbone of the 76B VLM
    "granite-moe-3b-a800m": (2.3e9, 4e9),
    "mamba2-2.7b": (2.2e9, 3.2e9),
    "gemma-2b": (2e9, 3e9),
    "seamless-m4t-medium": (0.5e9, 1.6e9),
}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_param_count(arch):
    model = build_model(get_arch(arch))
    n = model.n_params()
    lo, hi = EXPECTED_PARAMS[arch]
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9}, {hi/1e9}]B"
    assert model.n_active_params() <= n


def test_moe_active_params_below_total():
    model = build_model(get_arch("deepseek-v2-lite-16b"))
    # DeepSeek-V2-Lite: ~16B total, ~2.4B active
    assert model.n_active_params() < 0.35 * model.n_params()
