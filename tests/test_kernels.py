"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles in ``repro.kernels.ref`` (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import flash_attention, rms_norm, ssd_scan
from repro.kernels.ref import flash_attention_ref, rms_norm_ref, ssd_scan_ref
from repro.models.mamba2 import ssd_chunked


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (1, 2, 1, 128, 64),    # MQA
    (2, 4, 2, 160, 32),    # GQA, ragged seq
    (1, 8, 8, 96, 128),    # MHA
])
@pytest.mark.parametrize("causal,window,cap", [
    (True, 0, 0.0), (True, 64, 0.0), (True, 0, 50.0), (False, 0, 0.0),
])
def test_flash_attention_matches_ref(dtype, shape, causal, window, cap):
    b, h, kv, s, d = shape
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(b, h, s, d) * 0.3, dtype)
    k = jnp.asarray(rng.randn(b, kv, s, d) * 0.3, dtype)
    v = jnp.asarray(rng.randn(b, kv, s, d) * 0.3, dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          logit_cap=cap, block_q=64, block_k=64,
                          interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal, window=window,
                               logit_cap=cap)
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < _tol(dtype), err


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 64, 2, 16, 8), (2, 128, 3, 32, 16)])
@pytest.mark.parametrize("chunk", [32, 64])
def test_ssd_scan_matches_ref(dtype, shape, chunk):
    b, s, h, p, n = shape
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(b, s, h, p) * 0.5, dtype)
    dt = jnp.asarray(rng.rand(b, s, h) * 0.5 + 0.01, jnp.float32)
    a = jnp.asarray(-np.exp(rng.randn(h) * 0.3), jnp.float32)
    bm = jnp.asarray(rng.randn(b, s, h, n) * 0.4, dtype)
    cm = jnp.asarray(rng.randn(b, s, h, n) * 0.4, dtype)
    got = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    want, _ = ssd_scan_ref(x, dt, a, bm, cm)
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < (0.08 if dtype == jnp.bfloat16 else 1e-4), err


def test_ssd_chunked_model_path_matches_naive():
    """The model's XLA chunked path is itself validated against the naive
    recurrence, and is chunk-size invariant."""
    rng = np.random.RandomState(2)
    b, s, h, p, n = 2, 96, 2, 8, 4
    x = jnp.asarray(rng.randn(b, s, h, p) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.rand(b, s, h) * 0.5 + 0.01, jnp.float32)
    a = jnp.asarray(-np.exp(rng.randn(h) * 0.3), jnp.float32)
    bm = jnp.asarray(rng.randn(b, s, h, n) * 0.4, jnp.float32)
    cm = jnp.asarray(rng.randn(b, s, h, n) * 0.4, jnp.float32)
    want, want_state = ssd_scan_ref(x, dt, a, bm, cm)
    for chunk in (16, 32, 96):
        got, state = ssd_chunked(x, dt, a, bm, cm, chunk=chunk)
        assert float(jnp.max(jnp.abs(got - want))) < 1e-4
        assert float(jnp.max(jnp.abs(state - want_state))) < 1e-4


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(3, 50, 96), (1, 7, 256), (2, 256, 128)])
def test_rms_norm_matches_ref(dtype, shape):
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(*shape), dtype)
    s = jnp.asarray(rng.randn(shape[-1]) * 0.1, jnp.float32)
    got = rms_norm(x, s, block_rows=32, interpret=True)
    want = rms_norm_ref(x, s)
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < _tol(dtype), err


def test_flash_attention_long_and_ragged():
    """Non-multiple sequence lengths exercise the padding/mask path."""
    rng = np.random.RandomState(4)
    b, h, kv, sq, sk, d = 1, 2, 1, 130, 190, 32
    q = jnp.asarray(rng.randn(b, h, sq, d) * 0.3, jnp.float32)
    k = jnp.asarray(rng.randn(b, kv, sk, d) * 0.3, jnp.float32)
    v = jnp.asarray(rng.randn(b, kv, sk, d) * 0.3, jnp.float32)
    got = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    want = flash_attention_ref(q, k, v, causal=False)
    assert float(jnp.max(jnp.abs(got - want))) < 2e-5


def test_pallas_backend_model_equivalence():
    """The Pallas flash-attention kernel wired as the model's attention
    backend (USE_PALLAS_KERNEL) matches the default XLA path end-to-end,
    including SWA + softcap layers (gemma2)."""
    import repro.models.attention as A
    from repro.configs import get_arch
    from repro.models import build_model

    for arch in ("yi-6b", "gemma2-27b"):
        cfg = get_arch(arch).reduced()
        m = build_model(cfg, remat=False)
        params = m.init(jax.random.key(0))
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(
            rng.randint(0, cfg.vocab_size, (2, 64)), jnp.int32)}
        want, _ = m.forward(params, batch)
        try:
            A.USE_PALLAS_KERNEL = True
            got, _ = m.forward(params, batch)
        finally:
            A.USE_PALLAS_KERNEL = False
        assert float(jnp.max(jnp.abs(got - want))) < 5e-3, arch


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(100, 500, 64), (64, 1000, 32),
                                   (33, 257, 16)])
def test_ce_loss_kernel_matches_ref(dtype, shape):
    from repro.kernels.ops import ce_loss
    from repro.kernels.ref import ce_loss_ref
    t, v, d = shape
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(t, d) * 0.5, dtype)
    w = jnp.asarray(rng.randn(v, d) * 0.3, dtype)
    lbl = jnp.asarray(rng.randint(0, v, (t,)), jnp.int32)
    got = ce_loss(x, w, lbl, block_rows=32, block_v=128, interpret=True)
    want = ce_loss_ref(x, w, lbl)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    assert float(jnp.max(jnp.abs(got - want))) < tol
