"""Chaos-hardened control plane (DESIGN.md §12): zero-fault parity,
fault-injection node-time accounting, warm-state allocator recovery,
corrupt-checkpoint fallbacks, and straggler cost semantics."""
import dataclasses
import math

import numpy as np
import pytest

from repro.chaos import (
    ChaosBackend,
    ChaosSpec,
    FaultEvent,
    FaultSchedule,
    RestartingAllocator,
    generate_fault_schedule,
    inject_faults,
    run_chaos,
)
from repro.chaos.harness import pool_node_seconds
from repro.core import (
    AllocationEngine,
    AnalyticBackend,
    ControlLoop,
    TrainerJob,
    amdahl_curve,
    fragments_to_events,
    tab2_curve,
)
from repro.core.events import PoolEvent, merge_events
from repro.core.scaling import TAB2
from repro.sched.scenarios import CHAOS_SCENARIOS, SCENARIOS, build_scenario

_SWEEP_POLICIES = ["throughput", "weighted", "maxmin", "deadline", "costcap"]


def _policy_jobs(policy, n=4):
    names = list(TAB2)
    out = []
    for i in range(n):
        j = TrainerJob(id=i, curve=tab2_curve(names[i % len(names)]),
                       work=2e8, n_min=1, n_max=16, r_up=20.0, r_dw=5.0)
        if policy == "weighted":
            j.weight = 1.0 + (i % 3)
        if policy == "deadline":
            j.deadline = 3600.0 * (4 + i)
        if policy == "costcap":
            j.budget = 3.0e5
        out.append(j)
    return out


def normalized(stats):
    """LoopStats with every wall-clock field zeroed and the allocator
    label dropped — the bit-identical comparison surface (solver wall
    time is physical time, everything else must replay exactly)."""
    recs = [dataclasses.replace(r, solver_wall=0.0)
            for r in stats.event_records]
    return dataclasses.replace(stats, solver_wall_total=0.0,
                               allocator="", event_records=recs)


def _det_engine():
    return AllocationEngine(time_budget=0.0)


# ---------------------------------------------------------------------------
# Zero-fault parity: the chaos wrappers are exact no-ops without faults
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_zero_fault_chaos_replay_is_bit_identical(scenario):
    """Acceptance (ISSUE 6): wrapping the backend in ChaosBackend and the
    allocator in RestartingAllocator with a zero-fault spec replays
    bit-identically to the plain ControlLoop — on every existing
    scenario under all five policies."""
    sc = build_scenario(scenario, scale=0.12)
    events = fragments_to_events(sc.fragments)
    empty = generate_fault_schedule(events, ChaosSpec())
    assert empty.events == ()
    assert inject_faults(events, empty) == list(events)

    for policy in _SWEEP_POLICIES:
        plain = ControlLoop(events, _policy_jobs(policy), _det_engine(),
                            AnalyticBackend(), t_fwd=120.0, pj_max=10,
                            horizon=sc.duration, objective=policy).run()
        wrapped = ControlLoop(
            events, _policy_jobs(policy),
            RestartingAllocator(_det_engine, snapshot_every=600.0),
            ChaosBackend(AnalyticBackend(), empty),
            t_fwd=120.0, pj_max=10, horizon=sc.duration,
            objective=policy).run()
        assert normalized(wrapped) == normalized(plain), \
            f"{scenario}/{policy}: zero-fault chaos replay diverged"


# ---------------------------------------------------------------------------
# Fault injection: deterministic schedules, exact node-time accounting
# ---------------------------------------------------------------------------


def _trace_events(seed=5, n_nodes=12, hours=8.0):
    from repro.core.trace import generate_summit_like
    return fragments_to_events(generate_summit_like(
        n_nodes=n_nodes, duration=hours * 3600.0, seed=seed))


def test_fault_schedule_is_a_pure_function_of_seed():
    events = _trace_events()
    spec = ChaosSpec(seed=11, mtbf=2 * 3600.0, drain_frac=0.3,
                     corrupt_prob=0.2, straggler_rate=0.5,
                     blackout_every=3 * 3600.0)
    s1 = generate_fault_schedule(events, spec)
    s2 = generate_fault_schedule(events, spec)
    assert s1 == s2 and s1.events                       # bit-identical
    s3 = generate_fault_schedule(
        events, dataclasses.replace(spec, seed=12))
    assert s3 != s1                                     # seed matters


def test_injection_conserves_node_time_exactly():
    """Each kill/drain consumes the victim's next trace departure, so the
    injected stream loses exactly the killed tails — no double-counted
    departures, pool never negative."""
    events = _trace_events(seed=9)
    spec = ChaosSpec(seed=2, mtbf=3600.0, drain_frac=0.25)
    sched = generate_fault_schedule(events, spec)
    removals = [f for f in sched.events
                if f.kind in ("kill", "drain", "blackout")]
    assert removals, "spec produced no faults; pick a smaller mtbf"
    injected = inject_faults(events, sched)

    from repro.core.events import pool_sizes
    sizes = pool_sizes(injected)
    assert all(n >= 0 for _, n in sizes)
    assert sizes[-1][1] == 0                 # pool still drains to empty

    horizon = max(e.time for e in events)
    # expected loss: for each fault, the tail from fault time to the
    # victim's next scheduled departure in the original stream
    merged = merge_events(events)
    tails = 0.0
    ptr = {}
    for f in sorted(removals, key=lambda f: f.time):
        for e in merged:
            if e.time > f.time and f.node in e.left and \
                    ptr.get(f.node, -1.0) < e.time:
                tails += e.time - f.time
                ptr[f.node] = e.time
                break
    assert (pool_node_seconds(events, horizon)
            - pool_node_seconds(injected, horizon)
            == pytest.approx(tails))


def test_injected_kill_rolls_progress_back_to_lattice():
    """Single deterministic kill: progress restores to the last multiple
    of ckpt_every and total node-seconds still conserve."""
    events = [PoolEvent(time=0.0, joined=(0, 1)),
              PoolEvent(time=5000.0, left=(0, 1))]
    sched = FaultSchedule((FaultEvent(time=1000.0, kind="kill", node=1),))
    injected = inject_faults(events, sched)
    job = TrainerJob(id=0, curve=amdahl_curve("j", 10.0, 0.2),
                     work=math.inf, n_min=1, n_max=2, r_up=0.0, r_dw=0.0,
                     ckpt_every=3000.0)
    stats = ControlLoop(injected, [job], _det_engine(),
                        ChaosBackend(AnalyticBackend(), sched),
                        t_fwd=120.0, horizon=5000.0).run()
    thr2, thr1 = job.curve(2), job.curve(1)
    done_at_kill = 1000.0 * thr2
    lattice = math.floor(done_at_kill / 3000.0) * 3000.0
    assert stats.n_failures == 1
    assert stats.lost_progress == pytest.approx(done_at_kill - lattice)
    assert job.done == pytest.approx(lattice + 4000.0 * thr1)


def test_corrupt_restore_falls_back_one_more_interval():
    """A corrupt latest checkpoint restores one ckpt_every further back
    (the last *good* checkpoint) and is counted."""
    events = [PoolEvent(time=0.0, joined=(0, 1)),
              PoolEvent(time=5000.0, left=(0, 1))]
    kill = dict(time=2000.0, kind="kill", node=1)
    job_kw = dict(curve=amdahl_curve("j", 10.0, 0.2), work=math.inf,
                  n_min=1, n_max=2, r_up=0.0, r_dw=0.0, ckpt_every=1000.0)
    results = {}
    for corrupt in (False, True):
        sched = FaultSchedule((FaultEvent(corrupt=corrupt, **kill),))
        backend = ChaosBackend(AnalyticBackend(), sched)
        job = TrainerJob(id=0, **job_kw)
        stats = ControlLoop(inject_faults(events, sched), [job],
                            _det_engine(), backend, t_fwd=120.0,
                            horizon=5000.0).run()
        results[corrupt] = (stats.lost_progress, backend.corrupt_restores)
    lost_clean, n_clean = results[False]
    lost_corrupt, n_corrupt = results[True]
    assert n_clean == 0 and n_corrupt == 1
    assert lost_corrupt == pytest.approx(lost_clean + 1000.0)


def test_straggler_multiplier_applies_without_compounding():
    sched = FaultSchedule((FaultEvent(time=100.0, kind="straggler",
                                      duration=200.0, factor=4.0),))
    backend = ChaosBackend(AnalyticBackend(), sched)
    job = TrainerJob(id=0, curve=amdahl_curve("j", 10.0, 0.2),
                     work=1e9, r_up=20.0, r_dw=5.0)
    backend.refresh(job, 150.0)
    assert (job.r_up, job.r_dw) == (80.0, 20.0)
    backend.refresh(job, 200.0)              # still inside the episode
    assert (job.r_up, job.r_dw) == (80.0, 20.0)     # no 4x^2 compounding
    backend.refresh(job, 400.0)              # episode over
    assert (job.r_up, job.r_dw) == (20.0, 5.0)      # clean base restored
    # overlapping episodes *do* compound (two slow racks)
    sched2 = FaultSchedule((
        FaultEvent(time=0.0, kind="straggler", duration=300.0, factor=2.0),
        FaultEvent(time=100.0, kind="straggler", duration=300.0, factor=3.0)))
    assert sched2.straggler_multiplier(150.0) == 6.0
    assert sched2.straggler_multiplier(350.0) == 3.0
    assert sched2.straggler_multiplier(700.0) == 1.0


# ---------------------------------------------------------------------------
# Allocator crash/restart: warm recovery converges to the same decisions
# ---------------------------------------------------------------------------


def test_restarted_allocator_replays_identically():
    """Crashing the allocator mid-replay (warm or cold) must not change a
    single decision for deterministic engines: warm restores make old
    problems cache hits again, cold re-converges through the repair
    path — either way the stats are bit-identical to no crash at all."""
    events = _trace_events(seed=13, n_nodes=10, hours=10.0)
    horizon = 10 * 3600.0
    crash_times = [2 * 3600.0, 5 * 3600.0, 8 * 3600.0]

    def run(allocator):
        jobs = _policy_jobs("throughput")
        for j in jobs:
            j.work = math.inf            # keep allocating all trace long
        return ControlLoop(events, jobs, allocator, AnalyticBackend(),
                           t_fwd=120.0, pj_max=10, horizon=horizon).run()

    baseline = run(RestartingAllocator(_det_engine))
    warm_alloc = RestartingAllocator(_det_engine, crash_times=crash_times,
                                     snapshot_every=600.0, warm_restart=True)
    warm = run(warm_alloc)
    cold_alloc = RestartingAllocator(_det_engine, crash_times=crash_times,
                                     warm_restart=False)
    cold = run(cold_alloc)

    assert warm_alloc.restarts == len(crash_times)
    assert cold_alloc.restarts == len(crash_times)
    assert warm_alloc.recovered_entries > 0
    assert cold_alloc.recovered_entries == 0
    assert normalized(warm) == normalized(baseline)
    assert normalized(cold) == normalized(baseline)


# ---------------------------------------------------------------------------
# Chaos scenarios registry
# ---------------------------------------------------------------------------


def test_chaos_scenario_registry_is_separate_and_complete():
    assert set(CHAOS_SCENARIOS) == {"flaky", "straggler", "blackout"}
    assert not (set(CHAOS_SCENARIOS) & set(SCENARIOS))
    for name in CHAOS_SCENARIOS:
        sc = build_scenario(name, scale=0.1, seed=4)
        assert sc.chaos is not None and sc.name == name
        assert isinstance(sc.chaos, ChaosSpec)
    # base profiles stay fault-free
    assert build_scenario("capacity", scale=0.1).chaos is None


@pytest.mark.parametrize("name", sorted(CHAOS_SCENARIOS))
def test_chaos_scenarios_replay_end_to_end(name):
    sc = build_scenario(name, scale=0.1, seed=2)
    events = fragments_to_events(sc.fragments)
    jobs = [TrainerJob(id=i, curve=tab2_curve("ResNet18"), work=math.inf,
                       n_min=1, n_max=8, r_up=20.0, r_dw=5.0)
            for i in range(3)]
    rep = run_chaos(events, jobs, sc.chaos, engine_factory=_det_engine,
                    horizon=sc.duration)
    assert rep.stats.total_samples > 0
    assert rep.allocated_node_seconds <= rep.pool_node_seconds + 1e-6
    if name in ("flaky", "blackout"):
        assert rep.n_kills > 0


# ---------------------------------------------------------------------------
# Durable checkpoint integrity (repro.checkpoint)
# ---------------------------------------------------------------------------


def test_checkpoint_manager_falls_back_to_last_good(tmp_path):
    from repro.checkpoint import CheckpointManager, CorruptCheckpointError

    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.zeros(3)}
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(tree, step=10)
    newer = {"w": tree["w"] + 1.0, "b": tree["b"] + 1.0}
    path = mgr.save(newer, step=20)
    with open(path, "r+b") as f:              # flip payload bytes
        f.seek(64)
        f.write(b"\xde\xad\xbe\xef")
    got, meta, step = mgr.load_latest_good(tree)
    assert step == 10 and meta["step"] == 10
    np.testing.assert_array_equal(got["w"], tree["w"])
    # corrupt the survivor too: nothing left to restore
    with open(str(tmp_path / "ckpt_000000000010.npz"), "r+b") as f:
        f.seek(64)
        f.write(b"\xde\xad\xbe\xef")
    with pytest.raises(CorruptCheckpointError):
        mgr.load_latest_good(tree)


def test_checkpoint_manager_prunes_to_keep(tmp_path):
    from repro.checkpoint import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": np.ones(3)}
    for step in (1, 2, 3, 4):
        mgr.save(tree, step=step)
    assert mgr.steps() == [3, 4]


def test_elastic_trainer_restores_from_last_good(tmp_path):
    """End-to-end: a corrupt latest checkpoint silently falls back to the
    previous good one and training resumes from the older step."""
    from repro.checkpoint import CheckpointManager
    from repro.configs import get_arch
    from repro.elastic import ElasticTrainer
    from repro.models import build_model
    from repro.optim import AdamW

    cfg = get_arch("gemma-2b").reduced()
    tr = ElasticTrainer(build_model(cfg, remat=False), per_node_batch=2,
                        seed=0, optimizer=AdamW(lr=3e-3), warmup_steps=2)
    tr.pipeline.cfg.seq_len = 32
    tr.rescale(1)
    for _ in range(2):
        tr.train_step()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tr.save_checkpoint(mgr)                   # good, step 2
    tr.train_step()
    latest = tr.save_checkpoint(mgr)          # step 3, about to corrupt
    with open(latest, "r+b") as f:
        f.seek(256)
        f.write(b"\x00" * 16)
    tr.train_step()                           # drift past the checkpoint
    step = tr.restore_checkpoint(mgr)
    assert step == 2                          # fell back past corrupt 3
    m = tr.train_step()
    assert m.step == 3 and np.isfinite(m.loss)
