"""Dry-run machinery tests.

The full production dry-run (16x16 and 2x16x16 over all 40 combinations)
runs via ``python -m repro.launch.dryrun``; here we assert the machinery
end-to-end in a subprocess (which forces placeholder devices) on one
small-but-real combination per step kind, plus mesh-factory unit checks.
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_dryrun(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, timeout=timeout, env=env)


@pytest.mark.slow
def test_dryrun_subprocess_single_and_multi(tmp_path):
    out = str(tmp_path / "dr")
    res = run_dryrun(["--arch", "gemma-2b", "--shape", "decode_32k",
                      "--mesh", "both", "--out", out])
    assert res.returncode == 0, res.stdout + res.stderr
    files = os.listdir(out)
    assert len(files) == 2
    for f in files:
        data = json.load(open(os.path.join(out, f)))
        assert data["hlo_flops"] > 0
        assert data["t_compute"] > 0 and data["t_memory"] > 0
        assert data["bottleneck"] in ("compute", "memory", "collective")
    # multi-pod result must show the pod axis sharding the batch:
    single = json.load(open(os.path.join(
        out, "gemma-2b__decode_32k__1pod-16x16.json")))
    multi = json.load(open(os.path.join(
        out, "gemma-2b__decode_32k__2pod-2x16x16.json")))
    assert multi["n_devices"] == 2 * single["n_devices"]


@pytest.mark.slow
def test_dryrun_train_moe_subprocess(tmp_path):
    out = str(tmp_path / "dr2")
    res = run_dryrun(["--arch", "granite-moe-3b-a800m", "--shape",
                      "train_4k", "--mesh", "single", "--out", out])
    assert res.returncode == 0, res.stdout + res.stderr
    data = json.load(open(os.path.join(
        out, "granite-moe-3b-a800m__train_4k__1pod-16x16.json")))
    assert data["n_active_params"] < data["n_params"]
    assert data["collective_link_bytes"] > 0


def test_mesh_factory_axes():
    from repro.launch.mesh import make_production_mesh
    # shape arithmetic only; building uses available (1-CPU) devices would
    # fail, so assert via the documented contract instead of instantiating.
    import inspect
    src = inspect.getsource(make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src


def test_applicable_shapes_long_context_policy():
    from repro.configs import applicable_shapes, get_arch
    assert "long_500k" in applicable_shapes(get_arch("mamba2-2.7b"))
    assert "long_500k" in applicable_shapes(get_arch("jamba-v0.1-52b"))
    assert "long_500k" in applicable_shapes(get_arch("gemma2-27b"))
    for a in ("yi-6b", "minitron-8b", "gemma-2b", "internvl2-76b",
              "deepseek-v2-lite-16b", "granite-moe-3b-a800m",
              "seamless-m4t-medium"):
        assert "long_500k" not in applicable_shapes(get_arch(a)), a
        assert "decode_32k" in applicable_shapes(get_arch(a))
