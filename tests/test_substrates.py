"""Substrate tests: attention paths, optimizer, data pipeline, checkpoint,
sharding rules, roofline parsing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.distributed import batch_spec, sanitize, zero1_spec
from repro.models.attention import attend_blockwise, attend_direct
from repro.roofline import Roofline, parse_collectives


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 37),
                                           (False, 0), (False, 37)])
@pytest.mark.parametrize("cap", [0.0, 20.0])
def test_blockwise_equals_direct(causal, window, cap):
    rng = np.random.RandomState(0)
    b, s, kv, g, d = 2, 200, 2, 3, 16
    q = jnp.asarray(rng.randn(b, s, kv, g, d) * 0.3, jnp.float32)
    k = jnp.asarray(rng.randn(b, s, kv, d) * 0.3, jnp.float32)
    v = jnp.asarray(rng.randn(b, s, kv, d) * 0.3, jnp.float32)
    pos = jnp.arange(s)
    kw = dict(q_pos=pos, k_pos=pos, causal=causal, window=window,
              logit_cap=cap, scale=d ** -0.5)
    a = attend_direct(q, k, v, **kw)
    bw = attend_blockwise(q, k, v, q_block=64, kv_block=48, **kw)
    assert float(jnp.max(jnp.abs(a - bw))) < 2e-5


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    from repro.optim import AdamW
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        loss, g = jax.value_and_grad(
            lambda pp: jnp.sum((pp["w"] - 1.0) ** 2))(p)
        p2, s2 = opt.update(g, s, p)
        return p2, s2, loss

    for _ in range(300):
        params, state, loss = step(params, state)
    assert float(loss) < 1e-3


def test_lr_schedule_and_scaling():
    from repro.optim import linear_scaling, warmup_cosine
    assert float(warmup_cosine(jnp.int32(0), warmup_steps=10)) == 0.0
    mid = float(warmup_cosine(jnp.int32(5), warmup_steps=10))
    assert 0.4 < mid < 0.6
    top = float(warmup_cosine(jnp.int32(10), warmup_steps=10,
                              total_steps=100))
    assert abs(top - 1.0) < 1e-5
    assert linear_scaling(8) == 8.0
    assert linear_scaling(64, max_scale=32) == 32.0


def test_grad_clip_bounds_update():
    from repro.optim import AdamW
    opt = AdamW(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    huge = {"w": jnp.full(4, 1e9)}
    p2, _ = opt.update(huge, state, params)
    assert float(jnp.max(jnp.abs(p2["w"]))) < 10.0


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_pipeline_deterministic_and_resumes():
    from repro.data import DataConfig, TokenPipeline
    cfg = DataConfig(vocab_size=1000, seq_len=32, per_node_batch=4, seed=9)
    p1 = TokenPipeline(cfg)
    b1 = p1.next_batch(2)
    b2 = p1.next_batch(3)
    assert b1["tokens"].shape == (8, 32)
    assert b2["tokens"].shape == (12, 32)
    assert p1.samples_consumed == 20

    # restore mid-stream on a different "node count" (elastic rescale):
    p2 = TokenPipeline(cfg)
    p2.restore({"consumed": 8})
    b2b = p2.next_batch(3)
    np.testing.assert_array_equal(b2["tokens"], b2b["tokens"])

    # no sample served twice across the rescale
    p3 = TokenPipeline(cfg)
    a = p3.next_batch(2)["tokens"]
    b = p3.next_batch(3)["tokens"]
    assert not any((row == a).all(-1).any() for row in b)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import Snapshot, load_checkpoint, save_checkpoint
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.int32)}}
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, tree, meta={"step": 7})
    restored, meta = load_checkpoint(path, tree)
    assert meta == {"step": 7}
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y),
                 tree, restored)

    snap = Snapshot.take(tree, step=3)
    back = snap.restore()
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y),
                 tree, back)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


def _abstract_mesh(sizes, names):
    try:
        return AbstractMesh(sizes, names)            # newer jax signature
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))  # jax 0.4.x signature


def _mesh():
    return _abstract_mesh((16, 16), ("data", "model"))


def test_sanitize_drops_nondivisible_axes():
    m = _mesh()
    assert sanitize((49155, 1024), P("model", None), m) in (P(), P(None))
    assert sanitize((64000, 1024), P("model", None), m) == P("model")
    assert sanitize((100, 512), P(None, "model"), m) == P(None, "model")


def test_zero1_spec_shards_over_data():
    m = _mesh()
    s = zero1_spec((8192, 28672), P(None, "model"), m, ("data",))
    assert s == P("data", "model")
    # non-divisible first dim falls through to no extra sharding
    s2 = zero1_spec((49155, 1024), P(None, "model"), m, ("data",))
    assert s2[0] is None or s2[0] == "data"


def test_batch_spec_divisibility():
    m = _mesh()
    assert batch_spec((256, 4096), m, ("data",)) == P("data")
    assert batch_spec((1, 524288), m, ("data",)) == P(None)
    m3 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert batch_spec((256, 4096), m3, ("pod", "data")) == P(("pod", "data"))
    # batch_spec unwraps single-axis tuples; P("pod") == P(("pod",)) only
    # on newer jax, so compare against the unwrapped form directly.
    assert batch_spec((2, 1), m3, ("pod", "data")) == P("pod")


# ---------------------------------------------------------------------------
# Roofline parsing
# ---------------------------------------------------------------------------


HLO_SAMPLE = """
  %all-reduce.1 = f32[16,2048]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %all-reduce.2 = (f32[8,4]{1,0}, f32[8,4]{1,0}) all-reduce(%a, %b), replica_groups=[16,16]<=[256], to_apply=%add
  %ag = bf16[1024,512]{1,0} all-gather(%y), replica_groups={{0,1}}, dimensions={0}
  %foo = f32[2,2]{1,0} add(%p, %q)
  %cp-start = f32[4]{0} collective-permute-start(%z), source_target_pairs={{0,1}}
"""


def test_parse_collectives_counts_and_bytes():
    st = parse_collectives(HLO_SAMPLE)
    assert st.counts["all-reduce"] == 2
    assert st.counts["all-gather"] == 1
    assert st.counts["collective-permute"] == 1
    ar1 = 16 * 2048 * 4
    ar2 = 2 * 8 * 4 * 4
    ag = 1024 * 512 * 2
    assert st.bytes_by_kind["all-reduce"] == ar1 + ar2
    assert st.bytes_by_kind["all-gather"] == ag
    assert st.link_bytes > 0


def test_roofline_terms():
    r = Roofline(arch="x", shape="train_4k", mesh="m", n_devices=256,
                 hlo_flops=197e12 * 256, hlo_bytes=819e9 * 256 * 2,
                 collective_link_bytes=50e9 * 3,
                 model_flops=197e12 * 128, n_params=1, n_active_params=1)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 2.0) < 1e-9
    assert abs(r.t_collective - 3.0) < 1e-9
    assert r.bottleneck == "collective"
    assert abs(r.useful_flops_ratio - 0.5) < 1e-9
