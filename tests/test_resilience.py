"""Self-healing control plane tests (DESIGN.md §16).

Tier groups:

* **Hygiene** — dedup / reorder / late-drop / phantom-join / orphan-leave
  / conflict handling, clean streams passing through bit-identical, and
  the ``strict=`` / ``validate_events`` guards on ``repro.core.events``.
* **Anti-entropy** — the ``Reconciler`` repairs dropped events within one
  period; hypothesis property: *any* dup/reorder/drop/late corruption,
  sanitized, converges to ground-truth membership.
* **Zero-corruption parity** — the 6-scenario × 5-policy sweep through
  ``corrupt_stream`` + ``sanitize_stream`` is bit-identical to the
  direct replay (identity fast path AND the jitter-only path).
* **Deadline ladder** — every rung returns a feasible map, degraded
  decisions are not cached, ``upgrade()`` heals them, counters/status
  expose the rung.
* **Watchdog / quarantine** — state machine transitions; a failing pool
  is quarantined, its queued jobs evacuate and finish on healthy pools,
  and the pool is readmitted after probation.
* **Router compaction** — drained prefixes are freed without changing
  ``pending`` / ``next_time`` semantics.
"""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.chaos import ChaosSpec, corrupt_stream, run_chaos
from repro.core import (
    AllocationEngine,
    EventStreamError,
    PoolEvent,
    Simulator,
    pool_sizes,
    validate_events,
)
from repro.core.events import apply_events, fragments_to_events, merge_events
from repro.core.loop import TrainerJob
from repro.core.milp import AllocationProblem, TrainerSpec
from repro.core.scaling import TAB2, tab2_curve
from repro.federation import EventRouter, FederatedLoop, PoolMap
from repro.obs.telemetry import Telemetry
from repro.resilience import (
    EventHygiene,
    PoolWatchdog,
    Reconciler,
    membership_divergence,
    membership_oracle,
    sanitize_stream,
)
from repro.sched.scenarios import build_scenario

_SWEEP_SCENARIOS = ["capability", "capacity", "bursty", "maintenance",
                    "weekend", "overestimate"]
_SWEEP_POLICIES = ["throughput", "weighted", "maxmin", "deadline", "costcap"]


def _stamped(events):
    return [PoolEvent(e.time, e.joined, e.left, e.failed, seq=i)
            for i, e in enumerate(events)]


def _shape(events):
    """Event content without the seq stamp (hygiene must preserve it)."""
    return [(e.time, e.joined, e.left, e.failed) for e in events]


def _policy_jobs(policy="throughput", n=6):
    names = list(TAB2)
    out = []
    for i in range(n):
        j = TrainerJob(id=i, curve=tab2_curve(names[i % len(names)]),
                       work=2e8, n_min=1, n_max=16, r_up=20.0, r_dw=5.0)
        if policy == "weighted":
            j.weight = 1.0 + (i % 3)
        if policy == "deadline":
            j.deadline = 3600.0 * (4 + i)
        if policy == "costcap":
            j.budget = 3.0e5
        out.append(j)
    return out


def _det_engine(k=None):
    return AllocationEngine(time_budget=0.0)


# ---------------------------------------------------------------------------
# event-stream guards (satellite: strict modes + validate_events)
# ---------------------------------------------------------------------------


def test_apply_events_strict_rejects_unknown_leave():
    evs = [PoolEvent(0.0, joined=(1, 2)), PoolEvent(1.0, left=(3,))]
    assert apply_events(set(), evs) == {1, 2}          # permissive default
    with pytest.raises(EventStreamError):
        apply_events(set(), evs, strict=True)


def test_apply_events_strict_rejects_phantom_join():
    evs = [PoolEvent(0.0, joined=(1,)), PoolEvent(1.0, joined=(1,))]
    assert apply_events(set(), evs) == {1}
    with pytest.raises(EventStreamError):
        apply_events(set(), evs, strict=True)


def test_apply_events_strict_rejects_unknown_failure():
    with pytest.raises(EventStreamError):
        apply_events(set(), [PoolEvent(0.0, failed=(9,))], strict=True)


def test_pool_sizes_strict_rejects_negative():
    evs = [PoolEvent(0.0, joined=(1,)), PoolEvent(1.0, left=(1, 2))]
    assert pool_sizes(evs) == [(0.0, 1), (1.0, -1)]    # silent today
    with pytest.raises(EventStreamError):
        pool_sizes(evs, strict=True)
    clean = [PoolEvent(0.0, joined=(1, 2)), PoolEvent(1.0, left=(1,))]
    assert pool_sizes(clean, strict=True) == [(0.0, 2), (1.0, 1)]


def test_validate_events_classifies_defects():
    evs = [
        PoolEvent(0.0, joined=(1,), seq=0),
        PoolEvent(2.0, joined=(1,), seq=1),            # phantom join
        PoolEvent(1.0, left=(7,), seq=1),              # regression + dup seq
        PoolEvent(3.0, joined=(4,), left=(4,), seq=3),  # same-node conflict
    ]
    problems = validate_events(evs)
    text = "\n".join(problems)
    assert "already-live node 1" in text
    assert "timestamp regresses" in text
    assert "duplicate seq 1" in text
    assert "unknown node 7" in text
    assert "multiple actions" in text
    assert validate_events([PoolEvent(0.0, joined=(1,)),
                            PoolEvent(1.0, left=(1,))]) == []


def test_validate_events_respects_initial_pool():
    evs = [PoolEvent(0.0, left=(5,))]
    assert validate_events(evs) != []
    assert validate_events(evs, initial=(5,)) == []


# ---------------------------------------------------------------------------
# hygiene unit behaviour
# ---------------------------------------------------------------------------


def _clean_stream():
    return _stamped([
        PoolEvent(0.0, joined=(0, 1, 2, 3)),
        PoolEvent(100.0, joined=(4, 5)),
        PoolEvent(200.0, left=(1,)),
        PoolEvent(300.0, joined=(6,), left=(2,)),
        PoolEvent(400.0, left=(0, 3)),
    ])


def test_hygiene_clean_stream_bit_identical():
    evs = _clean_stream()
    hyg = EventHygiene(reorder_window=50.0)
    out = []
    for e in evs:
        out.extend(hyg.push(e))
    out.extend(hyg.flush())
    assert out == evs                   # same objects, order, seq stamps
    assert hyg.stats.defects == 0
    assert hyg.stats.events_in == hyg.stats.events_out == len(evs)


def test_hygiene_drops_duplicates_by_seq():
    evs = _clean_stream()
    dup = [evs[0], evs[1], evs[1], evs[2], evs[2], evs[3], evs[4]]
    out, hs, _ = sanitize_stream(dup, reorder_window=0.0)
    assert _shape(out) == _shape(evs)
    assert hs.duplicates_dropped == 2


def test_hygiene_undoes_reorder_within_window():
    evs = _clean_stream()
    swapped = [evs[1], evs[0]] + evs[2:]
    out, hs, _ = sanitize_stream(swapped, reorder_window=150.0)
    assert _shape(out) == _shape(evs)
    assert hs.reordered_fixed >= 1
    assert hs.late_dropped == 0


def test_hygiene_drops_late_beyond_window():
    evs = _clean_stream()
    late = evs[1:] + [evs[0]]           # t=0 join arrives dead last
    out, hs, _ = sanitize_stream(late, reorder_window=50.0)
    assert hs.late_dropped == 1
    # the lost join cascades: every leave of its nodes is now an orphan
    # (quarantined + dropped) — exactly what the reconciler exists for
    assert hs.orphan_leaves == 3
    assert _shape(out) == [(100.0, (4, 5), (), ()),
                           (300.0, (6,), (), ())]


def test_hygiene_drops_phantom_join():
    evs = _stamped([PoolEvent(0.0, joined=(1, 2)),
                    PoolEvent(10.0, joined=(1,)),
                    PoolEvent(20.0, left=(2,))])
    out, hs, _ = sanitize_stream(evs, reorder_window=0.0)
    assert hs.phantom_joins == 1
    assert _shape(out) == [(0.0, (1, 2), (), ()), (20.0, (), (2,), ())]


def test_hygiene_quarantines_orphan_leave():
    evs = _stamped([PoolEvent(0.0, joined=(1,)),
                    PoolEvent(10.0, left=(9,)),     # never joined
                    PoolEvent(20.0, left=(1,))])
    out, hs, _ = sanitize_stream(evs, reorder_window=0.0)
    assert hs.orphan_leaves == 1
    assert _shape(out) == [(0.0, (1,), (), ()), (20.0, (), (1,), ())]


def test_hygiene_resolves_same_time_conflict_last_writer_wins():
    # two monitor records at the same instant disagree about node 5:
    # seq order is ground truth, so the later record (leave) wins
    evs = [PoolEvent(0.0, joined=(5, 6), seq=0),
           PoolEvent(0.0, left=(5,), seq=1),
           PoolEvent(10.0, left=(6,), seq=2)]
    hyg = EventHygiene(reorder_window=5.0)
    out = []
    for e in evs:
        out.extend(hyg.push(e))
    out.extend(hyg.flush())
    assert hyg.stats.conflicts_resolved >= 1
    assert apply_events(set(), out) == set()
    assert hyg.believed == set()


def test_hygiene_strict_mode_raises():
    hyg = EventHygiene(strict=True)
    hyg.push(PoolEvent(0.0, joined=(1,), seq=0))
    with pytest.raises(EventStreamError):
        hyg.push(PoolEvent(1.0, joined=(1,), seq=1))


# ---------------------------------------------------------------------------
# anti-entropy reconciliation
# ---------------------------------------------------------------------------


def test_membership_oracle_walks_and_rewinds():
    evs = [PoolEvent(0.0, joined=(1, 2)), PoolEvent(10.0, left=(1,)),
           PoolEvent(20.0, joined=(3,))]
    oracle = membership_oracle(evs)
    assert oracle(-1.0) == set()
    assert oracle(5.0) == {1, 2}
    assert oracle(20.0) == {2, 3}
    assert oracle(5.0) == {1, 2}        # backward query rewinds
    assert oracle(1e9) == {2, 3}


def test_reconciler_repairs_dropped_leave_within_period():
    truth = [PoolEvent(0.0, joined=(1, 2, 3)), PoolEvent(100.0, left=(2,)),
             PoolEvent(250.0, joined=(5,)), PoolEvent(500.0, joined=(4,))]
    # the leave at t=100 is lost: believed keeps phantom node 2 until
    # the reconcile triggered by the (benign) t=250 arrival
    delivered = _stamped(truth)
    lost = [delivered[0], delivered[2], delivered[3]]
    out, hs, rs = sanitize_stream(
        lost, reorder_window=0.0, oracle=membership_oracle(truth),
        reconcile_period_s=200.0)
    assert rs.repair_events >= 1 and rs.nodes_removed >= 1
    assert apply_events(set(), out) == {1, 3, 4, 5}
    # the phantom existed for at most one reconcile period
    div = membership_divergence(truth, out, t_end=700.0)
    assert div["max_lag_s"] <= 200.0 + 1e-9
    assert div["divergence_node_s"] > 0.0


def test_reconciler_noop_on_clean_stream():
    truth = [PoolEvent(0.0, joined=(1, 2)), PoolEvent(50.0, left=(1,))]
    out, hs, rs = sanitize_stream(
        _stamped(truth), reorder_window=0.0,
        oracle=membership_oracle(truth), reconcile_period_s=10.0)
    assert rs.repair_events == 0 and rs.nodes_added == 0
    assert _shape(out) == _shape(truth)
    div = membership_divergence(truth, out, t_end=100.0)
    assert div["divergence_node_s"] == 0.0
    assert div["max_lag_s"] == 0.0


@st.composite
def _corruption_cases(draw):
    """A random clean membership story + a random corruption spec."""
    n_nodes = draw(st.integers(min_value=2, max_value=12))
    n_steps = draw(st.integers(min_value=2, max_value=14))
    truth, live, t = [], set(), 0.0
    for _ in range(n_steps):
        t += draw(st.floats(min_value=1.0, max_value=300.0))
        join = tuple(c for c in sorted(set(range(n_nodes)) - live)
                     if draw(st.booleans()))
        leave = tuple(c for c in sorted(live) if draw(st.booleans()))
        if not join and not leave:
            continue
        truth.append(PoolEvent(t, joined=join, left=leave))
        live |= set(join)
        live -= set(leave)
    spec = ChaosSpec(
        seed=draw(st.integers(min_value=0, max_value=2 ** 16)),
        duplicate_prob=draw(st.floats(min_value=0.0, max_value=0.5)),
        drop_prob=draw(st.floats(min_value=0.0, max_value=0.5)),
        late_prob=draw(st.floats(min_value=0.0, max_value=0.3)),
        reorder_window=draw(st.floats(min_value=0.0, max_value=200.0)))
    period = draw(st.floats(min_value=50.0, max_value=400.0))
    return truth, spec, period


@settings(max_examples=30, deadline=None)
@given(_corruption_cases())
def test_any_corruption_converges_to_ground_truth(case):
    """Hypothesis property (ISSUE 10): ANY dup/reorder/drop/late
    mutation of a clean stream, passed through EventHygiene +
    Reconciler, converges to ground-truth pool membership as of the
    last observed instant, and the repaired stream is strict-clean."""
    truth, spec, period = case
    corrupted = corrupt_stream(truth, spec)
    out, hs, rs = sanitize_stream(
        corrupted, reorder_window=spec.reorder_window,
        oracle=membership_oracle(truth), reconcile_period_s=period)
    believed = apply_events(set(), out)
    if out:
        # the forced final reconcile pins believed membership to ground
        # truth as of the last observed instant
        t_last = max(e.time for e in out)
        assert believed == membership_oracle(truth)(t_last)
        # and the sanitized stream is structurally clean: a strict
        # replay accepts it and its arithmetic matches the set view
        assert pool_sizes(out, strict=True)[-1][1] == len(believed)
        assert validate_events(out) == []
    else:
        assert believed == set()


def test_reconciler_rejects_nonpositive_period():
    with pytest.raises(ValueError):
        Reconciler(lambda t: set(), period_s=0.0)


def test_corrupt_stream_identity_when_clean():
    evs = [PoolEvent(0.0, joined=(1,)), PoolEvent(5.0, left=(1,))]
    out = corrupt_stream(evs, ChaosSpec(seed=3))
    assert _shape(out) == _shape(evs)
    assert [e.seq for e in out] == [0, 1]
    assert ChaosSpec().stream_clean
    assert not ChaosSpec(drop_prob=0.01).stream_clean


# ---------------------------------------------------------------------------
# zero-corruption parity: 6 scenarios x 5 policies, bit-identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", _SWEEP_SCENARIOS)
def test_zero_corruption_parity_sweep(scenario):
    """Acceptance sweep (ISSUE 10): a clean stream pushed through the
    full corruption + hygiene + reconcile machinery (jitter-only spec:
    arrivals shuffle inside the window but nothing is lost) replays
    bit-identically to the direct loop on every policy."""
    sc = build_scenario(scenario, scale=0.25)
    events = fragments_to_events(sc.fragments)
    # identity fast path: all-zero spec returns the stream unchanged
    assert _shape(corrupt_stream(events, ChaosSpec())) == \
        _shape(merge_events(events))
    # jitter-only path: arrivals are shuffled within the window, the
    # reorder buffer must restore exact time order
    spec = ChaosSpec(seed=7, reorder_window=600.0)
    sanitized, hs, _ = sanitize_stream(
        corrupt_stream(events, spec), reorder_window=spec.reorder_window)
    assert _shape(sanitized) == _shape(merge_events(events))
    assert hs.late_dropped == 0 and hs.phantom_joins == 0
    for policy in _SWEEP_POLICIES:
        base = Simulator(events, _policy_jobs(policy), _det_engine(),
                         t_fwd=120.0, pj_max=10, horizon=sc.duration,
                         objective=policy).run()
        san = Simulator(sanitized, _policy_jobs(policy), _det_engine(),
                        t_fwd=120.0, pj_max=10, horizon=sc.duration,
                        objective=policy).run()
        assert san.total_samples == base.total_samples, \
            f"{scenario}/{policy}: sanitized replay diverged"
        assert san.events_processed == base.events_processed
        assert san.rescale_cost_s == base.rescale_cost_s
        assert san.preempt_cost_s == base.preempt_cost_s


def test_run_chaos_clean_spec_unchanged_path():
    """run_chaos with a stream-clean spec must not touch the stream."""
    sc = build_scenario("bursty", scale=0.1)
    events = fragments_to_events(sc.fragments)
    rep = run_chaos(events, _policy_jobs(n=4), ChaosSpec(),
                    engine_factory=_det_engine, horizon=sc.duration)
    assert rep.hygiene is None and rep.reconcile is None
    assert rep.divergence is None
    assert rep.true_pool_node_seconds == rep.pool_node_seconds


def test_run_chaos_corruption_reports_divergence():
    sc = build_scenario("bursty", scale=0.1)
    events = fragments_to_events(sc.fragments)
    spec = ChaosSpec(seed=11, drop_prob=0.05, duplicate_prob=0.05,
                     reorder_window=300.0, reconcile_period_s=900.0)
    rep = run_chaos(events, _policy_jobs(n=4), spec,
                    engine_factory=_det_engine, horizon=sc.duration)
    assert rep.hygiene is not None and rep.reconcile is not None
    assert rep.divergence is not None
    assert rep.divergence["truth_node_s"] > 0
    assert rep.true_pool_node_seconds > 0
    # conservation against the *true* supply: reconciliation keeps the
    # believed stream honest enough that allocations fit reality's
    # envelope plus the bounded divergence window
    assert rep.allocated_node_seconds <= rep.true_pool_node_seconds \
        + rep.divergence["divergence_node_s"] + 1e-6


# ---------------------------------------------------------------------------
# deadline ladder
# ---------------------------------------------------------------------------


def _ladder_spec(i, n_min=1, n_max=8):
    curve = tab2_curve("ResNet18")
    pts, vals = curve.breakpoints(n_min, n_max)
    return TrainerSpec(id=i, n_min=n_min, n_max=n_max, r_up=20.0, r_dw=5.0,
                       points=tuple(pts), values=tuple(vals))


def _ladder_prob(n_nodes=24, n_jobs=4, current=None):
    return AllocationProblem(
        nodes=list(range(n_nodes)), trainers=[_ladder_spec(i)
                                              for i in range(n_jobs)],
        current=current or {}, t_fwd=120.0, objective="throughput",
        now=0.0)


def _assert_feasible(res, prob):
    pool = set(prob.nodes)
    seen = set()
    for t in prob.trainers:
        ns = res.allocation.get(t.id, [])
        assert len(ns) == res.counts.get(t.id, 0)
        assert len(ns) <= t.n_max
        for nid in ns:
            assert nid in pool and nid not in seen
            seen.add(nid)


def test_ladder_every_rung_returns_feasible_map():
    prob = _ladder_prob()
    warm = _ladder_prob(current={0: [0, 1, 2, 3], 1: [4, 5], 2: [], 3: []})

    # greedy rung (generous deadline, no MILP budget)
    eng = AllocationEngine(time_budget=0.0, decision_deadline_s=10.0)
    r = eng.allocate(prob)
    assert r.solver_status.endswith("+rung:greedy"), r.solver_status
    _assert_feasible(r, prob)
    # cache rung (same problem again)
    r = eng.allocate(prob)
    assert r.solver_status.endswith("+rung:cache")
    _assert_feasible(r, prob)
    assert eng.stats.rung_greedy == 1 and eng.stats.rung_cache == 1
    assert eng.stats.deadline_hits == 0

    # milp rung (budget allows, generous deadline) — annotated whichever
    # arm wins; must still be feasible
    eng = AllocationEngine(time_budget=0.050, decision_deadline_s=10.0)
    r = eng.allocate(prob)
    assert "+rung:" in r.solver_status
    _assert_feasible(r, prob)

    # project rung (impossible deadline, warm map)
    eng = AllocationEngine(time_budget=0.050, decision_deadline_s=1e-9)
    r = eng.allocate(warm)
    assert r.solver_status == "deadline-project+rung:project"
    _assert_feasible(r, warm)
    assert r.counts == {0: 4, 1: 2, 2: 0, 3: 0}

    # equal rung (impossible deadline, cold start)
    r = eng.allocate(prob)
    assert r.solver_status == "deadline-equal+rung:equal"
    _assert_feasible(r, prob)
    assert eng.stats.deadline_hits == 2
    assert eng.stats.rung_project == 1 and eng.stats.rung_equal == 1


def test_ladder_project_clamps_infeasible_current():
    # previous map oversizes trainer 0 beyond n_max and strands trainer
    # 1 below n_min: project must clamp both
    spec0 = _ladder_spec(0, n_min=1, n_max=2)
    spec1 = _ladder_spec(1, n_min=4, n_max=8)
    prob = AllocationProblem(
        nodes=list(range(10)), trainers=[spec0, spec1],
        current={0: [0, 1, 2, 3], 1: [4, 5]},
        t_fwd=120.0, objective="throughput", now=0.0)
    eng = AllocationEngine(decision_deadline_s=1e-9)
    r = eng.allocate(prob)
    assert r.counts[0] == 2             # clamped to n_max
    assert r.counts[1] == 0             # below n_min -> released
    _assert_feasible(r, prob)


def test_ladder_degraded_not_cached_and_upgrade_heals():
    prob = _ladder_prob()
    eng = AllocationEngine(time_budget=0.0, decision_deadline_s=1e-9)
    r1 = eng.allocate(prob)
    assert r1.solver_status.startswith("deadline-")
    assert eng.stats.cache_hits == 0
    r2 = eng.allocate(prob)             # still degraded, still no cache
    assert r2.solver_status.startswith("deadline-")
    assert eng.stats.cache_hits == 0
    assert len(eng._pending_upgrades) == 1      # dedup by signature
    assert eng.upgrade() == 1
    assert eng.stats.upgrades == 1
    r3 = eng.allocate(prob)
    assert r3.solver_status.startswith("cache(")
    _assert_feasible(r3, prob)


def test_ladder_within_deadline_and_telemetry():
    tel = Telemetry()
    deadline = 0.050
    eng = AllocationEngine(time_budget=0.0,
                           decision_deadline_s=deadline, telemetry=tel)
    probs = [_ladder_prob(n_nodes=256, n_jobs=12),
             _ladder_prob(n_nodes=256, n_jobs=12,
                          current={0: list(range(8))})]
    for prob in probs:
        r = eng.allocate(prob)
        assert r.wall_time <= deadline + 0.010, \
            f"decision blew its deadline: {r.wall_time*1e3:.1f} ms"
        _assert_feasible(r, prob)
    assert tel.counters.get("engine.events") == 2
    # per-rung mirrors present
    rung_counts = {k: v for k, v in tel.counters.items()
                   if k.startswith("engine.rung_")}
    assert sum(rung_counts.values()) == 2, rung_counts


def test_no_deadline_statuses_unchanged():
    """Without decision_deadline_s the engine must not annotate
    statuses or touch ladder counters (pre-PR bit-compat)."""
    eng = AllocationEngine(time_budget=0.0)
    prob = _ladder_prob()
    r = eng.allocate(prob)
    assert r.solver_status == "greedy"
    r = eng.allocate(prob)
    assert r.solver_status == "cache(greedy)"
    s = eng.stats
    assert s.deadline_hits == 0 and s.upgrades == 0
    assert s.rung_cache == s.rung_greedy == s.rung_project == 0


def test_engine_snapshot_roundtrip_with_deadline_config():
    eng = AllocationEngine(time_budget=0.0, decision_deadline_s=0.25)
    eng.allocate(_ladder_prob())
    snap = eng.snapshot()
    assert snap["config"]["decision_deadline_s"] == 0.25
    eng2 = AllocationEngine.from_snapshot(snap)
    assert eng2.decision_deadline_s == 0.25
    r = eng2.allocate(_ladder_prob())
    assert r.solver_status.startswith("cache(")


# ---------------------------------------------------------------------------
# EventRouter compaction (satellite)
# ---------------------------------------------------------------------------


def test_router_compaction_preserves_semantics():
    pm = PoolMap.stride(2)
    small = EventRouter(pm, compact_threshold=8)
    big = EventRouter(pm, compact_threshold=1 << 30)    # never compacts
    events = [PoolEvent(float(t), joined=(t % 10,), pool=(t % 10) % 2)
              for t in range(200)]
    for e in events:
        small.push(e)
        big.push(e)
    for upto in (50.0, 50.0, 120.0, 199.5, None):
        for k in (0, 1):
            assert small.pending(k) == big.pending(k)
            assert small.next_time(k) == big.next_time(k)
            a, b = small.drain(k, upto), big.drain(k, upto)
            assert a == b
            assert small.pending(k) == big.pending(k)
            assert small.next_time(k) == big.next_time(k)
        assert small.pools_with_pending() == big.pools_with_pending()
    assert small.compactions > 0
    # compaction actually freed the drained prefix
    assert all(len(small._queues[k]) <= small.compact_threshold
               for k in (0, 1))
    assert all(len(big._queues[k]) == 100 for k in (0, 1))


def test_router_compaction_bounds_memory_on_week_stream():
    pm = PoolMap.stride(1)
    router = EventRouter(pm, compact_threshold=64)
    for t in range(5000):
        router.push(PoolEvent(float(t), joined=(t,), pool=0))
        if t % 100 == 99:
            router.drain(0, float(t))
    assert len(router._queues[0]) < 256          # O(pending), not O(stream)
    assert router.compactions > 0


def test_router_compact_threshold_validation():
    with pytest.raises(ValueError):
        EventRouter(PoolMap.stride(1), compact_threshold=0)


# ---------------------------------------------------------------------------
# watchdog state machine + federated quarantine
# ---------------------------------------------------------------------------


def test_watchdog_state_machine():
    wd = PoolWatchdog(fail_threshold=2, quarantine_epochs=1,
                      probation_epochs=1)
    wd.record(0, failed=True); wd.tick(0)
    assert wd.state(0) == "healthy"             # below threshold
    wd.record(0, failed=False); wd.tick(0)
    wd.record(0, failed=True); wd.tick(0)
    assert wd.state(0) == "healthy"             # streak was reset
    wd.record(0, failed=True); wd.tick(0)
    assert wd.is_quarantined(0)                 # 2 consecutive
    wd.tick(0)                                  # skipped epoch
    assert wd.state(0) == "probation"
    wd.record(0, failed=True)
    assert wd.is_quarantined(0)                 # probation fail: instant
    wd.tick(0); wd.tick(0)
    assert wd.state(0) == "probation"
    wd.record(0, failed=False); wd.tick(0)
    assert wd.state(0) == "healthy"
    assert wd.stats.quarantines == 2
    assert wd.stats.readmissions == 1
    assert wd.stats.epochs_quarantined == 2


def test_watchdog_timeout_counts_as_failure():
    wd = PoolWatchdog(fail_threshold=1, timeout_s=0.5)
    assert wd.over_timeout(0.6) and not wd.over_timeout(0.4)
    wd.record(2, timed_out=True)
    assert wd.is_quarantined(2)
    assert wd.stats.timeouts == 1


class _BombAllocator:
    """Allocator that always raises — a maximally sick pool."""
    name = "bomb"

    def allocate(self, prob):
        raise RuntimeError("sick pool")


def _quarantine_fixture(watchdog):
    events = [PoolEvent(float(t), joined=tuple(range(t // 2000 * 4,
                                                     t // 2000 * 4 + 4)))
              for t in range(0, 20000, 2000)]
    # a late benign join keeps events pending until the final epoch, so
    # the sick pool gets idle epochs to serve out probation in
    events.append(PoolEvent(39000.0, joined=(41,)))
    names = list(TAB2)
    jobs = [TrainerJob(id=i, curve=tab2_curve(names[i % len(names)]),
                       work=5e6, n_min=1, n_max=8, r_up=20.0, r_dw=5.0)
            for i in range(8)]

    def factory(k):
        return _BombAllocator() if k == 0 else \
            AllocationEngine(time_budget=0.0)

    fed = FederatedLoop(events, jobs, pool_map=PoolMap.stride(2),
                        allocator_factory=factory, horizon=40000.0,
                        epoch_s=2000.0, parallel=False, watchdog=watchdog)
    return fed, jobs


def test_federated_quarantine_evacuates_and_readmits():
    """Acceptance (ISSUE 10): a quarantined pool's jobs make progress on
    healthy pools and the pool is readmitted after probation."""
    wd = PoolWatchdog(fail_threshold=2, quarantine_epochs=2,
                      probation_epochs=2)
    fed, jobs = _quarantine_fixture(wd)
    stats = fed.run()
    assert stats.quarantines >= 1
    assert stats.pool_failures >= 2
    assert stats.evacuations >= 1
    sick = stats.pools[0]
    assert sick.failures >= 2
    assert sick.quarantined_epochs >= 2
    # every evacuation left the sick pool
    moved = [m for m in stats.migrations if m.src == 0]
    assert len(moved) >= stats.evacuations
    assert all(m.dst == 1 for m in moved)
    # the healthy pool carried the fleet: all jobs finished
    assert stats.pools[1].total_samples > 0
    assert all(j.finished for j in jobs)
    # once idle, the sick pool served out probation and was readmitted
    assert stats.readmissions >= 1
    assert sick.state == "healthy"


def test_federated_no_watchdog_still_raises():
    """Without a watchdog a pool exception propagates (pre-PR
    fail-loudly contract)."""
    fed, _ = _quarantine_fixture(None)
    with pytest.raises(RuntimeError, match="sick pool"):
        fed.run()


def test_federated_deadline_threads_into_default_engines():
    events = [PoolEvent(0.0, joined=tuple(range(8)))]
    names = list(TAB2)
    jobs = [TrainerJob(id=i, curve=tab2_curve(names[i % len(names)]),
                       work=1e7, n_min=1, n_max=4, r_up=20.0, r_dw=5.0)
            for i in range(4)]
    fed = FederatedLoop(events, jobs, n_pools=2, horizon=20000.0,
                        epoch_s=5000.0, parallel=False,
                        decision_deadline_s=10.0)
    stats = fed.run()
    rungs = sum(p.engine.rung_cache + p.engine.rung_repair
                + p.engine.rung_greedy + p.engine.rung_milp
                + p.engine.rung_project + p.engine.rung_equal
                for p in stats.pools if p.engine is not None)
    decisions = sum(p.engine.events for p in stats.pools
                    if p.engine is not None)
    assert decisions > 0
    assert rungs == decisions           # every decision shows its rung
