"""ControlLoop / ExecutionBackend split (DESIGN.md §9): backend parity —
the same policy engine must hand identical allocation decisions to the
analytic (simulation) and live (real JAX trainers) backends — plus the
live path's newly policy-complete behaviours (pj_max, FCFS admission,
coalescing, stall accounting)."""
import math

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import (
    AllocationEngine,
    Allocator,
    AnalyticBackend,
    ControlLoop,
    MILPAllocator,
    Simulator,
    TrainerJob,
    amdahl_curve,
    fragments_to_events,
    generate_summit_like,
)
from repro.elastic import BFTrainerRuntime, ElasticTrainer, ManagedTrainer
from repro.models import build_model

R_UP, R_DW = 0.5, 0.1   # ElasticTrainer's pre-measurement defaults


class RecordingAllocator(Allocator):
    """Wraps an allocator and records every (problem, decision) pair in a
    node-id-level canonical form."""

    def __init__(self, inner):
        self.inner = inner
        self.name = f"recording-{inner.name}"
        self.calls = []

    def allocate(self, prob):
        res = self.inner.allocate(prob)
        self.calls.append((
            tuple(sorted(prob.nodes)),
            round(prob.t_fwd, 9),
            tuple(sorted((tid, tuple(sorted(cur)))
                         for tid, cur in prob.current.items())),
            tuple(sorted((t.id, tuple(sorted(res.allocation.get(t.id, ()))))
                         for t in prob.trainers)),
        ))
        return res


def tiny_trainer(seed=0):
    from repro.optim import AdamW
    cfg = get_arch("gemma-2b").reduced()
    model = build_model(cfg, remat=False)
    tr = ElasticTrainer(model, per_node_batch=2, seed=seed,
                        optimizer=AdamW(lr=3e-3), warmup_steps=2)
    tr.pipeline.cfg.seq_len = 32
    return tr


def small_events(seed=17, n_nodes=4, hours=12.0):
    frags = generate_summit_like(n_nodes=n_nodes, duration=hours * 3600.0,
                                 seed=seed)
    return fragments_to_events(frags)


CURVES = [amdahl_curve("t0", 100.0, 0.2), amdahl_curve("t1", 120.0, 0.15)]


def sim_jobs():
    return [TrainerJob(id=i, curve=CURVES[i], work=math.inf, n_min=1,
                       n_max=1, r_up=R_UP, r_dw=R_DW) for i in range(2)]


def managed_trainers():
    return [ManagedTrainer(id=i, trainer=tiny_trainer(seed=10 + i),
                           curve=CURVES[i], n_min=1, n_max=1,
                           target_steps=None) for i in range(2)]


def test_analytic_and_live_backends_get_identical_decisions():
    """The parity guarantee: on the same trace with a fixed allocator, the
    loop presents the same problems and hands out the same allocations
    regardless of execution substrate."""
    events = small_events()
    kw = dict(t_fwd=120.0, pj_max=10, coalesce_window=30.0)

    rec_sim = RecordingAllocator(AllocationEngine(time_budget=0.0))
    Simulator(events, sim_jobs(), rec_sim, horizon=12 * 3600.0, **kw).run()

    rec_live = RecordingAllocator(AllocationEngine(time_budget=0.0))
    rt = BFTrainerRuntime(managed_trainers(), rec_live, **kw)
    rep = rt.run(events, time_scale=1.0, max_steps_per_interval=1,
                 horizon=12 * 3600.0, measure_rescale_costs=False)

    assert rec_sim.calls, "no allocation decisions recorded"
    assert rec_sim.calls == rec_live.calls
    # and the live side really trained while following those decisions
    assert sum(rep.steps.values()) > 0
    assert all(np.isfinite(v) for ls in rep.losses.values() for v in ls)


def test_live_runtime_enforces_pjmax_and_fcfs():
    """Pre-refactor, BFTrainerRuntime silently dropped pj_max/FCFS; via the
    shared loop, at most pj_max Trainers are ever in a problem, admitted
    in id (arrival) order."""
    events = small_events(seed=23)
    managed = [ManagedTrainer(id=i, trainer=tiny_trainer(seed=30 + i),
                              curve=CURVES[i % 2], n_min=1, n_max=1,
                              target_steps=2) for i in range(2)]
    rec = RecordingAllocator(MILPAllocator("fast"))
    rt = BFTrainerRuntime(managed, rec, t_fwd=120.0, pj_max=1)
    rep = rt.run(events, time_scale=1.0, max_steps_per_interval=2)

    ids_per_call = [tuple(tid for tid, _ in call[2]) for call in rec.calls]
    assert all(len(ids) <= 1 for ids in ids_per_call)
    assert ids_per_call[0] == (0,)           # FCFS: lowest id first
    # trainer 1 only enters after trainer 0 finished its target steps
    assert rep.steps[0] == 2
    assert rep.steps[1] > 0
    assert (1,) in ids_per_call


def test_runtime_report_carries_shared_loop_stats():
    events = small_events(seed=29)
    managed = managed_trainers()
    rt = BFTrainerRuntime(managed, AllocationEngine(time_budget=0.0),
                          t_fwd=120.0)
    rep = rt.run(events, max_steps_per_interval=1, horizon=6 * 3600.0)
    st = rep.stats
    assert st is not None
    assert st.events_processed == rep.events
    assert st.event_records and st.makespan > 0
    # preemption/rescale accounting now exists on the live path
    assert st.rescale_cost_s >= 0 and st.preempt_cost_s >= 0
    assert all(r.allocated <= r.pool_size for r in st.event_records)


def test_live_coalescing_reduces_solves():
    """coalesce_window now applies to the live path: a join/leave burst
    triggers fewer solves with the window on."""
    from repro.core.events import PoolEvent
    events = []
    t, nid = 0.0, 0
    for burst in range(4):
        for k in range(3):
            events.append(PoolEvent(time=t, joined=(nid,)))
            nid += 1
            t += 5.0
        t += 900.0

    def run(window):
        rec = RecordingAllocator(AllocationEngine(time_budget=0.0))
        rt = BFTrainerRuntime(
            [ManagedTrainer(id=0, trainer=tiny_trainer(seed=40),
                            curve=CURVES[0], n_min=1, n_max=1)],
            rec, t_fwd=120.0, coalesce_window=window)
        rt.run(events, max_steps_per_interval=1, horizon=t)
        return len(rec.calls)

    assert run(30.0) < run(0.0)


def test_static_outcome_clamps_negative_arrivals():
    """The static baseline opens its pool at t=0; a Trainer 'arriving'
    before that must be treated as arriving at 0, not silently keep a
    negative arrival (the old dead-expression bug)."""
    from repro.core import static_outcome, tab2_curve
    jobs = [TrainerJob(id=0, curve=tab2_curve("ShuffleNet"), work=1e12,
                       n_min=1, n_max=8, arrival=-500.0)]
    ref = [TrainerJob(id=0, curve=tab2_curve("ShuffleNet"), work=1e12,
                      n_min=1, n_max=8, arrival=0.0)]
    a_neg = static_outcome(jobs, 4, 3600.0, MILPAllocator("fast"))
    a_ref = static_outcome(ref, 4, 3600.0, MILPAllocator("fast"))
    assert a_neg == pytest.approx(a_ref)
    assert a_neg > 0


def test_duplicate_timestamp_events_are_merged_not_dropped():
    """Hand-built event streams (unlike fragments_to_events output) may
    carry several PoolEvents at one timestamp; the loop must apply all of
    them, as the pre-refactor runtime did when iterating the raw list."""
    from repro.core.events import PoolEvent
    events = [PoolEvent(time=0.0, joined=(0,)),
              PoolEvent(time=0.0, joined=(1,)),
              PoolEvent(time=50.0, left=(0,)),
              PoolEvent(time=50.0, left=(1,)),
              PoolEvent(time=60.0, joined=(2,))]
    jobs = [TrainerJob(id=0, curve=CURVES[0], work=1e12, n_min=1, n_max=4)]
    stats = ControlLoop(events, jobs, MILPAllocator("fast"),
                        AnalyticBackend(), t_fwd=60.0, horizon=100.0).run()
    by_time = {r.time: r for r in stats.event_records}
    assert by_time[0.0].pool_size == 2
    assert by_time[50.0].pool_size == 0
    assert all(r.allocated <= r.pool_size for r in stats.event_records)

    # sequential semantics: leave followed by same-instant rejoin keeps the
    # node (the pre-refactor runtime applied same-time events in order)
    events2 = [PoolEvent(time=0.0, joined=(0,)),
               PoolEvent(time=50.0, left=(0,)),
               PoolEvent(time=50.0, joined=(0,)),
               PoolEvent(time=200.0, left=(0,))]
    stats2 = ControlLoop(events2, [TrainerJob(id=0, curve=CURVES[0],
                                              work=1e12, n_max=4)],
                         MILPAllocator("fast"), AnalyticBackend(),
                         t_fwd=60.0, horizon=300.0).run()
    by_time2 = {r.time: r for r in stats2.event_records}
    assert by_time2[50.0].pool_size == 1
    assert by_time2[200.0].pool_size == 0

    # post-construction mutation goes through the same normalization
    sim = Simulator(events2, [TrainerJob(id=0, curve=CURVES[0], work=1e12,
                                         n_max=4)],
                    MILPAllocator("fast"), t_fwd=60.0, horizon=100.0)
    sim.events = [PoolEvent(time=0.0, joined=(0,)),
                  PoolEvent(time=0.0, joined=(1,))]
    rep = sim.run()
    assert rep.event_records[0].pool_size == 2


def test_prefinished_job_neither_admitted_nor_unfinished():
    """A job that is already done on entry (resumed run) must not occupy a
    pj_max slot, must not be rescaled, and must not count as unfinished."""
    events = small_events(seed=37)
    pre = TrainerJob(id=0, curve=CURVES[0], work=5.0)
    pre.done = 10.0
    live = TrainerJob(id=1, curve=CURVES[1], work=1e12, n_min=1, n_max=4)
    stats = ControlLoop(events, [pre, live], MILPAllocator("fast"),
                        AnalyticBackend(), t_fwd=120.0, pj_max=1,
                        horizon=6 * 3600.0).run()
    assert pre.n_rescales == 0 and not pre.nodes
    assert live.done > 0                      # the slot went to the real job
    assert stats.unfinished == 1              # only the still-running job


def test_control_loop_direct_use_matches_simulator_facade():
    """Simulator is a pure facade: driving the ControlLoop directly with
    an AnalyticBackend gives the identical report core."""
    events = small_events(seed=31)
    jobs = lambda: [TrainerJob(id=i, curve=CURVES[i % 2], work=1e9,
                               n_min=1, n_max=2) for i in range(3)]
    rep = Simulator(events, jobs(), MILPAllocator("fast"), t_fwd=120.0,
                    horizon=6 * 3600.0).run()
    stats = ControlLoop(events, jobs(), MILPAllocator("fast"),
                        AnalyticBackend(), t_fwd=120.0,
                        horizon=6 * 3600.0).run()
    assert rep.total_samples == pytest.approx(stats.total_samples)
    assert rep.events_processed == stats.events_processed
    assert rep.rescale_cost_s == pytest.approx(stats.rescale_cost_s)


def test_kill_during_rescale_supersedes_stall():
    """Regression (DESIGN.md §12): a node failure landing while a Trainer
    is mid-rescale must *replace* the in-flight stall with the forced
    scale-down stall, not stack on top of it.  The old accounting kept
    the unserved R_up residual and charged R_dw after it — double-counting
    R_up for a rescale that was aborted by the kill."""
    from repro.core.events import PoolEvent

    events = [PoolEvent(time=0.0, joined=(0, 1)),
              PoolEvent(time=5.0, failed=(1,))]
    job = TrainerJob(id=0, curve=amdahl_curve("j", 100.0, 0.2),
                     work=math.inf, n_min=1, n_max=2, r_up=20.0, r_dw=5.0)
    stats = ControlLoop(events, [job], AllocationEngine(time_budget=0.0),
                        AnalyticBackend(), t_fwd=120.0, horizon=100.0).run()

    # t=0: 0->2 nodes, stalled until t=20.  t=5: node 1 killed; the
    # forced scale-down stall supersedes -> busy until 5 + r_dw = 10,
    # then 90 s of single-node progress.  The stacking bug would resume
    # at max(20, 5) + 5 = 25 (only 75 s of progress).
    assert job.busy_until == pytest.approx(10.0)
    assert stats.total_samples == pytest.approx(90.0 * job.curve(1))
    assert stats.n_failures == 1
    assert job.preempt_cost_s == pytest.approx(5.0)       # 1 node * r_dw
    assert job.rescale_cost_s == pytest.approx(25.0)      # r_up + forced r_dw
    # continuous checkpointing (default): a kill loses no progress
    assert stats.lost_progress == 0.0 and stats.restart_cost_s == 0.0


def test_kill_charges_restart_penalty_and_rolls_back_to_checkpoint():
    """Hard-kill semantics on the analytic path: progress rolls back to
    the ckpt_every lattice and the restart penalty extends the forced
    scale-down stall."""
    from repro.core.events import PoolEvent

    thr2 = amdahl_curve("j", 100.0, 0.2)(2)
    events = [PoolEvent(time=0.0, joined=(0, 1)),
              PoolEvent(time=1000.0, failed=(1,))]
    job = TrainerJob(id=0, curve=amdahl_curve("j", 100.0, 0.2),
                     work=math.inf, n_min=1, n_max=2, r_up=20.0, r_dw=5.0,
                     ckpt_every=1000.0, restart_penalty=30.0)
    stats = ControlLoop(events, [job], AllocationEngine(time_budget=0.0),
                        AnalyticBackend(), t_fwd=120.0, horizon=2000.0).run()

    done_at_kill = (1000.0 - 20.0) * thr2      # post-stall two-node progress
    lost = done_at_kill - math.floor(done_at_kill / 1000.0) * 1000.0
    assert stats.n_failures == 1
    assert stats.lost_progress == pytest.approx(lost)
    assert stats.restart_cost_s == pytest.approx(30.0)
    # stall = kill + r_dw + penalty, then single-node to the horizon
    resume = 1000.0 + 5.0 + 30.0
    expect = done_at_kill - lost + (2000.0 - resume) * job.curve(1)
    assert job.done == pytest.approx(expect)
